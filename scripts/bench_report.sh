#!/usr/bin/env bash
# Perf trajectory: run the sim-backed Figure-6 scaling bench (recorded
# as BENCH_pr5.json), the serving latency bench (recorded as
# BENCH_pr6.json), the skewed-routing placement scenario (recorded as
# BENCH_pr7.json), the fault/chaos scenario (recorded as
# BENCH_pr8.json), the ZeRO-sharded grad-sync record (recorded as
# BENCH_pr9.json) and the autotune predicted-vs-measured study
# (recorded as BENCH_pr10.json) at the repo root.
#
#   scripts/bench_report.sh            # default: 4 chunks, 4 iters
#   CHUNKS=8 ITERS=8 BUCKET_KB=256 NODES=2 scripts/bench_report.sh
#   SESSIONS=4 REQUESTS=64 MAX_BATCH=8 scripts/bench_report.sh
#
# One bench invocation scores FOUR schedules from the same measured
# compute, exchange volume, host copy/alloc counters and parameter
# volume:
#   * blocking              — wire + compute + host term
#   * overlapped (PR 2)     — max(wire, compute) per chunk, with the
#                             copy-heavy host term (per-chunk batches
#                             rebuilt from wire buffers, cloned padded
#                             into the executable, freshly allocated)
#   * zero-copy overlapped  — same pipeline with exactly the measured
#                             moe_copy_bytes / pool_alloc_bytes (single
#                             landing, slice-view staging, pooled
#                             buffers); the bench asserts it never
#                             scores above the copy-heavy schedule
#   * grad sync (PR 4)      — the trainer tail: blocking full-gradient
#                             ring + host Adam vs the bucketed
#                             nonblocking sync pipelined against
#                             backward and Adam; the bench asserts
#                             overlapped ≤ blocking at every point
#   * flat vs hier (PR 5)   — the same measured counters scored under
#                             the node-aware policies (NODES split,
#                             default 2): leader-aggregated all-to-all,
#                             two-level tree all-reduce, locality-
#                             ordered chunks; the bench asserts
#                             hier ≤ flat at every scale point where
#                             the model's inter-node bandwidth is the
#                             bottleneck (NetModel::hier_favourable)
#   * ZeRO-sharded (PR 9)   — the grad_shard = "zero" trainer tail:
#                             reduce-scatter, shard-local Adam (opt/w),
#                             all-gather of updated params, flat and
#                             rail-aware hier; the bench asserts
#                             zero ≤ blocking at every scale point
# so the comparison is apples-to-apples.  A second invocation actually
# *exercises* the pipelined zero-copy layer path (--overlap) as a
# correctness/perf sanity artifact under runs/.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
CHUNKS="${CHUNKS:-4}"
ITERS="${ITERS:-4}"
BUCKET_KB="${BUCKET_KB:-512}"
NODES="${NODES:-2}"
SESSIONS="${SESSIONS:-3}"
REQUESTS="${REQUESTS:-32}"
MAX_BATCH="${MAX_BATCH:-0}"

cd "$ROOT/rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install the rust toolchain" >&2
    echo "       (rustup.rs, or the image's baked-in rust_pallas toolchain)" >&2
    exit 1
fi

mkdir -p runs

# 1. measured on the blocking path, scored all four ways → the PR record
cargo bench --bench fig6_scale -- \
    --iters "$ITERS" --chunks "$CHUNKS" --bucket-kb "$BUCKET_KB" --nodes "$NODES" \
    --json "$ROOT/BENCH_pr5.json"

# 2. measured on the zero-copy pipelined path (exercises chunked
#    isend/irecv, slice-view staging, pools), kept as a side artifact
cargo bench --bench fig6_scale -- \
    --iters "$ITERS" --chunks "$CHUNKS" --bucket-kb "$BUCKET_KB" --nodes "$NODES" --overlap \
    --json runs/fig6_overlap_measured.json

# 3. serving (PR 6): continuous-batching throughput + request latency
#    percentiles of the `fastmoe serve` daemon — the modelled section
#    (forward-only serve step vs the training step, step-quantised
#    request latency) always runs; a real thread-backend daemon driven
#    by SESSIONS concurrent client sessions rides along where the
#    runtime is available.  latency_p50/p95/p99 keys are guaranteed in
#    the JSON either way.
cargo bench --bench serve_latency -- \
    --sessions "$SESSIONS" --requests "$REQUESTS" --max-batch "$MAX_BATCH" \
    --json "$ROOT/BENCH_pr6.json"

# 4. placement (PR 7): the skewed-routing scenario — a runaway-hot
#    expert scored under the static seed layout vs the layout the
#    shadow policy converges to (sim::NetModel::moe_step_skewed over
#    the plan-modelled per-rank rows).  Artifact-free and analytic;
#    the bench asserts rebalanced < static before writing the record.
cargo bench --bench fig6_scale -- --skew \
    --json "$ROOT/BENCH_pr7.json"

# 5. fault recovery (PR 8): the chaos scenario — a uniform routing
#    distribution scored healthy vs degraded with one rank quarantined,
#    shadow-covered (rows conserve, survivors absorb the load) vs
#    uncovered (the dead share is score-masked away), plus the α-β cost
#    of the rejoin peer-transfer.  Artifact-free and analytic; the
#    bench asserts row conservation and degraded ≥ healthy before
#    writing the record.
cargo bench --bench fig6_scale -- --chaos \
    --json "$ROOT/BENCH_pr8.json"

# 6. ZeRO-sharded grad sync (PR 9): a fresh measured pass whose record
#    is read for the grad_step_zero_s / grad_step_zero_hier_s columns —
#    the reduce-scatter → shard-Adam → all-gather schedule scored from
#    the same counters; the bench asserts zero ≤ blocking at every
#    scale point (and rail-zero ≤ flat-zero wherever hier is
#    favourable) before writing the record.
cargo bench --bench fig6_scale -- \
    --iters "$ITERS" --chunks "$CHUNKS" --bucket-kb "$BUCKET_KB" --nodes "$NODES" \
    --json "$ROOT/BENCH_pr9.json"

# 7. autotune (PR 10): the predicted-vs-measured tuner study — the
#    modelled section searches the [comm] knob lattice over synthetic
#    comm-bound / balanced / optimiser-bound operating points (asserts
#    the search is deterministic and never ranks the winner above the
#    current config); where the runtime is available a real
#    thread-backend calibration rides along, asserting the fitted model
#    agrees bitwise across ranks and recording the model-predicted step
#    time against the measured one plus the recommended [comm] snippet.
cargo bench --bench fig6_scale -- --autotune \
    --json "$ROOT/BENCH_pr10.json"

echo "bench_report.sh: wrote $ROOT/BENCH_pr5.json, $ROOT/BENCH_pr6.json," \
     "$ROOT/BENCH_pr7.json, $ROOT/BENCH_pr8.json, $ROOT/BENCH_pr9.json" \
     "and $ROOT/BENCH_pr10.json (and runs/fig6_overlap_measured.json)"
