#!/usr/bin/env bash
# Tier-1 gate: format, lint, test. Run from anywhere in the repo.
#
#   scripts/check.sh            # fmt --check + clippy -D warnings + tests
#   scripts/check.sh --fix      # rustfmt in write mode first
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install the rust toolchain" >&2
    echo "       (rustup.rs, or the image's baked-in rust_pallas toolchain)" >&2
    exit 1
fi

if [[ "${1:-}" == "--fix" ]]; then
    cargo fmt
else
    cargo fmt --check
fi
cargo clippy --all-targets -- -D warnings
# fast-fail on the protocol suites first (comm conformance incl. the
# bucketed all-reduce matrix, trainer equivalence incl. overlapped
# grad sync, failure injection incl. death mid-bucketed-sync, the
# serve containment pins, and the PR-8 recovery pins — chaos-driven
# degrade bitwise-equal to planned handover on thread and tcp, rejoin
# from checkpoint + live shadow transfer, recv-timeout-fed suspicion —
# the zero-copy/pooled-receive regressions, the serve suite:
# batched==sequential bitwise equivalence, admission control, queue
# overflow, session fairness, the placement suite: shadow/migration
# bitwise equivalence plus the skew-model acceptance, and the PR-10
# autotune suite: rank-symmetric calibration+search on thread and tcp,
# report-mode bit-transparency, live re-chunk == fresh launch), then
# the full run
cargo test -q --test comm_conformance --test trainer_equivalence \
    --test failure_injection --test zero_copy_regression \
    --test serve_integration --test placement_equivalence \
    --test autotune_equivalence
cargo test -q
echo "check.sh: all green"
