"""Grouped per-expert FFN kernel — the ``FMoELinear`` analog.

This is the paper's compute hot-spot.  FastMoE's CUDA version batches the
rows of each expert into one GEMM and overlaps experts on CUDA streams.
The TPU mapping (DESIGN.md §7): a 3-D grid over

    (expert e, row-block c, hidden-block h)

where each step performs two MXU matmuls on VMEM tiles and accumulates
the second projection in f32:

    y[e, c] += gelu(x[e, c] @ w1[e, :, h] + b1[e, h]) @ w2[e, h, :]

Because GeLU is elementwise over the hidden axis, tiling the hidden
dimension commutes with the activation, so the y-block is revisited
(classic k-loop accumulation) and the peak VMEM per step is

    bm*d_m + d_m*bh + bh + bh*d_m + bm*d_m   floats,

reported per artifact by ``aot.py --report``.  Streams are unnecessary on
TPU: the expert axis is just the slowest grid dimension, and cross-expert
overlap moves up to the Rust coordinator (worker shards).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128
DEFAULT_BLOCK_HIDDEN = 512


def _ffn_whole_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """Single-step variant: all operands resident, one grouped einsum.

    Used when lowering for the CPU PJRT backend: interpret-mode pallas
    pays ~10 ms of callback machinery *per grid step* (measured in
    EXPERIMENTS.md §Perf), so CPU artifacts collapse the grid; the tiled
    kernel above is the TPU mapping and stays under test.
    """
    x = x_ref[...].astype(jnp.float32)
    w1 = w1_ref[...].astype(jnp.float32)
    b1 = b1_ref[...].astype(jnp.float32)
    w2 = w2_ref[...].astype(jnp.float32)
    b2 = b2_ref[...].astype(jnp.float32)
    h = jax.nn.gelu(jnp.einsum("ecd,edh->ech", x, w1) + b1[:, None, :])
    o_ref[...] = (jnp.einsum("ech,ehd->ecd", h, w2) + b2[:, None, :]).astype(
        o_ref.dtype
    )


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    h_idx = pl.program_id(2)
    n_h = pl.num_programs(2)

    x = x_ref[0].astype(jnp.float32)     # [bm, d_m]
    w1 = w1_ref[0].astype(jnp.float32)   # [d_m, bh]
    b1 = b1_ref[0].astype(jnp.float32)   # [bh]
    w2 = w2_ref[0].astype(jnp.float32)   # [bh, d_m]
    b2 = b2_ref[0].astype(jnp.float32)   # [d_m]

    h = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1[None, :]
    h = jax.nn.gelu(h)
    acc = jnp.dot(h, w2, preferred_element_type=jnp.float32)

    @pl.when(h_idx == 0)
    def _init():
        o_ref[0] = (acc + b2[None, :]).astype(o_ref.dtype)

    @pl.when(h_idx != 0)
    def _accum():
        o_ref[0] = (o_ref[0].astype(jnp.float32) + acc).astype(o_ref.dtype)

    del n_h


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_hidden", "interpret", "whole")
)
def _expert_ffn_call(
    x,
    w1,
    b1,
    w2,
    b2,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_hidden: int = DEFAULT_BLOCK_HIDDEN,
    interpret: bool = True,
    whole: bool = False,
):
    if whole:
        return pl.pallas_call(
            _ffn_whole_kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(x, w1, b1, w2, b2)
    """Apply each expert's two-layer GeLU FFN to its row batch.

    Args:
      x:  ``[n_e, cap, d_m]`` expert-contiguous inputs (zeros at padding).
      w1: ``[n_e, d_m, d_h]``; b1: ``[n_e, d_h]``.
      w2: ``[n_e, d_h, d_m]``; b2: ``[n_e, d_m]``.
      block_rows / block_hidden: VMEM tile sizes for the row and hidden
        grid axes (padded up when the dims are smaller).

    Returns:
      ``[n_e, cap, d_m]`` expert outputs (same dtype as ``x``).

    Note: padding rows (zero inputs) produce ``gelu(b1) @ w2 + b2`` —
    *not* zero.  The combine step never reads padding slots, so this is
    harmless in the MoE layer; the oracle in ``ref.py`` matches this
    behaviour exactly so tests stay honest.
    """
    n_e, cap, d_m = x.shape
    assert w1.shape[0] == n_e and w1.shape[1] == d_m
    d_h = w1.shape[2]
    assert b1.shape == (n_e, d_h)
    assert w2.shape == (n_e, d_h, d_m)
    assert b2.shape == (n_e, d_m)

    bm = min(block_rows, cap)
    bh = min(block_hidden, d_h)
    pad_c = (-cap) % bm
    pad_h = (-d_h) % bh
    if pad_c:
        x = jnp.pad(x, ((0, 0), (0, pad_c), (0, 0)))
    if pad_h:
        w1 = jnp.pad(w1, ((0, 0), (0, 0), (0, pad_h)))
        b1 = jnp.pad(b1, ((0, 0), (0, pad_h)))
        # Padding the hidden axis adds gelu(0)=0 rows times w2 zeros: but
        # gelu(b1_pad=0)=0, and w2 pad rows are zero, so the sum is exact.
        w2 = jnp.pad(w2, ((0, 0), (0, pad_h), (0, 0)))
    grid = (n_e, (cap + pad_c) // bm, (d_h + pad_h) // bh)

    out = pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, d_m), lambda e, c, h: (e, c, 0)),
            pl.BlockSpec((1, d_m, bh), lambda e, c, h: (e, 0, h)),
            pl.BlockSpec((1, bh), lambda e, c, h: (e, h)),
            pl.BlockSpec((1, bh, d_m), lambda e, c, h: (e, h, 0)),
            pl.BlockSpec((1, d_m), lambda e, c, h: (e, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, d_m), lambda e, c, h: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((n_e, cap + pad_c, d_m), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)
    return out[:, :cap]


def expert_ffn(x, w1, b1, w2, b2, *, block_rows: int = DEFAULT_BLOCK_ROWS,
               block_hidden: int = DEFAULT_BLOCK_HIDDEN,
               interpret: bool = True, whole: bool = False):
    """Differentiable wrapper around the grouped-FFN Pallas kernel.

    The backward pass is recompute-style (FastMoE's CUDA backward also
    re-runs the first GEMM rather than saving the huge hidden tensor):
    the pre-activations are rebuilt from ``x`` and the five cotangents
    are batched-over-experts f32 GEMMs.
    """

    def impl(x_, w1_, b1_, w2_, b2_):
        return _expert_ffn_call(x_, w1_, b1_, w2_, b2_,
                                block_rows=block_rows,
                                block_hidden=block_hidden,
                                interpret=interpret, whole=whole)

    f = jax.custom_vjp(impl)

    def fwd(x_, w1_, b1_, w2_, b2_):
        return impl(x_, w1_, b1_, w2_, b2_), (x_, w1_, b1_, w2_, b2_)

    def bwd(res, dy):
        x_, w1_, b1_, w2_, b2_ = res
        x32 = x_.astype(jnp.float32)
        w1_32 = w1_.astype(jnp.float32)
        w2_32 = w2_.astype(jnp.float32)
        dy32 = dy.astype(jnp.float32)
        # Recompute pre-activations: s[e] = x[e] @ w1[e] + b1[e]
        s = jnp.einsum("ecd,edh->ech", x32, w1_32) + b1_.astype(jnp.float32)[:, None, :]
        h, gelu_vjp = jax.vjp(jax.nn.gelu, s)
        dh_pre = jnp.einsum("ecd,ehd->ech", dy32, w2_32)
        (ds,) = gelu_vjp(dh_pre)
        dx = jnp.einsum("ech,edh->ecd", ds, w1_32).astype(x_.dtype)
        dw1 = jnp.einsum("ecd,ech->edh", x32, ds).astype(w1_.dtype)
        db1 = jnp.sum(ds, axis=1).astype(b1_.dtype)
        dw2 = jnp.einsum("ech,ecd->ehd", h, dy32).astype(w2_.dtype)
        db2 = jnp.sum(dy32, axis=1).astype(b2_.dtype)
        return dx, dw1, db1, dw2, db2

    f.defvjp(fwd, bwd)
    return f(x, w1, b1, w2, b2)


def vmem_floats(d_m: int, d_h: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                block_hidden: int = DEFAULT_BLOCK_HIDDEN) -> int:
    """Peak VMEM floats per grid step (for aot.py --report / DESIGN.md §7)."""
    bm = block_rows
    bh = min(block_hidden, d_h)
    return bm * d_m + d_m * bh + bh + bh * d_m + d_m + bm * d_m
