//! Autotune equivalence: the `[auto]` subsystem must never change the
//! math, and its decisions must be the same on every rank.
//!
//! The load-bearing properties of `autotune` (PR 10):
//!
//! * **Rank symmetry** — the calibrated fit is an all-reduced *mean* of
//!   per-rank measurements, so even under deliberately skewed per-rank
//!   timings every rank derives the same `ModelFit` bits, and the pure
//!   `search` run on it returns the same `TuneOutcome` everywhere.
//!   Pinned on the thread backend and on real sockets.
//! * **Report transparency** — `apply = "report"` adds collectives (the
//!   fit agreement) but touches no knob: losses, parameters and Adam
//!   moments are *bitwise* identical to a run with the tuner disabled,
//!   step after step.
//! * **Live transparency** — `apply = "live"` may re-chunk the exchange
//!   and re-bucket the grad sync at step boundaries, but every knob it
//!   is allowed to touch is math-transparent by construction, so the
//!   run stays bitwise identical to an untuned one — and the applied
//!   knobs agree across ranks.
//! * **Re-chunk == fresh launch** — flipping `chunks`/`chunk_policy`
//!   mid-run at a step boundary (exactly what live apply does) produces
//!   the same bits as a run launched with the new chunking from step 0.
//!
//! Ports: 49600 (calibration agreement over tcp).  See
//! `placement_equivalence.rs` / `serve_integration.rs` for the
//! neighbouring allocations.

use std::sync::Arc;

use fastmoe::autotune::{search, Calibrator, KnobState, ModelFit, TuneOutcome};
use fastmoe::comm::tcp::TcpGroup;
use fastmoe::comm::{run_workers, Comm};
use fastmoe::config::{AutoConfig, CommConfig};
use fastmoe::coordinator::{MoeLayerBuilder, MoeLayerTrainer};
use fastmoe::metrics::Counters;
use fastmoe::moe::ChunkPolicy;
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::tensor::TensorF32;

const WORKERS: usize = 2;
const LR: f32 = 1e-3;

fn rt() -> Option<Arc<Runtime>> {
    Runtime::open_default().ok().map(Arc::new)
}

fn assert_bits(what: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (j, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what} elem {j}: {x} != {y}");
    }
}

fn assert_trainers_bitwise(what: &str, a: &MoeLayerTrainer, b: &MoeLayerTrainer) {
    for ((name, p1), (_, p2)) in a.layer.params().iter().zip(b.layer.params().iter()) {
        assert_bits(&format!("{what} {name}"), &p1.data, &p2.data);
    }
    for (i, (m1, m2)) in a.optimizer().m.iter().zip(&b.optimizer().m).enumerate() {
        assert_bits(&format!("{what} adam.m[{i}]"), &m1.data, &m2.data);
    }
    for (i, (v1, v2)) in a.optimizer().v.iter().zip(&b.optimizer().v).enumerate() {
        assert_bits(&format!("{what} adam.v[{i}]"), &v1.data, &v2.data);
    }
}

/// The same deterministic batch on every run for a given (rank, step).
fn step_input(nb: usize, dm: usize, rank: usize, step: usize) -> TensorF32 {
    let mut x = TensorF32::zeros(&[nb, dm]);
    Rng::new(6000 + (step * WORKERS + rank) as u64).fill_normal(&mut x.data, 1.0);
    x
}

/// One synthetic instrumented step, deliberately skewed per rank: the
/// all-reduce mean inside `Calibrator::finish` is what must restore
/// agreement.
fn feed_step(c: &mut Counters, rank: usize) {
    let r = rank as u64;
    c.add("phase_dispatch_ns", 1_000_000 + 60_000 * r);
    c.add("phase_combine_ns", 500_000 + 30_000 * r);
    c.add("phase_compute_ns", 2_000_000 + 100_000 * r);
    c.add("phase_opt_ns", 400_000 + 20_000 * r);
    c.add("phase_gradsync_ns", 300_000 + 10_000 * r);
    c.add("moe_a2a_bytes", 8 << 20);
    c.add("grad_sync_bytes", 4 << 20);
    c.add("moe_copy_bytes", 8 << 20);
}

/// Calibrate over skewed synthetic counters and search; every rank must
/// come back with the same bits.  Pure of the runtime — it exercises
/// only the comm substrate, so it runs everywhere.
fn calibrate_and_search(comm: &mut impl Comm) -> fastmoe::Result<(ModelFit, TuneOutcome)> {
    let mut counters = Counters::new();
    // pre-window noise the snapshot delta must exclude
    counters.add("moe_a2a_bytes", 999_999_999);
    counters.add("phase_compute_ns", 777);
    let mut cal = Calibrator::begin(&counters, comm.size(), 2);
    for _ in 0..5 {
        feed_step(&mut counters, comm.rank());
        cal.record_step(3.0e-3 + comm.rank() as f64 * 2.0e-4);
    }
    let fit = cal.finish(comm, &counters)?;
    let outcome = search(&fit, &KnobState::from_comm(&CommConfig::default()));
    // the search itself must be bit-stable under repetition
    let again = search(&fit, &KnobState::from_comm(&CommConfig::default()));
    assert!(outcome == again, "search must be deterministic");
    Ok((fit, outcome))
}

fn assert_all_ranks_agree(results: &[(ModelFit, TuneOutcome)]) {
    let (fit0, out0) = &results[0];
    for (r, (fit, out)) in results.iter().enumerate() {
        assert!(fit == fit0, "rank {r} fit diverged: {fit:?} vs {fit0:?}");
        assert!(out == out0, "rank {r} outcome diverged");
        // strict bit identity on the fields the drift check and the
        // argmin hang off (PartialEq alone can't see -0.0 vs 0.0)
        assert_eq!(fit.beta.to_bits(), fit0.beta.to_bits());
        assert_eq!(fit.step_time.to_bits(), fit0.step_time.to_bits());
        assert_eq!(
            out.best.predicted.to_bits(),
            out0.best.predicted.to_bits()
        );
        assert_eq!(out.live.predicted.to_bits(), out0.live.predicted.to_bits());
    }
}

#[test]
fn calibrated_search_is_rank_symmetric_thread() {
    let results =
        run_workers(4, |mut h| calibrate_and_search(&mut h)).unwrap();
    assert_all_ranks_agree(&results);
    assert_eq!(results[0].0.workers, 4);
}

#[test]
fn calibrated_search_is_rank_symmetric_tcp() {
    const TCP_WORKERS: usize = 3;
    let joins: Vec<_> = (0..TCP_WORKERS)
        .map(|rank| {
            std::thread::spawn(move || -> fastmoe::Result<(ModelFit, TuneOutcome)> {
                let mut g = TcpGroup::connect_local(rank, TCP_WORKERS, 49600)?;
                let out = calibrate_and_search(&mut g)?;
                g.barrier()?;
                Ok(out)
            })
        })
        .collect();
    let results: Vec<_> = joins
        .into_iter()
        .enumerate()
        .map(|(rank, j)| {
            j.join()
                .unwrap_or_else(|_| panic!("tcp rank {rank} panicked"))
                .unwrap()
        })
        .collect();
    assert_all_ranks_agree(&results);
    assert_eq!(results[0].0.workers, TCP_WORKERS);
}

fn build_trainer(
    rt: Arc<Runtime>,
    rank: usize,
    cfg: &CommConfig,
    auto: Option<AutoConfig>,
) -> fastmoe::Result<MoeLayerTrainer> {
    let layer = MoeLayerBuilder::new()
        .gate("topk")
        .seed(77)
        .comm_config(cfg)
        .build(rt, WORKERS, rank)?;
    layer.warm()?;
    let mut tr = MoeLayerTrainer::new(layer, LR);
    if let Some(a) = auto {
        tr = tr.with_autotune(a, cfg)?;
    }
    Ok(tr)
}

/// Drive a tuned and an untuned trainer in lockstep on the same comm
/// handle and assert bit-identical losses, parameters and Adam moments
/// after every step.  Returns the knobs the tuner ended on.
fn assert_tuned_bitwise(
    comm: &mut impl Comm,
    rt: Arc<Runtime>,
    apply: &str,
) -> fastmoe::Result<KnobState> {
    let cfg = CommConfig::default();
    let auto = AutoConfig {
        enabled: true,
        calib_steps: 2,
        apply: apply.into(),
        ..AutoConfig::default()
    };
    let rank = comm.rank();
    let mut plain = build_trainer(rt.clone(), rank, &cfg, None)?;
    let mut tuned = build_trainer(rt, rank, &cfg, Some(auto))?;
    let (mut c1, mut c2) = (Counters::new(), Counters::new());
    for step in 0..6 {
        let x = step_input(plain.layer.nb, plain.layer.dm, rank, step);
        let s1 = plain.train_step(comm, x.clone(), &mut c1)?;
        let s2 = tuned.train_step(comm, x, &mut c2)?;
        assert_eq!(
            s1.loss.to_bits(),
            s2.loss.to_bits(),
            "step {step} rank {rank}: loss {} != {}",
            s1.loss,
            s2.loss
        );
        assert_trainers_bitwise(&format!("step {step} rank {rank}"), &plain, &tuned);
    }
    let tuner = tuned.autotuner().expect("tuner attached");
    assert!(
        tuner.outcome.is_some(),
        "a 2-step window over 6 steps must have produced an outcome"
    );
    Ok(*tuner.current())
}

#[test]
fn report_mode_is_bit_identical_to_disabled() {
    let Some(rt) = rt() else { return };
    run_workers(WORKERS, move |mut h| {
        assert_tuned_bitwise(&mut h, rt.clone(), "report").map(|_| ())
    })
    .unwrap();
}

#[test]
fn live_mode_is_bit_identical_and_applies_in_lockstep() {
    let Some(rt) = rt() else { return };
    let knobs =
        run_workers(WORKERS, move |mut h| assert_tuned_bitwise(&mut h, rt.clone(), "live"))
            .unwrap();
    // whatever live mode applied, it applied the same thing everywhere
    for (r, k) in knobs.iter().enumerate() {
        assert!(k == &knobs[0], "rank {r} applied different knobs: {k:?}");
    }
}

/// Re-chunking at a step boundary — exactly the writes live apply does
/// (`layer.chunks`, `layer.set_chunk_policy`) — must match a run that
/// launched with the new chunking from step 0, bit for bit.
#[test]
fn mid_run_rechunk_matches_fresh_launch() {
    let Some(rt) = rt() else { return };
    run_workers(WORKERS, move |mut h| {
        let rank = h.rank();
        let before = CommConfig { overlap: true, chunks: 2, ..CommConfig::default() };
        let after = CommConfig { overlap: true, chunks: 4, ..CommConfig::default() };
        let mut retuned = build_trainer(rt.clone(), rank, &before, None)?;
        let mut fresh = build_trainer(rt.clone(), rank, &after, None)?;
        let (mut c1, mut c2) = (Counters::new(), Counters::new());
        for step in 0..4 {
            if step == 2 {
                // the step-boundary re-chunk live mode performs
                retuned.layer.chunks = 4;
                retuned.layer.set_chunk_policy(ChunkPolicy::Mean);
            }
            let x = step_input(retuned.layer.nb, retuned.layer.dm, rank, step);
            let s1 = retuned.train_step(&mut h, x.clone(), &mut c1)?;
            let s2 = fresh.train_step(&mut h, x, &mut c2)?;
            assert_eq!(
                s1.loss.to_bits(),
                s2.loss.to_bits(),
                "step {step} rank {rank}: loss {} != {}",
                s1.loss,
                s2.loss
            );
            assert_trainers_bitwise(&format!("step {step} rank {rank}"), &retuned, &fresh);
        }
        Ok(())
    })
    .unwrap();
}
