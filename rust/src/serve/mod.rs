//! `fastmoe serve` — a long-lived MoE inference daemon with continuous
//! batching over the expert-parallel workers.
//!
//! The training side drives [`DistMoeLayer`](crate::coordinator::
//! DistMoeLayer) from a fixed-iteration loop; serving turns the same
//! data path into a resident service:
//!
//! * **Front end** ([`ServeDaemon`], rank 0): a TCP listener accepting
//!   lightweight client sessions that speak the mesh's existing frame
//!   format (`src | tag | len | payload`) on plain sockets — `src`
//!   carries the client's request id and the tag's low byte the
//!   protocol code ([`CODE_REQ`], [`CODE_RESP`], [`CODE_REJECT`],
//!   [`CODE_SHUTDOWN`]) with the row count above it.  One reader
//!   thread per session feeds the batcher; responses are demultiplexed
//!   back over per-session writers.
//! * **Continuous batching** ([`Batcher`]): in-flight requests
//!   coalesce into token batches *between* steps — up to
//!   `[serve] max_batch` rows are admitted per step, the rest queue up
//!   to `[serve] queue_depth` rows, and anything beyond that is
//!   rejected immediately (admission control: the client gets a
//!   [`CODE_REJECT`] frame, never a silent stall).  Packing is
//!   whole-request (a request's rows stay contiguous in the batch) and
//!   round-robins across sessions — one request per session per turn,
//!   FIFO within a session — so a chatty session cannot starve the
//!   others out of step after step.
//! * **Workers** (ranks > 0): resident
//!   [`ServeLoop`](crate::coordinator::ServeLoop) participants that
//!   join each collective forward with zero batches.  The step is
//!   forward-only (`forward_infer`) — the PR 3 zero-copy dispatch and
//!   buffer pools run unchanged, the gradient machinery never wakes.
//! * **Metrics**: per-request latency (arrival → response write) and
//!   per-step time feed fixed-bucket [`Histogram`]s; [`ServeStats::
//!   to_json`] exports p50/p95/p99 for the bench record.
//!
//! Why batching preserves per-request bits: with the default top-k
//! gate every row's path — gate GEMM row, per-row top-k, expert FFN
//! rows, weighted combine — is row-local, so a request's outputs are
//! bitwise identical whether its rows share the batch with other
//! requests or ride at the same offsets in an otherwise-zero batch
//! (`serve_integration` pins exactly this against sequential
//! single-request forwards).

use std::collections::{BTreeMap, VecDeque};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::comm::tcp::{read_stream_frame, write_stream_frame};
use crate::comm::{run_workers, Comm, TopoComm};
use crate::config::{CommConfig, MoeConfig, ServeConfig};
use crate::coordinator::{MoeLayerBuilder, ServeLoop};
use crate::error::{Error, Result};
use crate::metrics::{Counters, Histogram, Stopwatch};
use crate::runtime::Runtime;
use crate::tensor::TensorF32;
use crate::util::json::Json;

/// Protocol code (tag low byte): client → daemon token request; the
/// row count rides in `tag >> 8` and the payload is `rows × dm`
/// floats.
pub const CODE_REQ: u64 = 1;
/// Protocol code: daemon → client response rows for one request.
pub const CODE_RESP: u64 = 2;
/// Protocol code: daemon → client admission-control rejection (empty
/// payload; `src` echoes the request id).
pub const CODE_REJECT: u64 = 3;
/// Protocol code: client → daemon orderly shutdown.
pub const CODE_SHUTDOWN: u64 = 4;

/// Compose a request/response tag from a code and row count.
pub fn serve_tag(code: u64, rows: usize) -> u64 {
    ((rows as u64) << 8) | code
}

fn tag_code(tag: u64) -> u64 {
    tag & 0xff
}

fn tag_rows(tag: u64) -> usize {
    (tag >> 8) as usize
}

/// One admitted client request, queued until a step has room for it.
#[derive(Debug)]
pub struct Request {
    /// Client-chosen id, echoed in the response's `src` field.
    pub id: u32,
    /// Front-end session index — selects the response writer.
    pub session: usize,
    /// Token rows in this request.
    pub rows: usize,
    /// Row-major `[rows, dm]` activations.
    pub data: Vec<f32>,
    /// Arrival time, for the latency histogram.
    pub arrived: Instant,
}

/// A request placed into a batch: the original request plus its row
/// offset, for demultiplexing the step output.
#[derive(Debug)]
pub struct Pending {
    pub req: Request,
    pub row: usize,
}

/// Continuous-batching queue with admission control.
///
/// `admit` is called by the session readers as requests arrive;
/// `take_batch` by the drive loop between steps.  Whole requests pack
/// round-robin across sessions — one request per session per turn,
/// FIFO *within* a session, starting from a cursor that rotates every
/// batch — so a single chatty session pipelining requests cannot
/// monopolise the step while everyone else queues (a single session
/// degenerates to plain FIFO, bit-identical to the old packing).  A
/// session whose next request does not fit sits the batch out; its own
/// order is preserved.  A request is rejected — handed back to the
/// caller — when it could *never* be scheduled (`rows == 0` or
/// `rows > max_batch`) or when the queue already holds `queue_depth`
/// rows (overload: reject fast rather than stall every later client).
#[derive(Debug)]
pub struct Batcher {
    max_batch: usize,
    queue_depth: usize,
    queue: VecDeque<Request>,
    queued_rows: usize,
    /// Fairness cursor: the session id round-robin packing favours for
    /// the next batch.
    rr_next: usize,
}

impl Batcher {
    pub fn new(max_batch: usize, queue_depth: usize) -> Batcher {
        Batcher {
            max_batch: max_batch.max(1),
            queue_depth: queue_depth.max(1),
            queue: VecDeque::new(),
            queued_rows: 0,
            rr_next: 0,
        }
    }

    /// Rows admitted into one step's batch.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Rows currently queued across all admitted requests.
    pub fn queued_rows(&self) -> usize {
        self.queued_rows
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Arrival time of the oldest queued request — the anchor the
    /// drive loop's idle coalescing window counts down from (so
    /// condvar wakeups cannot restart it).  `None` when the queue is
    /// empty.
    pub fn oldest_arrival(&self) -> Option<Instant> {
        self.queue.iter().map(|r| r.arrived).min()
    }

    /// Admit a request into the queue, or hand it back (`Err`) when
    /// admission control rejects it.
    pub fn admit(&mut self, req: Request) -> std::result::Result<(), Request> {
        if req.rows == 0
            || req.rows > self.max_batch
            || self.queued_rows + req.rows > self.queue_depth
        {
            return Err(req);
        }
        self.queued_rows += req.rows;
        self.queue.push_back(req);
        Ok(())
    }

    /// Empty the queue, handing every admitted-but-unserved request
    /// back to the caller.  Fault containment uses this when a step
    /// fails (worker death): each drained request gets an explicit
    /// [`CODE_REJECT`] so its client sees a typed verdict instead of a
    /// socket that never answers.
    pub fn drain(&mut self) -> Vec<Request> {
        self.queued_rows = 0;
        self.queue.drain(..).collect()
    }

    /// Pack queued requests into `min(max_batch, nb)` rows of a
    /// zero-initialised `[nb, dm]` batch: round-robin across sessions
    /// (one whole request per session per turn, FIFO within a
    /// session), starting from the rotating fairness cursor.  `None`
    /// when the queue is empty.
    pub fn take_batch(
        &mut self,
        nb: usize,
        dm: usize,
    ) -> Option<(TensorF32, Vec<Pending>)> {
        if self.queue.is_empty() {
            return None;
        }
        let budget = self.max_batch.min(nb);
        let mut x = TensorF32::zeros(&[nb, dm]);
        let mut pending = Vec::new();
        let mut row = 0usize;
        // the sessions with queued work, rotated so the cursor's
        // session packs first this batch and a different one the next
        let mut sessions: Vec<usize> = Vec::new();
        for r in &self.queue {
            if !sessions.contains(&r.session) {
                sessions.push(r.session);
            }
        }
        sessions.sort_unstable();
        let pivot =
            sessions.iter().position(|&s| s >= self.rr_next).unwrap_or(0);
        sessions.rotate_left(pivot);
        self.rr_next = sessions[0] + 1;
        // a session leaves the rotation once drained, or once its next
        // request does not fit (skipping *within* a session would
        // reorder it)
        let mut out = vec![false; sessions.len()];
        loop {
            let mut progress = false;
            for (i, &s) in sessions.iter().enumerate() {
                if out[i] {
                    continue;
                }
                let Some(idx) = self.queue.iter().position(|r| r.session == s)
                else {
                    out[i] = true;
                    continue;
                };
                if row + self.queue[idx].rows > budget {
                    out[i] = true;
                    continue;
                }
                let req = self.queue.remove(idx).unwrap();
                let rows = req.rows;
                self.queued_rows -= rows;
                let n = (rows * dm).min(req.data.len());
                x.data[row * dm..row * dm + n].copy_from_slice(&req.data[..n]);
                pending.push(Pending { req, row });
                row += rows;
                progress = true;
            }
            if !progress {
                break;
            }
        }
        debug_assert!(!pending.is_empty(), "every queued head exceeds the budget");
        Some((x, pending))
    }
}

/// Cumulative serving metrics, exported as the bench JSON record.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub steps: u64,
    pub requests: u64,
    pub rows: u64,
    pub rejected: u64,
    pub disconnects: u64,
    pub elapsed_sec: f64,
    /// Request latency (arrival → response write), seconds.
    pub latency: Histogram,
    /// Collective forward step time, seconds.
    pub step_time: Histogram,
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats {
            steps: 0,
            requests: 0,
            rows: 0,
            rejected: 0,
            disconnects: 0,
            elapsed_sec: 0.0,
            latency: Histogram::latency(),
            step_time: Histogram::latency(),
        }
    }

    /// The JSON record `bench_report.sh` archives: throughput plus the
    /// latency percentiles the integration test asserts on.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("steps".into(), Json::Num(self.steps as f64));
        m.insert("requests".into(), Json::Num(self.requests as f64));
        m.insert("rows".into(), Json::Num(self.rows as f64));
        m.insert("rejected".into(), Json::Num(self.rejected as f64));
        m.insert("disconnects".into(), Json::Num(self.disconnects as f64));
        m.insert("elapsed_sec".into(), Json::Num(self.elapsed_sec));
        let tput = if self.elapsed_sec > 0.0 {
            self.rows as f64 / self.elapsed_sec
        } else {
            0.0
        };
        m.insert("rows_per_sec".into(), Json::Num(tput));
        m.insert("latency_p50".into(), Json::Num(self.latency.p50()));
        m.insert("latency_p95".into(), Json::Num(self.latency.p95()));
        m.insert("latency_p99".into(), Json::Num(self.latency.p99()));
        m.insert("latency_mean".into(), Json::Num(self.latency.mean()));
        m.insert("step_p50".into(), Json::Num(self.step_time.p50()));
        m.insert("step_p95".into(), Json::Num(self.step_time.p95()));
        m.insert("step_p99".into(), Json::Num(self.step_time.p99()));
        Json::Object(m)
    }
}

impl Default for ServeStats {
    fn default() -> Self {
        Self::new()
    }
}

/// Front-end state shared between the drive loop, the accept thread
/// and the per-session readers.
struct Front {
    batcher: Batcher,
    shutdown: bool,
    rejected: u64,
}

struct Shared {
    state: Mutex<Front>,
    cv: Condvar,
    /// Per-session response writers (socket clones; a write into a
    /// dead session fails and is counted, never propagated).
    writers: Mutex<Vec<Arc<Mutex<TcpStream>>>>,
    dm: usize,
}

/// The rank-0 front end: listener, session readers, batcher, and the
/// drive loop connecting them to a [`ServeLoop`].
pub struct ServeDaemon {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<Vec<JoinHandle<()>>>>,
    port: u16,
    idle: Duration,
}

impl ServeDaemon {
    /// Bind the front-end listener and start accepting sessions.
    /// `nb`/`dm` are the layer geometry (`max_batch = 0` ⇒ the full
    /// layer batch; larger values clamp to it).
    pub fn bind(cfg: &ServeConfig, nb: usize, dm: usize) -> Result<ServeDaemon> {
        let port = u16::try_from(cfg.port).map_err(|_| {
            Error::Config(format!("serve.port {} out of range", cfg.port))
        })?;
        let max_batch = match cfg.max_batch {
            0 => nb,
            m => m.min(nb),
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(Front {
                batcher: Batcher::new(max_batch, cfg.queue_depth),
                shutdown: false,
                rejected: 0,
            }),
            cv: Condvar::new(),
            writers: Mutex::new(Vec::new()),
            dm,
        });
        let listener = TcpListener::bind(("0.0.0.0", port))?;
        listener.set_nonblocking(true)?;
        let accept = {
            let shared = shared.clone();
            std::thread::spawn(move || accept_loop(listener, shared))
        };
        Ok(ServeDaemon {
            shared,
            accept: Some(accept),
            port,
            idle: Duration::from_millis(cfg.idle_ms),
        })
    }

    pub fn port(&self) -> u16 {
        self.port
    }

    /// Wait for work and coalesce it into one step batch.  Blocks
    /// until the queue is non-empty (giving stragglers up to the idle
    /// window to join an undersized batch) or shutdown; `None` means
    /// an orderly shutdown with the queue drained.
    pub fn next_batch(&self, nb: usize, dm: usize) -> Option<(TensorF32, Vec<Pending>)> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if !st.batcher.is_empty() {
                // continuous batching's latency/utilisation trade: an
                // undersized batch waits out the idle window for more
                // arrivals, a full one departs immediately.  The window
                // is an *absolute* deadline anchored at the oldest
                // queued arrival: a wakeup mid-window (another request
                // joining, or a spurious notify) neither restarts the
                // countdown nor departs the batch early — it re-waits
                // for whatever remains.
                while st.batcher.queued_rows() < st.batcher.max_batch()
                    && !st.shutdown
                {
                    let deadline = st
                        .batcher
                        .oldest_arrival()
                        .expect("non-empty batcher has an oldest arrival")
                        + self.idle;
                    let Some(remaining) =
                        deadline.checked_duration_since(Instant::now())
                    else {
                        break; // window expired: depart undersized
                    };
                    let (guard, _) =
                        self.shared.cv.wait_timeout(st, remaining).unwrap();
                    st = guard;
                }
                return st.batcher.take_batch(nb, dm);
            }
            if st.shutdown {
                return None;
            }
            st = self.shared.cv.wait(st).unwrap();
        }
    }

    /// Demultiplex a step output back to the clients: each pending
    /// request gets its `[rows, dm]` slice as a [`CODE_RESP`] frame.
    /// A dead session's write failure is contained (counted in
    /// `disconnects`) — the daemon keeps serving everyone else.
    pub fn respond(&self, pending: Vec<Pending>, y: &TensorF32, stats: &mut ServeStats) {
        let dm = self.shared.dm;
        let writers = self.shared.writers.lock().unwrap();
        for p in pending {
            let rows = p.req.rows;
            let slice = &y.data[p.row * dm..(p.row + rows) * dm];
            let ok = match writers.get(p.req.session) {
                Some(w) => {
                    let mut w = w.lock().unwrap();
                    write_stream_frame(
                        &mut *w,
                        p.req.id,
                        serve_tag(CODE_RESP, rows),
                        slice,
                    )
                    .is_ok()
                }
                None => false,
            };
            if ok {
                stats.requests += 1;
                stats.rows += rows as u64;
                stats.latency.record(p.req.arrived.elapsed().as_secs_f64());
            } else {
                stats.disconnects += 1;
            }
        }
    }

    /// The resident drive loop: step whenever the batcher has work,
    /// stop the workers and return the stats on client-initiated
    /// shutdown.
    ///
    /// Worker-death containment: a failed collective step kills the
    /// daemon, but never silently — the step's own batch and every
    /// queued request get typed [`CODE_REJECT`] frames and the front
    /// end closes its sockets before the error propagates, so no
    /// client blocks forever on a response that cannot come.
    pub fn run(
        &mut self,
        lp: &ServeLoop,
        comm: &mut impl Comm,
        counters: &mut Counters,
    ) -> Result<ServeStats> {
        let (nb, dm) = (lp.layer().nb, lp.layer().dm);
        let mut stats = ServeStats::new();
        let clock = Stopwatch::start();
        while let Some((x, pending)) = self.next_batch(nb, dm) {
            let t = Stopwatch::start();
            let y = match lp.step(comm, x, counters) {
                Ok(y) => y,
                Err(e) => {
                    // no lp.stop(): the collective is already broken
                    // and stopping would hang on the dead worker
                    self.reject_drain(pending, &mut stats);
                    self.close();
                    return Err(e);
                }
            };
            stats.step_time.record(t.secs());
            stats.steps += 1;
            self.respond(pending, &y, &mut stats);
        }
        lp.stop(comm)?;
        stats.elapsed_sec = clock.secs();
        stats.rejected = self.shared.state.lock().unwrap().rejected;
        self.close();
        Ok(stats)
    }

    /// Reject the failed step's batch plus everything still queued:
    /// one empty [`CODE_REJECT`] frame per request, write failures
    /// ignored (a dead session cannot hang on a reject either).
    fn reject_drain(&self, pending: Vec<Pending>, stats: &mut ServeStats) {
        let queued = {
            let mut st = self.shared.state.lock().unwrap();
            let q = st.batcher.drain();
            st.rejected += (pending.len() + q.len()) as u64;
            stats.rejected = st.rejected;
            q
        };
        let writers = self.shared.writers.lock().unwrap();
        let reqs = pending
            .iter()
            .map(|p| (&p.req.id, p.req.session, p.req.rows))
            .chain(queued.iter().map(|r| (&r.id, r.session, r.rows)));
        for (&id, session, rows) in reqs {
            if let Some(w) = writers.get(session) {
                let _ = write_stream_frame(
                    &mut *w.lock().unwrap(),
                    id,
                    serve_tag(CODE_REJECT, rows),
                    &[],
                );
            }
        }
    }

    /// Tear the front end down: unblock the accept thread, close every
    /// session socket (which unblocks its reader), join everything.
    pub fn close(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.shared.writers.lock().unwrap().iter() {
            let _ = w.lock().unwrap().shutdown(Shutdown::Both);
        }
        if let Some(accept) = self.accept.take() {
            if let Ok(readers) = accept.join() {
                for r in readers {
                    let _ = r.join();
                }
            }
        }
    }
}

impl Drop for ServeDaemon {
    fn drop(&mut self) {
        self.close();
    }
}

/// Accept sessions until shutdown; returns the reader join handles so
/// [`ServeDaemon::close`] can reap them.
fn accept_loop(listener: TcpListener, shared: Arc<Shared>) -> Vec<JoinHandle<()>> {
    let mut readers = Vec::new();
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                let Ok(writer) = stream.try_clone() else { continue };
                let session = {
                    let mut writers = shared.writers.lock().unwrap();
                    writers.push(Arc::new(Mutex::new(writer)));
                    writers.len() - 1
                };
                let shared = shared.clone();
                readers.push(std::thread::spawn(move || {
                    session_reader(stream, session, shared)
                }));
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shared.state.lock().unwrap().shutdown {
                    return readers;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => return readers,
        }
    }
}

/// One session's reader: parse frames, admit requests (rejecting over
/// admission control *immediately*, so overload surfaces as a typed
/// frame rather than back-pressure), flag shutdown.  Any read error —
/// EOF, reset, truncated frame — ends the session; queued work from it
/// is handled by the containment in [`ServeDaemon::respond`].
fn session_reader(mut stream: TcpStream, session: usize, shared: Arc<Shared>) {
    loop {
        let msg = match read_stream_frame(&mut stream) {
            Ok(m) => m,
            Err(_) => break,
        };
        match tag_code(msg.tag) {
            CODE_REQ => {
                let rows = tag_rows(msg.tag);
                let req = Request {
                    id: msg.src as u32,
                    session,
                    rows,
                    data: msg.data,
                    arrived: Instant::now(),
                };
                let wrong_len = req.data.len() != rows * shared.dm;
                let mut st = shared.state.lock().unwrap();
                let verdict = if wrong_len { Err(req) } else { st.batcher.admit(req) };
                match verdict {
                    Ok(()) => shared.cv.notify_all(),
                    Err(req) => {
                        st.rejected += 1;
                        drop(st);
                        let writers = shared.writers.lock().unwrap();
                        if let Some(w) = writers.get(session) {
                            let _ = write_stream_frame(
                                &mut *w.lock().unwrap(),
                                req.id,
                                serve_tag(CODE_REJECT, req.rows),
                                &[],
                            );
                        }
                    }
                }
            }
            CODE_SHUTDOWN => {
                shared.state.lock().unwrap().shutdown = true;
                shared.cv.notify_all();
            }
            _ => break, // a client speaking garbage loses its session
        }
    }
    // session end is not itself an error (an orderly client just left);
    // wake the drive loop in case it was waiting on this session
    shared.cv.notify_all();
}

/// A client's reply to one request.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    /// The request's `[rows, dm]` output rows.
    Ok { id: u32, data: Vec<f32> },
    /// Admission control rejected the request (queue full or rows out
    /// of range); resubmit later or with fewer rows.
    Rejected { id: u32 },
}

/// A thin client session — the load generator (`fastmoe client`) and
/// the integration tests speak through this.
pub struct ClientConn {
    stream: TcpStream,
}

impl ClientConn {
    /// Connect to a daemon front end, retrying while it starts up.
    pub fn connect(addr: &str) -> Result<ClientConn> {
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    return Ok(ClientConn { stream });
                }
                Err(e) if Instant::now() >= deadline => {
                    return Err(Error::Comm(format!("serve client connect {addr}: {e}")))
                }
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    /// Submit `rows × dm` activation floats under a client-chosen id.
    pub fn request(&mut self, id: u32, rows: usize, data: &[f32]) -> Result<()> {
        write_stream_frame(&mut self.stream, id, serve_tag(CODE_REQ, rows), data)?;
        Ok(())
    }

    /// Block for the next reply frame (replies to a session's pipelined
    /// requests come back in step order; match on the echoed id).
    pub fn recv_reply(&mut self) -> Result<Reply> {
        let msg = read_stream_frame(&mut self.stream)?;
        match tag_code(msg.tag) {
            CODE_RESP => Ok(Reply::Ok { id: msg.src as u32, data: msg.data }),
            CODE_REJECT => Ok(Reply::Rejected { id: msg.src as u32 }),
            other => Err(Error::Comm(format!("serve client: bad reply code {other}"))),
        }
    }

    /// Ask the daemon to shut down once its queue drains.
    pub fn shutdown(&mut self) -> Result<()> {
        write_stream_frame(&mut self.stream, 0, serve_tag(CODE_SHUTDOWN, 0), &[])?;
        Ok(())
    }
}

/// Run a complete daemon on the thread backend: rank 0 is the front
/// end (listener + drive loop), ranks 1.. are resident serve workers.
/// Returns the front end's stats once a client sends
/// [`CODE_SHUTDOWN`].  Shared by `fastmoe serve --backend local`, the
/// integration tests and the measured bench section.
pub fn run_thread_daemon(
    rt: Arc<Runtime>,
    workers: usize,
    seed: u64,
    moe: MoeConfig,
    comm_cfg: CommConfig,
    cfg: ServeConfig,
) -> Result<ServeStats> {
    let out = run_workers(workers, move |h| {
        let rank = h.rank();
        let topo = comm_cfg.topology_for(workers)?;
        let mut c = TopoComm::new(h, topo)?;
        let layer = MoeLayerBuilder::from_config(&moe)
            .comm_config(&comm_cfg)
            .seed(seed)
            .build(rt.clone(), workers, rank)?;
        layer.warm()?;
        let lp = ServeLoop::new(layer);
        let mut counters = Counters::new();
        if rank == 0 {
            let mut daemon =
                ServeDaemon::bind(&cfg, lp.layer().nb, lp.layer().dm)?;
            Ok(Some(daemon.run(&lp, &mut c, &mut counters)?))
        } else {
            lp.serve_worker(&mut c, &mut counters)?;
            Ok(None)
        }
    })?;
    out.into_iter()
        .flatten()
        .next()
        .ok_or_else(|| Error::msg("serve: no front-end stats"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u32, rows: usize, dm: usize) -> Request {
        Request {
            id,
            session: 0,
            rows,
            data: vec![id as f32; rows * dm],
            arrived: Instant::now(),
        }
    }

    #[test]
    fn batcher_packs_fifo_with_offsets() {
        let dm = 4;
        let mut b = Batcher::new(8, 64);
        b.admit(req(1, 3, dm)).unwrap();
        b.admit(req(2, 2, dm)).unwrap();
        b.admit(req(3, 5, dm)).unwrap(); // 3 + 2 + 5 > 8: next batch
        assert_eq!(b.queued_rows(), 10);
        let (x, pending) = b.take_batch(16, dm).unwrap();
        assert_eq!(x.shape, vec![16, 4]);
        assert_eq!(pending.len(), 2);
        assert_eq!((pending[0].req.id, pending[0].row), (1, 0));
        assert_eq!((pending[1].req.id, pending[1].row), (2, 3));
        // rows landed at their offsets, the rest stayed zero
        assert_eq!(x.data[0], 1.0);
        assert_eq!(x.data[3 * dm], 2.0);
        assert_eq!(x.data[5 * dm], 0.0);
        // head-of-line request 3 is intact for the next batch
        assert_eq!(b.queued_rows(), 5);
        let (_, pending) = b.take_batch(16, dm).unwrap();
        assert_eq!(pending[0].req.id, 3);
        assert!(b.take_batch(16, dm).is_none());
    }

    fn sreq(id: u32, session: usize, rows: usize, dm: usize) -> Request {
        Request { session, ..req(id, rows, dm) }
    }

    #[test]
    fn idle_window_is_absolute_across_mid_window_arrivals() {
        // Pre-fix, `next_batch` handed the *fixed* idle duration to a
        // single `wait_timeout`, so the first mid-window wakeup (a
        // straggler joining the batch) departed the batch undersized
        // after ~40 ms instead of holding the window open.  The window
        // must be an absolute deadline anchored at the oldest arrival.
        let cfg = ServeConfig {
            port: 49570,
            max_batch: 8,
            queue_depth: 64,
            idle_ms: 200,
        };
        let daemon = ServeDaemon::bind(&cfg, 8, 2).unwrap();
        let shared = daemon.shared.clone();
        let feeder = std::thread::spawn(move || {
            // the first request opens the window; two stragglers
            // notify mid-window
            for (delay_ms, id) in [(0u64, 1u32), (40, 2), (80, 3)] {
                std::thread::sleep(Duration::from_millis(delay_ms));
                let mut st = shared.state.lock().unwrap();
                st.batcher.admit(req(id, 1, 2)).unwrap();
                drop(st);
                shared.cv.notify_all();
            }
        });
        let start = Instant::now();
        let (_, pending) = daemon.next_batch(8, 2).unwrap();
        let waited = start.elapsed();
        feeder.join().unwrap();
        assert_eq!(
            pending.len(),
            3,
            "mid-window arrivals must coalesce into the departing batch"
        );
        assert!(
            waited >= Duration::from_millis(150),
            "undersized batch departed after {waited:?} — a wakeup cut \
             the idle window short"
        );
        assert!(
            waited < Duration::from_secs(5),
            "idle window never expired ({waited:?})"
        );
    }

    #[test]
    fn full_batch_departs_without_waiting_out_the_window() {
        let cfg = ServeConfig {
            port: 49572,
            max_batch: 4,
            queue_depth: 64,
            idle_ms: 1000,
        };
        let daemon = ServeDaemon::bind(&cfg, 4, 2).unwrap();
        let shared = daemon.shared.clone();
        let feeder = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            let mut st = shared.state.lock().unwrap();
            for id in 1..=4u32 {
                st.batcher.admit(req(id, 1, 2)).unwrap();
            }
            drop(st);
            shared.cv.notify_all();
        });
        let start = Instant::now();
        let (_, pending) = daemon.next_batch(4, 2).unwrap();
        feeder.join().unwrap();
        assert_eq!(pending.len(), 4);
        assert!(
            start.elapsed() < Duration::from_millis(500),
            "a full batch must depart immediately, not wait out the \
             idle window"
        );
    }

    #[test]
    fn batcher_round_robins_sessions() {
        let dm = 1;
        let mut b = Batcher::new(2, 64);
        // a chatty session 0 floods four requests ahead of session 1's one
        for id in 1..=4 {
            b.admit(sreq(id, 0, 1, dm)).unwrap();
        }
        b.admit(sreq(9, 1, 1, dm)).unwrap();
        // session 1 rides in the very first batch despite arriving last
        let (_, p) = b.take_batch(2, dm).unwrap();
        let ids: Vec<u32> = p.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![1, 9]);
        // the flood then drains FIFO within its session
        let (_, p) = b.take_batch(2, dm).unwrap();
        let ids: Vec<u32> = p.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![2, 3]);
    }

    #[test]
    fn batcher_cursor_rotates_across_batches() {
        let dm = 1;
        let mut b = Batcher::new(1, 64);
        b.admit(sreq(1, 0, 1, dm)).unwrap();
        b.admit(sreq(2, 0, 1, dm)).unwrap();
        b.admit(sreq(9, 1, 1, dm)).unwrap();
        // one-row budget: each batch holds a single request, and the
        // cursor hands the slot to a different session each time
        assert_eq!(b.take_batch(1, dm).unwrap().1[0].req.id, 1);
        assert_eq!(b.take_batch(1, dm).unwrap().1[0].req.id, 9);
        assert_eq!(b.take_batch(1, dm).unwrap().1[0].req.id, 2);
        assert!(b.take_batch(1, dm).is_some());
        assert!(b.take_batch(1, dm).is_none());
    }

    #[test]
    fn batcher_admission_control() {
        let dm = 2;
        let mut b = Batcher::new(4, 6);
        // oversized for any step → immediate rejection
        assert!(b.admit(req(1, 5, dm)).is_err());
        // zero rows can never be scheduled
        assert!(b.admit(req(2, 0, dm)).is_err());
        // fill the queue to its depth…
        b.admit(req(3, 4, dm)).unwrap();
        b.admit(req(4, 2, dm)).unwrap();
        assert_eq!(b.queued_rows(), 6);
        // …then overflow rejects instead of queueing
        assert!(b.admit(req(5, 1, dm)).is_err());
        // draining a batch frees depth again
        let _ = b.take_batch(8, dm).unwrap();
        assert!(b.admit(req(6, 4, dm)).is_ok());
    }

    #[test]
    fn batcher_budget_is_min_of_max_batch_and_nb() {
        let dm = 1;
        let mut b = Batcher::new(16, 64);
        b.admit(req(1, 3, dm)).unwrap();
        b.admit(req(2, 3, dm)).unwrap();
        // nb = 4 < max_batch: only the first request fits
        let (x, pending) = b.take_batch(4, dm).unwrap();
        assert_eq!(x.shape, vec![4, 1]);
        assert_eq!(pending.len(), 1);
        assert_eq!(b.queued_rows(), 3);
    }

    #[test]
    fn batcher_drain_hands_back_everything() {
        let dm = 2;
        let mut b = Batcher::new(4, 16);
        b.admit(req(1, 2, dm)).unwrap();
        b.admit(req(2, 3, dm)).unwrap();
        let drained = b.drain();
        assert_eq!(
            drained.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![1, 2]
        );
        assert_eq!(b.queued_rows(), 0);
        assert!(b.is_empty());
        // depth freed: admission works again after the drain
        b.admit(req(3, 4, dm)).unwrap();
    }

    #[test]
    fn stats_json_has_latency_percentiles() {
        let mut s = ServeStats::new();
        s.latency.record(0.002);
        s.latency.record(0.004);
        s.steps = 1;
        s.requests = 2;
        let j = s.to_json();
        for key in [
            "latency_p50",
            "latency_p95",
            "latency_p99",
            "rows_per_sec",
            "step_p50",
            "rejected",
            "disconnects",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert!(j.get("latency_p99").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn tag_roundtrip() {
        let t = serve_tag(CODE_REQ, 37);
        assert_eq!(tag_code(t), CODE_REQ);
        assert_eq!(tag_rows(t), 37);
    }
}
