//! End-to-end driver (Figure 7): train the MoE GPT and the equal-FLOPs
//! dense GPT on the synthetic corpus, logging both loss curves.
//!
//! ```bash
//! cargo run --release --example train_gpt -- --steps 300 --out runs
//! ```
//!
//! Reproduces the paper's §5.4 comparison: the MoE model (top-2, expert
//! hidden size halved so per-token FLOPs match) should reach a lower lm
//! loss at the same iteration count, and — because the MoE step is only
//! moderately slower — a lower loss at equal wall-time by the end of
//! the run.  Results land in `<out>/fig7_loss.csv` and a summary table
//! is printed (recorded in EXPERIMENTS.md).

use fastmoe::bench::Table;
use fastmoe::cli::Args;
use fastmoe::coordinator::Trainer;
use fastmoe::data::{BatchIter, Corpus};
use fastmoe::metrics::{CsvWriter, Stopwatch, Summary};
use fastmoe::runtime::Runtime;
use fastmoe::util;

struct Run {
    model: String,
    losses: Vec<(u64, f64, f32)>, // (step, wall s, train loss)
    eval_losses: Vec<(u64, f32)>,
    step_secs: Summary,
    params: usize,
}

fn train_one(
    rt: &Runtime,
    model: &str,
    steps: usize,
    seed: u64,
    smooth: f32,
) -> fastmoe::Result<Run> {
    let mut tr = Trainer::new(rt, model, seed)?;
    let vocab = tr.entry.config_usize("vocab").unwrap_or(256);
    let seq = tr.entry.config_usize("seq").unwrap_or(128);
    let batch = tr.entry.config_usize("batch").unwrap_or(4);
    // same corpus + same batch stream for both models: the comparison
    // is purely architectural
    let corpus = Corpus::synthetic(vocab, 1_000_000, 1234);
    let mut train_it = BatchIter::new(&corpus, batch, seq, 777);
    let mut eval_it = BatchIter::new(&corpus, batch, seq, 778);
    let eval_batch = eval_it.next_batch();

    println!(
        "=== {model}: {} params, {} steps of {}x{} tokens ===",
        tr.params.n_elements(),
        steps,
        batch,
        seq
    );
    let watch = Stopwatch::start();
    let mut run = Run {
        model: model.to_string(),
        losses: Vec::new(),
        eval_losses: Vec::new(),
        step_secs: Summary::new(),
        params: tr.params.n_elements(),
    };
    let mut ema = f32::NAN;
    for i in 0..steps {
        let stats = tr.train_step(&train_it.next_batch())?;
        run.step_secs.add(stats.secs);
        ema = if ema.is_nan() {
            stats.loss
        } else {
            smooth * ema + (1.0 - smooth) * stats.loss
        };
        run.losses.push((stats.step, watch.secs(), stats.loss));
        if (i + 1) % 25 == 0 || i == 0 {
            let ev = tr.eval(&eval_batch)?;
            run.eval_losses.push((stats.step, ev));
            println!(
                "  step {:>5}  loss {:.4} (ema {:.4})  eval {:.4}  {}/step",
                stats.step,
                stats.loss,
                ema,
                ev,
                util::fmt_duration(std::time::Duration::from_secs_f64(stats.secs))
            );
        }
    }
    Ok(run)
}

fn main() -> fastmoe::Result<()> {
    let args = Args::from_env(&[])?;
    let steps = args.usize_or("steps", 300)?;
    let seed = args.u64_or("seed", 42)?;
    let out_dir = args.str_or("out", "runs");
    let rt = Runtime::open_default()?;

    let moe = train_one(&rt, "gpt_moe", steps, seed, 0.97)?;
    let dense = train_one(&rt, "gpt_dense", steps, seed, 0.97)?;

    // ---- CSV: both curves, by step and wall-time (Figure 7's two x-axes)
    let path = format!("{out_dir}/fig7_loss.csv");
    let mut csv = CsvWriter::create(&path, &["model", "step", "wall_s", "loss"])?;
    for run in [&moe, &dense] {
        for &(step, wall, loss) in &run.losses {
            csv.row(&[
                run.model.clone(),
                step.to_string(),
                format!("{wall:.3}"),
                format!("{loss:.5}"),
            ])?;
        }
    }

    // ---- summary table (EXPERIMENTS.md rows) ----
    let tail = |r: &Run| -> f32 {
        let n = r.losses.len();
        let k = (n / 10).max(1);
        r.losses[n - k..].iter().map(|x| x.2).sum::<f32>() / k as f32
    };
    let mut t = Table::new(&[
        "model", "params", "step_ms(p50)", "final_loss(tail10%)", "loss@equal_time",
    ]);
    // loss at the wall-time where the *slower* model finished
    let t_end = moe
        .losses
        .last()
        .map(|x| x.1)
        .unwrap_or(0.0)
        .min(dense.losses.last().map(|x| x.1).unwrap_or(0.0));
    let loss_at = |r: &Run, t_lim: f64| -> f32 {
        r.losses
            .iter()
            .take_while(|x| x.1 <= t_lim)
            .map(|x| x.2)
            .fold(f32::NAN, |_, l| l)
    };
    for run in [&moe, &dense] {
        t.row(vec![
            run.model.clone(),
            run.params.to_string(),
            format!("{:.1}", run.step_secs.p50() * 1e3),
            format!("{:.4}", tail(run)),
            format!("{:.4}", loss_at(run, t_end)),
        ]);
    }
    println!("\n{}", t.render());
    println!(
        "MoE/dense step-time ratio: {:.2}x (paper reports ≈3x at 96 experts)",
        moe.step_secs.p50() / dense.step_secs.p50()
    );
    println!("loss curves: {path}");

    let ok = tail(&moe) < tail(&dense);
    println!(
        "MoE beats dense at equal iterations: {}",
        if ok { "YES ✓" } else { "NO ✗" }
    );
    Ok(())
}
