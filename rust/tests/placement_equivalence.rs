//! Placement equivalence: dynamic expert placement must never change
//! the math.
//!
//! The load-bearing properties of `placement` (PR 7):
//!
//! * **Shadow transparency** — a run with a hot expert shadow-replicated
//!   onto another rank produces *bitwise* the same losses, parameters
//!   and Adam moments as a never-replicated run, step after step.  The
//!   forward may route rows to the nearest replica, but the backward
//!   rebuilds the owner schedule (the owner accumulates the complete
//!   gradient) and `sync_shadows` mirrors the owner's Adam update onto
//!   every replica, so the layouts are indistinguishable in state.
//!   Pinned on the thread backend and on real sockets.
//! * **Migration fidelity** — swapping two experts' owners between
//!   steps moves their parameter slots *and* Adam moments bit-for-bit
//!   (the checkpoint-format `pack_expert_slot` payload), leaving every
//!   expert's state identical to an unmigrated reference, just at a
//!   different address; training continues without error afterwards.
//! * **The point of it all** — on a skewed routing distribution the
//!   `sim::NetModel` scores the rebalanced layout (shadow or migrate)
//!   strictly below the static seed layout.
//!
//! Ports: 48970 (shadow equivalence over tcp).  See
//! `serve_integration.rs` for the neighbouring allocations.

use std::sync::Arc;

use fastmoe::comm::tcp::TcpGroup;
use fastmoe::comm::{run_workers, Comm};
use fastmoe::coordinator::{MoeLayerBuilder, MoeLayerTrainer};
use fastmoe::metrics::Counters;
use fastmoe::placement::{decide, PlacementPlan, PlacementPolicy, PlanDelta};
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::sim::{NetModel, NetPreset};
use fastmoe::tensor::TensorF32;

const WORKERS: usize = 2;
const STEPS: usize = 3;
const LR: f32 = 1e-3;

fn rt() -> Option<Arc<Runtime>> {
    Runtime::open_default().ok().map(Arc::new)
}

fn build_trainer(rt: Arc<Runtime>, rank: usize) -> fastmoe::Result<MoeLayerTrainer> {
    let layer = MoeLayerBuilder::new()
        .gate("topk")
        .seed(77)
        .build(rt, WORKERS, rank)?;
    layer.warm()?;
    Ok(MoeLayerTrainer::new(layer, LR))
}

/// The same deterministic batch on every run for a given (rank, step).
fn step_input(nb: usize, dm: usize, rank: usize, step: usize) -> TensorF32 {
    let mut x = TensorF32::zeros(&[nb, dm]);
    Rng::new(4000 + (step * WORKERS + rank) as u64).fill_normal(&mut x.data, 1.0);
    x
}

fn assert_bits(what: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (j, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what} elem {j}: {x} != {y}"
        );
    }
}

/// Drive a shadowed and a never-replicated trainer in lockstep on the
/// same comm handle and assert bit-identical losses, parameters and
/// Adam moments after every step.  Every rank calls this.
fn assert_shadow_bitwise(
    comm: &mut impl Comm,
    rt: Arc<Runtime>,
) -> fastmoe::Result<()> {
    let rank = comm.rank();
    let mut base = build_trainer(rt.clone(), rank)?;
    let mut shad = build_trainer(rt, rank)?;
    let (mut c1, mut c2) = (Counters::new(), Counters::new());
    // replicate expert 0 (owned by rank 0) onto rank 1 before any
    // training: rank 1's rows for it will route to the local replica
    shad.force_delta(comm, &PlanDelta::AddShadow { expert: 0, host: 1 })?;
    assert_eq!(shad.layer.placement().shadow_width(), 1);
    assert_eq!(shad.layer.placement().shadow_hosts(0), vec![1]);
    for step in 0..STEPS {
        let x = step_input(base.layer.nb, base.layer.dm, rank, step);
        let s1 = base.train_step(comm, x.clone(), &mut c1)?;
        let s2 = shad.train_step(comm, x, &mut c2)?;
        assert_eq!(
            s1.loss.to_bits(),
            s2.loss.to_bits(),
            "step {step} rank {rank}: loss {} != {}",
            s1.loss,
            s2.loss
        );
        for ((name, p1), (_, p2)) in
            base.layer.params().iter().zip(shad.layer.params().iter())
        {
            assert_bits(&format!("step {step} rank {rank} {name}"), &p1.data, &p2.data);
        }
        for (i, (m1, m2)) in
            base.optimizer().m.iter().zip(&shad.optimizer().m).enumerate()
        {
            assert_bits(&format!("step {step} rank {rank} adam.m[{i}]"), &m1.data, &m2.data);
        }
        for (i, (v1, v2)) in
            base.optimizer().v.iter().zip(&shad.optimizer().v).enumerate()
        {
            assert_bits(&format!("step {step} rank {rank} adam.v[{i}]"), &v1.data, &v2.data);
        }
    }
    // dropping the replicas is pure bookkeeping — still bit-identical
    shad.force_delta(comm, &PlanDelta::DropShadows)?;
    assert!(shad.layer.placement().is_seed());
    let x = step_input(base.layer.nb, base.layer.dm, rank, STEPS);
    let s1 = base.train_step(comm, x.clone(), &mut c1)?;
    let s2 = shad.train_step(comm, x, &mut c2)?;
    assert_eq!(s1.loss.to_bits(), s2.loss.to_bits());
    Ok(())
}

#[test]
fn shadow_run_is_bitwise_identical_thread() {
    let Some(rt) = rt() else { return };
    run_workers(WORKERS, move |mut h| assert_shadow_bitwise(&mut h, rt.clone()))
        .unwrap();
}

#[test]
fn shadow_run_is_bitwise_identical_tcp() {
    let Some(rt) = rt() else { return };
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            let rt = rt.clone();
            std::thread::spawn(move || -> fastmoe::Result<()> {
                let mut g = TcpGroup::connect_local(rank, WORKERS, 48970)?;
                assert_shadow_bitwise(&mut g, rt)?;
                g.barrier()
            })
        })
        .collect();
    for (rank, j) in joins.into_iter().enumerate() {
        j.join().unwrap_or_else(|_| panic!("tcp rank {rank} panicked")).unwrap();
    }
}

/// Per rank, per expert-shard tensor: the full data plus its Adam
/// moments (slots after the two gate slots), for cross-rank slot
/// comparison on the main thread.
type ExpertDump = Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>;

fn dump_expert_state(tr: &MoeLayerTrainer) -> ExpertDump {
    tr.layer
        .params()
        .iter()
        .skip(2) // wg, bg
        .enumerate()
        .map(|(j, (_, p))| {
            (
                p.data.clone(),
                tr.optimizer().m[2 + j].data.clone(),
                tr.optimizer().v[2 + j].data.clone(),
            )
        })
        .collect()
}

#[test]
fn migration_moves_params_and_adam_state_bitwise() {
    let Some(rt) = rt() else { return };
    // swap expert 0 (rank 0, slot 0) with rank 1's first expert
    let out = run_workers(WORKERS, move |mut h| {
        let rank = h.rank();
        let mut reference = build_trainer(rt.clone(), rank)?;
        let mut migrated = build_trainer(rt.clone(), rank)?;
        let (mut c1, mut c2) = (Counters::new(), Counters::new());
        // two warm-up steps populate Adam's moments with real values
        for step in 0..2 {
            let x = step_input(reference.layer.nb, reference.layer.dm, rank, step);
            let s1 = reference.train_step(&mut h, x.clone(), &mut c1)?;
            let s2 = migrated.train_step(&mut h, x, &mut c2)?;
            assert_eq!(s1.loss.to_bits(), s2.loss.to_bits());
        }
        let ne_local = migrated.layer.ne_local;
        let swap = PlanDelta::Swap { a: 0, b: ne_local };
        migrated.force_delta(&mut h, &swap)?;
        assert!(!migrated.layer.placement().is_seed());
        assert_eq!(migrated.layer.placement().owner(0), (1, 0));
        assert_eq!(migrated.layer.placement().owner(ne_local), (0, 0));
        let owners: Vec<(usize, usize)> = (0..WORKERS * ne_local)
            .map(|e| migrated.layer.placement().owner(e))
            .collect();
        let dump = (dump_expert_state(&reference), dump_expert_state(&migrated));
        // the migrated layout must still train (collective schedules
        // all agree on the new owner map)
        let x = step_input(migrated.layer.nb, migrated.layer.dm, rank, 99);
        let stats = migrated.train_step(&mut h, x, &mut c2)?;
        assert!(stats.loss.is_finite());
        Ok((ne_local, owners, dump.0, dump.1))
    })
    .unwrap();

    let (ne_local, owners, _, _) = &out[0];
    let slot = |dump: &ExpertDump, s: usize| -> Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        dump.iter()
            .map(|(p, m, v)| {
                let stride = p.len() / ne_local;
                (
                    p[s * stride..(s + 1) * stride].to_vec(),
                    m[s * stride..(s + 1) * stride].to_vec(),
                    v[s * stride..(s + 1) * stride].to_vec(),
                )
            })
            .collect()
    };
    for e in 0..WORKERS * ne_local {
        // reference: the seed layout; migrated: wherever the swap put it
        let (rr, rs) = (e / ne_local, e % ne_local);
        let (mr, ms) = owners[e];
        let want = slot(&out[rr].2, rs);
        let got = slot(&out[mr].3, ms);
        for (t, ((wp, wm, wv), (gp, gm, gv))) in
            want.iter().zip(got.iter()).enumerate()
        {
            assert_bits(&format!("expert {e} tensor {t} params"), gp, wp);
            assert_bits(&format!("expert {e} tensor {t} adam.m"), gm, wm);
            assert_bits(&format!("expert {e} tensor {t} adam.v"), gv, wv);
        }
    }
}

/// Acceptance (iii): on a skewed routing distribution the analytic step
/// model must score the policy's rebalanced layout strictly below the
/// static seed layout — the whole reason the subsystem exists.
#[test]
fn rebalanced_skew_scores_below_static() {
    let net = NetModel::preset(NetPreset::IbEdr);
    let (workers, ne_local) = (4, 2);
    let (bytes_per_row, secs_per_row) = (4096, 5e-6);

    // one runaway-hot expert: shadow replication spreads its rows
    let mut counts = vec![40u32; workers * ne_local];
    counts[0] = 600;
    let mut plan = PlacementPlan::seed(workers, ne_local);
    let static_secs =
        net.moe_step_skewed(&plan.rank_rows(&counts), bytes_per_row, secs_per_row);
    let mut moves = 0;
    for _ in 0..workers {
        match decide(PlacementPolicy::Shadow, &plan, &counts, 1.5) {
            Some(delta @ PlanDelta::AddShadow { .. }) => {
                plan.apply(&delta).unwrap();
                moves += 1;
            }
            _ => break,
        }
    }
    assert!(moves >= 1, "the skew must trigger at least one replication");
    let shadow_secs =
        net.moe_step_skewed(&plan.rank_rows(&counts), bytes_per_row, secs_per_row);
    assert!(
        shadow_secs < static_secs,
        "shadowed layout must beat static ({shadow_secs} vs {static_secs})"
    );

    // two warm experts crowded onto one rank: migration separates them
    let mut counts = vec![40u32; workers * ne_local];
    counts[0] = 300;
    counts[1] = 300;
    let mut plan = PlacementPlan::seed(workers, ne_local);
    let static_secs =
        net.moe_step_skewed(&plan.rank_rows(&counts), bytes_per_row, secs_per_row);
    let delta = decide(PlacementPolicy::Migrate, &plan, &counts, 1.5)
        .expect("crowding must trigger a migration");
    assert!(matches!(delta, PlanDelta::Swap { .. }), "{delta:?}");
    plan.apply(&delta).unwrap();
    let migrated_secs =
        net.moe_step_skewed(&plan.rank_rows(&counts), bytes_per_row, secs_per_row);
    assert!(
        migrated_secs < static_secs,
        "migrated layout must beat static ({migrated_secs} vs {static_secs})"
    );
}
