//! MoE dispatch machinery — the paper's §3.2/§4 logic on the host side,
//! organised as the §3.1 *hierarchical interface*:
//!
//! * **Gate policy** ([`gate`]) — the [`Gate`] trait routes score rows
//!   into assignments.  [`TopKSoftmaxGate`] (seed behaviour),
//!   [`SwitchGate`] (top-1 + capacity factor + token drop) and
//!   [`NoisyTopKGate`] (seeded exploration noise) are interchangeable.
//! * **Expert shard** ([`expert`]) — the [`ExpertShard`] trait owns one
//!   worker's expert parameters and runs the bucketed HLO executables;
//!   [`FfnExpertShard`] is the seed two-GEMM FFN.
//! * **Dispatch substrate** (this module) — fixed high-performance
//!   plumbing both plug into: counting tokens per (worker, expert),
//!   building the [`DispatchPlan`] (the *local data shuffle*), packing
//!   rows for the Figure-2 all-to-all (the *global data exchange*),
//!   re-batching incoming rows per local expert with power-of-two
//!   capacity [`bucket_for`] padding, and the reverse path.  For the
//!   pipelined layer, [`chunk_peer_groups`] partitions the exchange
//!   into ring-offset peer chunks so dispatch, expert compute, and the
//!   return stream overlap (§4's hidden exchange); [`ChunkSlice`] is a
//!   chunk's *slice view* of the full-batch buffer (rows land once,
//!   chunks gather their segments into one pooled staging), and
//!   [`adaptive_chunks`] picks the chunk count from a measured
//!   wire:compute ratio (`[comm] chunks = 0`).
//!
//! Layers are assembled from the three levels by
//! `coordinator::MoeLayerBuilder`, driven by the `[moe]` config section.
//!
//! Slot convention (shared with `python/compile/kernels/scatter.py`):
//! assignment `a = token*k + j` gets packed position `slots[a]`; packed
//! rows are ordered by (destination worker, local expert, token).

pub mod expert;
pub mod gate;
mod monitor;

pub use expert::{ExpertShard, FfnExpertShard};
pub use gate::{Gate, NoisyTopKGate, SwitchGate, TopKSoftmaxGate};
pub use monitor::{balance_loss, LoadMonitor};

use crate::comm::{Comm, CommRequest, Topology};
use crate::error::{Error, Result};
use crate::tensor::{ops, BufferPool, TensorF32};

/// Top-k gate selection + k-way softmax weights (matches
/// `stages.topk_softmax`; ties toward the lower expert id).
#[derive(Clone, Debug)]
pub struct GateAssign {
    pub nb: usize,
    pub k: usize,
    /// Chosen expert per assignment, `[nb * k]`, token-major.
    pub idx: Vec<u32>,
    /// Gate weight per assignment, `[nb * k]`.  A zero weight marks a
    /// dropped or filler assignment (capacity gates): the row still
    /// transits the exchange but contributes nothing to the combine.
    pub w: Vec<f32>,
    /// Full softmax probabilities `[nb, n_e]`, when the gate computes
    /// them (feeds [`balance_loss`] and capacity-gate backward; `None`
    /// on the raw [`topk_softmax`] fast path).
    pub probs: Option<TensorF32>,
}

impl GateAssign {
    /// Per-global-expert histogram of *kept* (weight > 0) assignments.
    ///
    /// Distinct from `DispatchPlan::counts_global`, which counts every
    /// slot because every slot transits the exchange: capacity gates
    /// emit zero-weight dropped/filler slots that carry no signal, so
    /// load metrics (balance loss, monitor) must count only kept ones.
    pub fn kept_counts(&self, ne: usize) -> Vec<u32> {
        let mut counts = vec![0u32; ne];
        for (a, &e) in self.idx.iter().enumerate() {
            if self.w[a] > 0.0 {
                counts[e as usize] += 1;
            }
        }
        counts
    }
}

/// Select top-k experts per row of `scores: [nb, n_e]` and softmax the
/// selected raw scores.
pub fn topk_softmax(scores: &TensorF32, k: usize) -> Result<GateAssign> {
    let (nb, ne) = scores.dims2()?;
    if k == 0 || k > ne {
        return Err(Error::Shape(format!("top-k {k} of {ne} experts")));
    }
    let mut idx = Vec::with_capacity(nb * k);
    let mut w = Vec::with_capacity(nb * k);
    let mut sel = vec![0.0f32; k];
    for i in 0..nb {
        let row = scores.row(i);
        let top = ops::topk_indices(row, k);
        for (j, &e) in top.iter().enumerate() {
            sel[j] = row[e];
            idx.push(e as u32);
        }
        ops::softmax_slice(&mut sel);
        w.extend_from_slice(&sel);
    }
    Ok(GateAssign { nb, k, idx, w, probs: None })
}

/// Backward of [`topk_softmax`]: scatter the k-way softmax Jacobian into
/// a full `[nb, n_e]` score-gradient matrix.
pub fn topk_softmax_bwd(
    assign: &GateAssign,
    dw: &[f32],
    ne: usize,
) -> Result<TensorF32> {
    if dw.len() != assign.nb * assign.k {
        return Err(Error::Shape("dw arity".into()));
    }
    let mut dscores = TensorF32::zeros(&[assign.nb, ne]);
    let k = assign.k;
    let mut ds = vec![0.0f32; k];
    for i in 0..assign.nb {
        let wrow = &assign.w[i * k..(i + 1) * k];
        let dwrow = &dw[i * k..(i + 1) * k];
        ops::softmax_slice_bwd(wrow, dwrow, &mut ds);
        for j in 0..k {
            let e = assign.idx[i * k + j] as usize;
            dscores.data[i * ne + e] += ds[j];
        }
    }
    Ok(dscores)
}

/// The local shuffle + global exchange plan for one iteration.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    pub nb: usize,
    pub k: usize,
    pub workers: usize,
    pub ne_local: usize,
    /// Assignment ids in packed (worker, local expert, token) order.
    pub order: Vec<u32>,
    /// Packed position per assignment, `[nb * k]` (inverse of `order`).
    pub slots: Vec<i32>,
    /// Rows sent to each destination worker.
    pub send_rows: Vec<usize>,
    /// Per destination worker, rows per local expert (the Figure-2
    /// "number of samples assigned to each expert on each worker").
    pub send_counts: Vec<Vec<u32>>,
    /// Tokens this worker routed to each *global* expert, `[ne_global]`
    /// — the counting-sort histogram, exposed so callers (load monitor,
    /// balance loss) never recount the assignments.
    pub counts_global: Vec<u32>,
}

impl DispatchPlan {
    /// Build the plan from gate assignments.  Global expert `e` lives on
    /// worker `e / ne_local` as local expert `e % ne_local` (the static
    /// seed layout — `build_routed` with the identity route, bit for
    /// bit).
    pub fn build(assign: &GateAssign, workers: usize, ne_local: usize) -> Result<Self> {
        Self::build_routed(assign, workers, ne_local, ne_local, |e| {
            (e / ne_local, e % ne_local)
        })
    }

    /// Build the plan under an arbitrary expert → `(rank, slot)` route —
    /// the placement-aware dispatch.  `width` is the number of compute
    /// slots per destination rank (`ne_local` plus any shadow slots);
    /// every `route(e)` must land in `rank < workers, slot < width`,
    /// and distinct experts must map to distinct `(rank, slot)` pairs
    /// (a [`crate::placement::PlacementPlan`] guarantees both).
    ///
    /// With the identity route and `width == ne_local` the counting
    /// sort keys on `rank * ne_local + slot == e`, so the packed
    /// order, slots, and send counts are identical to the historical
    /// [`DispatchPlan::build`] — the bit-compat anchor the equivalence
    /// suites pin.
    pub fn build_routed<F>(
        assign: &GateAssign,
        workers: usize,
        ne_local: usize,
        width: usize,
        route: F,
    ) -> Result<Self>
    where
        F: Fn(usize) -> (usize, usize),
    {
        let n_assign = assign.nb * assign.k;
        let ne_global = workers * ne_local;
        // per-expert destination key = rank * width + slot
        let mut dest = vec![0usize; ne_global];
        for (e, d) in dest.iter_mut().enumerate() {
            let (r, s) = route(e);
            if r >= workers || s >= width {
                return Err(Error::Shape(format!(
                    "route({e}) = ({r}, {s}) outside {workers} x {width}"
                )));
            }
            *d = r * width + s;
        }
        for &e in &assign.idx {
            if e as usize >= ne_global {
                return Err(Error::Shape(format!(
                    "expert id {e} out of range ({ne_global} global experts)"
                )));
            }
        }
        // counting sort by (worker, dest slot), stable in token order —
        // O(n + E); with the identity route the key is the global
        // expert id itself
        let mut counts_global = vec![0u32; ne_global];
        let mut counts_key = vec![0u32; workers * width];
        for &e in &assign.idx {
            counts_global[e as usize] += 1;
            counts_key[dest[e as usize]] += 1;
        }
        let nkey = workers * width;
        let mut offsets = vec![0u32; nkey + 1];
        for key in 0..nkey {
            offsets[key + 1] = offsets[key] + counts_key[key];
        }
        let mut order = vec![0u32; n_assign];
        let mut cursor = offsets.clone();
        for (a, &e) in assign.idx.iter().enumerate() {
            let key = dest[e as usize];
            let pos = cursor[key];
            order[pos as usize] = a as u32;
            cursor[key] += 1;
        }
        let mut slots = vec![0i32; n_assign];
        for (pos, &a) in order.iter().enumerate() {
            slots[a as usize] = pos as i32;
        }
        let send_counts: Vec<Vec<u32>> = (0..workers)
            .map(|wkr| counts_key[wkr * width..(wkr + 1) * width].to_vec())
            .collect();
        let send_rows = send_counts
            .iter()
            .map(|c| c.iter().map(|&x| x as usize).sum())
            .collect();
        Ok(DispatchPlan {
            nb: assign.nb,
            k: assign.k,
            workers,
            ne_local,
            order,
            slots,
            send_rows,
            send_counts,
            counts_global,
        })
    }

    /// Pack token features into per-destination-worker buffers in packed
    /// order (the scatter of §4, fused with the send staging).
    pub fn pack(&self, x: &TensorF32) -> Result<Vec<Vec<f32>>> {
        let mut pool = BufferPool::new(false);
        self.pack_into(x, &mut pool, "pack")
    }

    /// [`DispatchPlan::pack`] staging its per-peer buffers out of a
    /// [`BufferPool`] role, so steady-state steps re-use last step's
    /// send staging instead of allocating `workers` fresh vectors.
    pub fn pack_into(
        &self,
        x: &TensorF32,
        pool: &mut BufferPool,
        role: &'static str,
    ) -> Result<Vec<Vec<f32>>> {
        let (nb, dm) = x.dims2()?;
        if nb != self.nb {
            return Err(Error::Shape("pack: batch mismatch".into()));
        }
        let mut out: Vec<Vec<f32>> = self
            .send_rows
            .iter()
            .map(|&r| pool.take_vec(role, r * dm))
            .collect();
        let mut pos = 0usize;
        for wkr in 0..self.workers {
            let rows = self.send_rows[wkr];
            let buf = &mut out[wkr];
            for _ in 0..rows {
                let a = self.order[pos] as usize;
                let token = a / self.k;
                buf.extend_from_slice(x.row(token));
                pos += 1;
            }
        }
        Ok(out)
    }

    /// Reassemble per-peer returned buffers into `[nb*k, dm]` rows in
    /// packed order (the input expected by the combine kernel).
    pub fn unpack_returned(&self, parts: &[Vec<f32>], dm: usize) -> Result<TensorF32> {
        let mut ys = TensorF32::zeros(&[self.nb * self.k, dm]);
        self.unpack_returned_into(parts, dm, &mut ys)?;
        Ok(ys)
    }

    /// [`DispatchPlan::unpack_returned`] into a caller-provided (pooled)
    /// tensor; every row is overwritten.  Returns the bytes copied.
    pub fn unpack_returned_into(
        &self,
        parts: &[Vec<f32>],
        dm: usize,
        ys: &mut TensorF32,
    ) -> Result<usize> {
        if parts.len() != self.workers {
            return Err(Error::Shape("unpack: wrong peer count".into()));
        }
        let n_assign = self.nb * self.k;
        if ys.shape != vec![n_assign, dm] {
            return Err(Error::Shape(format!(
                "unpack: destination is {:?}, expected [{n_assign}, {dm}]",
                ys.shape
            )));
        }
        let mut pos = 0usize;
        for (wkr, part) in parts.iter().enumerate() {
            let rows = self.send_rows[wkr];
            if part.len() != rows * dm {
                return Err(Error::Shape(format!(
                    "unpack: peer {wkr} returned {} floats, expected {}",
                    part.len(),
                    rows * dm
                )));
            }
            ys.data[pos * dm..(pos + rows) * dm].copy_from_slice(part);
            pos += rows;
        }
        Ok(n_assign * dm * 4)
    }

    /// Slots as an `[nb, k]` i32 tensor (combine-kernel input).
    pub fn slots_i32(&self) -> crate::tensor::TensorI32 {
        crate::tensor::TensorI32 {
            shape: vec![self.nb, self.k],
            data: self.slots.clone(),
        }
    }

    /// Packed-row offset of each destination worker's block: prefix
    /// sums of `send_rows`, length `workers + 1`.  Slice `p`'s rows of
    /// a packed `[nb*k, dm]` tensor are `offsets[p]..offsets[p+1]` —
    /// the contiguous per-peer view the chunked exchange sends.
    pub fn send_offsets(&self) -> Vec<usize> {
        let mut offsets = Vec::with_capacity(self.workers + 1);
        let mut acc = 0usize;
        offsets.push(0);
        for &r in &self.send_rows {
            acc += r;
            offsets.push(acc);
        }
        offsets
    }
}

/// Peer groups of one pipelined-exchange chunk (ring-offset schedule).
///
/// Chunk `c` covers a contiguous range of ring offsets `o`; a worker
/// dispatches to `(rank + o) % workers` and simultaneously hosts rows
/// from `(rank − o) mod workers`, so every worker sends *and* receives
/// `≈ workers/chunks` peers' worth of rows per chunk — the balanced
/// decomposition the overlap schedule needs (contrast a naive
/// "worker group c receives in chunk c" split, which would idle every
/// other worker).  Each out-group is one *expert group*: the global
/// experts hosted by those destination workers.
#[derive(Clone, Debug)]
pub struct ChunkPeers {
    /// Peers this worker dispatches tokens to in the chunk (and later
    /// receives expert outputs back from): `(rank + o) % workers`.
    pub out_peers: Vec<usize>,
    /// Peers whose tokens this worker hosts in the chunk (receives
    /// dispatch from, returns outputs to): `(rank − o) mod workers`.
    pub in_peers: Vec<usize>,
}

impl ChunkPeers {
    /// The return direction of the same chunk: expert outputs flow
    /// back along reversed edges (hosts send to the token owners).
    pub fn reversed(&self) -> ChunkPeers {
        ChunkPeers {
            out_peers: self.in_peers.clone(),
            in_peers: self.out_peers.clone(),
        }
    }
}

/// Partition the peer ring into `chunks` contiguous offset groups
/// (sizes differ by at most one; `chunks` is clamped to `workers`).
/// Offset 0 — the worker itself — lands in chunk 0, so local rows are
/// computable before any remote bytes arrive.
pub fn chunk_peer_groups(rank: usize, workers: usize, chunks: usize) -> Vec<ChunkPeers> {
    let w = workers.max(1);
    let c = chunks.clamp(1, w);
    (0..c)
        .map(|i| {
            let lo = i * w / c;
            let hi = (i + 1) * w / c;
            ChunkPeers {
                out_peers: (lo..hi).map(|o| (rank + o) % w).collect(),
                in_peers: (lo..hi).map(|o| (rank + w - o) % w).collect(),
            }
        })
        .collect()
}

/// [`chunk_peer_groups`] with node locality: under a hierarchical
/// [`Topology`], ring offsets are ordered **most-local-first** before
/// being split into chunks, so chunk 0 carries the offsets that are
/// intra-node for the most ranks (self always first) and the
/// inter-node offsets ride the later chunks — the cheap local rows
/// compute while the expensive cross-node rows are still on the wire.
///
/// The offset → chunk assignment is *rank-independent* (offsets are
/// scored by how many ranks they keep on-node, not by this rank's own
/// view), which is what preserves the mirror property — `r` dispatches
/// to `p` in chunk `c` exactly when `p` hosts `r` in its chunk `c` —
/// and therefore the cross-rank tag lockstep of the pipeline.  Flat
/// topologies reproduce [`chunk_peer_groups`] exactly (all offsets
/// score alike, and the ascending-offset tie-break restores the ring
/// order), so `topology = "flat"` stays bit-compatible.
pub fn chunk_peer_groups_topo(
    rank: usize,
    topo: &Topology,
    chunks: usize,
) -> Vec<ChunkPeers> {
    let w = topo.world().max(1);
    let l = topo.local_size();
    if l <= 1 || l >= w {
        // flat, or a single node: every offset is equally local
        return chunk_peer_groups(rank, w, chunks);
    }
    // score(o) = #ranks whose offset-o peer shares their node; with
    // contiguous blocks of l that is max(0, l−o) forward plus the
    // wrap-around max(0, l−(w−o)) — independent of the rank
    let score = |o: usize| -> usize {
        if o == 0 {
            return l; // self
        }
        l.saturating_sub(o) + l.saturating_sub(w - o)
    };
    let mut offsets: Vec<usize> = (0..w).collect();
    offsets.sort_by(|&a, &b| score(b).cmp(&score(a)).then(a.cmp(&b)));
    let c = chunks.clamp(1, w);
    (0..c)
        .map(|i| {
            let group = &offsets[i * w / c..(i + 1) * w / c];
            ChunkPeers {
                out_peers: group.iter().map(|&o| (rank + o) % w).collect(),
                in_peers: group.iter().map(|&o| (rank + w - o) % w).collect(),
            }
        })
        .collect()
}

/// How the ranks reduce their exchanged per-rank wire:compute ratios
/// into one agreed adaptive chunk count (`[comm] chunk_policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkPolicy {
    /// Average balance across ranks (the default).
    Mean,
    /// Straggler-aware: the rank with the most wire-bound step decides,
    /// so one skewed-routing straggler pulls everyone to finer chunks
    /// (its wire time is what the others end up waiting on anyway).
    Max,
}

impl ChunkPolicy {
    /// The valid `[comm] chunk_policy` spellings — the one list config
    /// validation and the builder both consult (kept adjacent to
    /// [`ChunkPolicy::parse`] so they cannot drift).
    pub const KINDS: &'static [&'static str] = &["mean", "max"];

    /// Parse a `[comm] chunk_policy` value.
    pub fn parse(s: &str) -> Option<ChunkPolicy> {
        match s {
            "mean" => Some(ChunkPolicy::Mean),
            "max" => Some(ChunkPolicy::Max),
            _ => None,
        }
    }

    /// The config spelling of this policy — [`ChunkPolicy::parse`]'s
    /// inverse, for emitting `[comm]` snippets.
    pub fn as_str(&self) -> &'static str {
        match self {
            ChunkPolicy::Mean => "mean",
            ChunkPolicy::Max => "max",
        }
    }
}

/// Reduce the exchanged per-rank ratios (negative = no measurement
/// yet) into the agreed chunk count for the next pipelined step, or
/// `None` when nobody has measured anything.  Every rank holds the
/// same rank-ordered ratio vector, so every rank derives the same
/// count — the agreement invariant of `[comm] chunks = 0`.
pub fn agree_chunks(
    ratios: &[f32],
    policy: ChunkPolicy,
    workers: usize,
) -> Option<usize> {
    let valid: Vec<f64> = ratios
        .iter()
        .filter(|&&r| r >= 0.0)
        .map(|&r| r as f64)
        .collect();
    if valid.is_empty() {
        return None;
    }
    let agg = match policy {
        ChunkPolicy::Mean => valid.iter().sum::<f64>() / valid.len() as f64,
        ChunkPolicy::Max => valid.iter().cloned().fold(f64::MIN, f64::max),
    };
    Some(adaptive_chunks(agg, 1.0, workers))
}

/// Pick an exchange chunk count from a measured wire:compute balance
/// (`[comm] chunks = 0` — the adaptive satellite of the zero-copy PR).
///
/// Intuition from the α-β pipeline model (`sim::NetModel`): with all
/// the time on the wire, every peer wants to be its own chunk
/// (`workers`) so compute can start the moment the first rows land;
/// with all the time in compute, chunking only adds padding and tag
/// overhead (`1`).  In between the useful granularity scales with the
/// *wire fraction* `wire / (wire + compute)` of a step.
pub fn adaptive_chunks(wire: f64, compute: f64, workers: usize) -> usize {
    let w = workers.max(1);
    if !wire.is_finite() || wire <= 0.0 {
        return 1;
    }
    if !compute.is_finite() || compute <= 0.0 {
        return w;
    }
    let frac = wire / (wire + compute);
    ((w as f64 * frac).round() as usize).clamp(1, w)
}

/// Receive requests of one in-flight exchange chunk, by absolute peer.
pub type PendingChunk = Vec<(usize, CommRequest)>;

/// Queue one chunk's sends and bookmark its arrivals: isend this
/// worker's buffers to the chunk's out-peers, irecv from its in-peers.
/// Self rows short-circuit the wire into `self_part`.  Buffers are
/// taken out of `send`, so each peer's slot can be posted only once.
pub fn post_chunk<C: Comm>(
    comm: &mut C,
    rank: usize,
    group: &ChunkPeers,
    tag: u64,
    send: &mut [Vec<f32>],
    self_part: &mut [Option<Vec<f32>>],
    pend: &mut PendingChunk,
) -> Result<()> {
    for &p in &group.out_peers {
        let buf = std::mem::take(&mut send[p]);
        if p == rank {
            self_part[rank] = Some(buf);
        } else {
            comm.isend(p, tag, buf)?;
        }
    }
    for &p in &group.in_peers {
        if p != rank {
            pend.push((p, comm.irecv(p, tag)?));
        }
    }
    Ok(())
}

/// Complete one chunk's posted receives (arrival order where the
/// backend supports it) and file the buffers by absolute peer.
pub fn wait_chunk<C: Comm>(
    comm: &mut C,
    pend: PendingChunk,
    parts: &mut [Option<Vec<f32>>],
) -> Result<()> {
    let (peers, reqs): (Vec<usize>, Vec<CommRequest>) = pend.into_iter().unzip();
    let datas = comm.wait_all(reqs)?;
    for (p, data) in peers.into_iter().zip(datas) {
        parts[p] = Some(data.unwrap_or_default());
    }
    Ok(())
}

/// Rows arriving at one worker, regrouped per local expert and padded to
/// a capacity bucket — the receiver side of Figure 2.
#[derive(Clone, Debug)]
pub struct ExpertBatch {
    pub ne_local: usize,
    pub bucket: usize,
    pub dm: usize,
    /// `[ne_local, bucket, dm]` zero-padded expert inputs.
    pub xs: TensorF32,
    /// Incoming rows per (peer, local expert).
    pub recv_counts: Vec<Vec<u32>>,
    /// Total rows per local expert.
    pub rows_per_expert: Vec<usize>,
}

/// Per-peer layout of an [`ExpertBatch`]: total rows per local expert
/// and the capacity bucket those rows pad into.
fn batch_layout(
    recv_counts: &[Vec<u32>],
    ne_local: usize,
    buckets: &[usize],
) -> Result<(Vec<usize>, usize)> {
    let mut rows_per_expert = vec![0usize; ne_local];
    for counts in recv_counts {
        if counts.len() != ne_local {
            return Err(Error::Shape("recv counts arity".into()));
        }
        for (e, &c) in counts.iter().enumerate() {
            rows_per_expert[e] += c as usize;
        }
    }
    let max_rows = rows_per_expert.iter().copied().max().unwrap_or(0);
    let bucket = bucket_for(max_rows.max(1), buckets)?;
    Ok((rows_per_expert, bucket))
}

impl ExpertBatch {
    /// Regroup incoming rows (grouped by expert *within* each peer
    /// buffer) into per-expert contiguous blocks across peers.
    pub fn build(
        recv_counts: Vec<Vec<u32>>,
        recv_parts: &[Vec<f32>],
        ne_local: usize,
        dm: usize,
        buckets: &[usize],
    ) -> Result<ExpertBatch> {
        let refs: Vec<&[f32]> = recv_parts.iter().map(|p| p.as_slice()).collect();
        Self::build_from(recv_counts, &refs, ne_local, dm, buckets)
    }

    /// [`ExpertBatch::build`] over borrowed per-peer slices — one
    /// [`ExpertBatch::shell`] filled from every peer (identical layout
    /// and bits by construction).
    pub fn build_from(
        recv_counts: Vec<Vec<u32>>,
        recv_parts: &[&[f32]],
        ne_local: usize,
        dm: usize,
        buckets: &[usize],
    ) -> Result<ExpertBatch> {
        if recv_parts.len() != recv_counts.len() {
            return Err(Error::Shape("recv parts/counts mismatch".into()));
        }
        let mut eb = Self::shell(recv_counts, ne_local, dm, buckets)?;
        for (p, part) in recv_parts.iter().enumerate() {
            eb.fill_peer(p, part)?;
        }
        Ok(eb)
    }

    /// Allocate the padded batch for known per-peer counts with every
    /// row still zero — the receiving side of a *pipelined* exchange,
    /// where buffers land chunk by chunk and are copied straight into
    /// their final positions with [`ExpertBatch::fill_peer`].  Bucket
    /// selection and layout match [`ExpertBatch::build`] exactly, so a
    /// shell filled from every peer is bit-identical to a batch built
    /// in one shot.
    pub fn shell(
        recv_counts: Vec<Vec<u32>>,
        ne_local: usize,
        dm: usize,
        buckets: &[usize],
    ) -> Result<ExpertBatch> {
        let (rows_per_expert, bucket) = batch_layout(&recv_counts, ne_local, buckets)?;
        let xs = TensorF32::zeros(&[ne_local, bucket, dm]);
        Ok(ExpertBatch { ne_local, bucket, dm, xs, recv_counts, rows_per_expert })
    }

    /// [`ExpertBatch::shell`] backed by a pooled buffer: the padded
    /// full-batch container comes from (and later returns to) `pool`,
    /// so steady-state steps never reallocate it.
    pub fn shell_pooled(
        recv_counts: Vec<Vec<u32>>,
        ne_local: usize,
        dm: usize,
        buckets: &[usize],
        pool: &mut BufferPool,
        role: &'static str,
    ) -> Result<ExpertBatch> {
        let (rows_per_expert, bucket) = batch_layout(&recv_counts, ne_local, buckets)?;
        let xs = pool.take_tensor(role, &[ne_local, bucket, dm])?;
        Ok(ExpertBatch { ne_local, bucket, dm, xs, recv_counts, rows_per_expert })
    }

    /// Wrap an already-staged padded tensor as a compute batch (the
    /// per-chunk slice-view staging of the pipelined path).  Only the
    /// geometry and `xs` matter to an [`ExpertShard`]; `recv_counts`
    /// is left empty — use the owning [`ChunkSlice`] for splitting.
    pub fn for_compute(
        ne_local: usize,
        bucket: usize,
        dm: usize,
        xs: TensorF32,
        rows_per_expert: Vec<usize>,
    ) -> ExpertBatch {
        ExpertBatch { ne_local, bucket, dm, xs, recv_counts: Vec::new(), rows_per_expert }
    }

    /// Copy one peer's buffer (rows grouped by expert, as sent) into
    /// its final rows of a [`ExpertBatch::shell`].  Positions depend
    /// only on the counts, so peers may be filled in any arrival
    /// order; filling the same peer twice just rewrites the same rows.
    /// Returns the bytes copied (copy-counter food).
    pub fn fill_peer(&mut self, p: usize, part: &[f32]) -> Result<usize> {
        let expect: usize = self.recv_counts[p].iter().map(|&c| c as usize).sum();
        if part.len() != expect * self.dm {
            return Err(Error::Shape(format!(
                "peer {p} buffer has {} floats, counts say {}",
                part.len(),
                expect * self.dm
            )));
        }
        // rows of peers q < p precede ours inside every expert block
        let mut fill = vec![0usize; self.ne_local];
        for counts in &self.recv_counts[..p] {
            for (e, &c) in counts.iter().enumerate() {
                fill[e] += c as usize;
            }
        }
        let mut off = 0usize;
        for e in 0..self.ne_local {
            let rows = self.recv_counts[p][e] as usize;
            let src = &part[off * self.dm..(off + rows) * self.dm];
            let dst = (e * self.bucket + fill[e]) * self.dm;
            self.xs.data[dst..dst + rows * self.dm].copy_from_slice(src);
            off += rows;
        }
        Ok(part.len() * 4)
    }

    /// Split expert outputs `[ne_local, bucket, dm]` back into per-peer
    /// return buffers (inverse of `build`, same grouping as arrival).
    pub fn split_outputs(&self, ys: &TensorF32) -> Result<Vec<Vec<f32>>> {
        let mut pool = BufferPool::new(false);
        self.split_outputs_pooled(ys, &mut pool, "split")
    }

    /// [`ExpertBatch::split_outputs`] with the per-peer return buffers
    /// staged out of a [`BufferPool`] role.
    pub fn split_outputs_pooled(
        &self,
        ys: &TensorF32,
        pool: &mut BufferPool,
        role: &'static str,
    ) -> Result<Vec<Vec<f32>>> {
        if ys.shape != vec![self.ne_local, self.bucket, self.dm] {
            return Err(Error::Shape(format!(
                "split_outputs: got {:?}, expected [{}, {}, {}]",
                ys.shape, self.ne_local, self.bucket, self.dm
            )));
        }
        let peers = self.recv_counts.len();
        let mut out: Vec<Vec<f32>> = self
            .recv_counts
            .iter()
            .map(|cs| {
                let rows: u32 = cs.iter().sum();
                pool.take_vec(role, rows as usize * self.dm)
            })
            .collect();
        let mut taken = vec![0usize; self.ne_local];
        for p in 0..peers {
            for e in 0..self.ne_local {
                let rows = self.recv_counts[p][e] as usize;
                let start = (e * self.bucket + taken[e]) * self.dm;
                out[p].extend_from_slice(&ys.data[start..start + rows * self.dm]);
                taken[e] += rows;
            }
        }
        Ok(out)
    }

    /// Zero-padded cotangent container shaped like `xs` (backward path).
    pub fn zeros_like(&self) -> TensorF32 {
        TensorF32::zeros(&[self.ne_local, self.bucket, self.dm])
    }

    /// Regroup another set of per-peer buffers (e.g. output cotangents
    /// on the backward pass) into this batch's exact layout — same
    /// counts, same bucket, padding rows zero.
    pub fn rebatch(&self, parts: &[Vec<f32>]) -> Result<TensorF32> {
        let mut xs = self.zeros_like();
        self.rebatch_into(parts, &mut xs)?;
        Ok(xs)
    }

    /// [`ExpertBatch::rebatch`] into a caller-provided *zeroed* (pooled)
    /// tensor shaped like `xs`.  Returns the bytes copied.
    pub fn rebatch_into(&self, parts: &[Vec<f32>], xs: &mut TensorF32) -> Result<usize> {
        if parts.len() != self.recv_counts.len() {
            return Err(Error::Shape("rebatch: peer count".into()));
        }
        if xs.shape != vec![self.ne_local, self.bucket, self.dm] {
            return Err(Error::Shape("rebatch: destination shape".into()));
        }
        let mut copied = 0usize;
        let mut fill = vec![0usize; self.ne_local];
        for (p, part) in parts.iter().enumerate() {
            let mut off = 0usize;
            for e in 0..self.ne_local {
                let rows = self.recv_counts[p][e] as usize;
                let src = &part[off * self.dm..(off + rows) * self.dm];
                let dst = (e * self.bucket + fill[e]) * self.dm;
                xs.data[dst..dst + rows * self.dm].copy_from_slice(src);
                fill[e] += rows;
                off += rows;
            }
            if off * self.dm != part.len() {
                return Err(Error::Shape("rebatch: ragged buffer".into()));
            }
            copied += part.len() * 4;
        }
        Ok(copied)
    }

    /// The slice view of one exchange chunk: where the chunk peers'
    /// (already landed) rows live inside this full-batch buffer, and
    /// the compact layout they occupy in the chunk's compute staging.
    ///
    /// Rows are laid out by absolute peer inside each expert block (the
    /// blocking layout, which parameter-gradient reduction order — and
    /// therefore bitwise equivalence — depends on), so one chunk's rows
    /// are a *set of segments* per expert, not a single range.
    pub fn chunk_slice(&self, peers: &[usize], buckets: &[usize]) -> Result<ChunkSlice> {
        let all = self.recv_counts.len();
        let mut segs: Vec<Vec<SliceSeg>> =
            (0..self.ne_local).map(|_| Vec::with_capacity(peers.len())).collect();
        let mut rows_per_expert = vec![0usize; self.ne_local];
        for e in 0..self.ne_local {
            let mut dst = 0usize;
            for &p in peers {
                if p >= all {
                    return Err(Error::Shape(format!("chunk peer {p} of {all}")));
                }
                let src: usize = self.recv_counts[..p]
                    .iter()
                    .map(|cs| cs[e] as usize)
                    .sum();
                let rows = self.recv_counts[p][e] as usize;
                segs[e].push(SliceSeg { src, rows, dst });
                dst += rows;
            }
            rows_per_expert[e] = dst;
        }
        let max_rows = rows_per_expert.iter().copied().max().unwrap_or(0);
        let bucket = bucket_for(max_rows.max(1), buckets)?;
        Ok(ChunkSlice { peers: peers.to_vec(), segs, rows_per_expert, bucket })
    }

    /// Gather a chunk's rows out of this full-batch buffer into the
    /// compact padded staging `dst: [ne_local, slice.bucket, dm]` (the
    /// bucketed executable's input).  `dst` must arrive zeroed; only
    /// real rows are written.  Returns the bytes copied — the *single*
    /// stage copy that replaced the PR 2 path's wire→chunk-batch copy
    /// (the rows already landed here via [`ExpertBatch::fill_peer`]).
    pub fn gather_chunk(&self, slice: &ChunkSlice, dst: &mut TensorF32) -> Result<usize> {
        if dst.shape != vec![self.ne_local, slice.bucket, self.dm] {
            return Err(Error::Shape(format!(
                "gather_chunk: staging is {:?}, expected [{}, {}, {}]",
                dst.shape, self.ne_local, slice.bucket, self.dm
            )));
        }
        let mut copied = 0usize;
        for e in 0..self.ne_local {
            for seg in &slice.segs[e] {
                if seg.rows == 0 {
                    continue;
                }
                let src = (e * self.bucket + seg.src) * self.dm;
                let to = (e * slice.bucket + seg.dst) * self.dm;
                dst.data[to..to + seg.rows * self.dm]
                    .copy_from_slice(&self.xs.data[src..src + seg.rows * self.dm]);
                copied += seg.rows * self.dm * 4;
            }
        }
        Ok(copied)
    }
}

/// One per-expert row segment of a [`ChunkSlice`]: `rows` rows starting
/// at `src` inside the full-batch expert block, landing at `dst` inside
/// the chunk's compact staging block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SliceSeg {
    pub src: usize,
    pub rows: usize,
    pub dst: usize,
}

/// Row-offset view of one exchange chunk inside a full-batch
/// [`ExpertBatch`] — see [`ExpertBatch::chunk_slice`].  `segs[e][i]` is
/// peer `peers[i]`'s segment in expert `e` (possibly zero rows, kept so
/// indices align).
#[derive(Clone, Debug)]
pub struct ChunkSlice {
    pub peers: Vec<usize>,
    pub segs: Vec<Vec<SliceSeg>>,
    pub rows_per_expert: Vec<usize>,
    /// Compute bucket of the chunk (smallest that fits its rows; never
    /// larger than the full batch's bucket, since chunk rows ⊆ rows).
    pub bucket: usize,
}

impl ChunkSlice {
    /// Split a chunk's expert outputs `[ne_local, bucket, dm]` into
    /// per-peer return buffers (`peers` order, rows grouped by expert —
    /// the grouping [`DispatchPlan::unpack_returned`] expects back).
    pub fn split_outputs_pooled(
        &self,
        ys: &TensorF32,
        dm: usize,
        pool: &mut BufferPool,
        role: &'static str,
    ) -> Result<(Vec<Vec<f32>>, usize)> {
        let ne_local = self.segs.len();
        if ys.shape != vec![ne_local, self.bucket, dm] {
            return Err(Error::Shape(format!(
                "chunk split: got {:?}, expected [{}, {}, {}]",
                ys.shape, ne_local, self.bucket, dm
            )));
        }
        let mut copied = 0usize;
        let mut out = Vec::with_capacity(self.peers.len());
        for i in 0..self.peers.len() {
            let rows: usize = self.segs.iter().map(|s| s[i].rows).sum();
            let mut buf = pool.take_vec(role, rows * dm);
            for (e, segs) in self.segs.iter().enumerate() {
                let seg = segs[i];
                let start = (e * self.bucket + seg.dst) * dm;
                buf.extend_from_slice(&ys.data[start..start + seg.rows * dm]);
            }
            copied += buf.len() * 4;
            out.push(buf);
        }
        Ok((out, copied))
    }
}

/// Smallest compiled bucket that fits `n` rows.
pub fn bucket_for(n: usize, buckets: &[usize]) -> Result<usize> {
    buckets
        .iter()
        .copied()
        .filter(|&b| b >= n)
        .min()
        .ok_or_else(|| {
            Error::Shape(format!(
                "no capacity bucket fits {n} rows (have {buckets:?}); \
                 re-run aot.py with larger buckets"
            ))
        })
}

/// Combine weighted expert outputs on the host (test oracle for the
/// combine kernel; the hot path uses the HLO artifact).
pub fn combine_host(
    ys: &TensorF32,
    assign: &GateAssign,
    slots: &[i32],
) -> Result<TensorF32> {
    let (n_rows, dm) = ys.dims2()?;
    if n_rows != assign.nb * assign.k {
        return Err(Error::Shape("combine rows".into()));
    }
    let mut out = TensorF32::zeros(&[assign.nb, dm]);
    for i in 0..assign.nb {
        for j in 0..assign.k {
            let a = i * assign.k + j;
            let s = slots[a] as usize;
            let wgt = assign.w[a];
            let src = &ys.data[s * dm..(s + 1) * dm];
            let dst = &mut out.data[i * dm..(i + 1) * dm];
            for (d, v) in dst.iter_mut().zip(src) {
                *d += wgt * v;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::{check, prop_assert, prop_assert_eq};

    fn scores(nb: usize, ne: usize, seed: u64) -> TensorF32 {
        let mut t = TensorF32::zeros(&[nb, ne]);
        Rng::new(seed).fill_normal(&mut t.data, 1.0);
        t
    }

    #[test]
    fn topk_weights_normalised_and_sorted() {
        let s = scores(10, 6, 1);
        let a = topk_softmax(&s, 3).unwrap();
        for i in 0..10 {
            let wsum: f32 = a.w[i * 3..(i + 1) * 3].iter().sum();
            assert!((wsum - 1.0).abs() < 1e-5);
            // weights descend with score rank
            assert!(a.w[i * 3] >= a.w[i * 3 + 1] && a.w[i * 3 + 1] >= a.w[i * 3 + 2]);
            // chosen experts are distinct
            let mut e: Vec<u32> = a.idx[i * 3..(i + 1) * 3].to_vec();
            e.sort_unstable();
            e.dedup();
            assert_eq!(e.len(), 3);
        }
    }

    #[test]
    fn kept_counts_ignore_zero_weight_slots() {
        let a = GateAssign {
            nb: 2,
            k: 2,
            idx: vec![0, 1, 2, 1],
            w: vec![0.5, 0.0, 0.7, 0.3],
            probs: None,
        };
        assert_eq!(a.kept_counts(4), vec![1, 1, 1, 0]);
    }

    #[test]
    fn topk_rejects_bad_k() {
        let s = scores(4, 2, 1);
        assert!(topk_softmax(&s, 0).is_err());
        assert!(topk_softmax(&s, 3).is_err());
    }

    #[test]
    fn plan_is_permutation_and_counts_conserve() {
        let s = scores(50, 8, 2);
        let a = topk_softmax(&s, 2).unwrap();
        let plan = DispatchPlan::build(&a, 4, 2).unwrap();
        // order is a permutation of assignments
        let mut o = plan.order.clone();
        o.sort_unstable();
        assert_eq!(o, (0..100u32).collect::<Vec<_>>());
        // slots invert order
        for (pos, &aid) in plan.order.iter().enumerate() {
            assert_eq!(plan.slots[aid as usize], pos as i32);
        }
        // counts sum to assignments
        let total: usize = plan.send_rows.iter().sum();
        assert_eq!(total, 100);
        // per-worker counts match send_rows
        for w in 0..4 {
            let c: u32 = plan.send_counts[w].iter().sum();
            assert_eq!(c as usize, plan.send_rows[w]);
        }
        // exposed global histogram is the same data, unsliced
        assert_eq!(plan.counts_global.iter().sum::<u32>(), 100);
        for w in 0..4 {
            assert_eq!(&plan.counts_global[w * 2..(w + 1) * 2], &plan.send_counts[w][..]);
        }
    }

    #[test]
    fn packed_order_groups_by_worker_then_expert() {
        let s = scores(40, 6, 3);
        let a = topk_softmax(&s, 2).unwrap();
        let plan = DispatchPlan::build(&a, 3, 2).unwrap();
        let mut last_key = 0u32;
        for &aid in &plan.order {
            let e = a.idx[aid as usize];
            assert!(e >= last_key, "packed order not sorted by expert");
            last_key = e;
        }
    }

    #[test]
    fn pack_moves_correct_rows() {
        let nb = 6;
        let mut x = TensorF32::zeros(&[nb, 2]);
        for i in 0..nb {
            x.data[i * 2] = i as f32;
            x.data[i * 2 + 1] = 100.0 + i as f32;
        }
        let s = scores(nb, 4, 4);
        let a = topk_softmax(&s, 2).unwrap();
        let plan = DispatchPlan::build(&a, 2, 2).unwrap();
        let bufs = plan.pack(&x).unwrap();
        // reconstruct: walking the packed order must visit x rows
        let mut pos = 0;
        for (w, buf) in bufs.iter().enumerate() {
            assert_eq!(buf.len(), plan.send_rows[w] * 2);
            for r in 0..plan.send_rows[w] {
                let aid = plan.order[pos] as usize;
                let tok = aid / 2;
                assert_eq!(buf[r * 2], tok as f32);
                pos += 1;
            }
        }
    }

    #[test]
    fn expert_batch_roundtrip() {
        // two peers, two local experts, known rows
        let dm = 3;
        let recv_counts = vec![vec![2u32, 1], vec![1, 2]];
        // peer buffers grouped by expert: peer0 = [e0r0, e0r1, e1r0]
        let row = |v: f32| vec![v, v, v];
        let p0: Vec<f32> = [row(1.), row(2.), row(10.)].concat();
        let p1: Vec<f32> = [row(3.), row(20.), row(21.)].concat();
        let eb = ExpertBatch::build(
            recv_counts.clone(),
            &[p0.clone(), p1.clone()],
            2,
            dm,
            &[4, 8],
        )
        .unwrap();
        assert_eq!(eb.bucket, 4);
        assert_eq!(eb.rows_per_expert, vec![3, 3]);
        // expert 0 block: rows 1,2 (peer0) then 3 (peer1), padded with 0
        assert_eq!(&eb.xs.data[0..12], &[1., 1., 1., 2., 2., 2., 3., 3., 3., 0., 0., 0.]);
        // identity "compute": split back must reproduce the peer buffers
        let back = eb.split_outputs(&eb.xs).unwrap();
        assert_eq!(back[0], p0);
        assert_eq!(back[1], p1);
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(bucket_for(1, &[16, 32]).unwrap(), 16);
        assert_eq!(bucket_for(16, &[16, 32]).unwrap(), 16);
        assert_eq!(bucket_for(17, &[16, 32]).unwrap(), 32);
        assert!(bucket_for(33, &[16, 32]).is_err());
    }

    #[test]
    fn prop_plan_pack_unpack_roundtrip() {
        check("scatter∘gather = identity through the plan", 40, |g| {
            let nb = g.usize_in(1, 60);
            let workers = *g.choose(&[1usize, 2, 4]);
            let ne_local = g.usize_in(1, 3);
            let ne = workers * ne_local;
            let k = g.usize_in(1, ne.min(3));
            let dm = g.usize_in(1, 8);
            let s = scores(nb, ne, g.rng.next_u64());
            let a = topk_softmax(&s, k).map_err(|e| e.to_string())?;
            let plan =
                DispatchPlan::build(&a, workers, ne_local).map_err(|e| e.to_string())?;
            let mut x = TensorF32::zeros(&[nb, dm]);
            g.rng.fill_normal(&mut x.data, 1.0);

            // send -> (identity expert) -> return -> combine with w=…:
            let bufs = plan.pack(&x).map_err(|e| e.to_string())?;
            // conservation of rows
            let total: usize = bufs.iter().map(|b| b.len()).sum();
            prop_assert_eq(total, nb * k * dm)?;
            let ys = plan
                .unpack_returned(&bufs, dm)
                .map_err(|e| e.to_string())?;
            let out = combine_host(&ys, &a, &plan.slots).map_err(|e| e.to_string())?;
            // identity experts + weights summing to 1 ⇒ out == x
            for i in 0..nb * dm {
                prop_assert(
                    (out.data[i] - x.data[i]).abs() < 1e-4,
                    format!("mismatch at {i}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_expert_batch_conserves_rows() {
        check("expert batch regroup conserves rows", 40, |g| {
            let peers = g.usize_in(1, 4);
            let ne_local = g.usize_in(1, 4);
            let dm = g.usize_in(1, 6);
            let counts: Vec<Vec<u32>> = (0..peers)
                .map(|_| (0..ne_local).map(|_| g.usize_in(0, 9) as u32).collect())
                .collect();
            let mut val = 0.0f32;
            let parts: Vec<Vec<f32>> = counts
                .iter()
                .map(|cs| {
                    let rows: u32 = cs.iter().sum();
                    (0..rows as usize * dm)
                        .map(|_| {
                            val += 1.0;
                            val
                        })
                        .collect()
                })
                .collect();
            let eb = ExpertBatch::build(counts, &parts, ne_local, dm, &[16, 64, 256])
                .map_err(|e| e.to_string())?;
            let back = eb.split_outputs(&eb.xs).map_err(|e| e.to_string())?;
            for (p, buf) in back.iter().enumerate() {
                prop_assert(
                    buf == &parts[p],
                    format!("peer {p} buffer not reproduced"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn send_offsets_are_prefix_sums() {
        let s = scores(50, 8, 2);
        let a = topk_softmax(&s, 2).unwrap();
        let plan = DispatchPlan::build(&a, 4, 2).unwrap();
        let offsets = plan.send_offsets();
        assert_eq!(offsets.len(), 5);
        assert_eq!(offsets[0], 0);
        assert_eq!(offsets[4], 100);
        for w in 0..4 {
            assert_eq!(offsets[w + 1] - offsets[w], plan.send_rows[w]);
        }
    }

    #[test]
    fn chunk_peer_groups_cover_and_mirror() {
        for workers in [1usize, 2, 3, 4, 7, 8] {
            for chunks in [1usize, 2, 3, 4, 16] {
                for rank in 0..workers {
                    let groups = chunk_peer_groups(rank, workers, chunks);
                    assert_eq!(groups.len(), chunks.clamp(1, workers));
                    // self is in chunk 0, both directions
                    assert!(groups[0].out_peers.contains(&rank));
                    assert!(groups[0].in_peers.contains(&rank));
                    // every peer appears exactly once per direction
                    let mut outs: Vec<usize> =
                        groups.iter().flat_map(|g| g.out_peers.clone()).collect();
                    let mut ins: Vec<usize> =
                        groups.iter().flat_map(|g| g.in_peers.clone()).collect();
                    outs.sort_unstable();
                    ins.sort_unstable();
                    assert_eq!(outs, (0..workers).collect::<Vec<_>>());
                    assert_eq!(ins, (0..workers).collect::<Vec<_>>());
                }
                // mirror property: r dispatches to p in chunk c exactly
                // when p hosts r in its own chunk c — the invariant that
                // makes the per-chunk tags line up across ranks.
                for r in 0..workers {
                    let gr = chunk_peer_groups(r, workers, chunks);
                    for (c, g) in gr.iter().enumerate() {
                        for &p in &g.out_peers {
                            let gp = chunk_peer_groups(p, workers, chunks);
                            assert!(
                                gp[c].in_peers.contains(&r),
                                "w={workers} c={chunks}: {r}→{p} not mirrored"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn build_from_matches_build() {
        let dm = 2;
        let recv_counts = vec![vec![1u32, 2], vec![2, 0]];
        let p0: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let p1: Vec<f32> = (10..14).map(|i| i as f32).collect();
        let owned = ExpertBatch::build(
            recv_counts.clone(),
            &[p0.clone(), p1.clone()],
            2,
            dm,
            &[4],
        )
        .unwrap();
        let borrowed = ExpertBatch::build_from(
            recv_counts,
            &[p0.as_slice(), p1.as_slice()],
            2,
            dm,
            &[4],
        )
        .unwrap();
        assert_eq!(owned.xs.data, borrowed.xs.data);
        assert_eq!(owned.rows_per_expert, borrowed.rows_per_expert);
        assert_eq!(owned.bucket, borrowed.bucket);
    }

    #[test]
    fn shell_filled_in_any_order_matches_build() {
        let dm = 2;
        let recv_counts = vec![vec![1u32, 2], vec![2, 0], vec![0, 1]];
        let parts: Vec<Vec<f32>> = [3usize, 2, 1]
            .iter()
            .enumerate()
            .map(|(p, &rows)| {
                (0..rows * dm).map(|i| (p * 100 + i) as f32).collect()
            })
            .collect();
        let built =
            ExpertBatch::build(recv_counts.clone(), &parts, 2, dm, &[4]).unwrap();
        let mut shell = ExpertBatch::shell(recv_counts, 2, dm, &[4]).unwrap();
        assert_eq!(shell.bucket, built.bucket);
        assert_eq!(shell.rows_per_expert, built.rows_per_expert);
        // fill peers out of order — positions depend only on counts
        for &p in &[2usize, 0, 1] {
            shell.fill_peer(p, &parts[p]).unwrap();
        }
        assert_eq!(shell.xs.data, built.xs.data);
        // length validation
        assert!(shell.fill_peer(0, &[1.0]).is_err());
    }

    #[test]
    fn chunk_slice_gather_matches_per_chunk_build() {
        // The zero-copy contract: gathering a chunk's rows out of the
        // full-batch shell must reproduce, bit for bit, the batch the
        // PR 2 path built from the raw wire buffers of those peers.
        let dm = 2;
        let buckets = [4usize, 8, 16];
        let recv_counts =
            vec![vec![2u32, 1], vec![1, 3], vec![0, 2], vec![2, 0]];
        let parts: Vec<Vec<f32>> = recv_counts
            .iter()
            .enumerate()
            .map(|(p, cs)| {
                let rows: u32 = cs.iter().sum();
                (0..rows as usize * dm).map(|i| (p * 100 + i) as f32).collect()
            })
            .collect();
        let full =
            ExpertBatch::build(recv_counts.clone(), &parts, 2, dm, &buckets).unwrap();
        // two "chunks" with non-contiguous absolute peers
        for peers in [vec![0usize, 2], vec![3usize, 1], vec![1usize], vec![0, 1, 2, 3]]
        {
            let slice = full.chunk_slice(&peers, &buckets).unwrap();
            // chunk bucket never exceeds the full bucket
            assert!(slice.bucket <= full.bucket, "peers {peers:?}");
            let mut staging = TensorF32::zeros(&[2, slice.bucket, dm]);
            let copied = full.gather_chunk(&slice, &mut staging).unwrap();
            let rows: usize = slice.rows_per_expert.iter().sum();
            assert_eq!(copied, rows * dm * 4);
            // reference: the PR 2 per-chunk batch from wire buffers
            let counts_c: Vec<Vec<u32>> =
                peers.iter().map(|&p| recv_counts[p].clone()).collect();
            let parts_c: Vec<&[f32]> =
                peers.iter().map(|&p| parts[p].as_slice()).collect();
            let eb_c =
                ExpertBatch::build_from(counts_c, &parts_c, 2, dm, &buckets).unwrap();
            assert_eq!(eb_c.bucket, slice.bucket);
            assert_eq!(staging.data, eb_c.xs.data, "peers {peers:?}: staging bits");
            // and the chunk split must reproduce the per-peer buffers
            let mut pool = BufferPool::new(true);
            let (back, _) = slice
                .split_outputs_pooled(&staging, dm, &mut pool, "ret")
                .unwrap();
            for (i, &p) in peers.iter().enumerate() {
                assert_eq!(back[i], parts[p], "peer {p} round trip");
            }
        }
    }

    #[test]
    fn pooled_helpers_match_allocating_ones() {
        let s = scores(30, 6, 8);
        let a = topk_softmax(&s, 2).unwrap();
        let plan = DispatchPlan::build(&a, 3, 2).unwrap();
        let mut x = TensorF32::zeros(&[30, 4]);
        Rng::new(5).fill_normal(&mut x.data, 1.0);
        let mut pool = BufferPool::new(true);
        let plain = plan.pack(&x).unwrap();
        let pooled = plan.pack_into(&x, &mut pool, "wire").unwrap();
        assert_eq!(plain, pooled);
        // unpack into a pooled tensor == allocating unpack
        let ys = plan.unpack_returned(&plain, 4).unwrap();
        let mut dst = pool.take_tensor("y", &[60, 4]).unwrap();
        let copied = plan.unpack_returned_into(&pooled, 4, &mut dst).unwrap();
        assert_eq!(copied, 60 * 4 * 4);
        assert_eq!(ys.data, dst.data);
    }

    #[test]
    fn rebatch_into_matches_rebatch() {
        let dm = 3;
        let recv_counts = vec![vec![2u32, 1], vec![1, 2]];
        let parts: Vec<Vec<f32>> = recv_counts
            .iter()
            .map(|cs| {
                let rows: u32 = cs.iter().sum();
                (0..rows as usize * dm).map(|i| i as f32 + 0.5).collect()
            })
            .collect();
        let eb = ExpertBatch::build(recv_counts, &parts, 2, dm, &[4]).unwrap();
        let plain = eb.rebatch(&parts).unwrap();
        let mut dst = eb.zeros_like();
        let copied = eb.rebatch_into(&parts, &mut dst).unwrap();
        assert_eq!(plain.data, dst.data);
        assert_eq!(copied, parts.iter().map(|p| p.len() * 4).sum::<usize>());
    }

    #[test]
    fn topo_chunk_groups_cover_mirror_and_prefer_local() {
        for (w, l) in [(4usize, 2usize), (8, 2), (8, 4), (6, 3), (12, 4)] {
            let topo = Topology::new(w, l).unwrap();
            for chunks in [1usize, 2, 3, 4] {
                for rank in 0..w {
                    let groups = chunk_peer_groups_topo(rank, &topo, chunks);
                    let flat = chunk_peer_groups(rank, w, chunks);
                    assert_eq!(groups.len(), flat.len());
                    // same chunk sizes as the flat split
                    for (g, f) in groups.iter().zip(&flat) {
                        assert_eq!(g.out_peers.len(), f.out_peers.len());
                    }
                    // self in chunk 0, both directions
                    assert!(groups[0].out_peers.contains(&rank));
                    assert!(groups[0].in_peers.contains(&rank));
                    // every peer exactly once per direction
                    let mut outs: Vec<usize> =
                        groups.iter().flat_map(|g| g.out_peers.clone()).collect();
                    let mut ins: Vec<usize> =
                        groups.iter().flat_map(|g| g.in_peers.clone()).collect();
                    outs.sort_unstable();
                    ins.sort_unstable();
                    assert_eq!(outs, (0..w).collect::<Vec<_>>());
                    assert_eq!(ins, (0..w).collect::<Vec<_>>());
                }
                // mirror property survives the locality reordering
                for r in 0..w {
                    let gr = chunk_peer_groups_topo(r, &topo, chunks);
                    for (c, g) in gr.iter().enumerate() {
                        for &p in &g.out_peers {
                            let gp = chunk_peer_groups_topo(p, &topo, chunks);
                            assert!(
                                gp[c].in_peers.contains(&r),
                                "w={w} l={l} c={chunks}: {r}→{p} not mirrored"
                            );
                        }
                    }
                }
                // locality: summed over ranks, chunk 0 keeps at least
                // as many intra-node edges as the last chunk
                if chunks >= 2 {
                    let intra_edges = |c: usize| -> usize {
                        (0..w)
                            .map(|r| {
                                chunk_peer_groups_topo(r, &topo, chunks)[c]
                                    .out_peers
                                    .iter()
                                    .filter(|&&p| topo.node_of(p) == topo.node_of(r))
                                    .count()
                            })
                            .sum()
                    };
                    let nc = chunk_peer_groups_topo(0, &topo, chunks).len();
                    assert!(
                        intra_edges(0) >= intra_edges(nc - 1),
                        "w={w} l={l} chunks={chunks}: chunk 0 not most local"
                    );
                }
            }
        }
        // flat topology reproduces the ring schedule exactly
        let topo = Topology::flat(8);
        for rank in 0..8 {
            let a = chunk_peer_groups_topo(rank, &topo, 4);
            let b = chunk_peer_groups(rank, 8, 4);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.out_peers, y.out_peers);
                assert_eq!(x.in_peers, y.in_peers);
            }
        }
    }

    #[test]
    fn agree_chunks_mean_vs_max_under_skew() {
        // three balanced ranks and one wire-bound straggler: the mean
        // barely moves, the max policy chases the straggler to finer
        // chunks — the ROADMAP "beyond the mean" satellite
        let ratios = [0.1f32, 0.1, 0.1, 4.0];
        let mean = agree_chunks(&ratios, ChunkPolicy::Mean, 8).unwrap();
        let max = agree_chunks(&ratios, ChunkPolicy::Max, 8).unwrap();
        assert!(max > mean, "max {max} must exceed mean {mean} under skew");
        assert_eq!(max, adaptive_chunks(4.0, 1.0, 8));
        // unmeasured ranks (negative) are skipped by both policies
        let ratios = [-1.0f32, 2.0, -1.0];
        assert_eq!(
            agree_chunks(&ratios, ChunkPolicy::Mean, 4),
            agree_chunks(&ratios, ChunkPolicy::Max, 4),
        );
        // nobody measured: no agreement
        assert_eq!(agree_chunks(&[-1.0, -1.0], ChunkPolicy::Max, 4), None);
        // identical ratios: the policies coincide
        let ratios = [1.5f32; 4];
        assert_eq!(
            agree_chunks(&ratios, ChunkPolicy::Mean, 8),
            agree_chunks(&ratios, ChunkPolicy::Max, 8),
        );
        assert_eq!(ChunkPolicy::parse("mean"), Some(ChunkPolicy::Mean));
        assert_eq!(ChunkPolicy::parse("max"), Some(ChunkPolicy::Max));
        assert_eq!(ChunkPolicy::parse("median"), None);
        // the advertised list and the parser cannot drift apart
        for k in ChunkPolicy::KINDS {
            assert!(ChunkPolicy::parse(k).is_some(), "KINDS entry `{k}` unparsable");
        }
    }

    #[test]
    fn adaptive_chunks_tracks_wire_fraction() {
        // no wire → no pipelining; no compute → every peer its own chunk
        assert_eq!(adaptive_chunks(0.0, 1.0, 8), 1);
        assert_eq!(adaptive_chunks(1.0, 0.0, 8), 8);
        assert_eq!(adaptive_chunks(f64::NAN, 1.0, 8), 1);
        // balanced → about half the peers per chunk group
        assert_eq!(adaptive_chunks(1.0, 1.0, 8), 4);
        // monotone in the wire share, bounded by [1, workers]
        let mut last = 0usize;
        for wire in [0.01, 0.1, 0.5, 1.0, 5.0, 100.0] {
            let c = adaptive_chunks(wire, 1.0, 8);
            assert!((1..=8).contains(&c));
            assert!(c >= last, "chunks must not shrink as wire grows");
            last = c;
        }
        assert_eq!(last, 8);
        // degenerate worker counts
        assert_eq!(adaptive_chunks(1.0, 1.0, 1), 1);
        assert_eq!(adaptive_chunks(1.0, 1.0, 0), 1);
    }

    #[test]
    fn topk_bwd_matches_finite_diff() {
        let s = scores(6, 5, 9);
        let k = 2;
        let a = topk_softmax(&s, k).unwrap();
        let mut rng = Rng::new(10);
        let dw: Vec<f32> = (0..6 * k).map(|_| rng.normal() as f32).collect();
        let ds = topk_softmax_bwd(&a, &dw, 5).unwrap();
        let eps = 1e-3f32;
        for i in 0..6 {
            for e in 0..5 {
                let mut sp = s.clone();
                sp.data[i * 5 + e] += eps;
                let mut sm = s.clone();
                sm.data[i * 5 + e] -= eps;
                let ap = topk_softmax(&sp, k).unwrap();
                let am = topk_softmax(&sm, k).unwrap();
                // finite diff only valid when the top-k set is stable
                if ap.idx != a.idx || am.idx != a.idx {
                    continue;
                }
                let f = |x: &GateAssign| -> f32 {
                    x.w[i * k..(i + 1) * k]
                        .iter()
                        .zip(&dw[i * k..(i + 1) * k])
                        .map(|(a, b)| a * b)
                        .sum()
                };
                let fd = (f(&ap) - f(&am)) / (2.0 * eps);
                assert!(
                    (fd - ds.data[i * 5 + e]).abs() < 2e-3,
                    "i={i} e={e}: fd={fd} ds={}",
                    ds.data[i * 5 + e]
                );
            }
        }
    }
}
