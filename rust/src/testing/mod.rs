//! proptest-lite: a tiny seeded property-testing harness (substrate; no
//! `proptest` in the offline registry).
//!
//! Properties run `cases` times with generated inputs; on failure the
//! harness re-runs with simple input shrinking (halving generated sizes)
//! and reports the seed so the exact case can be replayed.
//!
//! ```ignore
//! check("tokens conserved", 200, |g| {
//!     let n = g.usize_in(1, 64);
//!     /* … build inputs from g … */
//!     prop_assert(total_in == total_out, "lost tokens")
//! });
//! ```

use crate::rng::Rng;

/// Property outcome: `Ok(())` or a failure description.
pub type PropResult = Result<(), String>;

pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{a:?} != {b:?}"))
    }
}

/// Input generator handed to properties; wraps a seeded RNG with a size
/// budget that the shrinker reduces on failure.
pub struct Gen {
    pub rng: Rng,
    /// Current size multiplier in (0, 1]; shrink lowers it.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), size: 1.0 }
    }

    /// Integer in `[lo, hi]` scaled by the current shrink size.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + if span == 0 { 0 } else { self.rng.below(span + 1) }
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `prop` for `cases` random cases; panics with seed + shrink report
/// on the first failure (so `cargo test` surfaces it).
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    let base_seed = env_seed().unwrap_or(0xFA57_0001);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            // shrink: retry same seed with smaller size budgets
            let mut best = (1.0f64, msg.clone());
            let mut sz = 0.5;
            while sz > 0.01 {
                let mut g2 = Gen::new(seed);
                g2.size = sz;
                match prop(&mut g2) {
                    Err(m) => {
                        best = (sz, m);
                        sz *= 0.5;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property `{name}` failed (seed={seed}, case={case}, \
                 min_size={:.3}): {}\nreplay: FASTMOE_PROP_SEED={seed}",
                best.0, best.1
            );
        }
    }
}

fn env_seed() -> Option<u64> {
    std::env::var("FASTMOE_PROP_SEED").ok()?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum is commutative", 50, |g| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            prop_assert((a + b - (b + a)).abs() < 1e-6, "not commutative")
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_seed() {
        check("always fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn generator_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
        }
        let xs = g.vec_f32(16, -1.0, 1.0);
        assert_eq!(xs.len(), 16);
        assert!(xs.iter().all(|x| (-1.0..1.0).contains(x)));
    }

    #[test]
    fn shrink_reduces_sizes() {
        let mut g = Gen::new(2);
        g.size = 0.1;
        // span 0..100 shrunk to ~0..10
        for _ in 0..50 {
            assert!(g.usize_in(0, 100) <= 11);
        }
    }
}
