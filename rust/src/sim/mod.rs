//! Simulated hardware models.

mod net;

pub use net::{NetModel, NetPreset};
