//! The Layer-3 coordinator — FastMoE's system contribution.
//!
//! * [`DistMoeLayer`] (`dist_moe`) — the expert-parallel MoE layer: the
//!   Figure-2 two-phase exchange and the full manual backward chain,
//!   as thin orchestration over the pluggable
//!   [`Gate`](crate::moe::Gate) /
//!   [`ExpertShard`](crate::moe::ExpertShard) hierarchy.
//! * [`MoeLayerBuilder`] — assembles a layer from the `[moe]` config
//!   section (gate kind, capacity factor, noise std) and the artifact
//!   manifest's geometry.
//! * [`Trainer`] / [`DistTrainer`] / [`MoeLayerTrainer`] (`trainer`) —
//!   the fused single-graph training loop (Figure 7), its
//!   data-parallel multi-worker variant with tag-aware gradient
//!   synchronisation, and the expert-parallel layer trainer with
//!   per-step balance-loss metrics.
//! * [`GradSync`] — the heterogeneity-aware synchronisation module of
//!   §3.2: parameters tagged `world` / `data_parallel` are averaged over
//!   their groups, `none` (expert shards) are left alone in sharded
//!   mode.

mod dist_moe;
mod trainer;

pub use dist_moe::{DistMoeLayer, LayerGrads, MoeLayerBuilder, MoeLayerState};
pub use trainer::{DistTrainer, MoeLayerTrainer, MoeStepStats, StepStats, Trainer};

use crate::comm::Comm;
use crate::error::Result;
use crate::runtime::SyncTag;
use crate::tensor::TensorF32;

/// How `SyncTag::None` parameters are handled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpertMode {
    /// Expert params physically sharded per worker (stage mode): never
    /// synchronised — each shard already saw every token routed to it.
    Sharded,
    /// Expert params replicated on every worker (the DP-emulated fig-7
    /// path): averaged like `world`, which is mathematically identical
    /// to one global expert updated with all routed tokens.
    Replicated,
}

/// Tag-aware gradient synchroniser (the paper's customised DDP).
pub struct GradSync {
    /// Ranks of this worker's data-parallel group (must include self).
    pub dp_group: Vec<usize>,
    pub mode: ExpertMode,
}

impl GradSync {
    /// Everyone in one DP group (pure data/expert parallelism).
    pub fn world(size: usize, mode: ExpertMode) -> GradSync {
        GradSync { dp_group: (0..size).collect(), mode }
    }

    /// Average gradients according to their tags.
    ///
    /// * `world` — all-reduce over **all** ranks.
    /// * `data_parallel` — all-reduce over `dp_group`.
    /// * `none` — skipped (Sharded) or treated as `world` (Replicated).
    pub fn sync(
        &self,
        comm: &mut impl Comm,
        grads: &mut [TensorF32],
        tags: &[SyncTag],
    ) -> Result<()> {
        assert_eq!(grads.len(), tags.len());
        let world: Vec<usize> = (0..comm.size()).collect();
        for (g, &tag) in grads.iter_mut().zip(tags) {
            let group: Option<&[usize]> = match tag {
                SyncTag::World => Some(&world),
                SyncTag::DataParallel => Some(&self.dp_group),
                SyncTag::None => match self.mode {
                    ExpertMode::Sharded => None,
                    ExpertMode::Replicated => Some(&world),
                },
            };
            if let Some(group) = group {
                if group.len() > 1 {
                    if group.len() == comm.size() {
                        comm.all_reduce_sum(&mut g.data)?;
                    } else {
                        comm.all_reduce_sum_group(&mut g.data, group)?;
                    }
                    let scale = 1.0 / group.len() as f32;
                    for x in g.data.iter_mut() {
                        *x *= scale;
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_workers;
    use crate::runtime::SyncTag::*;

    #[test]
    fn grad_sync_respects_tags() {
        let got = run_workers(4, |mut h| {
            let r = h.rank() as f32;
            let mut grads = vec![
                TensorF32::from_vec(&[2], vec![r, r]).unwrap(), // world
                TensorF32::from_vec(&[2], vec![r, r]).unwrap(), // dp
                TensorF32::from_vec(&[2], vec![r, r]).unwrap(), // none
            ];
            let tags = [World, DataParallel, None];
            // dp groups: {0,1} and {2,3}
            let dp = if h.rank() < 2 { vec![0, 1] } else { vec![2, 3] };
            let sync = GradSync { dp_group: dp, mode: ExpertMode::Sharded };
            sync.sync(&mut h, &mut grads, &tags)?;
            Ok((h.rank(), grads))
        })
        .unwrap();
        for (rank, grads) in got {
            // world: mean(0,1,2,3) = 1.5 everywhere
            assert_eq!(grads[0].data, vec![1.5, 1.5], "rank {rank}");
            // dp: mean within the pair
            let want_dp = if rank < 2 { 0.5 } else { 2.5 };
            assert_eq!(grads[1].data, vec![want_dp, want_dp]);
            // none: untouched
            assert_eq!(grads[2].data, vec![rank as f32, rank as f32]);
        }
    }

    #[test]
    fn replicated_mode_averages_experts() {
        let got = run_workers(2, |mut h| {
            let r = h.rank() as f32;
            let mut grads = vec![TensorF32::from_vec(&[1], vec![r]).unwrap()];
            let sync = GradSync::world(2, ExpertMode::Replicated);
            sync.sync(&mut h, &mut grads, &[None])?;
            Ok(grads[0].data[0])
        })
        .unwrap();
        assert_eq!(got, vec![0.5, 0.5]);
    }
}
