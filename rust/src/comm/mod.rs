//! Collective communication substrate (the NCCL analog).
//!
//! Two backends share one [`Comm`] trait whose collectives are built
//! from point-to-point sends, exactly as the paper describes for its
//! global exchanges:
//!
//! * [`CommHandle`] — thread-backed channels (one process, used by the
//!   benches so timing isn't polluted by the kernel's socket stack);
//! * [`tcp::TcpGroup`] — real sockets over a full mesh, usable across
//!   processes and hosts (the paper's "multiple GPUs on multiple
//!   nodes" topology; `fastmoe dist-moe --backend tcp` spawns worker
//!   *processes*).
//!
//! The interface is two-level:
//!
//! * **Transport** — blocking `send`/`recv` plus the nonblocking
//!   [`Comm::isend`]/[`Comm::irecv`], which return [`CommRequest`]
//!   handles completed by [`Comm::wait`]/[`Comm::wait_all`],
//!   [`Comm::flush`] to push queued frames ahead of a long compute,
//!   and [`Comm::reclaim_spent`] to hand copied-out send buffers back
//!   for pooling.  The handles are what lets the MoE layer keep tokens
//!   on the wire while the expert shard computes (§4's overlap); the
//!   TCP backend's optional *progress engine*
//!   ([`tcp::TcpGroup::enable_progress`]) drains arrivals during that
//!   compute and completes `wait_all` in true arrival order.
//! * **Collectives** — [`Comm::all_to_all_v`] (the Figure-2 protocol:
//!   phase 1 exchanges per-peer *counts*, phase 2 the data) decomposes
//!   into per-peer requests via [`Comm::all_to_all_v_start`], so
//!   callers can consume arrivals as they land; plus
//!   [`Comm::all_reduce_sum`] (ring reduce-scatter + all-gather),
//!   its bucketed nonblocking decomposition
//!   [`Comm::all_reduce_start`] → [`PendingAllReduce`] (one in-flight
//!   ring per gradient bucket, completed in arrival order — the
//!   trainers' overlapped gradient sync), `all_gather`, `broadcast`,
//!   subgroup all-reduce, and `barrier` (dissemination, ⌈log₂ n⌉
//!   rounds; the legacy O(n²) empty all-to-all survives as
//!   [`Comm::barrier_a2a`]).
//!
//! Liveness: the thread backend's *receive paths* (`recv`,
//! `wait`/`wait_all`, and every collective built on them) are
//! death-aware — a worker whose closure fails drops its handle, and
//! peers blocked on a message from it surface [`Error::Comm`] instead
//! of hanging, so a crash mid-collective (e.g. mid-bucketed-sync) is
//! contained as a typed [`Error::Worker`] by [`run_workers`].  The one
//! exception is [`CommHandle`]'s OS-barrier fast path, which still
//! requires every rank to arrive.
//!
//! Topology: [`topology::Topology`] maps ranks onto nodes, and
//! [`Comm::split`] yields `{intra, inter}` [`topology::ProcessGroup`]
//! sub-handles with their own rank/size/tag namespaces on which every
//! collective above runs unchanged.  [`topology::TopoComm`] selects
//! the collective policy (`[comm] topology`): flat — bit-for-bit
//! today's behaviour — or hierarchical, which reroutes the all-to-all
//! through node leaders and builds the two-level tree reduction as an
//! alternate schedule under [`PendingAllReduce`], so the bucketed
//! overlapped gradient sync composes with it unchanged.
//!
//! Every handle records bytes sent per collective, which
//! [`crate::sim::NetModel`] converts into simulated wire time for the
//! Figure-6 scalability study.

pub mod tcp;
pub mod topology;

pub use topology::{
    topology_fallbacks, BoundGroup, CommGroups, ProcessGroup, TopoComm, Topology,
};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::metrics::Counters;

/// A tagged point-to-point message.
pub(crate) struct Msg {
    pub src: usize,
    pub tag: u64,
    pub data: Vec<f32>,
}

/// Handle to an in-flight point-to-point operation, returned by
/// [`Comm::isend`] / [`Comm::irecv`] and completed by [`Comm::wait`] /
/// [`Comm::wait_all`].
///
/// Both backends buffer sends, so a send request is complete the
/// moment it is issued; a receive request is a bookmark for a
/// `(src, tag)` match that `wait` claims from the wire (or the parked
/// out-of-order queue) when the caller is ready for the data.
#[derive(Debug)]
pub struct CommRequest {
    kind: ReqKind,
}

#[derive(Debug)]
enum ReqKind {
    /// isend already queued its payload; nothing left to wait for.
    SendDone,
    /// irecv bookmark, completed by a matching wait.
    Recv { src: usize, tag: u64 },
}

impl CommRequest {
    pub(crate) fn send_done() -> CommRequest {
        CommRequest { kind: ReqKind::SendDone }
    }

    pub(crate) fn recv_from(src: usize, tag: u64) -> CommRequest {
        CommRequest { kind: ReqKind::Recv { src, tag } }
    }

    /// The `(src, tag)` a receive request is still waiting on, if any.
    pub fn pending_recv(&self) -> Option<(usize, u64)> {
        match self.kind {
            ReqKind::SendDone => None,
            ReqKind::Recv { src, tag } => Some((src, tag)),
        }
    }
}

/// An [`Comm::all_to_all_v`] whose payload phase is still in flight:
/// one receive request per peer, which the caller can complete one at
/// a time ([`PendingA2a::wait_peer`]) as arrivals land — the hook the
/// pipelined MoE layer uses — or all at once ([`PendingA2a::finish`]).
pub struct PendingA2a {
    /// Outstanding per-peer receive requests (`None` = done or self).
    reqs: Vec<Option<CommRequest>>,
    /// Completed per-peer buffers (self's loopback buffer pre-filled).
    bufs: Vec<Option<Vec<f32>>>,
    /// Float counts announced in phase 1, validated on completion.
    expected: Vec<usize>,
}

impl PendingA2a {
    /// Floats peer `p` announced in the count phase.
    pub fn expected(&self, p: usize) -> usize {
        self.expected[p]
    }

    fn check(p: usize, want: usize, data: Vec<f32>) -> Result<Vec<f32>> {
        if data.len() != want {
            return Err(Error::Comm(format!(
                "a2a: peer {p} announced {want} floats, sent {}",
                data.len()
            )));
        }
        Ok(data)
    }

    /// Complete one peer's payload receive (self completes instantly).
    pub fn wait_peer<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        p: usize,
    ) -> Result<Vec<f32>> {
        if let Some(buf) = self.bufs[p].take() {
            return Ok(buf);
        }
        let req = self.reqs[p]
            .take()
            .ok_or_else(|| Error::Comm(format!("a2a: peer {p} already consumed")))?;
        let data = comm.wait(req)?.unwrap_or_default();
        Self::check(p, self.expected[p], data)
    }

    /// Complete every outstanding receive (in arrival order where the
    /// backend supports it) and return the buffers indexed by peer.
    ///
    /// Errors if any peer was already drained via
    /// [`PendingA2a::wait_peer`] — its data was handed out and cannot
    /// appear in the result; drain the rest peer-by-peer instead.
    pub fn finish<C: Comm + ?Sized>(mut self, comm: &mut C) -> Result<Vec<Vec<f32>>> {
        let mut peers = Vec::new();
        let mut reqs = Vec::new();
        for (p, slot) in self.reqs.iter_mut().enumerate() {
            match slot.take() {
                Some(req) => {
                    peers.push(p);
                    reqs.push(req);
                }
                None if self.bufs[p].is_none() => {
                    return Err(Error::Comm(format!(
                        "a2a: peer {p} already consumed via wait_peer; \
                         finish cannot return its buffer"
                    )));
                }
                None => {}
            }
        }
        let datas = comm.wait_all(reqs)?;
        for (&p, data) in peers.iter().zip(datas) {
            self.bufs[p] =
                Some(Self::check(p, self.expected[p], data.unwrap_or_default())?);
        }
        Ok(self
            .bufs
            .into_iter()
            .map(|b| b.unwrap_or_default())
            .collect())
    }
}

/// Float range of ring chunk `i` for a buffer of `len` floats across
/// `n` ranks — the exact split [`Comm::all_reduce_sum`] uses, so the
/// bucketed nonblocking reduction reproduces its addition order (and
/// therefore its bits).
fn ring_chunk(len: usize, n: usize, i: usize) -> std::ops::Range<usize> {
    let per = len / n;
    let s = i * per;
    let e = if i + 1 == n { len } else { s + per };
    s..e
}

/// What one ring round of a bucket does: `(send_idx, recv_idx, tag,
/// is_gather)` for completed-round count `round` — rounds `0..n-1` are
/// the reduce-scatter, `n-1..2(n-1)` the all-gather, with the same tag
/// scheme as the blocking ring.
fn ring_round(n: usize, rank: usize, round: usize, seq: u64) -> (usize, usize, u64, bool) {
    if round < n - 1 {
        let send_idx = (rank + n - round) % n;
        let recv_idx = (rank + n - round - 1) % n;
        (send_idx, recv_idx, (seq << 8) | (2 + round as u64), false)
    } else {
        let s = round - (n - 1);
        let send_idx = (rank + 1 + n - s) % n;
        let recv_idx = (rank + n - s) % n;
        (send_idx, recv_idx, (seq << 8) | (64 + s as u64), true)
    }
}

/// One bucket's in-flight reduction.  Only the current round is ever
/// on the wire, because round `r+1` sends the very chunk round `r`
/// just updated — but across *buckets* every reduction progresses
/// concurrently, which is where the overlap comes from.
struct ArBucket {
    /// The bucket's working buffer.  Hierarchical *members* ship their
    /// buffer to the leader at start time and hold an empty `buf`
    /// until the broadcast replaces it — `want` keeps the length the
    /// result must have.
    buf: Vec<f32>,
    /// Float count of the reduced result (== the caller's buffer).
    want: usize,
    seq: u64,
    /// Completed rounds — flat ring: `0..2(n-1)`; hierarchical leader:
    /// `0..(L-1) + 2(nodes-1)` (gathers then the leader ring);
    /// hierarchical member: `0..1` (the broadcast); zero:
    /// `0..2(l-1) + 2(nodes-1)` (intra gather, rail ring scatter,
    /// [pause], rail ring gather, intra exchange).
    round: usize,
    /// Outstanding receive of the current round.
    req: Option<CommRequest>,
    /// Zero schedule only: this rank's own slice contribution, saved
    /// when the intra gather's first arrival (local source 0) must
    /// restart the fold so additions stay in ascending local order.
    own: Vec<f32>,
    /// Zero schedule only: reduce-scatter complete, waiting for the
    /// caller's shard-local optimiser before the gather resumes.
    paused: bool,
}

/// Which reduction schedule a [`PendingAllReduce`]'s buckets follow.
///
/// `Hier` is the two-level tree ([`topology::TopoComm`]'s policy):
/// members send their buffers to the node leader, the leader adds them
/// in **ascending local-rank order**, the leaders run the ordinary
/// ring ([`ring_round`]/[`ring_chunk`] over the node count) on the
/// node sums, and each leader broadcasts the result to its members.
/// That reduction order is fixed and identical between the blocking
/// and bucketed paths (hier-blocking == hier-bucketed bitwise by
/// construction); it differs from the flat ring's order, so hier vs
/// flat agree bitwise only where f32 addition is associative for the
/// data (pinned on integer-valued payloads by the conformance matrix).
/// `Zero` is the ZeRO-sharded schedule (reduce-scatter → shard-local
/// optimiser pause → all-gather), parameterised by a [`Topology`] whose
/// degenerate flat form (`local_size == 1`, every rank its own node) is
/// the plain ring split over all ranks.  Under a hierarchical topology
/// it is *rail-aware*: each local rank first aggregates its slice
/// within the node (ascending local-rank order, the tree's fold), then
/// rings across nodes with its peer rank (same local index) — all
/// `local_size` NICs carry traffic instead of the tree's leader alone.
/// The nested chunking (`ring_chunk` over nodes, then over local ranks
/// within each node chunk) preserves the flat ring's / hier tree's
/// per-element addition order, so zero partials are bit-identical to
/// the matching replicated schedule by construction.
#[derive(Clone, Copy, Debug)]
enum ArSched {
    Flat,
    Hier(Topology),
    Zero(Topology),
}

/// Gather tag code of the hier schedule (member buffer → leader).
const AR_TAG_GATHER: u64 = 130;
/// Broadcast tag code of the hier schedule (leader result → member).
const AR_TAG_BCAST: u64 = 131;
/// Intra-node slice gather tag code of the zero schedule.
const AR_TAG_ZINTRA: u64 = 132;
/// Intra-node updated-slice exchange tag code of the zero schedule.
const AR_TAG_ZXCHG: u64 = 133;

/// Absolute float ranges of rail sub-slice `loc` within every node
/// chunk of a `len`-float buffer — the pieces rank `(node, loc)`
/// aggregates in the zero schedule's intra phases.
fn zero_slice_pieces(
    len: usize,
    nodes: usize,
    l: usize,
    loc: usize,
) -> Vec<std::ops::Range<usize>> {
    (0..nodes)
        .map(|j| {
            let c = ring_chunk(len, nodes, j);
            let s = ring_chunk(c.len(), l, loc);
            c.start + s.start..c.start + s.end
        })
        .collect()
}

/// The contiguous shard of a `len`-float bucket that `rank` owns (and
/// shard-updates) under the zero schedule: rail sub-slice `local_of`
/// of node chunk `(node+1) % nodes` — the chunk the inter-node ring
/// leaves fully reduced on this rank's node.
pub(crate) fn zero_shard_range(
    topo: &Topology,
    rank: usize,
    len: usize,
) -> std::ops::Range<usize> {
    let nodes = topo.nodes();
    let c = ring_chunk(len, nodes, (topo.node_of(rank) + 1) % nodes);
    let s = ring_chunk(c.len(), topo.local_size(), topo.local_of(rank));
    c.start + s.start..c.start + s.end
}

/// A bucketed [`Comm::all_reduce_sum`] whose rings are still in
/// flight, returned by [`Comm::all_reduce_start`].  Each bucket is an
/// independent ring reduction (reduce-scatter + all-gather, the same
/// chunking and addition order as the blocking ring, so per-bucket
/// results are **bit-identical** to [`Comm::all_reduce_sum`] on the
/// same buffer).  Complete one bucket at a time with
/// [`PendingAllReduce::wait_bucket`] — the hook that lets a trainer
/// run the host optimiser on already-synced buckets while later ones
/// are still on the wire — or all at once with
/// [`PendingAllReduce::finish`], which drives every ring concurrently
/// and consumes round arrivals in arrival order where the backend
/// supports it.
pub struct PendingAllReduce {
    n: usize,
    rank: usize,
    /// The schedule every bucket follows (flat ring, or the two-level
    /// tree of a hierarchical [`Topology`]).
    sched: ArSched,
    /// Per-bucket ring state (`None` once reduced or handed out).
    buckets: Vec<Option<ArBucket>>,
    /// Reduced buffers not yet claimed by the caller.
    done: Vec<Option<Vec<f32>>>,
}

impl PendingAllReduce {
    /// Number of buckets this reduction was started with.
    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// Buckets whose rings have not completed yet.
    pub fn pending(&self) -> usize {
        self.buckets.iter().filter(|b| b.is_some()).count()
    }

    /// Queue bucket `i`'s current round under the schedule: bookmark
    /// the next arrival (and isend whatever that round owes the wire).
    fn post_round<C: Comm + ?Sized>(&mut self, comm: &mut C, i: usize) -> Result<()> {
        match self.sched {
            ArSched::Flat => self.post_round_flat(comm, i),
            ArSched::Hier(topo) => self.post_round_hier(comm, i, topo),
            ArSched::Zero(topo) => self.post_round_zero(comm, i, topo),
        }
    }

    /// Apply one arrived round to bucket `i` and post its next round,
    /// if any.  The spent round buffer is offered to the backend's
    /// receive freelist.
    fn apply_round<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        i: usize,
        data: Vec<f32>,
    ) -> Result<()> {
        match self.sched {
            ArSched::Flat => self.apply_round_flat(comm, i, data),
            ArSched::Hier(topo) => self.apply_round_hier(comm, i, topo, data),
            ArSched::Zero(topo) => self.apply_round_zero(comm, i, topo, data),
        }
    }

    /// Flat ring: isend the outgoing chunk to the ring successor,
    /// bookmark the matching arrival.
    fn post_round_flat<C: Comm + ?Sized>(&mut self, comm: &mut C, i: usize) -> Result<()> {
        let n = self.n;
        let next = (self.rank + 1) % n;
        let prev = (self.rank + n - 1) % n;
        let b = self.buckets[i].as_mut().expect("bucket active");
        let (send_idx, _, tag, _) = ring_round(n, self.rank, b.round, b.seq);
        let payload = b.buf[ring_chunk(b.buf.len(), n, send_idx)].to_vec();
        comm.isend(next, tag, payload)?;
        b.req = Some(comm.irecv(prev, tag)?);
        Ok(())
    }

    /// Flat ring: add on the scatter half, copy on the gather half.
    fn apply_round_flat<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        i: usize,
        data: Vec<f32>,
    ) -> Result<()> {
        let n = self.n;
        let b = self.buckets[i].as_mut().expect("bucket active");
        let (_, recv_idx, _, gather) = ring_round(n, self.rank, b.round, b.seq);
        let range = ring_chunk(b.buf.len(), n, recv_idx);
        if data.len() != range.len() {
            return Err(Error::Comm(format!(
                "bucketed all-reduce: round payload {} floats, chunk is {}",
                data.len(),
                range.len()
            )));
        }
        if gather {
            b.buf[range].copy_from_slice(&data);
        } else {
            for (x, y) in b.buf[range].iter_mut().zip(&data) {
                *x += y;
            }
        }
        let _ = comm.recycle(vec![data]);
        b.round += 1;
        if b.round == 2 * (n - 1) {
            let buf = self.buckets[i].take().expect("bucket active").buf;
            self.done[i] = Some(buf);
        } else {
            self.post_round(comm, i)?;
        }
        Ok(())
    }

    /// Two-level tree, posting side.  Members have exactly one wait
    /// (the leader's broadcast; their contribution departed at start
    /// time).  Leaders first gather members in ascending local-rank
    /// order, then run the ordinary ring over the node leaders.
    fn post_round_hier<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        i: usize,
        topo: Topology,
    ) -> Result<()> {
        let rank = self.rank;
        let b = self.buckets[i].as_mut().expect("bucket active");
        if !topo.is_leader(rank) {
            let leader = topo.leader_of(topo.node_of(rank));
            b.req = Some(comm.irecv(leader, (b.seq << 8) | AR_TAG_BCAST)?);
            return Ok(());
        }
        let l_sz = topo.local_size();
        if b.round < l_sz - 1 {
            // gather member `round + 1` — waited one at a time, so the
            // leader's additions happen in ascending local-rank order
            b.req = Some(comm.irecv(rank + b.round + 1, (b.seq << 8) | AR_TAG_GATHER)?);
            return Ok(());
        }
        // leader ring over the node sums (the flat machinery, with the
        // node index as the ring rank)
        let nodes = topo.nodes();
        let s = topo.node_of(rank);
        let rr = b.round - (l_sz - 1);
        let (send_idx, _, tag, _) = ring_round(nodes, s, rr, b.seq);
        let payload = b.buf[ring_chunk(b.buf.len(), nodes, send_idx)].to_vec();
        comm.isend(topo.leader_of((s + 1) % nodes), tag, payload)?;
        b.req = Some(comm.irecv(topo.leader_of((s + nodes - 1) % nodes), tag)?);
        Ok(())
    }

    /// Two-level tree, arrival side.
    fn apply_round_hier<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        i: usize,
        topo: Topology,
        data: Vec<f32>,
    ) -> Result<()> {
        let rank = self.rank;
        let l_sz = topo.local_size();
        let nodes = topo.nodes();
        let b = self.buckets[i].as_mut().expect("bucket active");
        if !topo.is_leader(rank) {
            // the broadcast: the reduced buffer IS the result (the
            // member's own buffer departed to the leader at start time
            // — no copy was kept)
            if data.len() != b.want {
                return Err(Error::Comm(format!(
                    "hier all-reduce: broadcast payload {} floats, bucket is {}",
                    data.len(),
                    b.want
                )));
            }
            b.buf = data;
            let buf = self.buckets[i].take().expect("bucket active").buf;
            self.done[i] = Some(buf);
            return Ok(());
        }
        if b.round < l_sz - 1 {
            if data.len() != b.buf.len() {
                return Err(Error::Comm(format!(
                    "hier all-reduce: member buffer {} floats, bucket is {}",
                    data.len(),
                    b.buf.len()
                )));
            }
            for (x, y) in b.buf.iter_mut().zip(&data) {
                *x += y;
            }
            let _ = comm.recycle(vec![data]);
            b.round += 1;
            if b.round == l_sz - 1 && nodes == 1 {
                return self.finish_leader(comm, i, topo);
            }
            return self.post_round(comm, i);
        }
        let s = topo.node_of(rank);
        let rr = b.round - (l_sz - 1);
        let (_, recv_idx, _, gather) = ring_round(nodes, s, rr, b.seq);
        let range = ring_chunk(b.buf.len(), nodes, recv_idx);
        if data.len() != range.len() {
            return Err(Error::Comm(format!(
                "hier all-reduce: ring payload {} floats, chunk is {}",
                data.len(),
                range.len()
            )));
        }
        if gather {
            b.buf[range].copy_from_slice(&data);
        } else {
            for (x, y) in b.buf[range].iter_mut().zip(&data) {
                *x += y;
            }
        }
        let _ = comm.recycle(vec![data]);
        b.round += 1;
        if b.round == (l_sz - 1) + 2 * (nodes - 1) {
            return self.finish_leader(comm, i, topo);
        }
        self.post_round(comm, i)
    }

    /// Leader completion: broadcast the reduced bucket to the node's
    /// members (flushed — they are blocked on exactly these frames)
    /// and retire the bucket.
    fn finish_leader<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        i: usize,
        topo: Topology,
    ) -> Result<()> {
        let rank = self.rank;
        let b = self.buckets[i].take().expect("bucket active");
        for m in 1..topo.local_size() {
            comm.isend(rank + m, (b.seq << 8) | AR_TAG_BCAST, b.buf.clone())?;
        }
        comm.flush()?;
        self.done[i] = Some(b.buf);
        Ok(())
    }

    /// Zero schedule, posting side.  Four phases of `round`, with
    /// `intra = l-1` and `inter = nodes-1`:
    ///
    /// * `0..intra` — intra-node slice gather: every rank's foreign
    ///   slices depart to their local owners at round 0; each round
    ///   bookmarks one local source (ascending local-rank order, so the
    ///   owner's fold matches the hier tree's leader fold).
    /// * `intra..intra+inter` — rail ring reduce-scatter: the ordinary
    ///   [`ring_round`] geometry over *node* indices, run between peer
    ///   ranks (same local index) on each rail, restricted to this
    ///   rail's sub-slice of each node chunk.
    /// * **pause** — reduce-scatter complete; the caller runs its
    ///   shard-local optimiser via
    ///   [`PendingAllReduce::wait_bucket_shard`].
    /// * `..intra+2*inter` — rail ring all-gather of the updated shards.
    /// * `..2*intra+2*inter` — intra-node exchange: every rank's
    ///   updated slice departs to all local peers at phase entry; each
    ///   round bookmarks one local source's slice.
    fn post_round_zero<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        i: usize,
        topo: Topology,
    ) -> Result<()> {
        let rank = self.rank;
        let nodes = topo.nodes();
        let l = topo.local_size();
        let node = topo.node_of(rank);
        let loc = topo.local_of(rank);
        let (intra, inter) = (l - 1, nodes - 1);
        let b = self.buckets[i].as_mut().expect("bucket active");
        let len = b.buf.len();
        let r = b.round;
        if r < intra {
            if r == 0 {
                for m in 0..l {
                    if m == loc {
                        continue;
                    }
                    let mut payload = Vec::new();
                    for p in zero_slice_pieces(len, nodes, l, m) {
                        payload.extend_from_slice(&b.buf[p]);
                    }
                    comm.isend(node * l + m, (b.seq << 8) | AR_TAG_ZINTRA, payload)?;
                }
            }
            let src = if r < loc { r } else { r + 1 };
            b.req = Some(comm.irecv(node * l + src, (b.seq << 8) | AR_TAG_ZINTRA)?);
        } else if r < intra + 2 * inter {
            // both ring phases: ring_round over node indices (the zero
            // ring's rounds line up 1:1 with the flat ring's)
            let (send_idx, _, tag, _) = ring_round(nodes, node, r - intra, b.seq);
            let c = ring_chunk(len, nodes, send_idx);
            let s = ring_chunk(c.len(), l, loc);
            let payload = b.buf[c.start + s.start..c.start + s.end].to_vec();
            comm.isend(((node + 1) % nodes) * l + loc, tag, payload)?;
            b.req =
                Some(comm.irecv(((node + nodes - 1) % nodes) * l + loc, tag)?);
        } else {
            let rd = r - intra - 2 * inter;
            if rd == 0 {
                let mut payload = Vec::new();
                for p in zero_slice_pieces(len, nodes, l, loc) {
                    payload.extend_from_slice(&b.buf[p]);
                }
                for m in 0..l {
                    if m != loc {
                        comm.isend(
                            node * l + m,
                            (b.seq << 8) | AR_TAG_ZXCHG,
                            payload.clone(),
                        )?;
                    }
                }
            }
            let src = if rd < loc { rd } else { rd + 1 };
            b.req = Some(comm.irecv(node * l + src, (b.seq << 8) | AR_TAG_ZXCHG)?);
        }
        Ok(())
    }

    /// Zero schedule, arrival side.  Mirrors [`Self::post_round_zero`]'s
    /// phases; sets `paused` (instead of posting) once the
    /// reduce-scatter half completes, and retires the bucket after the
    /// final intra exchange.
    fn apply_round_zero<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        i: usize,
        topo: Topology,
        data: Vec<f32>,
    ) -> Result<()> {
        let rank = self.rank;
        let nodes = topo.nodes();
        let l = topo.local_size();
        let node = topo.node_of(rank);
        let loc = topo.local_of(rank);
        let (intra, inter) = (l - 1, nodes - 1);
        let b = self.buckets[i].as_mut().expect("bucket active");
        let len = b.buf.len();
        let r = b.round;
        let add_pieces = |buf: &mut [f32], pieces: &[std::ops::Range<usize>], src: &[f32]| {
            let mut off = 0;
            for p in pieces {
                for (x, y) in buf[p.clone()].iter_mut().zip(&src[off..off + p.len()]) {
                    *x += *y;
                }
                off += p.len();
            }
        };
        let copy_pieces = |buf: &mut [f32], pieces: &[std::ops::Range<usize>], src: &[f32]| {
            let mut off = 0;
            for p in pieces {
                buf[p.clone()].copy_from_slice(&src[off..off + p.len()]);
                off += p.len();
            }
        };
        if r < intra {
            let pieces = zero_slice_pieces(len, nodes, l, loc);
            let want: usize = pieces.iter().map(|p| p.len()).sum();
            if data.len() != want {
                return Err(Error::Comm(format!(
                    "zero all-reduce: intra payload {} floats, slice is {want}",
                    data.len()
                )));
            }
            if loc > 0 && r == 0 {
                // local source 0 precedes this rank in the fold: save
                // our own contribution and restart from the wire data
                b.own = pieces
                    .iter()
                    .flat_map(|p| b.buf[p.clone()].iter().copied())
                    .collect();
                copy_pieces(&mut b.buf, &pieces, &data);
            } else {
                if r == loc && loc > 0 {
                    // our own contribution folds in at position `loc`
                    let own = std::mem::take(&mut b.own);
                    add_pieces(&mut b.buf, &pieces, &own);
                }
                add_pieces(&mut b.buf, &pieces, &data);
            }
            let _ = comm.recycle(vec![data]);
            b.round += 1;
            if b.round == intra && loc + 1 == l && loc > 0 {
                // this rank is the last local source: fold own last
                let own = std::mem::take(&mut b.own);
                add_pieces(&mut b.buf, &pieces, &own);
            }
            if b.round == intra + inter {
                // single node: the reduce-scatter is already complete
                b.paused = true;
                return Ok(());
            }
            return self.post_round(comm, i);
        }
        if r < intra + 2 * inter {
            let (_, recv_idx, _, gather) = ring_round(nodes, node, r - intra, b.seq);
            let c = ring_chunk(len, nodes, recv_idx);
            let s = ring_chunk(c.len(), l, loc);
            let range = c.start + s.start..c.start + s.end;
            if data.len() != range.len() {
                return Err(Error::Comm(format!(
                    "zero all-reduce: ring payload {} floats, sub-chunk is {}",
                    data.len(),
                    range.len()
                )));
            }
            if gather {
                b.buf[range].copy_from_slice(&data);
            } else {
                for (x, y) in b.buf[range].iter_mut().zip(&data) {
                    *x += y;
                }
            }
        } else {
            let rd = r - intra - 2 * inter;
            let src = if rd < loc { rd } else { rd + 1 };
            let pieces = zero_slice_pieces(len, nodes, l, src);
            let want: usize = pieces.iter().map(|p| p.len()).sum();
            if data.len() != want {
                return Err(Error::Comm(format!(
                    "zero all-reduce: exchange payload {} floats, slice is {want}",
                    data.len()
                )));
            }
            copy_pieces(&mut b.buf, &pieces, &data);
        }
        let _ = comm.recycle(vec![data]);
        b.round += 1;
        if b.round == intra + inter {
            b.paused = true;
            return Ok(());
        }
        if b.round == 2 * (intra + inter) {
            let buf = self.buckets[i].take().expect("bucket active").buf;
            self.done[i] = Some(buf);
            return Ok(());
        }
        self.post_round(comm, i)
    }

    /// Clear bucket `i`'s shard pause, if set, and post its gather
    /// phase.  Returns whether a resume happened.
    fn resume_if_paused<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        i: usize,
    ) -> Result<bool> {
        let resumed = match self.buckets[i].as_mut() {
            Some(b) if b.paused => {
                b.paused = false;
                true
            }
            _ => false,
        };
        if resumed {
            self.post_round(comm, i)?;
            comm.flush()?;
        }
        Ok(resumed)
    }

    /// Drive a zero-scheduled bucket to its shard point — reduce-
    /// scatter complete, this rank's owned shard fully reduced — and
    /// return `(range, buf)`: the shard's float range within the bucket
    /// and the bucket's whole working buffer (only `buf[range]` holds
    /// reduced data; the rest is partial sums in transit).  The caller
    /// updates `buf[range]` in place (scale, shard-local optimiser,
    /// write the *updated params* back into the range) and then calls
    /// [`PendingAllReduce::gather_bucket`], which all-gathers exactly
    /// those ranges from every rank.  Same cross-rank ordering rule as
    /// [`PendingAllReduce::wait_bucket`].  Errors on non-zero
    /// schedules; under a single-rank world the shard is the entire
    /// (already final) buffer.
    pub fn wait_bucket_shard<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        i: usize,
    ) -> Result<(std::ops::Range<usize>, &mut [f32])> {
        let ArSched::Zero(topo) = self.sched else {
            return Err(Error::Comm(
                "wait_bucket_shard: not a zero-sharded reduction".into(),
            ));
        };
        if self.done[i].is_some() {
            // single-rank short-circuit: the bucket went straight to
            // done and this rank owns all of it
            let buf = self.done[i].as_mut().expect("done");
            let len = buf.len();
            return Ok((0..len, buf.as_mut_slice()));
        }
        if self.buckets[i].is_none() {
            return Err(Error::Comm(format!(
                "all-reduce bucket {i} already consumed"
            )));
        }
        while !self.buckets[i].as_ref().expect("bucket active").paused {
            let Some(req) = self.buckets[i].as_mut().expect("bucket active").req.take()
            else {
                return Err(Error::Comm(format!(
                    "all-reduce bucket {i}: ring interrupted by an earlier error"
                )));
            };
            let data = comm.wait(req)?.unwrap_or_default();
            self.apply_round(comm, i, data)?;
        }
        let b = self.buckets[i].as_mut().expect("bucket active");
        let range = zero_shard_range(&topo, self.rank, b.buf.len());
        Ok((range, b.buf.as_mut_slice()))
    }

    /// Resume a zero-scheduled bucket past its shard pause: all-gather
    /// every rank's updated shard and return the full buffer.  Must
    /// follow [`PendingAllReduce::wait_bucket_shard`] on the same
    /// bucket (on every rank, in the same shared bucket order).
    pub fn gather_bucket<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        i: usize,
    ) -> Result<Vec<f32>> {
        if !matches!(self.sched, ArSched::Zero(_)) {
            return Err(Error::Comm(
                "gather_bucket: not a zero-sharded reduction".into(),
            ));
        }
        if let Some(buf) = self.done[i].take() {
            return Ok(buf);
        }
        if self.buckets[i].is_none() {
            return Err(Error::Comm(format!(
                "all-reduce bucket {i} already consumed"
            )));
        }
        self.resume_if_paused(comm, i)?;
        while self.buckets[i].is_some() {
            let Some(req) = self.buckets[i].as_mut().expect("bucket active").req.take()
            else {
                return Err(Error::Comm(format!(
                    "all-reduce bucket {i}: ring interrupted by an earlier error"
                )));
            };
            let data = comm.wait(req)?.unwrap_or_default();
            self.apply_round(comm, i, data)?;
        }
        Ok(self.done[i].take().expect("bucket completed"))
    }

    /// Drive bucket `i`'s ring to completion and return the reduced
    /// buffer.  Other buckets' in-flight rounds stay on the wire (their
    /// out-of-order arrivals park in the backend).
    ///
    /// Like any collective, the completion sequence is wire protocol:
    /// ring rounds only advance inside a rank's wait calls, so **every
    /// rank must complete buckets in the same order** — a rank waiting
    /// on bucket 0 while its neighbour waits on bucket 1 leaves both
    /// rings without their next round and deadlocks.  The same rule
    /// covers mixing styles: ranks must either all drain bucket-by-
    /// bucket in one shared order, or all call
    /// [`PendingAllReduce::finish`] (whose sweeps complete one round of
    /// *every* bucket before the next) — one rank in `finish` against a
    /// neighbour in `wait_bucket` deadlocks just the same.  The
    /// trainers complete buckets in shared plan order.
    pub fn wait_bucket<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        i: usize,
    ) -> Result<Vec<f32>> {
        if let Some(buf) = self.done[i].take() {
            return Ok(buf);
        }
        if self.buckets[i].is_none() {
            return Err(Error::Comm(format!(
                "all-reduce bucket {i} already consumed"
            )));
        }
        while self.buckets[i].is_some() {
            // a zero-scheduled bucket driven as a plain all-reduce:
            // skip the shard pause (no optimiser step, the gathered
            // result is the ordinary reduced sum)
            if self.resume_if_paused(comm, i)? {
                continue;
            }
            let Some(req) = self.buckets[i].as_mut().unwrap().req.take() else {
                // an earlier wait errored after taking this round's
                // request; the ring cannot be resumed coherently
                return Err(Error::Comm(format!(
                    "all-reduce bucket {i}: ring interrupted by an earlier error"
                )));
            };
            let data = comm.wait(req)?.unwrap_or_default();
            self.apply_round(comm, i, data)?;
        }
        Ok(self.done[i].take().expect("bucket completed"))
    }

    /// Complete every bucket and return the reduced buffers in bucket
    /// order.  All rings progress concurrently: each sweep waits on one
    /// outstanding round per active bucket (arrival order where the
    /// backend supports it) and immediately posts that bucket's next
    /// round.  Subject to the same cross-rank ordering rule as
    /// [`PendingAllReduce::wait_bucket`]: every rank must drive its
    /// buckets the same way.  Errors if a bucket was already drained
    /// via `wait_bucket`, or if an earlier wait error left a ring
    /// without its posted round.
    pub fn finish<C: Comm + ?Sized>(mut self, comm: &mut C) -> Result<Vec<Vec<f32>>> {
        loop {
            // zero-scheduled buckets driven as a plain all-reduce skip
            // their shard pause (see `wait_bucket`)
            for i in 0..self.buckets.len() {
                self.resume_if_paused(comm, i)?;
            }
            let mut idx = Vec::new();
            let mut reqs = Vec::new();
            for (i, slot) in self.buckets.iter_mut().enumerate() {
                if let Some(b) = slot {
                    let Some(req) = b.req.take() else {
                        return Err(Error::Comm(format!(
                            "all-reduce bucket {i}: ring interrupted by an \
                             earlier error"
                        )));
                    };
                    idx.push(i);
                    reqs.push(req);
                }
            }
            if idx.is_empty() {
                break;
            }
            let datas = comm.wait_all(reqs)?;
            for (i, data) in idx.into_iter().zip(datas) {
                self.apply_round(comm, i, data.unwrap_or_default())?;
            }
        }
        let mut out = Vec::with_capacity(self.done.len());
        for (i, slot) in self.done.iter_mut().enumerate() {
            out.push(slot.take().ok_or_else(|| {
                Error::Comm(format!(
                    "all-reduce bucket {i} already consumed via wait_bucket; \
                     finish cannot return its buffer"
                ))
            })?);
        }
        Ok(out)
    }
}

/// Start a bucketed nonblocking all-reduce over the two-level tree of
/// a hierarchical [`Topology`] — [`TopoComm`]'s alternate schedule
/// under [`PendingAllReduce`], completed by the very same
/// `wait_bucket`/`finish` calls (and therefore composing with
/// `GradSync`'s bucketed overlap unchanged).  At start time every
/// member's contribution is on the wire toward its node leader and
/// every wait is posted, mirroring the flat path's round-0 guarantee.
pub(crate) fn all_reduce_start_hier<C: Comm + ?Sized>(
    comm: &mut C,
    topo: &Topology,
    bufs: Vec<Vec<f32>>,
) -> Result<PendingAllReduce> {
    let n = comm.size();
    let rank = comm.rank();
    debug_assert!(topo.world() == n && topo.hierarchical());
    let mut pending = PendingAllReduce {
        n,
        rank,
        sched: ArSched::Hier(*topo),
        buckets: (0..bufs.len()).map(|_| None).collect(),
        done: (0..bufs.len()).map(|_| None).collect(),
    };
    if n == 1 {
        for (slot, buf) in pending.done.iter_mut().zip(bufs) {
            *slot = Some(buf);
        }
        return Ok(pending);
    }
    comm.counters().add("allreduce_buckets", pending.buckets.len() as u64);
    comm.counters().add("allreduce_hier_calls", 1);
    for (i, buf) in bufs.into_iter().enumerate() {
        let seq = comm.next_seq();
        let want = buf.len();
        comm.counters().add("allreduce_calls", 1);
        // this rank's actual egress under the tree schedule: a member
        // ships its buffer up once; a leader rings 2(nodes−1)/nodes of
        // it with the other leaders and broadcasts it to each member
        let sent = if topo.is_leader(rank) {
            let nodes = topo.nodes();
            let ring = if nodes > 1 { want * 4 * 2 * (nodes - 1) / nodes } else { 0 };
            ring + (topo.local_size() - 1) * want * 4
        } else {
            want * 4
        };
        comm.counters().add("allreduce_bytes", sent as u64);
        let buf = if topo.is_leader(rank) {
            buf
        } else {
            // the member's contribution departs now — moved, not
            // cloned; the broadcast will hand back the result buffer
            let leader = topo.leader_of(topo.node_of(rank));
            comm.isend(leader, (seq << 8) | AR_TAG_GATHER, buf)?;
            Vec::new()
        };
        pending.buckets[i] = Some(ArBucket {
            buf,
            want,
            seq,
            round: 0,
            req: None,
            own: Vec::new(),
            paused: false,
        });
        pending.post_round(comm, i)?;
    }
    comm.flush()?;
    Ok(pending)
}

/// Start a bucketed ZeRO-sharded reduction ([`ArSched::Zero`]):
/// reduce-scatter each bucket so every rank owns a contiguous fully-
/// reduced shard ([`zero_shard_range`]), pause for the caller's
/// shard-local optimiser ([`PendingAllReduce::wait_bucket_shard`]),
/// then all-gather the updated buffers
/// ([`PendingAllReduce::gather_bucket`]).
///
/// `topo` picks the geometry.  A flat topology (`local_size == 1`) is
/// the plain ring split over all ranks — partials bit-identical to
/// [`Comm::all_reduce_sum`] by shared-helper construction.  A
/// hierarchical topology is *rail-aware*: each local rank aggregates
/// its slice within the node and rings across nodes with its peer
/// rank, spreading the inter-node traffic over all `local_size` NICs
/// where the tree funnels it through the leader — with partials
/// bit-identical to the hier tree's (same fold order).
pub(crate) fn all_reduce_zero_start<C: Comm + ?Sized>(
    comm: &mut C,
    topo: &Topology,
    bufs: Vec<Vec<f32>>,
) -> Result<PendingAllReduce> {
    let n = comm.size();
    let rank = comm.rank();
    debug_assert_eq!(topo.world(), n);
    let mut pending = PendingAllReduce {
        n,
        rank,
        sched: ArSched::Zero(*topo),
        buckets: (0..bufs.len()).map(|_| None).collect(),
        done: (0..bufs.len()).map(|_| None).collect(),
    };
    if n == 1 {
        for (slot, buf) in pending.done.iter_mut().zip(bufs) {
            *slot = Some(buf);
        }
        return Ok(pending);
    }
    let nodes = topo.nodes();
    let l = topo.local_size();
    comm.counters().add("allreduce_buckets", pending.buckets.len() as u64);
    comm.counters().add("allreduce_zero_calls", 1);
    for (i, buf) in bufs.into_iter().enumerate() {
        let seq = comm.next_seq();
        let want = buf.len();
        comm.counters().add("allreduce_calls", 1);
        // egress: the intra gather + exchange each ship (l-1)/l of the
        // buffer on the local links, the rail ring ships
        // 2(nodes-1)/nodes of this rank's 1/l slice across nodes
        let intra = if l > 1 { 2 * want * 4 * (l - 1) / l } else { 0 };
        let ring = if nodes > 1 { (want / l) * 4 * 2 * (nodes - 1) / nodes } else { 0 };
        comm.counters().add("allreduce_bytes", (intra + ring) as u64);
        pending.buckets[i] = Some(ArBucket {
            buf,
            want,
            seq,
            round: 0,
            req: None,
            own: Vec::new(),
            paused: false,
        });
        pending.post_round(comm, i)?;
    }
    comm.flush()?;
    Ok(pending)
}

/// The process-group interface: p2p primitives required, collectives
/// provided (identical across backends).
pub trait Comm {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;
    fn counters(&mut self) -> &mut Counters;

    /// Send `data` to `dst` under `tag` (non-blocking or buffered).
    fn send(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<()>;

    /// Blocking receive of the message with (src, tag); out-of-order
    /// arrivals must be parked, not dropped.
    fn recv(&mut self, src: usize, tag: u64) -> Result<Vec<f32>>;

    /// Monotonic per-handle collective sequence number (tag namespace).
    fn next_seq(&mut self) -> u64;

    /// Nonblocking send: queue `data` for `dst` and return a request
    /// handle immediately.  The default delegates to the buffered
    /// blocking `send`; backends override to defer flushing.
    fn isend(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<CommRequest> {
        self.send(dst, tag, data)?;
        Ok(CommRequest::send_done())
    }

    /// Nonblocking receive: post interest in `(src, tag)` and return a
    /// handle; the payload is claimed by [`Comm::wait`].
    fn irecv(&mut self, src: usize, tag: u64) -> Result<CommRequest> {
        Ok(CommRequest::recv_from(src, tag))
    }

    /// Block until `req` completes.  Send requests yield `None`,
    /// receive requests yield the payload.
    fn wait(&mut self, req: CommRequest) -> Result<Option<Vec<f32>>> {
        match req.kind {
            ReqKind::SendDone => Ok(None),
            ReqKind::Recv { src, tag } => self.recv(src, tag).map(Some),
        }
    }

    /// Complete a batch of requests; result `i` belongs to request `i`.
    /// Backends override this to consume arrivals in whatever order
    /// the wire delivers them, instead of the posted order.
    fn wait_all(&mut self, reqs: Vec<CommRequest>) -> Result<Vec<Option<Vec<f32>>>> {
        reqs.into_iter().map(|r| self.wait(r)).collect()
    }

    /// Push every queued `isend` toward the peers without blocking on
    /// arrivals.  Call before a long local compute so buffered frames
    /// travel *during* it — waits flush implicitly, but only when they
    /// run.  No-op on backends whose sends are immediately visible.
    fn flush(&mut self) -> Result<()> {
        Ok(())
    }

    /// Hand back send buffers the backend is finished with, so callers
    /// can recycle them through a buffer pool instead of reallocating
    /// next step.  A backend that *copies* payloads on `isend` (TCP
    /// frames them into the socket writer) is done with the `Vec`
    /// immediately; a backend that *moves* them (thread channels hand
    /// the very buffer to the receiver) returns nothing here — the
    /// receiving side recycles instead.  Default: nothing to reclaim.
    fn reclaim_spent(&mut self) -> Vec<Vec<f32>> {
        Vec::new()
    }

    /// Offer payload buffers the caller is finished with back to the
    /// backend for its *receive* path, and return whatever the backend
    /// declined so the caller can repool them itself.  The TCP backend
    /// feeds its progress-engine readers from this freelist, making
    /// steady-state frame reads allocation-free; the thread backend
    /// declines everything — its received buffers *are* the peers' send
    /// staging, which must flow back to the caller's arena instead.
    fn recycle(&mut self, bufs: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        bufs
    }

    /// Synchronisation barrier — dissemination algorithm: ⌈log₂ n⌉
    /// rounds in which every rank signals `(rank + 2^r) % n` and waits
    /// on `(rank − 2^r) mod n`, O(n log n) empty messages total.  The
    /// legacy O(n²) empty all-to-all survives as
    /// [`Comm::barrier_a2a`] for tests that assert message counts.
    fn barrier(&mut self) -> Result<()> {
        dissemination_barrier(self)
    }

    /// Legacy barrier: an empty all-to-all (every pair exchanges a
    /// count) — O(n²) messages, but a fixed and easily audited pattern
    /// (bumps `a2a_calls` exactly once on a backend handle; policy
    /// wrappers like [`TopoComm`] may nest sub-group collectives that
    /// add their own).
    fn barrier_a2a(&mut self) -> Result<()> {
        let empties: Vec<Vec<f32>> = (0..self.size()).map(|_| Vec::new()).collect();
        let _ = self.all_to_all_v(empties)?;
        Ok(())
    }

    /// Start a variable all-to-all and return the in-flight payload
    /// phase as per-peer requests (the decomposed Figure-2 protocol).
    ///
    /// Phase 1 (counts) completes inside this call — receivers need the
    /// sizes to validate — and every payload isend is queued before it
    /// returns, so by completion time all `n−1` outgoing buffers are on
    /// the wire while the caller is free to overlap work and consume
    /// arrivals one peer at a time.
    fn all_to_all_v_start(&mut self, send: Vec<Vec<f32>>) -> Result<PendingA2a> {
        let size = self.size();
        let rank = self.rank();
        if send.len() != size {
            return Err(Error::Comm(format!(
                "all_to_all_v: {} buffers for {} peers",
                send.len(),
                size
            )));
        }
        let seq = self.next_seq();
        let tag_count = seq << 8;
        let tag_data = (seq << 8) | 1;
        self.counters().add("a2a_calls", 1);

        // Phase 1: counts.
        for (p, buf) in send.iter().enumerate() {
            if p != rank {
                self.isend(p, tag_count, vec![buf.len() as f32])?;
            }
        }
        let mut expected = vec![0usize; size];
        expected[rank] = send[rank].len();
        for p in 0..size {
            if p != rank {
                let c = self.recv(p, tag_count)?;
                expected[p] = c[0] as usize;
            }
        }
        self.counters()
            .add("a2a_count_bytes", (4 * (size - 1)) as u64);

        // Phase 2: queue every payload, bookmark every arrival.
        let mut send = send;
        let mut bufs: Vec<Option<Vec<f32>>> = (0..size).map(|_| None).collect();
        bufs[rank] = Some(std::mem::take(&mut send[rank]));
        let mut data_bytes = 0u64;
        let mut reqs: Vec<Option<CommRequest>> = (0..size).map(|_| None).collect();
        for (p, slot) in send.iter_mut().enumerate() {
            if p != rank {
                let buf = std::mem::take(slot);
                data_bytes += (buf.len() * 4) as u64;
                self.isend(p, tag_data, buf)?;
            }
        }
        self.counters().add("a2a_data_bytes", data_bytes);
        for (p, slot) in reqs.iter_mut().enumerate() {
            if p != rank {
                *slot = Some(self.irecv(p, tag_data)?);
            }
        }
        Ok(PendingA2a { reqs, bufs, expected })
    }

    /// Variable all-to-all (Figure 2): `send[p]` goes to peer `p`; the
    /// return value's `recv[p]` came from peer `p`.
    ///
    /// Phase 1 exchanges the lengths (the paper's "exchange the size of
    /// expert inputs"), phase 2 the payloads. Counters record both.
    /// This is [`Comm::all_to_all_v_start`] completed on the spot — the
    /// blocking degenerate case of the decomposed protocol.
    fn all_to_all_v(&mut self, send: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let pending = self.all_to_all_v_start(send)?;
        pending.finish(self)
    }

    /// Start a bucketed nonblocking all-reduce: one independent ring
    /// reduction per bucket, round 0 of every ring queued (and flushed)
    /// before this returns, so all buckets' first frames travel during
    /// whatever compute the caller overlaps before waiting.  Complete
    /// with [`PendingAllReduce::wait_bucket`] / [`PendingAllReduce::
    /// finish`].  Per bucket, chunking, tags and addition order match
    /// [`Comm::all_reduce_sum`] exactly, so each bucket's result is
    /// bit-identical to the blocking ring over the same buffer.
    fn all_reduce_start(&mut self, bufs: Vec<Vec<f32>>) -> Result<PendingAllReduce> {
        let n = self.size();
        let rank = self.rank();
        let mut pending = PendingAllReduce {
            n,
            rank,
            sched: ArSched::Flat,
            buckets: (0..bufs.len()).map(|_| None).collect(),
            done: (0..bufs.len()).map(|_| None).collect(),
        };
        if n == 1 {
            for (slot, buf) in pending.done.iter_mut().zip(bufs) {
                *slot = Some(buf);
            }
            return Ok(pending);
        }
        self.counters().add("allreduce_buckets", pending.buckets.len() as u64);
        for (i, buf) in bufs.into_iter().enumerate() {
            let seq = self.next_seq();
            self.counters().add("allreduce_calls", 1);
            self.counters()
                .add("allreduce_bytes", (buf.len() * 4 * 2 * (n - 1) / n) as u64);
            let want = buf.len();
            pending.buckets[i] = Some(ArBucket {
                buf,
                want,
                seq,
                round: 0,
                req: None,
                own: Vec::new(),
                paused: false,
            });
            pending.post_round(self, i)?;
        }
        self.flush()?;
        Ok(pending)
    }

    /// Start a bucketed ZeRO-sharded reduction on the flat geometry
    /// (every rank its own "node" — the plain ring split over all
    /// ranks).  [`TopoComm`] overrides this to the rail schedule of its
    /// hierarchical topology.  Complete each bucket with
    /// [`PendingAllReduce::wait_bucket_shard`] (shard-local optimiser)
    /// then [`PendingAllReduce::gather_bucket`] — or `wait_bucket` /
    /// `finish`, which skip the pause and yield the plain reduced sum.
    fn all_reduce_zero(&mut self, bufs: Vec<Vec<f32>>) -> Result<PendingAllReduce> {
        let topo = Topology::flat(self.size());
        all_reduce_zero_start(self, &topo, bufs)
    }

    /// The contiguous float range of a `len`-float bucket this rank
    /// owns (and shard-updates) under [`Comm::all_reduce_zero`]'s
    /// schedule.  Deterministic in `(rank, size, len)`, so shard-sized
    /// optimiser state can be laid out before any collective runs.
    fn zero_shard(&self, len: usize) -> std::ops::Range<usize> {
        zero_shard_range(&Topology::flat(self.size()), self.rank(), len)
    }

    /// Ring all-reduce (sum): reduce-scatter then all-gather, the
    /// standard 2(n-1)/n-bandwidth algorithm NCCL uses.  Round
    /// geometry, tags and addition order come from [`ring_round`] /
    /// [`ring_chunk`] — the *same* helpers the bucketed nonblocking
    /// path uses, so the two stay bit-identical by construction.
    fn all_reduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        let n = self.size();
        let rank = self.rank();
        if n == 1 {
            return Ok(());
        }
        let seq = self.next_seq();
        self.counters().add("allreduce_calls", 1);
        self.counters()
            .add("allreduce_bytes", (buf.len() * 4 * 2 * (n - 1) / n) as u64);
        let next = (rank + 1) % n;
        let prev = (rank + n - 1) % n;
        for round in 0..2 * (n - 1) {
            let (send_idx, recv_idx, tag, gather) = ring_round(n, rank, round, seq);
            self.send(next, tag, buf[ring_chunk(buf.len(), n, send_idx)].to_vec())?;
            let data = self.recv(prev, tag)?;
            let range = ring_chunk(buf.len(), n, recv_idx);
            if gather {
                buf[range].copy_from_slice(&data);
            } else {
                for (x, y) in buf[range].iter_mut().zip(&data) {
                    *x += y;
                }
            }
        }
        Ok(())
    }

    /// All-reduce over a subgroup (data-parallel groups). `group` must
    /// contain this rank and be identical on all members.
    fn all_reduce_sum_group(&mut self, buf: &mut [f32], group: &[usize]) -> Result<()> {
        if group.len() <= 1 {
            return Ok(());
        }
        let rank = self.rank();
        let me = group
            .iter()
            .position(|&r| r == rank)
            .ok_or_else(|| Error::Comm("rank not in group".into()))?;
        let seq = self.next_seq();
        self.counters().add(
            "allreduce_bytes",
            (buf.len() * 4 * 2 * (group.len() - 1) / group.len()) as u64,
        );
        // gather onto group[0], sum, broadcast back
        let tag = (seq << 8) | 7;
        if me == 0 {
            let mut acc = buf.to_vec();
            for &p in &group[1..] {
                let data = self.recv(p, tag)?;
                for (x, y) in acc.iter_mut().zip(&data) {
                    *x += y;
                }
            }
            for &p in &group[1..] {
                self.send(p, tag + 1, acc.clone())?;
            }
            buf.copy_from_slice(&acc);
        } else {
            self.send(group[0], tag, buf.to_vec())?;
            let data = self.recv(group[0], tag + 1)?;
            buf.copy_from_slice(&data);
        }
        Ok(())
    }

    /// Gather equal-size buffers from all ranks (concatenated by rank).
    fn all_gather(&mut self, mine: &[f32]) -> Result<Vec<f32>> {
        let send: Vec<Vec<f32>> = (0..self.size()).map(|_| mine.to_vec()).collect();
        let parts = self.all_to_all_v(send)?;
        let mut out = Vec::with_capacity(mine.len() * self.size());
        for p in parts {
            if p.len() != mine.len() {
                return Err(Error::Comm("all_gather: ragged input".into()));
            }
            out.extend_from_slice(&p);
        }
        Ok(out)
    }

    /// Split this handle's world under a [`Topology`] into the
    /// `{intra, inter}` sub-group namespaces ([`CommGroups`]): the
    /// intra-node group this rank belongs to, and — on node leaders —
    /// the leaders' inter-node group.  Bind a group to the handle
    /// ([`ProcessGroup::bind`]) to run any collective of this trait on
    /// the sub-group.  Hold one split per handle lifetime: a second
    /// split restarts the groups' tag sequences (safe only once the
    /// first split's collectives have fully drained).
    fn split(&self, topo: &Topology) -> Result<CommGroups> {
        if topo.world() != self.size() {
            return Err(Error::Comm(format!(
                "split: topology is over {} ranks, comm has {}",
                topo.world(),
                self.size()
            )));
        }
        CommGroups::new(topo, self.rank())
    }

    /// Broadcast from `root` (everyone returns root's buffer).
    fn broadcast(&mut self, buf: &mut Vec<f32>, root: usize) -> Result<()> {
        let seq = self.next_seq();
        let tag = (seq << 8) | 9;
        if self.rank() == root {
            for p in 0..self.size() {
                if p != root {
                    self.send(p, tag, buf.clone())?;
                }
            }
        } else {
            *buf = self.recv(root, tag)?;
        }
        Ok(())
    }
}

/// The message-based dissemination barrier [`Comm::barrier`] defaults
/// to — a free function so backends that override `barrier` (the
/// thread handle's OS barrier) can still fall back to it when a recv
/// deadline is armed: an OS barrier cannot time out, messages can.
pub fn dissemination_barrier<C: Comm + ?Sized>(c: &mut C) -> Result<()> {
    let n = c.size();
    if n <= 1 {
        return Ok(());
    }
    let rank = c.rank();
    let seq = c.next_seq();
    let mut dist = 1usize;
    let mut round = 0u64;
    while dist < n {
        let tag = (seq << 8) | round;
        c.send((rank + dist) % n, tag, Vec::new())?;
        c.recv((rank + n - dist) % n, tag)?;
        dist <<= 1;
        round += 1;
    }
    c.counters().add("barrier_rounds", round);
    Ok(())
}

/// How often a blocked thread-channel receive checks whether the peer
/// it waits on has died (see [`CommHandle`]'s liveness notes).
const DEATH_POLL: Duration = Duration::from_millis(50);

/// One worker's endpoint into a thread-backed (single-process) group.
///
/// Receives are *death-aware*: dropping a handle (worker exit, clean
/// or failed) marks its rank dead, and any peer blocked on a message
/// from a dead rank surfaces [`Error::Comm`] instead of hanging — a
/// worker crash mid-collective is contained, never a deadlock.
pub struct CommHandle {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Msg>>,
    receiver: Receiver<Msg>,
    /// Messages that arrived out of order (wrong tag/src), parked.
    parked: Vec<Msg>,
    barrier: Arc<Barrier>,
    /// Per-rank liveness, flipped false by each handle's `Drop`.
    alive: Arc<Vec<AtomicBool>>,
    /// Optional deadline for blocking receives (`[fault]
    /// recv_timeout_ms`): a peer silent past it surfaces
    /// [`Error::Timeout`] instead of hanging the rank.  Checked at
    /// [`DEATH_POLL`] granularity.  `None` (the default) waits forever.
    recv_timeout: Option<Duration>,
    seq: u64,
    pub counters: Counters,
}

/// Create a local (thread-backed) group of `size` workers.
pub fn local_group(size: usize) -> Vec<CommHandle> {
    assert!(size > 0);
    let mut senders = Vec::with_capacity(size);
    let mut receivers = Vec::with_capacity(size);
    for _ in 0..size {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    let barrier = Arc::new(Barrier::new(size));
    let alive: Arc<Vec<AtomicBool>> =
        Arc::new((0..size).map(|_| AtomicBool::new(true)).collect());
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, receiver)| CommHandle {
            rank,
            size,
            senders: senders.clone(),
            receiver,
            parked: Vec::new(),
            barrier: barrier.clone(),
            alive: alive.clone(),
            recv_timeout: None,
            seq: 0,
            counters: Counters::new(),
        })
        .collect()
}

impl Drop for CommHandle {
    fn drop(&mut self) {
        self.alive[self.rank].store(false, Ordering::Release);
    }
}

impl CommHandle {
    /// Drain everything already delivered to this handle's channel into
    /// the parked queue (closing the race between a death check and a
    /// message that arrived just before the sender died).
    fn park_delivered(&mut self) {
        while let Ok(msg) = self.receiver.try_recv() {
            self.parked.push(msg);
        }
    }

    /// Claim a `(src, tag)` match from the parked queue, if present —
    /// the one copy of the out-of-order match scan both `recv` and
    /// `wait_all` use.
    fn take_parked(&mut self, src: usize, tag: u64) -> Option<Vec<f32>> {
        self.parked
            .iter()
            .position(|m| m.src == src && m.tag == tag)
            .map(|i| self.parked.swap_remove(i).data)
    }

    fn dead_peer_err(src: usize, tag: u64) -> Error {
        Error::Comm(format!(
            "worker {src} died before its message (tag {tag}) arrived"
        ))
    }

    /// Arm (or disarm, `None`) the blocking-receive deadline — the
    /// `[fault] recv_timeout_ms` knob.  While armed, [`Comm::barrier`]
    /// runs over messages instead of the OS barrier, so it times out
    /// with everything else.
    pub fn set_recv_timeout(&mut self, timeout: Option<Duration>) {
        self.recv_timeout = timeout;
    }
}

impl Comm for CommHandle {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn counters(&mut self) -> &mut Counters {
        &mut self.counters
    }

    fn send(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<()> {
        self.counters.add("bytes_sent", (data.len() * 4) as u64);
        self.senders[dst]
            .send(Msg { src: self.rank, tag, data })
            .map_err(|_| Error::Comm(format!("peer {dst} hung up")))
    }

    fn recv(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        if let Some(data) = self.take_parked(src, tag) {
            return Ok(data);
        }
        let deadline = self
            .recv_timeout
            .map(|d| (std::time::Instant::now() + d, d.as_millis() as u64));
        loop {
            match self.receiver.recv_timeout(DEATH_POLL) {
                Ok(msg) => {
                    if msg.src == src && msg.tag == tag {
                        return Ok(msg.data);
                    }
                    self.parked.push(msg);
                }
                Err(RecvTimeoutError::Timeout) => {
                    if !self.alive[src].load(Ordering::Acquire) {
                        // final sweep: the message may have raced in
                        // just before the sender died
                        self.park_delivered();
                        if let Some(data) = self.take_parked(src, tag) {
                            return Ok(data);
                        }
                        return Err(Self::dead_peer_err(src, tag));
                    }
                    if let Some((at, ms)) = deadline {
                        if std::time::Instant::now() >= at {
                            self.park_delivered();
                            if let Some(data) = self.take_parked(src, tag) {
                                return Ok(data);
                            }
                            return Err(Error::Timeout { peer: src, tag, ms });
                        }
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Comm("channel closed".into()))
                }
            }
        }
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Complete requests in *arrival order*: drain the channel and
    /// satisfy whichever posted receive each message matches, parking
    /// strays — the channel backend's "consume as they land".
    fn wait_all(&mut self, reqs: Vec<CommRequest>) -> Result<Vec<Option<Vec<f32>>>> {
        let mut out: Vec<Option<Vec<f32>>> = Vec::with_capacity(reqs.len());
        // (slot, src, tag) still outstanding
        let mut pending: Vec<(usize, usize, u64)> = Vec::new();
        for (slot, req) in reqs.into_iter().enumerate() {
            out.push(None);
            if let Some((src, tag)) = req.pending_recv() {
                pending.push((slot, src, tag));
            }
        }
        pending.retain(|&(slot, src, tag)| match self.take_parked(src, tag) {
            Some(data) => {
                out[slot] = Some(data);
                false
            }
            None => true,
        });
        let deadline = self
            .recv_timeout
            .map(|d| (std::time::Instant::now() + d, d.as_millis() as u64));
        while !pending.is_empty() {
            match self.receiver.recv_timeout(DEATH_POLL) {
                Ok(msg) => {
                    match pending
                        .iter()
                        .position(|&(_, src, tag)| src == msg.src && tag == msg.tag)
                    {
                        Some(i) => {
                            let (slot, _, _) = pending.swap_remove(i);
                            out[slot] = Some(msg.data);
                        }
                        None => self.parked.push(msg),
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    if pending
                        .iter()
                        .any(|&(_, src, _)| !self.alive[src].load(Ordering::Acquire))
                    {
                        self.park_delivered();
                        pending.retain(|&(slot, src, tag)| {
                            match self.take_parked(src, tag) {
                                Some(data) => {
                                    out[slot] = Some(data);
                                    false
                                }
                                None => true,
                            }
                        });
                        if let Some(&(_, src, tag)) = pending
                            .iter()
                            .find(|&&(_, s, _)| !self.alive[s].load(Ordering::Acquire))
                        {
                            return Err(Self::dead_peer_err(src, tag));
                        }
                    }
                    if let Some((at, ms)) = deadline {
                        if std::time::Instant::now() >= at {
                            self.park_delivered();
                            pending.retain(|&(slot, src, tag)| {
                                match self.take_parked(src, tag) {
                                    Some(data) => {
                                        out[slot] = Some(data);
                                        false
                                    }
                                    None => true,
                                }
                            });
                            if let Some(&(_, src, tag)) = pending.first() {
                                return Err(Error::Timeout { peer: src, tag, ms });
                            }
                        }
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Comm("channel closed".into()))
                }
            }
        }
        Ok(out)
    }

    /// Threads share an OS barrier — cheaper than the message fallback —
    /// unless a recv deadline is armed: an OS barrier cannot time out,
    /// so the deadline path runs the message-based dissemination rounds.
    fn barrier(&mut self) -> Result<()> {
        if self.recv_timeout.is_some() {
            return dissemination_barrier(self);
        }
        self.barrier.wait();
        Ok(())
    }
}

/// Spawn `size` workers, run `f(handle)` on each, join, propagate errors.
pub fn run_workers<T, F>(size: usize, f: F) -> Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(CommHandle) -> Result<T> + Send + Sync + 'static,
{
    let handles = local_group(size);
    let f = Arc::new(f);
    let mut joins = Vec::new();
    for h in handles {
        let f = f.clone();
        let rank = h.rank;
        joins.push((
            rank,
            std::thread::Builder::new()
                .name(format!("worker-{rank}"))
                .spawn(move || f(h))
                .expect("spawn"),
        ));
    }
    let mut out = Vec::with_capacity(size);
    for (rank, j) in joins {
        match j.join() {
            Ok(Ok(v)) => out.push(v),
            Ok(Err(e)) => {
                return Err(Error::Worker { rank, msg: e.to_string() })
            }
            Err(_) => {
                return Err(Error::Worker { rank, msg: "panicked".into() })
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert, prop_assert_eq, PropResult};

    #[test]
    fn all_to_all_v_routes_correctly() {
        let out = run_workers(4, |mut h| {
            let r = h.rank() as f32;
            // send [r, p] to each peer p
            let send: Vec<Vec<f32>> =
                (0..4).map(|p| vec![r, p as f32]).collect();
            let recv = h.all_to_all_v(send)?;
            for (p, buf) in recv.iter().enumerate() {
                assert_eq!(buf, &vec![p as f32, r]);
            }
            Ok(())
        });
        out.unwrap();
    }

    #[test]
    fn all_to_all_v_variable_sizes() {
        run_workers(3, |mut h| {
            let r = h.rank();
            // rank r sends r+p floats to peer p
            let send: Vec<Vec<f32>> =
                (0..3).map(|p| vec![1.0; r + p]).collect();
            let recv = h.all_to_all_v(send)?;
            for (p, buf) in recv.iter().enumerate() {
                assert_eq!(buf.len(), p + r);
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn ring_all_reduce_sums() {
        for n in [1, 2, 3, 4, 8] {
            run_workers(n, move |mut h| {
                let mut buf: Vec<f32> =
                    (0..37).map(|i| (h.rank() * 100 + i) as f32).collect();
                let want: Vec<f32> = (0..37)
                    .map(|i| {
                        (0..n).map(|r| (r * 100 + i) as f32).sum::<f32>()
                    })
                    .collect();
                h.all_reduce_sum(&mut buf)?;
                assert_eq!(buf, want, "n={n}");
                Ok(())
            })
            .unwrap();
        }
    }

    #[test]
    fn recv_deadline_surfaces_timeout() {
        run_workers(2, |mut h| {
            let peer = 1 - h.rank();
            h.set_recv_timeout(Some(Duration::from_millis(100)));
            // nothing was ever sent on this tag: both ranks must time
            // out with the peer and tag attached, not hang
            match h.recv(peer, (1u64 << 40) | 5) {
                Err(Error::Timeout { peer: p, tag, ms }) => {
                    assert_eq!(p, peer);
                    assert_eq!(tag, (1u64 << 40) | 5);
                    assert_eq!(ms, 100);
                }
                other => panic!("rank {}: {other:?}", h.rank()),
            }
            // an armed deadline routes barrier over messages, so it
            // completes (both ranks participate) without the OS barrier
            h.barrier()?;
            // and the handle still works once disarmed
            h.set_recv_timeout(None);
            h.send(peer, 7, vec![h.rank() as f32])?;
            assert_eq!(h.recv(peer, 7)?, vec![peer as f32]);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn subgroup_all_reduce() {
        run_workers(4, |mut h| {
            let group: Vec<usize> = if h.rank() % 2 == 0 {
                vec![0, 2]
            } else {
                vec![1, 3]
            };
            let mut buf = vec![h.rank() as f32 + 1.0; 5];
            h.all_reduce_sum_group(&mut buf, &group)?;
            let want = if h.rank() % 2 == 0 { 4.0 } else { 6.0 }; // 1+3 / 2+4
            assert!(buf.iter().all(|&x| x == want));
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn all_gather_concatenates() {
        run_workers(3, |mut h| {
            let mine = vec![h.rank() as f32; 2];
            let all = h.all_gather(&mine)?;
            assert_eq!(all, vec![0., 0., 1., 1., 2., 2.]);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn broadcast_from_each_root() {
        run_workers(3, |mut h| {
            for root in 0..3 {
                let mut buf = if h.rank() == root {
                    vec![root as f32 * 10.0; 4]
                } else {
                    vec![]
                };
                h.broadcast(&mut buf, root)?;
                assert_eq!(buf, vec![root as f32 * 10.0; 4]);
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn worker_error_propagates_with_rank() {
        let res = run_workers(3, |h| {
            if h.rank() == 1 {
                Err(Error::msg("boom"))
            } else {
                Ok(())
            }
        });
        match res {
            Err(Error::Worker { rank: 1, msg }) => assert!(msg.contains("boom")),
            other => panic!("expected worker error, got {other:?}"),
        }
    }

    #[test]
    fn prop_all_reduce_equals_sequential_sum() {
        check("ring all-reduce = sum", 20, |g| {
            let n = *g.choose(&[1usize, 2, 3, 4, 5, 8]);
            let len = g.usize_in(1, 200);
            let data: Vec<Vec<f32>> = (0..n)
                .map(|_| g.vec_f32(len, -8.0, 8.0))
                .collect();
            let want: Vec<f32> = (0..len)
                .map(|i| data.iter().map(|d| d[i]).sum())
                .collect();
            let data2 = data.clone();
            let got = run_workers(n, move |mut h| {
                let mut buf = data2[h.rank()].clone();
                h.all_reduce_sum(&mut buf)?;
                Ok(buf)
            })
            .map_err(|e| e.to_string())?;
            for r in 0..n {
                for i in 0..len {
                    prop_assert(
                        (got[r][i] - want[i]).abs() < 1e-3,
                        format!("rank {r} idx {i}: {} vs {}", got[r][i], want[i]),
                    )?;
                }
            }
            Ok(()) as PropResult
        });
    }

    #[test]
    fn isend_irecv_wait_roundtrip() {
        run_workers(3, |mut h| {
            let r = h.rank();
            let n = h.size();
            let tag = (h.next_seq() << 8) | 1;
            for p in 0..n {
                if p != r {
                    h.isend(p, tag, vec![r as f32, p as f32])?;
                }
            }
            for p in 0..n {
                if p != r {
                    let req = h.irecv(p, tag)?;
                    assert_eq!(req.pending_recv(), Some((p, tag)));
                    let data = h.wait(req)?.unwrap();
                    assert_eq!(data, vec![p as f32, r as f32]);
                }
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn wait_all_matches_results_to_requests() {
        // Every rank sends two differently-tagged messages to every
        // peer; wait_all must route each arrival to the right slot no
        // matter the wire order.
        run_workers(4, |mut h| {
            let r = h.rank();
            let n = h.size();
            let seq = h.next_seq();
            for p in 0..n {
                if p != r {
                    h.isend(p, (seq << 8) | 2, vec![(r * 10 + 2) as f32])?;
                    h.isend(p, (seq << 8) | 1, vec![(r * 10 + 1) as f32])?;
                }
            }
            let mut reqs = Vec::new();
            let mut want = Vec::new();
            for p in 0..n {
                if p != r {
                    for t in [1u64, 2] {
                        reqs.push(h.irecv(p, (seq << 8) | t)?);
                        want.push((p * 10) as f32 + t as f32);
                    }
                }
            }
            let got = h.wait_all(reqs)?;
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.as_deref(), Some(&[*w][..]));
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn a2a_start_consumes_peers_in_any_order() {
        run_workers(4, |mut h| {
            let r = h.rank();
            let send: Vec<Vec<f32>> =
                (0..4).map(|p| vec![(r * 4 + p) as f32; p + 1]).collect();
            let mut pending = h.all_to_all_v_start(send)?;
            // consume highest peer first — arrivals land out of order
            for p in (0..4).rev() {
                assert_eq!(pending.expected(p), r + 1);
                let buf = pending.wait_peer(&mut h, p)?;
                assert_eq!(buf, vec![(p * 4 + r) as f32; r + 1]);
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn a2a_finish_rejects_already_consumed_peers() {
        run_workers(2, |mut h| {
            let send: Vec<Vec<f32>> = (0..2).map(|p| vec![p as f32; 2]).collect();
            let other = 1 - h.rank();
            let mut pending = h.all_to_all_v_start(send)?;
            let _ = pending.wait_peer(&mut h, other)?;
            // double-drain of the same peer is an error…
            assert!(pending.wait_peer(&mut h, other).is_err());
            // …and so is finish, whose result could not include it
            assert!(pending.finish(&mut h).is_err());
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn barrier_a2a_keeps_message_count_contract() {
        run_workers(3, |mut h| {
            h.barrier_a2a()?;
            h.barrier_a2a()?;
            assert_eq!(h.counters.get("a2a_calls"), 2);
            // OS-barrier override: no a2a traffic from plain barrier()
            h.barrier()?;
            assert_eq!(h.counters.get("a2a_calls"), 2);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn bucketed_all_reduce_matches_blocking_ring_bitwise() {
        run_workers(4, |mut h| {
            let r = h.rank();
            // empty, non-divisible, divisible, large-ish, tiny buckets
            let lens = [0usize, 7, 64, 1000, 3];
            let bufs: Vec<Vec<f32>> = lens
                .iter()
                .enumerate()
                .map(|(b, &l)| {
                    (0..l)
                        .map(|i| (r + 1) as f32 * 1.1 + b as f32 * 0.3 + i as f32 * 0.01)
                        .collect()
                })
                .collect();
            let mut want = bufs.clone();
            for w in want.iter_mut() {
                h.all_reduce_sum(w)?;
            }
            // in-order finish
            let pending = h.all_reduce_start(bufs.clone())?;
            assert_eq!(pending.len(), lens.len());
            let got = pending.finish(&mut h)?;
            assert_eq!(got, want, "finish != blocking ring");
            // reverse-order per-bucket completion: arrival order across
            // buckets must not change any bucket's bits
            let mut pending = h.all_reduce_start(bufs)?;
            for b in (0..lens.len()).rev() {
                assert_eq!(pending.wait_bucket(&mut h, b)?, want[b], "bucket {b}");
            }
            assert_eq!(pending.pending(), 0);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn bucketed_all_reduce_rejects_double_consume() {
        run_workers(2, |mut h| {
            let bufs = vec![vec![h.rank() as f32; 8], vec![1.0; 4]];
            let mut pending = h.all_reduce_start(bufs)?;
            let _ = pending.wait_bucket(&mut h, 0)?;
            assert!(pending.wait_bucket(&mut h, 0).is_err());
            // finish cannot return the already-drained bucket
            assert!(pending.finish(&mut h).is_err());
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn bucketed_all_reduce_single_worker_is_identity() {
        run_workers(1, |mut h| {
            let bufs = vec![vec![1.5f32, -2.0], Vec::new()];
            let pending = h.all_reduce_start(bufs.clone())?;
            assert_eq!(pending.pending(), 0);
            assert_eq!(pending.finish(&mut h)?, bufs);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn prop_bucket_completion_order_never_changes_sums() {
        check("bucket completion order invariant", 15, |g| {
            let n = *g.choose(&[2usize, 3, 4]);
            let nb = g.usize_in(1, 5);
            let lens: Vec<usize> = (0..nb).map(|_| g.usize_in(0, 40)).collect();
            let data: Vec<Vec<Vec<f32>>> = (0..n)
                .map(|_| lens.iter().map(|&l| g.vec_f32(l, -4.0, 4.0)).collect())
                .collect();
            // random completion order, same on every rank
            let mut order: Vec<usize> = (0..nb).collect();
            for i in (1..nb).rev() {
                let j = g.usize_in(0, i);
                order.swap(i, j);
            }
            let data2 = data.clone();
            let order2 = order.clone();
            let got = run_workers(n, move |mut h| {
                let bufs = data2[h.rank()].clone();
                let mut want = bufs.clone();
                for w in want.iter_mut() {
                    h.all_reduce_sum(w)?;
                }
                let mut pending = h.all_reduce_start(bufs)?;
                let mut out: Vec<Vec<f32>> = vec![Vec::new(); want.len()];
                for &b in &order2 {
                    out[b] = pending.wait_bucket(&mut h, b)?;
                }
                Ok((out, want))
            })
            .map_err(|e| e.to_string())?;
            for (r, (out, want)) in got.iter().enumerate() {
                prop_assert(
                    out == want,
                    format!("rank {r}: order {order:?} changed bits"),
                )?;
            }
            Ok(()) as PropResult
        });
    }

    #[test]
    fn recv_from_dead_worker_errors_instead_of_hanging() {
        let res = run_workers(3, |mut h| {
            if h.rank() == 1 {
                return Err(Error::msg("injected death"));
            }
            // both survivors wait on rank 1 — must error, not hang
            let err = h.recv(1, 12345).unwrap_err();
            assert!(err.to_string().contains("died"), "{err}");
            Err(err)
        });
        assert!(matches!(res, Err(Error::Worker { .. })), "{res:?}");
    }

    #[test]
    fn prop_all_to_all_conserves_floats() {
        check("a2a conserves data", 20, |g| {
            let n = *g.choose(&[2usize, 3, 4]);
            let sizes: Vec<Vec<usize>> = (0..n)
                .map(|_| g.vec_usize(n, 0, 50))
                .collect();
            let sizes2 = sizes.clone();
            let got = run_workers(n, move |mut h| {
                let r = h.rank();
                let send: Vec<Vec<f32>> = (0..n)
                    .map(|p| vec![(r * n + p) as f32; sizes2[r][p]])
                    .collect();
                let total_sent: usize = send.iter().map(|b| b.len()).sum();
                let recv = h.all_to_all_v(send)?;
                // payload correctness: from peer p we see value p*n+r
                for (p, buf) in recv.iter().enumerate() {
                    for &v in buf {
                        if v != (p * n + r) as f32 {
                            return Err(Error::Comm("wrong payload".into()));
                        }
                    }
                }
                let total_recv: usize = recv.iter().map(|b| b.len()).sum();
                Ok((total_sent, total_recv))
            })
            .map_err(|e| e.to_string())?;
            let sent: usize = got.iter().map(|(s, _)| s).sum();
            let recv: usize = got.iter().map(|(_, r)| r).sum();
            prop_assert_eq(sent, recv)
        });
    }

    #[test]
    fn zero_all_reduce_matches_blocking_ring_bitwise() {
        // driven as a plain all-reduce (wait_bucket / finish skip the
        // shard pause), the flat zero schedule must reproduce the
        // blocking ring's bits — same chunking, same addition order
        run_workers(4, |mut h| {
            let r = h.rank();
            let lens = [0usize, 7, 64, 1000, 3];
            let bufs: Vec<Vec<f32>> = lens
                .iter()
                .enumerate()
                .map(|(b, &l)| {
                    (0..l)
                        .map(|i| (r + 1) as f32 * 1.3 + b as f32 * 0.7 + i as f32 * 0.01)
                        .collect()
                })
                .collect();
            let mut want = bufs.clone();
            for w in want.iter_mut() {
                h.all_reduce_sum(w)?;
            }
            let pending = h.all_reduce_zero(bufs.clone())?;
            let got = pending.finish(&mut h)?;
            assert_eq!(got, want, "zero finish != blocking ring");
            let mut pending = h.all_reduce_zero(bufs)?;
            for b in (0..lens.len()).rev() {
                assert_eq!(pending.wait_bucket(&mut h, b)?, want[b], "bucket {b}");
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn zero_shard_gather_roundtrip() {
        // the real usage: wait to the shard point, check the owned
        // range holds exactly the blocking ring's partial, overwrite it
        // with position-coded values, gather — every rank must end with
        // the full position-coded buffer (each float delivered by its
        // one owner)
        run_workers(4, |mut h| {
            let r = h.rank();
            let lens = [37usize, 256];
            let bufs: Vec<Vec<f32>> = lens
                .iter()
                .map(|&l| (0..l).map(|i| (r * 100 + i) as f32).collect())
                .collect();
            let mut want = bufs.clone();
            for w in want.iter_mut() {
                h.all_reduce_sum(w)?;
            }
            let mut pending = h.all_reduce_zero(bufs)?;
            for (b, &l) in lens.iter().enumerate() {
                assert_eq!(h.zero_shard(l), zero_shard_range(&Topology::flat(4), r, l));
                let (range, buf) = pending.wait_bucket_shard(&mut h, b)?;
                assert_eq!(range, h.zero_shard(l), "bucket {b}");
                assert_eq!(
                    &buf[range.clone()],
                    &want[b][range.clone()],
                    "bucket {b}: shard partial != blocking ring"
                );
                for i in range.clone() {
                    buf[i] = b as f32 * 10_000.0 + i as f32;
                }
                let full = pending.gather_bucket(&mut h, b)?;
                let expect: Vec<f32> =
                    (0..l).map(|i| b as f32 * 10_000.0 + i as f32).collect();
                assert_eq!(full, expect, "bucket {b}: gathered updates");
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn zero_hier_matches_tree_bitwise() {
        // the rail schedule's fold order (ascending local ranks within
        // the node, then the node ring) is the hier tree's, so the two
        // agree bitwise — and the rail shard/gather roundtrip covers
        // every float exactly once
        let topo = Topology::new(4, 2).unwrap();
        run_workers(4, move |mut h| {
            let r = h.rank();
            let lens = [0usize, 7, 64, 500];
            let bufs: Vec<Vec<f32>> = lens
                .iter()
                .enumerate()
                .map(|(b, &l)| {
                    (0..l)
                        .map(|i| (r + 2) as f32 * 0.9 + b as f32 * 0.4 + i as f32 * 0.02)
                        .collect()
                })
                .collect();
            let want = all_reduce_start_hier(&mut h, &topo, bufs.clone())?
                .finish(&mut h)?;
            let got =
                all_reduce_zero_start(&mut h, &topo, bufs.clone())?.finish(&mut h)?;
            assert_eq!(got, want, "rail zero != hier tree");
            // shard → position-coded update → gather under the rail
            let mut pending = all_reduce_zero_start(&mut h, &topo, bufs)?;
            for (b, &l) in lens.iter().enumerate() {
                let (range, buf) = pending.wait_bucket_shard(&mut h, b)?;
                assert_eq!(range, zero_shard_range(&topo, r, l), "bucket {b}");
                assert_eq!(
                    &buf[range.clone()],
                    &want[b][range.clone()],
                    "bucket {b}: rail shard partial != tree"
                );
                for i in range.clone() {
                    buf[i] = b as f32 * 10_000.0 + i as f32;
                }
                let full = pending.gather_bucket(&mut h, b)?;
                let expect: Vec<f32> =
                    (0..l).map(|i| b as f32 * 10_000.0 + i as f32).collect();
                assert_eq!(full, expect, "bucket {b}: rail gathered updates");
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn zero_shard_ranges_partition_the_buffer() {
        // every (world, local_size, len) partition: shard ranges are
        // disjoint, ordered by construction within each node chunk, and
        // cover the buffer exactly
        for (w, l) in [(1, 1), (2, 1), (4, 1), (4, 2), (6, 3), (8, 2), (8, 4)] {
            let topo = if l == 1 {
                Topology::flat(w)
            } else {
                Topology::new(w, l).unwrap()
            };
            for len in [0usize, 1, 7, 64, 1000] {
                let mut covered = vec![0u8; len];
                for rank in 0..w {
                    for i in zero_shard_range(&topo, rank, len) {
                        covered[i] += 1;
                    }
                }
                assert!(
                    covered.iter().all(|&c| c == 1),
                    "w={w} l={l} len={len}: {covered:?}"
                );
            }
        }
    }

    #[test]
    fn zero_single_worker_owns_everything() {
        run_workers(1, |mut h| {
            let bufs = vec![vec![1.5f32, -2.0], Vec::new()];
            let mut pending = h.all_reduce_zero(bufs.clone())?;
            let (range, buf) = pending.wait_bucket_shard(&mut h, 0)?;
            assert_eq!(range, 0..2);
            buf[0] = 9.0;
            assert_eq!(pending.gather_bucket(&mut h, 0)?, vec![9.0, -2.0]);
            assert_eq!(pending.gather_bucket(&mut h, 1)?, Vec::<f32>::new());
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn zero_rejects_double_consume_and_wrong_schedule() {
        run_workers(2, |mut h| {
            let bufs = vec![vec![h.rank() as f32; 8]];
            let mut pending = h.all_reduce_zero(bufs.clone())?;
            let _ = pending.wait_bucket_shard(&mut h, 0)?;
            let _ = pending.gather_bucket(&mut h, 0)?;
            assert!(pending.gather_bucket(&mut h, 0).is_err());
            assert!(pending.wait_bucket_shard(&mut h, 0).is_err());
            // shard calls on a non-zero schedule are refused up front
            let mut plain = h.all_reduce_start(bufs)?;
            assert!(plain.wait_bucket_shard(&mut h, 0).is_err());
            assert!(plain.gather_bucket(&mut h, 0).is_err());
            let _ = plain.wait_bucket(&mut h, 0)?;
            Ok(())
        })
        .unwrap();
    }
}
