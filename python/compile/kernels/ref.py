"""Pure-jnp oracles for every Layer-1 kernel.

These are deliberately written with the most obvious jnp formulation —
no pallas, no tiling, no padding tricks — and serve as the correctness
ground truth for ``python/tests/test_kernels.py`` (hypothesis sweeps) and,
transitively, for the Rust integration tests that execute the lowered
HLO artifacts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gate_scores_ref(x, wg, bg):
    """``[n_b, d_m] @ [d_m, n_e] + [n_e] -> f32 [n_b, n_e]``."""
    return (x.astype(jnp.float32) @ wg.astype(jnp.float32)) + bg.astype(jnp.float32)


def scatter_rows_ref(x, src, n_slots):
    """Slot s gets row ``x[src[s]]``; src < 0 (padding) gets zeros."""
    gathered = jnp.where(
        (src >= 0)[:, None],
        x[jnp.clip(src, 0, x.shape[0] - 1)],
        jnp.zeros((n_slots, x.shape[1]), x.dtype),
    )
    return gathered


def combine_rows_ref(y, slots, w):
    """``out[i] = sum_j w[i,j] * y[slots[i,j]]``, OOB slots contribute 0."""
    n_slots = y.shape[0]
    valid = (slots >= 0) & (slots < n_slots)
    g = y[jnp.clip(slots, 0, n_slots - 1)].astype(jnp.float32)  # [n_b, k, d_m]
    g = jnp.where(valid[..., None], g, 0.0)
    return jnp.sum(g * w.astype(jnp.float32)[..., None], axis=1).astype(y.dtype)


def expert_ffn_ref(x, w1, b1, w2, b2):
    """Per-expert ``gelu(x @ w1 + b1) @ w2 + b2`` in f32 accumulation."""

    def one(xe, w1e, b1e, w2e, b2e):
        h = jax.nn.gelu(
            xe.astype(jnp.float32) @ w1e.astype(jnp.float32) + b1e.astype(jnp.float32)
        )
        return (h @ w2e.astype(jnp.float32) + b2e.astype(jnp.float32)).astype(x.dtype)

    return jax.vmap(one)(x, w1, b1, w2, b2)


def topk_compat(x, k):
    """Top-k via argsort (ties -> lower index), returning (values, idx).

    ``jax.lax.top_k`` lowers to the `topk` HLO instruction, which the
    pinned XLA 0.5.1 text parser predates; argsort lowers to `sort`,
    which round-trips.  Semantics match `lax.top_k` exactly for our use
    (stable descending order).
    """
    # indices are a non-differentiable routing choice: stop gradients
    # before the sort (also sidesteps sort-JVP entirely)
    idx = jnp.argsort(jax.lax.stop_gradient(-x), axis=-1, stable=True)[..., :k]
    vals = jnp.take_along_axis(x, idx, axis=-1)
    return vals, idx


def topk_gate_ref(scores, k):
    """Softmax -> top-k -> renormalised weights (Algorithm 1).

    Returns ``(weights [n_b, k] f32, indices [n_b, k] i32)``.
    """
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    w, idx = topk_compat(probs, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w, idx.astype(jnp.int32)


def moe_layer_ref(x, wg, bg, w1, b1, w2, b2, k, capacity):
    """Whole-layer oracle: loop over tokens/choices, no batching at all.

    The most literal transcription of Algorithm 1 plus GShard-style
    capacity dropping (token order priority within each expert).  Used to
    validate the fused pallas layer end to end.
    """
    n_b = x.shape[0]
    n_e = wg.shape[1]
    scores = gate_scores_ref(x, wg, bg)
    w, idx = topk_gate_ref(scores, k)

    # Capacity bookkeeping in plain python semantics via cumsum ranks.
    flat_e = idx.reshape(-1)  # [n_b * k], token-major
    onehot = jax.nn.one_hot(flat_e, n_e, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - 1  # occurrences before+self per expert
    pos_in_e = jnp.take_along_axis(rank, flat_e[:, None], axis=1)[:, 0]
    kept = pos_in_e < capacity

    y = jnp.zeros_like(x, dtype=jnp.float32)
    ffn_all = expert_ffn_ref(
        jnp.broadcast_to(x, (n_e,) + x.shape), w1, b1, w2, b2
    )  # [n_e, n_b, d_m]: every expert applied to every token (oracle only)
    for i in range(n_b):
        for j in range(k):
            flat = i * k + j
            e = flat_e[flat]
            contrib = jnp.where(
                kept[flat], w[i, j] * ffn_all[e, i].astype(jnp.float32), 0.0
            )
            y = y.at[i].add(contrib)
    return y.astype(x.dtype)
