//! Figure 3: GEMM throughput vs batch size.
//!
//! The paper's single-device insight: cuBLAS GEMM throughput collapses
//! when the batch (row) dimension shrinks — at batch 1 an FFN layer is
//! a GEMV at <5 % of peak — so tokens must be batched per expert.  We
//! regenerate the same curve on the XLA CPU backend: matmul
//! `[nb, d_m] · [d_m, d_h]` for nb = 1 … 4096, built at run time with
//! the XlaBuilder (no artifacts needed).
//!
//! ```bash
//! cargo bench --bench fig3_gemm                  # scaled dims (256×1024)
//! cargo bench --bench fig3_gemm -- --paper       # paper dims (1024×4096)
//! ```
//!
//! Expected shape (paper Fig. 3): near-linear growth with nb until a
//! plateau near peak; tiny nb ≪ 5 % of peak.

use fastmoe::bench::{bench, BenchOpts, Table};
use fastmoe::cli::Args;
use fastmoe::metrics::{matmul_flops, CsvWriter};
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::util::gflops;

fn main() -> fastmoe::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(argv, &["paper"])?;
    let (dm, dh) = if args.has_flag("paper") { (1024, 4096) } else { (256, 1024) };
    let max_nb = args.usize_or("max-nb", 4096)?;
    let rt = Runtime::open_default()?;
    let opts = BenchOpts::from_env();

    println!("Figure 3 — GEMM throughput vs batch size (d_m={dm}, d_h={dh})\n");
    let mut table = Table::new(&["batch", "ms", "GFLOP/s", "%peak"]);
    let mut csv = CsvWriter::create("runs/fig3_gemm.csv", &["batch", "ms", "gflops"])?;

    let mut rng = Rng::new(1);
    let mut results = Vec::new();
    let mut nb = 1usize;
    while nb <= max_nb {
        // Build [nb, dm] @ [dm, dh] with the XlaBuilder at this shape.
        let builder = xla::XlaBuilder::new(&format!("gemm_{nb}"));
        let x = builder.parameter(0, xla::ElementType::F32, &[nb as i64, dm as i64], "x")?;
        let w = builder.parameter(1, xla::ElementType::F32, &[dm as i64, dh as i64], "w")?;
        let comp = x.matmul(&w)?.build()?;
        let exe = rt.compile_computation(&comp)?;

        let mut xv = vec![0f32; nb * dm];
        let mut wv = vec![0f32; dm * dh];
        rng.fill_normal(&mut xv, 1.0);
        rng.fill_normal(&mut wv, 1.0);
        let xl = xla::Literal::vec1(&xv).reshape(&[nb as i64, dm as i64])?;
        let wl = xla::Literal::vec1(&wv).reshape(&[dm as i64, dh as i64])?;

        let r = bench(&format!("nb{nb}"), &opts, || {
            let out = exe.execute::<&xla::Literal>(&[&xl, &wl]).unwrap();
            let _ = out[0][0].to_literal_sync().unwrap();
        });
        let flops = matmul_flops(nb, dm, dh);
        results.push((nb, r.mean_secs(), gflops(flops, r.mean_secs())));
        nb *= 2;
    }

    let peak = results.iter().map(|r| r.2).fold(0.0, f64::max);
    for (nb, secs, gf) in &results {
        table.row(vec![
            nb.to_string(),
            format!("{:.3}", secs * 1e3),
            format!("{gf:.2}"),
            format!("{:.1}%", 100.0 * gf / peak),
        ]);
        csv.rowf(&[*nb as f64, secs * 1e3, *gf])?;
    }
    println!("{}", table.render());

    let small = results[0].2;
    println!(
        "GEMV (batch 1) runs at {:.1}% of plateau — the paper's <5% motivates \
         FastMoE's per-expert batching.",
        100.0 * small / peak
    );
    Ok(())
}
