//! Figure 5: single-device MoE layer latency vs number of experts —
//! FastMoE (batched dispatch + grouped expert GEMM) against the naive
//! pure-framework-ops baseline (Rau 2019-style: every expert over the
//! whole batch, masked), forward-only and forward+backward.
//!
//! ```bash
//! cargo bench --bench fig5_single
//! ```
//!
//! Expected shape (paper Fig. 5): FastMoE latency roughly flat in the
//! expert count; the baseline grows ~linearly; the gap widens with
//! more experts.
//!
//! The `moe_fwd_zc_ms` column times the same forward through the
//! zero-copy argument path (`Executable::run_refs`: borrowed inputs,
//! no owned-tensor staging) — the single-device share of the PR-3
//! bytes-copied win, visible next to the owned-argument `run`.

use std::collections::BTreeSet;

use fastmoe::bench::{bench, BenchOpts, Table};
use fastmoe::metrics::CsvWriter;
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::tensor::{HostTensor, HostTensorRef, TensorF32};

fn inputs_for(rt: &Runtime, name: &str, rng: &mut Rng) -> Vec<HostTensor> {
    let meta = &rt.manifest.artifact(name).unwrap().inputs;
    meta.iter()
        .map(|s| {
            let mut t = TensorF32::zeros(&s.shape);
            rng.fill_normal(&mut t.data, 0.3);
            HostTensor::F32(t)
        })
        .collect()
}

fn main() -> fastmoe::Result<()> {
    let rt = Runtime::open_default()?;
    let opts = BenchOpts::from_env();
    let fig5 = rt.manifest.family("fig5");
    let expert_counts: BTreeSet<usize> = fig5
        .iter()
        .filter_map(|a| a.meta_usize("n_expert"))
        .collect();
    let some = fig5.first().expect("fig5 artifacts missing (make artifacts)");
    println!(
        "Figure 5 — MoE layer latency vs experts (nb={}, d_m={}, d_h={}, k={})\n",
        some.meta_usize("nb").unwrap(),
        some.meta_usize("d_model").unwrap(),
        some.meta_usize("d_hidden").unwrap(),
        some.meta_usize("top_k").unwrap(),
    );

    let mut table = Table::new(&[
        "experts",
        "fastmoe_fwd_ms",
        "fastmoe_fwd_zc_ms",
        "naive_fwd_ms",
        "fwd_speedup",
        "fastmoe_train_ms",
        "naive_train_ms",
        "train_speedup",
    ]);
    let mut csv = CsvWriter::create(
        "runs/fig5_single.csv",
        &[
            "experts", "moe_fwd_ms", "moe_fwd_zc_ms", "naive_fwd_ms", "moe_train_ms",
            "naive_train_ms",
        ],
    )?;
    let mut rng = Rng::new(5);

    for &ne in &expert_counts {
        let mut ms = [0f64; 4];
        let mut zc_ms = 0f64;
        for (i, kind) in ["moe_fwd", "naive_fwd", "moe_grad", "naive_grad"]
            .iter()
            .enumerate()
        {
            let name = format!("{kind}_e{ne}");
            let exe = rt.executable(&name)?;
            let inputs = inputs_for(&rt, &name, &mut rng);
            let r = bench(&name, &opts, || {
                let _ = exe.run(&inputs).unwrap();
            });
            ms[i] = r.mean_secs() * 1e3;
            if *kind == "moe_fwd" {
                // same forward, zero-copy argument staging
                let refs: Vec<HostTensorRef> =
                    inputs.iter().map(HostTensorRef::from).collect();
                let r = bench(&format!("{name}_zc"), &opts, || {
                    let _ = exe.run_refs(&refs).unwrap();
                });
                zc_ms = r.mean_secs() * 1e3;
            }
        }
        // "train" = fwd + bwd: the grad artifacts contain both
        table.row(vec![
            ne.to_string(),
            format!("{:.2}", ms[0]),
            format!("{:.2}", zc_ms),
            format!("{:.2}", ms[1]),
            format!("{:.2}x", ms[1] / ms[0]),
            format!("{:.2}", ms[2]),
            format!("{:.2}", ms[3]),
            format!("{:.2}x", ms[3] / ms[2]),
        ]);
        csv.rowf(&[ne as f64, ms[0], zc_ms, ms[1], ms[2], ms[3]])?;
        println!(
            "  e{ne}: fwd {:.2} (zc {:.2}) vs {:.2} ms, train {:.2} vs {:.2} ms",
            ms[0], zc_ms, ms[1], ms[2], ms[3]
        );
    }

    println!("\n{}", table.render());
    println!("runs/fig5_single.csv written");
    Ok(())
}
