"""Stage-split graphs for the distributed runtime + fused fig-5 layers.

The distributed (expert-parallel) MoE layer runs as a chain of small HLO
programs with the Rust coordinator doing the routing between them
(DESIGN.md §4).  Forward:

    gate_fwd -> [host: top-k softmax, counts, Fig-2 all-to-all, scatter]
    expert_fwd (bucketed rows) -> [host: all-to-all back] -> combine_fwd

Backward mirrors it with ``combine_bwd``, ``expert_bwd`` (recompute-style
vjp) and ``gate_bwd``.  Expert row counts vary per iteration, so expert
graphs are compiled per power-of-two *bucket* and inputs are zero-padded
to the bucket — the static-shape analog of FastMoE's dynamic buffers.

Gating convention (identical in fused, staged and Rust code): select
top-k raw scores, then softmax over exactly those k scores.  For
renormalised-softmax gates this is mathematically the same weights, and
it makes the host-side backward a local k-way softmax Jacobian.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .kernels import combine_rows, expert_ffn, gate_scores


# ---------------------------------------------------------------------------
# Gate stages
# ---------------------------------------------------------------------------

_BIG = 1 << 30  # whole-array blocks: single grid step (CPU PJRT config)


def gate_fwd(x, wg, bg, *, interpret: bool = True):
    """``[n_b, d_m] -> [n_b, n_e_global]`` raw gate scores (L1 kernel)."""
    return (gate_scores(x, wg, bg, block_rows=_BIG, interpret=interpret),)


def gate_bwd(x, wg, dscores):
    """Backward of the gate GEMM: returns ``(dx, dwg, dbg)``."""
    x32 = x.astype(jnp.float32)
    ds = dscores.astype(jnp.float32)
    dx = ds @ wg.astype(jnp.float32).T
    dwg = x32.T @ ds
    dbg = jnp.sum(ds, axis=0)
    return dx.astype(x.dtype), dwg, dbg


# ---------------------------------------------------------------------------
# Expert shard stages (bucketed)
# ---------------------------------------------------------------------------

def expert_fwd(xs, w1, b1, w2, b2, *, interpret: bool = True):
    """Grouped FFN over one worker's expert shard: ``[n_e_l, B, d_m]``."""
    return (expert_ffn(xs, w1, b1, w2, b2, interpret=interpret, whole=True),)


def expert_bwd(xs, w1, b1, w2, b2, dys, *, interpret: bool = True):
    """Recompute-style vjp of :func:`expert_fwd`.

    Returns ``(dxs, dw1, db1, dw2, db2)``.  Padding rows carry zero
    cotangents (the host zero-fills them), so their spurious forward
    values contribute nothing.
    """
    def f(xs_, w1_, b1_, w2_, b2_):
        return expert_ffn(xs_, w1_, b1_, w2_, b2_, interpret=interpret,
                          whole=True)

    _, vjp = jax.vjp(f, xs, w1, b1, w2, b2)
    return vjp(dys)


# ---------------------------------------------------------------------------
# Combine stages
# ---------------------------------------------------------------------------

def combine_fwd(ys, slots, w, *, interpret: bool = True):
    """Weighted gather back to token order: ``(y_slots, slots, w) -> out``."""
    return (combine_rows(ys, slots, w, block_rows=_BIG, interpret=interpret),)


def combine_bwd(ys, slots, w, dout, *, interpret: bool = True):
    """vjp of :func:`combine_fwd` wrt ``(ys, w)`` -> ``(dys, dw)``."""
    def f(ys_, w_):
        return combine_rows(ys_, slots, w_, block_rows=_BIG,
                            interpret=interpret)

    _, vjp = jax.vjp(f, ys, w)
    return vjp(dout)


# ---------------------------------------------------------------------------
# Fused single-device layers (Figure 5)
# ---------------------------------------------------------------------------

def fused_moe_fwd(x, wg, bg, w1, b1, w2, b2, *, k: int, capacity: int,
                  interpret: bool = True):
    """Whole MoE layer in one program (the FastMoE single-GPU path)."""
    return (
        layers.moe_ffn(x, wg, bg, w1, b1, w2, b2, k=k, capacity=capacity,
                       interpret=interpret),
    )


def fused_moe_grad(x, wg, bg, w1, b1, w2, b2, *, k: int, capacity: int,
                   interpret: bool = True):
    """Training-shaped fused layer: loss = mean(y²)/2, grads wrt all inputs.

    Returns ``(loss, dx, dwg, dbg, dw1, db1, dw2, db2)`` — the fig-5
    "forward + backward" configuration.
    """
    def loss_fn(x_, wg_, bg_, w1_, b1_, w2_, b2_):
        y = layers.moe_ffn(x_, wg_, bg_, w1_, b1_, w2_, b2_, k=k,
                           capacity=capacity, interpret=interpret)
        return 0.5 * jnp.mean(jnp.square(y.astype(jnp.float32)))

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2, 3, 4, 5, 6))(
        x, wg, bg, w1, b1, w2, b2
    )
    return (loss,) + grads


def naive_moe_fwd(x, wg, bg, w1, b1, w2, b2, *, k: int):
    """The pure-framework-ops baseline layer (no kernels, no dispatch)."""
    return (layers.naive_moe_ffn(x, wg, bg, w1, b1, w2, b2, k=k),)


def naive_moe_grad(x, wg, bg, w1, b1, w2, b2, *, k: int):
    def loss_fn(x_, wg_, bg_, w1_, b1_, w2_, b2_):
        y = layers.naive_moe_ffn(x_, wg_, bg_, w1_, b1_, w2_, b2_, k=k)
        return 0.5 * jnp.mean(jnp.square(y.astype(jnp.float32)))

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2, 3, 4, 5, 6))(
        x, wg, bg, w1, b1, w2, b2
    )
    return (loss,) + grads


def dense_ffn_fwd(x, w1, b1, w2, b2):
    """Dense FFN reference layer (per-sample-loop baseline feeds it row
    slices; fig-3's GEMM-vs-GEMV cliff is driven from Rust XlaBuilder)."""
    return (layers.dense_ffn(x, w1, b1, w2, b2),)


# ---------------------------------------------------------------------------
# Host-side gating reference (mirrors rust/src/moe/topk.rs; python tests
# pin the Rust implementation to this).
# ---------------------------------------------------------------------------

def topk_softmax(scores, k: int):
    """Top-k raw scores -> softmax over the selected k. Returns (w, idx)."""
    from .kernels.ref import topk_compat

    s, idx = topk_compat(scores.astype(jnp.float32), k)
    w = jax.nn.softmax(s, axis=-1)
    return w, idx.astype(jnp.int32)


def topk_softmax_bwd(scores, k: int, dw):
    """Backward of :func:`topk_softmax` wrt raw scores (scatter k-way
    softmax Jacobian into the full score matrix)."""
    def f(s):
        w, _ = topk_softmax(s, k)
        return w

    _, vjp = jax.vjp(f, scores)
    return vjp(dw)[0]
