//! Elastic fault recovery — from *contained* worker death to *survived*.
//!
//! The failure machinery built up through PRs 3–5 (TCP keepalive probes,
//! death-aware receives, `Error::Worker` containment in
//! [`run_workers`](crate::comm::run_workers)) only detects a dead rank
//! and unwinds; every death still ends the run.  This module adds the
//! recovery layer the ROADMAP names as the robustness north-star:
//!
//! * [`Membership`] + [`agree_membership`] — a dissemination-style
//!   gossip of suspected-dead bitsets over the reserved [`FAULT_TAG`]
//!   band.  Survivors exchange snapshots for a fixed `world` rounds
//!   (monotone union ⇒ convergence in ≤ world−1), folding send *and*
//!   recv failures into the suspected set as they happen, so organic
//!   detection (a peer's handle is gone) and schedule-injected
//!   suspicion (deterministic chaos) flow through one code path.  The
//!   gossip runs on raw sends in a reserved band and consumes **zero**
//!   collective sequence numbers — the world tag namespace stays in
//!   lockstep across ranks that did and did not gossip.
//! * [`RecoverMode`] — the `[fault] recover` policy: `abort` (today's
//!   behaviour), `degrade` (quarantine the dead rank, reroute its
//!   experts to shadow replicas or zero-weight drops, keep training),
//!   `rejoin` (degrade, then restore the rank from checkpoint +
//!   live peer-transfer and return to full strength).
//! * [`ChaosSchedule`] — the deterministic fault harness: `kill@N:rR`,
//!   `delay@N:rR:MS`, `rejoin@N:rR` events parsed from `[fault] chaos`
//!   and fired at step boundaries by [`Recovery::poll`], identically on
//!   the thread and tcp backends.  Events fire at the **start** of step
//!   `N` (the step executes under the new membership) so recovery runs
//!   are pinnable bit-for-bit against planned-handover references —
//!   no sleeps-and-hope.
//! * [`Recovery`] — the per-rank driver the trainers poll once per step
//!   boundary: it merges schedule events with organically
//!   [`suspect`](Recovery::suspect)ed ranks and emits the
//!   [`RecoveryAction`] the trainer executes (degrade / rejoin /
//!   abort).
//!
//! Failure model: a *quarantined* rank stays in the world-sized
//! collectives as a drained zombie (its batch contributes zero weight
//! and zero gradient) so that survivor tag namespaces never diverge —
//! this models compute-level failure (accelerator loss, wedged expert)
//! where the host process survives.  True process death on the thread
//! backend is also survived: the gossip's death-aware receives fold the
//! dropped handle into the suspected set and the survivors continue —
//! but then the dead rank's own training loop is simply gone, and a
//! full-strength return needs the `rejoin` path (fresh process,
//! `--resume`).  False suspicion of a *live* rank is unsupported: the
//! gossip skips suspected peers entirely, so a live-but-suspected rank
//! would wait forever on peers that no longer talk to it.  On these
//! backends sends to live peers do not fail transiently, so suspicion
//! is always genuine (injected or observed).

use crate::comm::{Comm, ProcessGroup};
use crate::error::{Error, Result};

/// Reserved tag band of the membership gossip.  Low byte `2` keeps the
/// band disjoint from every collective code (low byte 0–9, 11, 64+,
/// 130/131 all ride `(seq << 8) | code` with seq ≥ 1, so their bit 59
/// is clear at any realistic seq), from the serve control band
/// `CTL_TAG = (1 << 59) | 1`, from the shadow-group salts (bit 60), the
/// topology salts (bits 61/62) and the keepalive tag (`u64::MAX`).
pub const FAULT_TAG: u64 = (1 << 59) | 2;

/// Tag-space salt of the survivor [`ProcessGroup`] a degraded run
/// re-binds its collectives to — its own band, disjoint from the
/// shadow (bit 60) and topology (bits 61/62) salts.
pub const FAULT_SALT: u64 = 1 << 58;

/// Tag of gossip round `round` in membership epoch `epoch`: rounds in
/// bits 8–19, epochs in bits 20–58, the [`FAULT_TAG`] marker in bit 59
/// + low byte.  Distinct epochs (successive failures) and rounds never
/// collide, and parked stale messages can never be mistaken for a
/// collective.
pub fn gossip_tag(epoch: u64, round: u64) -> u64 {
    debug_assert!(round < (1 << 12), "gossip round fits 12 bits");
    FAULT_TAG | (epoch << 20) | (round << 8)
}

/// The `[fault] recover` policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoverMode {
    /// Detect and unwind — the pre-fault behaviour.
    Abort,
    /// Quarantine the dead rank and keep training on the survivors.
    Degrade,
    /// Degrade, then restore the rank (checkpoint + peer-transfer) and
    /// return to full strength at the scheduled reconnect step.
    Rejoin,
}

impl RecoverMode {
    pub const KINDS: &'static [&'static str] = &["abort", "degrade", "rejoin"];

    pub fn parse(s: &str) -> Result<RecoverMode> {
        match s {
            "abort" => Ok(RecoverMode::Abort),
            "degrade" => Ok(RecoverMode::Degrade),
            "rejoin" => Ok(RecoverMode::Rejoin),
            other => Err(Error::Config(format!(
                "unknown recover mode {other:?} (expected one of {:?})",
                Self::KINDS
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RecoverMode::Abort => "abort",
            RecoverMode::Degrade => "degrade",
            RecoverMode::Rejoin => "rejoin",
        }
    }
}

/// An agreed view of which ranks are dead, shared by every surviving
/// rank (and assumed, identically, by a quarantined one).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Membership {
    /// Full world size the view is over.
    pub world: usize,
    /// Dead ranks, ascending.
    pub dead: Vec<usize>,
}

impl Membership {
    /// Build the view without gossiping — the quarantined rank's (and
    /// the chaos tests' reference runs') entry point.
    pub fn assume(world: usize, dead: &[usize]) -> Membership {
        let mut dead: Vec<usize> = dead.iter().copied().filter(|&r| r < world).collect();
        dead.sort_unstable();
        dead.dedup();
        Membership { world, dead }
    }

    pub fn is_dead(&self, rank: usize) -> bool {
        self.dead.binary_search(&rank).is_ok()
    }

    /// Live ranks, ascending.
    pub fn survivors(&self) -> Vec<usize> {
        (0..self.world).filter(|&r| !self.is_dead(r)).collect()
    }

    /// The survivor sub-group collectives re-bind to (`rank` must be a
    /// survivor), salted into the [`FAULT_SALT`] band.
    pub fn survivor_group(&self, rank: usize) -> Result<ProcessGroup> {
        ProcessGroup::new(self.survivors(), rank, FAULT_SALT)
    }
}

/// Dissemination-style membership agreement over the [`FAULT_TAG`]
/// band: every rank snapshots its suspected-dead bitset as an f32 0/1
/// vector, exchanges it with every peer it still believes alive, and
/// folds arrivals (and send/recv *failures* — organic death detection)
/// into its own set, for a fixed `world` rounds.  The union is
/// monotone, so all survivors converge on the same set; suspected
/// peers are skipped entirely, so a gossip round never blocks on a
/// dead rank.  Consumes no collective sequence numbers.
pub fn agree_membership<C: Comm + ?Sized>(
    comm: &mut C,
    suspected: &[usize],
    epoch: u64,
) -> Result<Membership> {
    let world = comm.size();
    let me = comm.rank();
    let mut sus = vec![false; world];
    for &r in suspected {
        if r < world {
            sus[r] = true;
        }
    }
    if sus[me] {
        return Err(Error::Comm(format!(
            "membership: rank {me} gossiping while suspecting itself \
             (a quarantined rank assumes, it does not agree)"
        )));
    }
    for round in 0..world as u64 {
        let tag = gossip_tag(epoch, round);
        let snapshot: Vec<f32> =
            sus.iter().map(|&d| if d { 1.0 } else { 0.0 }).collect();
        // send first (failures mark the peer before we'd block on it)…
        for p in 0..world {
            if p == me || sus[p] {
                continue;
            }
            if comm.send(p, tag, snapshot.clone()).is_err() {
                sus[p] = true;
            }
        }
        // …then fold arrivals; a recv failure (death-aware receive,
        // tcp read error) is this round's detection of that peer
        for p in 0..world {
            if p == me || sus[p] {
                continue;
            }
            match comm.recv(p, tag) {
                Ok(bits) => {
                    if bits.len() != world {
                        return Err(Error::Comm(format!(
                            "membership: rank {p} gossip of {} bits, world {world}",
                            bits.len()
                        )));
                    }
                    for (r, s) in sus.iter_mut().enumerate() {
                        if r != me && bits[r] != 0.0 {
                            *s = true;
                        }
                    }
                }
                Err(_) => sus[p] = true,
            }
        }
    }
    let dead: Vec<usize> =
        (0..world).filter(|&r| sus[r]).collect();
    Ok(Membership { world, dead })
}

/// One event of a deterministic fault schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ChaosEvent {
    /// Rank `rank` dies at the start of step `step`.
    Kill { rank: usize, step: u64 },
    /// Rank `rank` sleeps `millis` ms at the start of step `step` —
    /// a straggler/timeout probe, membership-neutral.
    Delay { rank: usize, step: u64, millis: u64 },
    /// Rank `rank` reconnects at the start of step `step` (meaningful
    /// under [`RecoverMode::Rejoin`]).
    Rejoin { rank: usize, step: u64 },
}

/// A parsed `[fault] chaos` schedule: comma-separated
/// `kill@STEP:rRANK`, `delay@STEP:rRANK:MILLIS`, `rejoin@STEP:rRANK`
/// events, e.g. `"kill@3:r1,rejoin@5:r1"`.  Purely data — the same
/// schedule drives the thread and tcp backends identically.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ChaosSchedule {
    events: Vec<ChaosEvent>,
}

impl ChaosSchedule {
    pub fn parse(spec: &str) -> Result<ChaosSchedule> {
        let mut events = Vec::new();
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind, rest) = item.split_once('@').ok_or_else(|| {
                Error::Config(format!("chaos event {item:?}: expected KIND@STEP:rRANK"))
            })?;
            let mut parts = rest.split(':');
            let step: u64 = parts
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| {
                    Error::Config(format!("chaos event {item:?}: bad step"))
                })?;
            let rank: usize = parts
                .next()
                .and_then(|r| r.strip_prefix('r'))
                .and_then(|r| r.parse().ok())
                .ok_or_else(|| {
                    Error::Config(format!("chaos event {item:?}: bad rank (want rN)"))
                })?;
            let millis = parts.next();
            let event = match (kind, millis) {
                ("kill", None) => ChaosEvent::Kill { rank, step },
                ("rejoin", None) => ChaosEvent::Rejoin { rank, step },
                ("delay", Some(ms)) => ChaosEvent::Delay {
                    rank,
                    step,
                    millis: ms.parse().map_err(|_| {
                        Error::Config(format!("chaos event {item:?}: bad millis"))
                    })?,
                },
                _ => {
                    return Err(Error::Config(format!(
                        "chaos event {item:?}: unknown kind or arity \
                         (kill@N:rR, delay@N:rR:MS, rejoin@N:rR)"
                    )))
                }
            };
            if parts.next().is_some() {
                return Err(Error::Config(format!(
                    "chaos event {item:?}: trailing fields"
                )));
            }
            events.push(event);
        }
        Ok(ChaosSchedule { events })
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[ChaosEvent] {
        &self.events
    }

    /// Ranks killed at the start of `step`, ascending.
    pub fn kills_at(&self, step: u64) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .events
            .iter()
            .filter_map(|e| match e {
                ChaosEvent::Kill { rank, step: s } if *s == step => Some(*rank),
                _ => None,
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Ranks rejoining at the start of `step`, ascending.
    pub fn rejoins_at(&self, step: u64) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .events
            .iter()
            .filter_map(|e| match e {
                ChaosEvent::Rejoin { rank, step: s } if *s == step => Some(*rank),
                _ => None,
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Total injected delay for `rank` at the start of `step`, if any.
    pub fn delay_for(&self, rank: usize, step: u64) -> Option<u64> {
        let ms: u64 = self
            .events
            .iter()
            .filter_map(|e| match e {
                ChaosEvent::Delay { rank: r, step: s, millis }
                    if *r == rank && *s == step =>
                {
                    Some(*millis)
                }
                _ => None,
            })
            .sum();
        (ms > 0).then_some(ms)
    }
}

/// What the trainer must do at this step boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum RecoveryAction {
    /// Agreed membership changed: quarantine the dead rank(s) and
    /// continue on the survivors.
    Degrade(Membership),
    /// The named rank rejoins: restore it and return to full strength.
    Rejoin(usize),
    /// `recover = "abort"`: unwind with the named rank as the cause.
    Abort(usize),
}

/// The per-rank recovery driver: polled once per step boundary, it
/// merges [`ChaosSchedule`] events with organically
/// [`suspect`](Recovery::suspect)ed ranks and emits the action the
/// trainer executes.  Every rank polls with the same step, so schedule
/// events fire on all ranks at the same boundary — the determinism the
/// bitwise recovery pins stand on.
#[derive(Debug)]
pub struct Recovery {
    pub mode: RecoverMode,
    schedule: ChaosSchedule,
    epoch: u64,
    membership: Option<Membership>,
    pending: Vec<usize>,
}

impl Recovery {
    pub fn new(mode: RecoverMode, schedule: ChaosSchedule) -> Recovery {
        Recovery { mode, schedule, epoch: 0, membership: None, pending: Vec::new() }
    }

    /// Build from the `[fault]` config section.
    pub fn from_config(cfg: &crate::config::FaultConfig) -> Result<Recovery> {
        Ok(Recovery::new(
            RecoverMode::parse(&cfg.recover)?,
            ChaosSchedule::parse(&cfg.chaos)?,
        ))
    }

    /// The current degraded view, if any.
    pub fn degraded(&self) -> Option<&Membership> {
        self.membership.as_ref()
    }

    /// Fold an organically-detected failure (e.g. an
    /// [`Error::Worker`]/[`Error::Timeout`] observed mid-step) into the
    /// next [`poll`](Recovery::poll).
    pub fn suspect(&mut self, rank: usize) {
        if !self.pending.contains(&rank) {
            self.pending.push(rank);
        }
    }

    /// Fire the step-`step` boundary: injected delays sleep here,
    /// rejoin events (under [`RecoverMode::Rejoin`], while degraded)
    /// return [`RecoveryAction::Rejoin`], and kills — injected or
    /// [`suspect`](Recovery::suspect)ed — run membership agreement
    /// (survivors gossip, quarantined ranks assume) and return
    /// [`RecoveryAction::Degrade`] / [`RecoveryAction::Abort`].
    pub fn poll<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        step: u64,
    ) -> Result<Option<RecoveryAction>> {
        if let Some(ms) = self.schedule.delay_for(comm.rank(), step) {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        if self.membership.is_some() && self.mode == RecoverMode::Rejoin {
            if let Some(&r) = self.schedule.rejoins_at(step).first() {
                self.membership = None;
                return Ok(Some(RecoveryAction::Rejoin(r)));
            }
        }
        let mut suspects: Vec<usize> = self.pending.drain(..).collect();
        suspects.extend(self.schedule.kills_at(step));
        suspects.sort_unstable();
        suspects.dedup();
        if suspects.is_empty() {
            return Ok(None);
        }
        if self.mode == RecoverMode::Abort {
            return Ok(Some(RecoveryAction::Abort(suspects[0])));
        }
        self.epoch += 1;
        let m = if suspects.contains(&comm.rank()) {
            // the quarantined rank does not gossip — it assumes the
            // same view the survivors will agree on
            Membership::assume(comm.size(), &suspects)
        } else {
            agree_membership(comm, &suspects, self.epoch)?
        };
        self.membership = Some(m.clone());
        Ok(Some(RecoveryAction::Degrade(m)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_workers;

    #[test]
    fn recover_mode_parses_and_names() {
        for &k in RecoverMode::KINDS {
            assert_eq!(RecoverMode::parse(k).unwrap().name(), k);
        }
        assert!(RecoverMode::parse("retry").is_err());
    }

    #[test]
    fn fault_band_is_disjoint_from_every_other_band() {
        // serve control band: (1 << 59) | 1 — same bit, different low byte
        assert_eq!(FAULT_TAG & 0xff, 2);
        assert_ne!(FAULT_TAG, (1 << 59) | 1);
        // collective tags are (seq << 8) | code with code ≤ 131 and a
        // seq far below 2^51, so bit 59 is never set on them
        for code in [0u64, 1, 2, 7, 8, 9, 11, 64, 130, 131] {
            assert_eq!(((1_000_000u64 << 8) | code) & (1 << 59), 0);
        }
        // gossip tags stay inside the bit-59 band for sane epochs/rounds
        let t = gossip_tag(3, 2);
        assert_eq!(t & (1 << 59), 1 << 59);
        assert_eq!(t & 0xff, 2);
        assert_eq!(t & (0b1111 << 60), 0, "clear of shadow/topology salts");
        assert_ne!(gossip_tag(1, 0), gossip_tag(2, 0));
        assert_ne!(gossip_tag(1, 0), gossip_tag(1, 1));
        // the survivor-group salt is its own band too
        assert_eq!(FAULT_SALT & FAULT_TAG, 0);
        assert_eq!(FAULT_SALT & (0b111 << 60), 0);
    }

    #[test]
    fn chaos_schedule_parses_and_queries() {
        let s = ChaosSchedule::parse("kill@5:r1, delay@3:r0:20 ,rejoin@9:r1").unwrap();
        assert_eq!(s.events().len(), 3);
        assert_eq!(s.kills_at(5), vec![1]);
        assert!(s.kills_at(3).is_empty());
        assert_eq!(s.rejoins_at(9), vec![1]);
        assert_eq!(s.delay_for(0, 3), Some(20));
        assert_eq!(s.delay_for(1, 3), None);
        assert!(ChaosSchedule::parse("").unwrap().is_empty());
        // duplicate kills collapse
        let s = ChaosSchedule::parse("kill@2:r3,kill@2:r1,kill@2:r3").unwrap();
        assert_eq!(s.kills_at(2), vec![1, 3]);
        for bad in [
            "boom@1:r0",
            "kill@x:r0",
            "kill@1:q0",
            "kill@1:r0:7",
            "delay@1:r0",
            "delay@1:r0:ms",
            "rejoin@1:r0:9",
            "kill@1:r0:1:2",
        ] {
            assert!(ChaosSchedule::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn membership_assume_and_queries() {
        let m = Membership::assume(4, &[2, 2, 9]);
        assert_eq!(m.dead, vec![2]);
        assert!(m.is_dead(2) && !m.is_dead(1));
        assert_eq!(m.survivors(), vec![0, 1, 3]);
        let g = m.survivor_group(3).unwrap();
        assert_eq!(g.ranks(), &[0, 1, 3]);
        assert_eq!(g.rank(), 2);
        assert!(m.survivor_group(2).is_err(), "dead rank has no group slot");
    }

    #[test]
    fn injected_suspicion_agrees_without_touching_the_dead_rank() {
        // the chaos path: every survivor starts from the same injected
        // suspicion, so the dead rank is never sent to or waited on —
        // here rank 3 is a quarantined zombie that only assumes
        run_workers(4, |mut h| {
            let m = if h.rank() == 3 {
                Membership::assume(h.size(), &[3])
            } else {
                agree_membership(&mut h, &[3], 1)?
            };
            assert_eq!(m, Membership::assume(4, &[3]));
            assert_eq!(m.survivors(), vec![0, 1, 2]);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn organic_death_is_detected_and_agreed() {
        // rank 2 exits immediately (its handle drops); ranks 0 and 1
        // start with NO suspicion and must still converge on {2} via
        // send/recv failures folding into the gossip — the death-aware
        // receive turns the dropped handle into suspicion within one
        // round, and the next round spreads it
        run_workers(3, |mut h| {
            if h.rank() == 2 {
                return Ok(());
            }
            let m = agree_membership(&mut h, &[], 1)?;
            assert_eq!(m, Membership::assume(3, &[2]), "rank {}", h.rank());
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn gossip_consumes_no_collective_seqs() {
        // the reserved band must leave the world tag namespace in
        // lockstep: a collective issued *after* agreement still works
        run_workers(4, |mut h| {
            let m = if h.rank() == 1 {
                Membership::assume(h.size(), &[1])
            } else {
                agree_membership(&mut h, &[1], 1)?
            };
            let survivors = m.survivors();
            let mut buf = vec![(h.rank() + 1) as f32; 3];
            if h.rank() != 1 {
                h.all_reduce_sum_group(&mut buf, &survivors)?;
                // 1 + 3 + 4 = 8
                assert!(buf.iter().all(|&x| x == 8.0), "{buf:?}");
            } else {
                // the zombie burns the matching seq (survivor group > 1)
                let _ = h.next_seq();
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn recovery_poll_fires_schedule_events() {
        run_workers(2, |mut h| {
            let sched = ChaosSchedule::parse("kill@3:r1,rejoin@5:r1").unwrap();
            let mut rec = Recovery::new(RecoverMode::Rejoin, sched);
            assert_eq!(rec.poll(&mut h, 0)?, None);
            assert!(rec.degraded().is_none());
            let want = Membership::assume(2, &[1]);
            match rec.poll(&mut h, 3)? {
                Some(RecoveryAction::Degrade(m)) => assert_eq!(m, want),
                other => panic!("rank {}: {other:?}", h.rank()),
            }
            assert_eq!(rec.degraded(), Some(&want));
            assert_eq!(rec.poll(&mut h, 4)?, None);
            assert_eq!(rec.poll(&mut h, 5)?, Some(RecoveryAction::Rejoin(1)));
            assert!(rec.degraded().is_none());
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn recovery_abort_mode_and_organic_suspicion() {
        run_workers(2, |mut h| {
            // abort mode: a kill returns Abort without gossiping
            let sched = ChaosSchedule::parse("kill@2:r0").unwrap();
            let mut rec = Recovery::new(RecoverMode::Abort, sched);
            assert_eq!(rec.poll(&mut h, 2)?, Some(RecoveryAction::Abort(0)));
            // organic suspicion folds into the next poll
            let mut rec =
                Recovery::new(RecoverMode::Degrade, ChaosSchedule::default());
            rec.suspect(if h.rank() == 0 { 1 } else { 0 });
            // each rank suspects the other, so each gossips over a
            // world with no believed-alive peers — agreement degenerates
            // to its own (asymmetric) view without blocking
            let got = rec.poll(&mut h, 0)?;
            match got {
                Some(RecoveryAction::Degrade(m)) => {
                    assert_eq!(m.dead, vec![1 - h.rank()]);
                }
                other => panic!("{other:?}"),
            }
            Ok(())
        })
        .unwrap();
    }
}
