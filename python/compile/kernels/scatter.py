"""Row scatter / weighted gather kernels (FastMoE's §4 data shuffle).

FastMoE's core single-device insight: tokens routed to the same expert
must be *contiguous* so the expert sees one batched GEMM instead of many
GEMVs.  ``scatter_rows`` materialises the expert-contiguous layout from a
slot->source index map; ``combine_rows`` reverses it, weighting each of a
token's ``k`` expert outputs by its gate score (Algorithm 1's synthesis
step).

Index conventions (shared with the Rust ``moe::DispatchPlan``):

* ``src[s]``  — for output slot ``s``, the source token row, or ``-1``
  for a padding slot (capacity slack).  Padding slots produce zero rows.
* ``slots[i, j]`` — for token ``i``, the slot holding its ``j``-th expert
  output, or an out-of-range sentinel (``>= n_slots``) when the
  assignment was dropped by capacity; dropped assignments contribute 0.

On TPU the index map is a scalar-prefetch operand; under interpret mode
the same kernel body runs with numpy semantics.  The feature matrix is
kept whole in VMEM per grid step (documented trade-off: row-permute is
bandwidth-bound, so blocking the *output* rows is what matters).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _scatter_kernel(src_ref, x_ref, o_ref):
    src = src_ref[...]
    x = x_ref[...]
    # Negative indices would *wrap* under jnp.take, so remap the -1
    # padding sentinel to an out-of-range index first; mode="fill" then
    # yields exact zero rows for every padding slot.
    src = jnp.where(src < 0, x.shape[0], src)
    o_ref[...] = jnp.take(x, src, axis=0, mode="fill", fill_value=0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_slots", "block_rows", "interpret"))
def _scatter_rows_call(x, src, *, n_slots: int, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """Scatter token rows into expert-contiguous slots.

    Args:
      x:   ``[n_b, d_m]`` token features.
      src: ``[n_slots]`` int32 source row per slot (``-1`` = padding).
      n_slots: total slot count (``n_e * capacity`` in the fused layer).

    Returns:
      ``[n_slots, d_m]`` scattered features, zeros at padding slots.
    """
    n_b, d_m = x.shape
    assert src.shape == (n_slots,)
    bm = min(block_rows, n_slots)
    pad = (-n_slots) % bm
    if pad:
        src = jnp.pad(src, (0, pad), constant_values=-1)
    grid = ((n_slots + pad) // bm,)

    out = pl.pallas_call(
        _scatter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((n_b, d_m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d_m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_slots + pad, d_m), x.dtype),
        interpret=interpret,
    )(src, x)
    return out[:n_slots]


def scatter_rows(x, src, *, n_slots: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                 interpret: bool = True):
    """Differentiable wrapper: forward is the Pallas scatter; backward is
    the transposed movement, a segment scatter-add back to token order
    (``dx[src[s]] += dxs[s]``), expressed as an XLA scatter-add."""

    def impl(x_):
        return _scatter_rows_call(x_, src, n_slots=n_slots,
                                  block_rows=block_rows, interpret=interpret)

    f = jax.custom_vjp(impl)

    def fwd(x_):
        return impl(x_), (x_.shape[0],)

    def bwd(res, dxs):
        (n_b,) = res
        valid = src >= 0
        idx = jnp.where(valid, src, n_b)  # OOB -> dropped by mode="drop"
        contrib = jnp.where(valid[:, None], dxs.astype(jnp.float32), 0.0)
        dx = (
            jnp.zeros((n_b + 1, dxs.shape[1]), jnp.float32)
            .at[idx]
            .add(contrib, mode="drop")[:n_b]
        )
        return (dx.astype(x.dtype),)

    f.defvjp(fwd, bwd)
    return f(x)


def _combine_kernel(slots_ref, w_ref, y_ref, o_ref):
    slots = slots_ref[...]          # [bm, k]
    w = w_ref[...].astype(jnp.float32)  # [bm, k]
    y = y_ref[...].astype(jnp.float32)  # [n_slots, d_m]
    # Gather each token's k expert outputs; OOB sentinel -> zero row.
    # (negative would wrap under jnp.take, remap like the scatter kernel)
    slots = jnp.where(slots < 0, y.shape[0], slots)
    g = jnp.take(y, slots, axis=0, mode="fill", fill_value=0)  # [bm, k, d_m]
    o_ref[...] = jnp.sum(g * w[..., None], axis=1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _combine_rows_call(y, slots, w, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """Weighted gather: recombine expert outputs into token order.

    Args:
      y:     ``[n_slots, d_m]`` expert outputs in scattered slot order.
      slots: ``[n_b, k]`` int32 slot per (token, choice); OOB = dropped.
      w:     ``[n_b, k]`` gate weights.

    Returns:
      ``[n_b, d_m]`` combined outputs, ``out[i] = sum_j w[i,j] * y[slots[i,j]]``.
    """
    n_slots, d_m = y.shape
    n_b, k = slots.shape
    assert w.shape == (n_b, k)
    bm = min(block_rows, n_b)
    pad = (-n_b) % bm
    if pad:
        slots = jnp.pad(slots, ((0, pad), (0, 0)), constant_values=n_slots)
        w = jnp.pad(w, ((0, pad), (0, 0)))
    grid = ((n_b + pad) // bm,)

    out = pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((n_slots, d_m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d_m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_b + pad, d_m), y.dtype),
        interpret=interpret,
    )(slots, w, y)
    return out[:n_b]


def combine_rows(y, slots, w, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                 interpret: bool = True):
    """Differentiable wrapper around the Pallas combine.

    Backward (both cotangents follow Algorithm 1's synthesis step):
      ``dy[slots[i,j]] += w[i,j] * dout[i]``   (scatter-add) and
      ``dw[i,j] = <y[slots[i,j]], dout[i]>``   (per-assignment dot).
    """

    def impl(y_, w_):
        return _combine_rows_call(y_, slots, w_, block_rows=block_rows,
                                  interpret=interpret)

    f = jax.custom_vjp(impl)

    def fwd(y_, w_):
        return impl(y_, w_), (y_, w_)

    def bwd(res, dout):
        y_, w_ = res
        n_slots, d_m = y_.shape
        n_b, k = slots.shape
        dout32 = dout.astype(jnp.float32)
        valid = (slots >= 0) & (slots < n_slots)
        flat_slots = jnp.where(valid, slots, n_slots).reshape(-1)
        contrib = (w_.astype(jnp.float32)[..., None] * dout32[:, None, :])
        contrib = jnp.where(valid[..., None], contrib, 0.0).reshape(-1, d_m)
        dy = (
            jnp.zeros((n_slots + 1, d_m), jnp.float32)
            .at[flat_slots]
            .add(contrib, mode="drop")[:n_slots]
        ).astype(y_.dtype)
        g = jnp.take(
            y_.astype(jnp.float32),
            jnp.where(valid, slots, n_slots),
            axis=0, mode="fill", fill_value=0,
        )  # [n_b, k, d_m]
        dw = jnp.sum(g * dout32[:, None, :], axis=-1)
        dw = jnp.where(valid, dw, 0.0).astype(w_.dtype)
        return dy, dw

    f.defvjp(fwd, bwd)
    return f(y, w)
