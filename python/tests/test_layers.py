"""L2 layer semantics: dispatch invariants, MoE layer vs literal oracle,
baseline equivalences, capacity behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers
from compile.kernels import ref


# ---------------------------------------------------------------------------
# moe_dispatch invariants (mirrored by rust/src/moe proptests)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    nb=st.integers(1, 80),
    ne=st.integers(1, 12),
    k=st.integers(1, 4),
    factor=st.floats(0.25, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_dispatch_invariants(nb, ne, k, factor, seed):
    k = min(k, ne)
    r = np.random.default_rng(seed)
    idx = jnp.asarray(r.integers(0, ne, (nb, k)), jnp.int32)
    cap = max(1, int(nb * k / ne * factor))
    src, slots = layers.moe_dispatch(idx, ne, cap)
    src, slots = np.asarray(src), np.asarray(slots)
    n_slots = ne * cap

    # 1. every non-padding slot points at a real token
    live = src[src >= 0]
    assert ((live >= 0) & (live < nb)).all()

    # 2. slots/src are mutually inverse where kept
    for i in range(nb):
        for j in range(k):
            s = slots[i, j]
            if s < n_slots:
                assert src[s] == i, (i, j, s)

    # 3. a token's kept assignment sits in the expert block it chose
    for i in range(nb):
        for j in range(k):
            s = slots[i, j]
            if s < n_slots:
                assert s // cap == int(idx[i, j])

    # 4. conservation: kept assignments == non-padding slots
    kept = int((slots < n_slots).sum())
    assert kept == int((src >= 0).sum())

    # 5. capacity never exceeded per expert
    for e in range(ne):
        assert int((src[e * cap : (e + 1) * cap] >= 0).sum()) <= cap


def test_dispatch_drop_priority_is_token_order():
    """When an expert overflows, later tokens are dropped first (matches
    the Rust DispatchPlan and the paper's policy)."""
    idx = jnp.zeros((5, 1), jnp.int32)  # everyone picks expert 0
    src, slots = layers.moe_dispatch(idx, n_e=2, capacity=3)
    src, slots = np.asarray(src), np.asarray(slots)
    assert list(src[:3]) == [0, 1, 2]
    assert (slots[:3, 0] < 6).all() and (slots[3:, 0] == 6).all()


# ---------------------------------------------------------------------------
# MoE layer vs the literal Algorithm-1 oracle
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(
    nb=st.integers(4, 24),
    dm=st.sampled_from([8, 16]),
    dh=st.sampled_from([16, 32]),
    ne=st.sampled_from([2, 4]),
    k=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_ffn_matches_literal_oracle(nb, dm, dh, ne, k, seed):
    r = np.random.default_rng(seed)
    cap = nb * k  # capacity large enough that nothing drops
    x = jnp.asarray(r.standard_normal((nb, dm)), jnp.float32)
    wg = jnp.asarray(r.standard_normal((dm, ne)), jnp.float32)
    bg = jnp.asarray(r.standard_normal(ne) * 0.1, jnp.float32)
    w1 = jnp.asarray(r.standard_normal((ne, dm, dh)) * 0.3, jnp.float32)
    b1 = jnp.asarray(r.standard_normal((ne, dh)) * 0.1, jnp.float32)
    w2 = jnp.asarray(r.standard_normal((ne, dh, dm)) * 0.3, jnp.float32)
    b2 = jnp.asarray(r.standard_normal((ne, dm)) * 0.1, jnp.float32)
    got = layers.moe_ffn(x, wg, bg, w1, b1, w2, b2, k=k, capacity=cap)
    want = ref.moe_layer_ref(x, wg, bg, w1, b1, w2, b2, k, cap)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_naive_moe_matches_fast_moe_without_drops(rng):
    """The fig-5 baseline and the FastMoE layer are the same function when
    capacity is unbounded — only the implementation differs."""
    nb, dm, dh, ne, k = 20, 8, 16, 4, 2
    x = jnp.asarray(rng.standard_normal((nb, dm)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((dm, ne)), jnp.float32)
    bg = jnp.asarray(rng.standard_normal(ne) * 0.1, jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((ne, dm, dh)) * 0.3, jnp.float32)
    b1 = jnp.asarray(rng.standard_normal((ne, dh)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((ne, dh, dm)) * 0.3, jnp.float32)
    b2 = jnp.asarray(rng.standard_normal((ne, dm)) * 0.1, jnp.float32)
    fast = layers.moe_ffn(x, wg, bg, w1, b1, w2, b2, k=k, capacity=nb * k)
    naive = layers.naive_moe_ffn(x, wg, bg, w1, b1, w2, b2, k=k)
    np.testing.assert_allclose(fast, naive, rtol=2e-4, atol=2e-5)


def test_capacity_drops_reduce_output_norm(rng):
    """With capacity 1 almost all assignments drop; output must shrink."""
    nb, dm, dh, ne = 32, 8, 16, 2
    x = jnp.asarray(rng.standard_normal((nb, dm)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((dm, ne)), jnp.float32)
    bg = jnp.zeros(ne, jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((ne, dm, dh)) * 0.3, jnp.float32)
    b1 = jnp.zeros((ne, dh), jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((ne, dh, dm)) * 0.3, jnp.float32)
    b2 = jnp.zeros((ne, dm), jnp.float32)
    full = layers.moe_ffn(x, wg, bg, w1, b1, w2, b2, k=2, capacity=nb * 2)
    tiny = layers.moe_ffn(x, wg, bg, w1, b1, w2, b2, k=2, capacity=1)
    n_full = float(jnp.linalg.norm(full))
    n_tiny = float(jnp.linalg.norm(tiny))
    assert n_tiny < n_full
    # with capacity 1 per expert, at most ne rows are non-zero... each
    # token's contribution needs its slot; count non-zero output rows
    nonzero = int((jnp.abs(tiny).max(axis=1) > 1e-7).sum())
    assert nonzero <= ne * 1


def test_capacity_for_rule():
    assert layers.capacity_for(512, 2, 16) >= 512 * 2 / 16
    assert layers.capacity_for(512, 2, 16) % 8 == 0
    assert layers.capacity_for(1, 1, 64) == 8  # floor


# ---------------------------------------------------------------------------
# attention / layernorm sanity
# ---------------------------------------------------------------------------

def test_layernorm_normalizes(rng):
    x = jnp.asarray(rng.standard_normal((4, 32)) * 5 + 3, jnp.float32)
    y = layers.layernorm(x, jnp.ones(32), jnp.zeros(32))
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(jnp.var(y, -1), 1.0, rtol=1e-3)


def test_attention_is_causal(rng):
    seq, d, h = 16, 32, 4
    x = jnp.asarray(rng.standard_normal((seq, d)), jnp.float32)
    wqkv = jnp.asarray(rng.standard_normal((d, 3 * d)) * 0.2, jnp.float32)
    bqkv = jnp.zeros(3 * d, jnp.float32)
    wo = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)
    bo = jnp.zeros(d, jnp.float32)
    y1 = layers.causal_attention(x, wqkv, bqkv, wo, bo, h)
    # perturbing the future must not change the past
    x2 = x.at[10:].add(1.0)
    y2 = layers.causal_attention(x2, wqkv, bqkv, wo, bo, h)
    np.testing.assert_allclose(y1[:10], y2[:10], rtol=1e-4, atol=1e-5)
    assert float(jnp.abs(y1[10:] - y2[10:]).max()) > 1e-3
