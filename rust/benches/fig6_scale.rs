//! Figure 6: cross-worker scalability of the distributed MoE layer,
//! blocking vs pipelined (overlap) exchange.
//!
//! Throughput (matmul FLOPs of the layer, fwd+bwd) against the number
//! of expert-parallel workers.  The Figure-2 exchange runs on the real
//! comm substrate; *device* time is simulated: this testbed has one
//! CPU core, so W workers are time-sliced and the measured group wall
//! time equals the total serial compute.  Each simulated device gets
//! `wall / W` of compute per worker, overlapped across workers, plus
//! α-β wire time for its egress — exactly the paper's topology of one
//! device per node over Infiniband EDR (substitution table, DESIGN.md
//! §1).  The net model is *scaled* so the comm:compute ratio matches
//! the paper's V100 testbed (a V100 does ~14 TFLOPs against a 12.5
//! GB/s link; this CPU does ~0.05 TFLOPs, so the simulated link is
//! slowed by the same factor — otherwise communication would be
//! invisibly cheap and the figure's shape unreproducible).
//!
//! Every worker count is scored *three* ways from the same measured
//! compute, exchange volume and host copy/alloc counters: blocking
//! (`wire + compute + host`), the PR-2 overlapped schedule
//! (`max(wire, compute)` per chunk, plus the copy-heavy host term —
//! per-chunk batches rebuilt from wire buffers, cloned padded into the
//! executable, freshly allocated), and the PR-3 zero-copy overlapped
//! schedule (same pipeline with exactly the measured copy/alloc
//! bytes — single landing, slice-view staging, pooled buffers).  See
//! `sim::NetModel::moe_step_overlapped_host`; the bench asserts
//! zero-copy ≤ overlapped at every point.
//!
//! A `--nodes N` split (default 2) adds flat-vs-hier columns: the same
//! measured compute, exchange volume and host counters scored under
//! the `[comm] topology = "hier"` policies — leader-aggregated
//! all-to-all, two-level tree all-reduce, locality-ordered chunks
//! (`sim::NetModel::{moe_step_*_hier, grad_step_*_hier}` over the
//! intra-node `alpha_local`/`beta_local` lane).  At every scale point
//! where the model's inter-node bandwidth is the bottleneck
//! (`NetModel::hier_favourable`), the bench asserts hier ≤ flat.
//!
//! A fourth pair of columns scores the *trainer tail* over the layer's
//! parameter volume: the blocking full-gradient ring + host Adam vs
//! the PR-4 bucketed nonblocking sync pipelined against backward and
//! Adam (`sim::NetModel::grad_step_{blocking,overlapped}`, bucket
//! count from `--bucket-kb`).  The overlapped number is the model's
//! idealized pipeline bound (see `grad_step_overlapped`'s docs for
//! what the runtime realises); the bench asserts overlapped ≤
//! blocking at every scale point.  PR-9 adds the ZeRO-sharded columns
//! (`[comm] grad_shard = "zero"`, `sim::NetModel::grad_step_zero` and
//! the rail-aware `grad_step_zero_hier`): same ring volume, optimiser
//! shrunk to the owned `1/w` shard — asserted ≤ blocking at every
//! scale point, and ≤ the flat zero step wherever hier is favourable.
//!
//! A `--skew` mode (PR 7) runs the *placement* scenario instead: an
//! artifact-free analytic study of a skewed routing distribution (one
//! hot expert, paper Fig. 5's pathology).  The static layout and the
//! layout the [`fastmoe::placement::decide`] policy converges to
//! (shadow replicas of the hot expert) are both scored with
//! `sim::NetModel::moe_step_skewed` over the plan-modelled per-rank
//! rows; the bench asserts the rebalanced layout scores strictly below
//! static, and `--json` records both.
//!
//! A `--chaos` mode (PR 8) scores the *fault* scenarios: the same
//! analytic step model over a uniform routing distribution, healthy vs
//! degraded with one rank quarantined — once with every dead-owned
//! expert shadow-covered (its rows redistribute to live replicas: no
//! tokens lost, survivors pay the extra load) and once uncovered (the
//! dead rank's share is score-masked away: cheap but lossy) — plus the
//! α-β cost of the rejoin peer-transfer (three tensors-and-moments
//! slots per covered expert).  The bench asserts covered conserves
//! every row, uncovered drops exactly the dead rank's share, and
//! degraded never scores below healthy.
//!
//! An `--autotune` mode (PR 10) studies the `autotune` subsystem's
//! predicted-vs-measured quality: an artifact-free section searches the
//! `[comm]` knob lattice over three synthetic α-β operating points
//! (comm-bound / balanced / optimiser-bound) and asserts the search is
//! deterministic and never ranks the winner above the current config;
//! when the runtime artifacts are present, a measured section runs a
//! real thread-backend calibration ([`fastmoe::autotune::Calibrator`]
//! via the trainer's `[auto]` hook), asserts the fit is bit-identical
//! on every rank, and records the model-predicted step time against
//! the measured one plus the recommended `[comm]` snippet.
//!
//! ```bash
//! cargo bench --bench fig6_scale                    # scaled IB-EDR (default)
//! cargo bench --bench fig6_scale -- --overlap       # run the pipelined layer path
//! cargo bench --bench fig6_scale -- --chunks 8      # overlap granularity
//! cargo bench --bench fig6_scale -- --json out.json # machine-readable record
//! cargo bench --bench fig6_scale -- --net none      # ablation: free network
//! cargo bench --bench fig6_scale -- --skew          # PR-7 placement scenario
//! cargo bench --bench fig6_scale -- --chaos         # PR-8 fault scenario
//! cargo bench --bench fig6_scale -- --autotune      # PR-10 tuner study
//! ```
//!
//! Expected shape (paper Fig. 6): going 1→2 workers roughly *halves*
//! per-worker efficiency (communication appears); 2→8 grows aggregate
//! throughput sub-linearly (paper: 10 → 25 TFLOPs, ≈2.5×), and the
//! overlapped score recovers part of the gap at every W ≥ 2.

use std::collections::BTreeMap;
use std::sync::Arc;

use fastmoe::bench::Table;
use fastmoe::cli::Args;
use fastmoe::comm::{run_workers, Comm};
use fastmoe::coordinator::MoeLayerBuilder;
use fastmoe::metrics::{Counters, CsvWriter, Stopwatch};
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::sim::{NetModel, NetPreset};
use fastmoe::tensor::TensorF32;
use fastmoe::util::gflops;
use fastmoe::util::json::Json;

fn main() -> fastmoe::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(argv, &["overlap", "skew", "chaos", "autotune"])?;
    let iters = args.usize_or("iters", 4)?;
    let net_name = args.str_or("net", "ib-edr-scaled");
    let chunks = args.usize_or("chunks", 4)?.max(1);
    let bucket_kb = args.usize_or("bucket-kb", 512)?.max(1);
    // node count of the flat-vs-hier comparison columns (worker counts
    // that don't divide evenly fall back to flat, l = 1)
    let nodes = args.usize_or("nodes", 2)?.max(1);
    let overlap_path = args.has_flag("overlap");
    let json_path = args.get("json").map(|s| s.to_string());
    if args.has_flag("skew") {
        // the PR-7 placement scenario is purely analytic — no artifacts
        // or runtime needed, so it runs (and exits) before the open
        return skew_scenario(&args, json_path);
    }
    if args.has_flag("chaos") {
        // the PR-8 fault scenario is likewise analytic-only
        return chaos_scenario(&args, json_path);
    }
    if args.has_flag("autotune") {
        // the PR-10 tuner study: the modelled section needs no
        // artifacts; the measured section gates on the runtime itself
        return autotune_scenario(&args, json_path);
    }
    // V100 fp32 ≈ 14 TFLOP/s against 12.5 GB/s EDR (the paper's nodes)
    const PAPER_DEVICE_GFLOPS: f64 = 14_000.0;
    let rt = Arc::new(Runtime::open_default()?);

    // worker counts available in the preset (gate_fwd_w{N} artifacts)
    let mut worker_counts: Vec<usize> = rt
        .manifest
        .artifacts
        .iter()
        .filter(|a| a.kind() == "gate_fwd")
        .filter_map(|a| a.meta_usize("workers"))
        .collect();
    worker_counts.sort_unstable();
    println!(
        "Figure 6 — distributed MoE layer scalability \
         (iters={iters}, net={net_name}, chunks={chunks}, hier nodes={nodes}, \
         measured path: {})\n",
        if overlap_path { "overlapped" } else { "blocking" }
    );

    let mut table = Table::new(&[
        "workers", "experts", "compute_s/dev", "wire_ms/iter", "blocking_ms/iter",
        "overlap_ms/iter", "zerocopy_ms/iter", "hier_blk_ms", "hier_ovl_ms",
        "speedup", "zc_speedup", "agg_GFLOP/s", "efficiency", "a2a_MB/iter",
        "copied_MB/iter", "gsync_blk_ms", "gsync_ovl_ms", "gsync_hier_ms",
        "gsync_zero_ms", "gsync_zhier_ms",
    ]);
    let mut csv = CsvWriter::create(
        "runs/fig6_scale.csv",
        &[
            "workers", "agg_gflops", "agg_gflops_overlap", "agg_gflops_zerocopy",
            "compute_s_per_dev", "wire_ms_per_iter", "blocking_ms_per_iter",
            "overlap_ms_per_iter", "zerocopy_ms_per_iter", "hier_nodes",
            "hier_blocking_ms_per_iter", "hier_overlap_ms_per_iter",
            "a2a_bytes_per_iter", "copied_bytes_per_iter", "alloc_bytes_per_iter",
            "grad_bytes", "grad_step_blocking_ms", "grad_step_overlapped_ms",
            "grad_step_hier_ms", "grad_step_zero_ms", "grad_step_zero_hier_ms",
        ],
    )?;
    let mut base: Option<f64> = None;
    let mut device_gflops: Option<f64> = None;
    let mut json_rows: Vec<Json> = Vec::new();

    for &w in &worker_counts {
        let rt2 = rt.clone();
        let results = run_workers(w, move |mut h| {
            let layer = MoeLayerBuilder::new()
                .seed(11)
                .overlap(overlap_path)
                .chunks(chunks)
                .build(rt2.clone(), w, h.rank())?;
            layer.warm()?;
            let mut counters = Counters::new();
            let mut rng = Rng::new(100 + h.rank() as u64);
            let mut flops = 0.0f64;
            h.barrier()?;
            let watch = Stopwatch::start();
            for _ in 0..iters {
                let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
                rng.fill_normal(&mut x.data, 1.0);
                let (_, state) = layer.forward(&mut h, x, &mut counters)?;
                let dy = TensorF32::full(&[layer.nb, layer.dm], 1e-3);
                let _ = layer.backward(&mut h, &state, &dy, &mut counters)?;
                flops += 3.0 * layer.flops(&state);
                layer.recycle(state);
            }
            h.barrier()?;
            let bucket_bytes = counters.get("moe_bucket_rows") * layer.dm as u64 * 4;
            let grad_bytes: u64 = layer
                .params()
                .iter()
                .map(|(_, t)| (t.numel() * 4) as u64)
                .sum();
            Ok((
                watch.secs(),
                flops,
                counters.get("moe_a2a_bytes"),
                counters.get("moe_copy_bytes"),
                counters.get("pool_alloc_bytes"),
                bucket_bytes,
                grad_bytes,
            ))
        })?;

        // one core time-slices the workers: the group wall time is the
        // total serial compute; each simulated device does wall/W of it
        let wall = results.iter().map(|r| r.0).fold(0.0f64, f64::max);
        let total_flops: f64 = results.iter().map(|r| r.1).sum();
        let bytes_per_iter =
            results.iter().map(|r| r.2).max().unwrap_or(0) as usize / iters.max(1);
        let copied_per_iter =
            results.iter().map(|r| r.3).max().unwrap_or(0) as usize / iters.max(1);
        let alloc_per_iter =
            results.iter().map(|r| r.4).max().unwrap_or(0) as usize / iters.max(1);
        let bucket_bytes_per_iter =
            results.iter().map(|r| r.5).max().unwrap_or(0) as usize / iters.max(1);
        let compute_per_dev = wall / w as f64;
        let compute_per_iter = compute_per_dev / iters.max(1) as f64;

        // calibrate the scaled net from the single-worker measurement
        if device_gflops.is_none() {
            device_gflops = Some(gflops(total_flops / w as f64, compute_per_dev));
        }
        let net = match net_name.as_str() {
            "ib-edr-scaled" => {
                let ratio = device_gflops.unwrap() / PAPER_DEVICE_GFLOPS;
                let base_net = NetModel::preset(NetPreset::IbEdr);
                // host copy/alloc bandwidths scale with the device so
                // the copy:compute ratio matches the paper's testbed
                NetModel {
                    alpha: base_net.alpha / ratio.max(1e-9),
                    beta: base_net.beta * ratio,
                    // both links scale together, so the local:inter
                    // ratio (what hier_favourable checks) is preserved
                    alpha_local: base_net.alpha_local / ratio.max(1e-9),
                    beta_local: base_net.beta_local * ratio,
                    host_beta: base_net.host_beta * ratio,
                    alloc_beta: base_net.alloc_beta * ratio,
                    enabled: true,
                }
            }
            other => NetModel::preset(NetPreset::parse(other).unwrap_or(NetPreset::IbEdr)),
        };

        let wire_per_iter = net.all_to_all(w, bytes_per_iter);
        let blocking_iter = net.moe_step_blocking_host(
            w,
            bytes_per_iter,
            compute_per_iter,
            copied_per_iter,
            alloc_per_iter,
        );
        // the PR 2 overlapped schedule: per-chunk batches were rebuilt
        // from the wire buffers AND cloned (padded) into the
        // executable, with every container freshly allocated — one
        // extra padded-bucket copy and one padded-bucket allocation
        // per step beyond what the zero-copy schedule measures
        let overlap_iter = net.moe_step_overlapped_host(
            w,
            bytes_per_iter,
            compute_per_iter,
            chunks,
            copied_per_iter + bucket_bytes_per_iter,
            alloc_per_iter + bucket_bytes_per_iter,
        );
        // the PR 3 schedule: rows land once, chunks compute on slice
        // views, staging recycles through the pool — exactly the
        // measured copy/alloc counters
        let zerocopy_iter = net.moe_step_overlapped_host(
            w,
            bytes_per_iter,
            compute_per_iter,
            chunks,
            copied_per_iter,
            alloc_per_iter,
        );
        assert!(
            zerocopy_iter <= overlap_iter,
            "zero-copy must not score above the copy-heavy overlap \
             (w={w}: {zerocopy_iter} vs {overlap_iter})"
        );
        // PR-4 grad-sync column: the data-parallel trainer tail over
        // this layer's parameter volume — the serial blocking ring +
        // host Adam vs the bucketed nonblocking sync pipelined against
        // backward and Adam.  Adam is priced as host traffic (≈7 float
        // passes per element: read p/m/v/g, write p/m/v).
        let grad_bytes = results.iter().map(|r| r.6).max().unwrap_or(0) as usize;
        let opt_secs = net.host_overhead(7 * grad_bytes, 0);
        let grad_buckets = grad_bytes.div_ceil(bucket_kb << 10).clamp(1, 32);
        let gsync_block =
            net.grad_step_blocking(w, grad_bytes, compute_per_iter, opt_secs);
        let gsync_overlap = net.grad_step_overlapped(
            w,
            grad_bytes,
            compute_per_iter,
            opt_secs,
            grad_buckets,
        );
        assert!(
            gsync_overlap <= gsync_block,
            "overlapped grad sync must not score above blocking \
             (w={w}: {gsync_overlap} vs {gsync_block})"
        );
        // PR-5 flat-vs-hier columns: the same measured compute, bytes
        // and host counters scored under the node-aware policies
        // (leader-aggregated a2a, tree all-reduce, locality-ordered
        // chunks).  `l = 1` (a worker count the node split doesn't
        // divide) falls back to flat exactly.
        let l = if w % nodes == 0 { (w / nodes).max(1) } else { 1 };
        let hier_blk = net.moe_step_blocking_hier_host(
            w,
            l,
            bytes_per_iter,
            compute_per_iter,
            copied_per_iter,
            alloc_per_iter,
        );
        let hier_ovl = net.moe_step_overlapped_hier_host(
            w,
            l,
            bytes_per_iter,
            compute_per_iter,
            chunks,
            copied_per_iter,
            alloc_per_iter,
        );
        let gsync_hier = net.grad_step_overlapped_hier(
            w,
            l,
            grad_bytes,
            compute_per_iter,
            opt_secs,
            grad_buckets,
        );
        // PR-9 ZeRO columns: the reduce-scatter → shard-Adam →
        // all-gather schedule — same ring volume as blocking, the
        // optimiser term shrunk to the owned 1/w shard (flat), and the
        // rail-aware hier variant (each local rank rings its sub-slice
        // across nodes with its peer rank).
        let gsync_zero =
            net.grad_step_zero(w, grad_bytes, compute_per_iter, opt_secs);
        assert!(
            gsync_zero <= gsync_block + 1e-15,
            "zero-sharded grad step must not score above blocking \
             (w={w}: {gsync_zero} vs {gsync_block})"
        );
        let gsync_zero_hier =
            net.grad_step_zero_hier(w, l, grad_bytes, compute_per_iter, opt_secs);
        if net.hier_favourable(w, l) {
            // the acceptance property: wherever the model's inter-node
            // bandwidth is the bottleneck, hier scores ≤ flat
            assert!(
                hier_blk <= blocking_iter + 1e-15,
                "hier blocking must not score above flat \
                 (w={w} l={l}: {hier_blk} vs {blocking_iter})"
            );
            assert!(
                hier_ovl <= zerocopy_iter + 1e-15,
                "hier overlapped must not score above flat overlapped \
                 (w={w} l={l}: {hier_ovl} vs {zerocopy_iter})"
            );
            assert!(
                gsync_hier <= gsync_overlap + 1e-15,
                "hier grad sync must not score above the flat rings \
                 (w={w} l={l}: {gsync_hier} vs {gsync_overlap})"
            );
            assert!(
                gsync_zero_hier <= gsync_zero + 1e-15,
                "rail-sharded zero step must not score above the flat one \
                 (w={w} l={l}: {gsync_zero_hier} vs {gsync_zero})"
            );
        }
        let speedup = blocking_iter / overlap_iter.max(1e-12);
        let zc_speedup = blocking_iter / zerocopy_iter.max(1e-12);
        let agg = gflops(total_flops, blocking_iter * iters as f64);
        let agg_overlap = gflops(total_flops, overlap_iter * iters as f64);
        let agg_zerocopy = gflops(total_flops, zerocopy_iter * iters as f64);
        let ne_global = rt
            .manifest
            .artifact(&format!("gate_fwd_w{w}"))
            .and_then(|a| a.meta_usize("n_expert_global"))
            .unwrap_or(0);
        if base.is_none() {
            base = Some(agg);
        }
        let eff = agg / (w as f64 * base.unwrap());
        table.row(vec![
            w.to_string(),
            ne_global.to_string(),
            format!("{compute_per_dev:.2}"),
            format!("{:.1}", wire_per_iter * 1e3),
            format!("{:.1}", blocking_iter * 1e3),
            format!("{:.1}", overlap_iter * 1e3),
            format!("{:.1}", zerocopy_iter * 1e3),
            format!("{:.1}", hier_blk * 1e3),
            format!("{:.1}", hier_ovl * 1e3),
            format!("{speedup:.2}x"),
            format!("{zc_speedup:.2}x"),
            format!("{agg:.2}"),
            format!("{:.0}%", eff * 100.0),
            format!("{:.2}", bytes_per_iter as f64 / 1e6),
            format!("{:.2}", copied_per_iter as f64 / 1e6),
            format!("{:.1}", gsync_block * 1e3),
            format!("{:.1}", gsync_overlap * 1e3),
            format!("{:.1}", gsync_hier * 1e3),
            format!("{:.1}", gsync_zero * 1e3),
            format!("{:.1}", gsync_zero_hier * 1e3),
        ]);
        csv.rowf(&[
            w as f64,
            agg,
            agg_overlap,
            agg_zerocopy,
            compute_per_dev,
            wire_per_iter * 1e3,
            blocking_iter * 1e3,
            overlap_iter * 1e3,
            zerocopy_iter * 1e3,
            if l > 1 { nodes as f64 } else { 1.0 },
            hier_blk * 1e3,
            hier_ovl * 1e3,
            bytes_per_iter as f64,
            copied_per_iter as f64,
            alloc_per_iter as f64,
            grad_bytes as f64,
            gsync_block * 1e3,
            gsync_overlap * 1e3,
            gsync_hier * 1e3,
            gsync_zero * 1e3,
            gsync_zero_hier * 1e3,
        ])?;
        let mut row = BTreeMap::new();
        row.insert("workers".into(), Json::Num(w as f64));
        row.insert("chunks".into(), Json::Num(chunks as f64));
        row.insert("compute_s_per_iter".into(), Json::Num(compute_per_iter));
        row.insert("a2a_bytes_per_iter".into(), Json::Num(bytes_per_iter as f64));
        row.insert(
            "copied_bytes_per_iter".into(),
            Json::Num(copied_per_iter as f64),
        );
        row.insert(
            "alloc_bytes_per_iter".into(),
            Json::Num(alloc_per_iter as f64),
        );
        row.insert("wire_s_per_iter".into(), Json::Num(wire_per_iter));
        row.insert("blocking_s_per_iter".into(), Json::Num(blocking_iter));
        row.insert("overlapped_s_per_iter".into(), Json::Num(overlap_iter));
        row.insert(
            "zerocopy_overlapped_s_per_iter".into(),
            Json::Num(zerocopy_iter),
        );
        row.insert("speedup".into(), Json::Num(speedup));
        row.insert("zerocopy_speedup".into(), Json::Num(zc_speedup));
        row.insert("agg_gflops_blocking".into(), Json::Num(agg));
        row.insert("agg_gflops_overlapped".into(), Json::Num(agg_overlap));
        row.insert("agg_gflops_zerocopy".into(), Json::Num(agg_zerocopy));
        row.insert("grad_bytes".into(), Json::Num(grad_bytes as f64));
        row.insert("grad_buckets".into(), Json::Num(grad_buckets as f64));
        row.insert("grad_step_blocking_s".into(), Json::Num(gsync_block));
        row.insert(
            "grad_step_overlapped_s".into(),
            Json::Num(gsync_overlap),
        );
        row.insert("hier_local_size".into(), Json::Num(l as f64));
        row.insert("hier_favourable".into(), Json::Bool(net.hier_favourable(w, l)));
        row.insert("hier_blocking_s_per_iter".into(), Json::Num(hier_blk));
        row.insert("hier_overlapped_s_per_iter".into(), Json::Num(hier_ovl));
        row.insert("grad_step_hier_s".into(), Json::Num(gsync_hier));
        row.insert("grad_step_zero_s".into(), Json::Num(gsync_zero));
        row.insert(
            "grad_step_zero_hier_s".into(),
            Json::Num(gsync_zero_hier),
        );
        json_rows.push(Json::Object(row));
        println!(
            "  {w} workers: blocking {:.1} ms/iter vs overlapped {:.1} ms/iter \
             vs zero-copy {:.1} ms/iter ({speedup:.2}x / {zc_speedup:.2}x; \
             {:.1} ms wire, {:.0} ms compute, {:.2} MB copied; \
             grad sync {:.1} -> {:.1} ms over {} buckets, zero {:.1} ms)",
            blocking_iter * 1e3,
            overlap_iter * 1e3,
            zerocopy_iter * 1e3,
            wire_per_iter * 1e3,
            compute_per_iter * 1e3,
            copied_per_iter as f64 / 1e6,
            gsync_block * 1e3,
            gsync_overlap * 1e3,
            grad_buckets,
            gsync_zero * 1e3,
        );
    }

    println!("\n{}", table.render());
    println!("runs/fig6_scale.csv written");
    if let Some(path) = json_path {
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Json::Str("fig6_scale".into()));
        root.insert("net".into(), Json::Str(net_name));
        root.insert(
            "measured_path".into(),
            Json::Str(if overlap_path { "overlapped".into() } else { "blocking".into() }),
        );
        root.insert("iters".into(), Json::Num(iters as f64));
        root.insert("rows".into(), Json::Array(json_rows));
        std::fs::write(&path, Json::Object(root).to_string())?;
        println!("{path} written");
    }
    Ok(())
}

/// The PR-7 `--skew` placement scenario: score a one-hot-expert routing
/// distribution (paper Fig. 5's pathology) under the static seed layout
/// and under the layout the shadow policy converges to, with
/// `NetModel::moe_step_skewed` over the plan-modelled per-rank rows.
/// Purely analytic — no artifacts, runtime, or wire traffic.
fn skew_scenario(args: &Args, json_path: Option<String>) -> fastmoe::Result<()> {
    use fastmoe::placement::{decide, PlacementPlan, PlacementPolicy, PlanDelta};

    let workers = args.usize_or("workers", 4)?.max(2);
    let ne_local = args.usize_or("ne-local", 2)?.max(1);
    let threshold = args.f64_or("placement-threshold", 1.5)?;
    let net_name = args.str_or("net", "ib-edr");
    let net = NetModel::preset(NetPreset::parse(&net_name).unwrap_or(NetPreset::IbEdr));
    // a forward row is dm floats each way on the wire; the per-row
    // compute rate is arbitrary but fixed across layouts, so the
    // static-vs-rebalanced comparison is scale-free
    let dm = args.usize_or("dm", 1024)?;
    let bytes_per_row = dm * 4;
    let secs_per_row = 5e-6;

    // skewed routing: expert 0 drains most of the batch, the rest cold
    let ne_global = workers * ne_local;
    let mut counts = vec![40u32; ne_global];
    counts[0] = 600;

    let mut plan = PlacementPlan::seed(workers, ne_local);
    let static_rows = plan.rank_rows(&counts);
    let static_secs = net.moe_step_skewed(&static_rows, bytes_per_row, secs_per_row);

    // run the pure policy to convergence, exactly as every rank would
    // at a window boundary (same counts -> same deltas)
    let mut moves: Vec<String> = Vec::new();
    for _ in 0..workers {
        match decide(PlacementPolicy::Shadow, &plan, &counts, threshold) {
            Some(PlanDelta::AddShadow { expert, host }) => {
                plan.add_shadow(expert, host)?;
                moves.push(format!("shadow e{expert} -> r{host}"));
            }
            // healthy (or no eligible move): the layout has converged
            Some(PlanDelta::DropShadows) | Some(PlanDelta::Swap { .. }) | None => break,
        }
    }
    let rebal_rows = plan.rank_rows(&counts);
    let rebal_secs = net.moe_step_skewed(&rebal_rows, bytes_per_row, secs_per_row);

    let hottest = |rows: &[f64]| rows.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "Figure 6 (skew) — dynamic placement vs static layout \
         (workers={workers}, experts={ne_global}, hot expert 0: {} of {} rows, \
         threshold={threshold}, net={net_name})\n",
        counts[0],
        counts.iter().map(|&c| c as u64).sum::<u64>(),
    );
    let mut table = Table::new(&["layout", "hottest_rows", "step_ms", "speedup", "moves"]);
    table.row(vec![
        "static".into(),
        format!("{:.0}", hottest(&static_rows)),
        format!("{:.2}", static_secs * 1e3),
        "1.00x".into(),
        "-".into(),
    ]);
    table.row(vec![
        "rebalanced".into(),
        format!("{:.0}", hottest(&rebal_rows)),
        format!("{:.2}", rebal_secs * 1e3),
        format!("{:.2}x", static_secs / rebal_secs.max(1e-12)),
        moves.join(", "),
    ]);
    println!("{}", table.render());

    // the acceptance property: rebalancing a skewed workload must score
    // strictly below the static layout
    assert!(
        rebal_secs < static_secs,
        "rebalanced layout must beat static on skewed routing \
         ({rebal_secs} vs {static_secs})"
    );

    if let Some(path) = json_path {
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Json::Str("fig6_scale".into()));
        root.insert("mode".into(), Json::Str("skew".into()));
        root.insert("net".into(), Json::Str(net_name));
        root.insert("workers".into(), Json::Num(workers as f64));
        root.insert("ne_global".into(), Json::Num(ne_global as f64));
        root.insert("hot_expert_rows".into(), Json::Num(counts[0] as f64));
        root.insert("threshold".into(), Json::Num(threshold));
        root.insert("static_hottest_rows".into(), Json::Num(hottest(&static_rows)));
        root.insert(
            "rebalanced_hottest_rows".into(),
            Json::Num(hottest(&rebal_rows)),
        );
        root.insert("static_s_per_iter".into(), Json::Num(static_secs));
        root.insert("rebalanced_s_per_iter".into(), Json::Num(rebal_secs));
        root.insert(
            "speedup".into(),
            Json::Num(static_secs / rebal_secs.max(1e-12)),
        );
        root.insert(
            "moves".into(),
            Json::Array(moves.into_iter().map(Json::Str).collect()),
        );
        std::fs::write(&path, Json::Object(root).to_string())?;
        println!("{path} written");
    }
    Ok(())
}

/// The PR-8 `--chaos` fault scenario: price what surviving a worker
/// death costs.  A uniform routing distribution is scored healthy, then
/// degraded with rank `--dead` quarantined under the two coverage
/// regimes the trainer supports — every dead-owned expert
/// shadow-covered (rows redistribute to live replicas) vs uncovered
/// (the dead share is score-masked away) — and the rejoin
/// peer-transfer is priced as α-β point-to-point traffic over the
/// checkpoint-format expert slots.  Purely analytic — no artifacts,
/// runtime, or wire traffic.
fn chaos_scenario(args: &Args, json_path: Option<String>) -> fastmoe::Result<()> {
    use fastmoe::placement::PlacementPlan;

    let workers = args.usize_or("workers", 4)?.max(2);
    let ne_local = args.usize_or("ne-local", 2)?.max(1);
    let dead = args.usize_or("dead", 1)?.min(workers - 1);
    let net_name = args.str_or("net", "ib-edr");
    let net = NetModel::preset(NetPreset::parse(&net_name).unwrap_or(NetPreset::IbEdr));
    let dm = args.usize_or("dm", 1024)?;
    let dh = args.usize_or("dh", 4096)?;
    let bytes_per_row = dm * 4;
    let secs_per_row = 5e-6;

    // uniform routing: every expert drains the same share, so the
    // degraded deltas are purely the fault's doing
    let ne_global = workers * ne_local;
    let counts = vec![120u32; ne_global];
    let total_rows: f64 = counts.iter().map(|&c| c as f64).sum();
    let survivors: Vec<usize> = (0..workers).filter(|&r| r != dead).collect();

    let healthy_plan = PlacementPlan::seed(workers, ne_local);
    let healthy_rows = healthy_plan.rank_rows(&counts);
    let healthy_secs = net.moe_step_skewed(&healthy_rows, bytes_per_row, secs_per_row);

    // covered: every dead-owned expert has a live replica, spread
    // round-robin over the survivors (what the rebalancer converges to)
    let mut covered_plan = PlacementPlan::seed(workers, ne_local);
    for (k, e) in (dead * ne_local..(dead + 1) * ne_local).enumerate() {
        covered_plan.add_shadow(e, survivors[k % survivors.len()])?;
    }
    covered_plan.set_down(Some(dead))?;
    let covered_rows = covered_plan.rank_rows(&counts);
    let covered_secs = net.moe_step_skewed(&covered_rows, bytes_per_row, secs_per_row);

    // uncovered: no replicas — the dead rank's experts are score-masked
    // and their rows simply vanish from the step
    let mut uncovered_plan = PlacementPlan::seed(workers, ne_local);
    uncovered_plan.set_down(Some(dead))?;
    let uncovered_rows = uncovered_plan.rank_rows(&counts);
    let uncovered_secs =
        net.moe_step_skewed(&uncovered_rows, bytes_per_row, secs_per_row);

    // rejoin catch-up: per covered expert, params + both Adam moments
    // of the w1/b1/w2/b2 slot stream back from the shadow host
    // (`pack_expert_slot` layout), priced as one α-β message each
    let slot_bytes = 3 * (2 * dm * dh + dm + dh) * 4;
    let rejoin_bytes = ne_local * slot_bytes;
    let rejoin_secs =
        ne_local as f64 * (net.alpha + slot_bytes as f64 * net.beta);

    let sum = |rows: &[f64]| rows.iter().sum::<f64>();
    let hottest = |rows: &[f64]| rows.iter().cloned().fold(0.0f64, f64::max);
    let dead_share = (ne_local * 120) as f64;
    // conservation: coverage loses no tokens; masking loses exactly the
    // dead rank's share
    assert!(
        (sum(&covered_rows) - total_rows).abs() < 1e-6,
        "covered layout must conserve every row ({} vs {total_rows})",
        sum(&covered_rows)
    );
    assert!(
        (sum(&uncovered_rows) - (total_rows - dead_share)).abs() < 1e-6,
        "uncovered layout must drop exactly the dead share ({} vs {})",
        sum(&uncovered_rows),
        total_rows - dead_share
    );
    // a degraded step never beats the healthy one
    assert!(covered_secs >= healthy_secs - 1e-15, "{covered_secs} vs {healthy_secs}");
    assert!(uncovered_secs >= healthy_secs - 1e-15, "{uncovered_secs} vs {healthy_secs}");

    println!(
        "Figure 6 (chaos) — degraded-mode cost of losing rank {dead} \
         (workers={workers}, experts={ne_global}, uniform {} rows, net={net_name})\n",
        total_rows as u64,
    );
    let mut table =
        Table::new(&["layout", "live_rows", "hottest_rows", "step_ms", "slowdown"]);
    let mut row = |name: &str, rows: &[f64], secs: f64| {
        table.row(vec![
            name.into(),
            format!("{:.0}", sum(rows)),
            format!("{:.0}", hottest(rows)),
            format!("{:.2}", secs * 1e3),
            format!("{:.2}x", secs / healthy_secs.max(1e-12)),
        ]);
    };
    row("healthy", &healthy_rows, healthy_secs);
    row("degraded/covered", &covered_rows, covered_secs);
    row("degraded/uncovered", &uncovered_rows, uncovered_secs);
    println!("{}", table.render());
    println!(
        "rejoin catch-up: {} covered experts, {:.2} MB peer-transfer, {:.2} ms",
        ne_local,
        rejoin_bytes as f64 / 1e6,
        rejoin_secs * 1e3,
    );

    if let Some(path) = json_path {
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Json::Str("fig6_scale".into()));
        root.insert("mode".into(), Json::Str("chaos".into()));
        root.insert("net".into(), Json::Str(net_name));
        root.insert("workers".into(), Json::Num(workers as f64));
        root.insert("ne_global".into(), Json::Num(ne_global as f64));
        root.insert("dead_rank".into(), Json::Num(dead as f64));
        root.insert("total_rows".into(), Json::Num(total_rows));
        root.insert("healthy_s_per_iter".into(), Json::Num(healthy_secs));
        root.insert("covered_s_per_iter".into(), Json::Num(covered_secs));
        root.insert("uncovered_s_per_iter".into(), Json::Num(uncovered_secs));
        root.insert("covered_rows".into(), Json::Num(sum(&covered_rows)));
        root.insert("uncovered_rows".into(), Json::Num(sum(&uncovered_rows)));
        root.insert(
            "covered_slowdown".into(),
            Json::Num(covered_secs / healthy_secs.max(1e-12)),
        );
        root.insert("rejoin_payload_bytes".into(), Json::Num(rejoin_bytes as f64));
        root.insert("rejoin_transfer_s".into(), Json::Num(rejoin_secs));
        std::fs::write(&path, Json::Object(root).to_string())?;
        println!("{path} written");
    }
    Ok(())
}

/// The PR-10 `--autotune` tuner study: how well does the fitted α-β
/// model rank the `[comm]` lattice, and how close does its prediction
/// land to a real step?  The modelled section is artifact-free (pure
/// `autotune::search` over synthetic operating points); the measured
/// section runs a real thread-backend calibration and is skipped
/// gracefully when the AOT runtime can't open.
fn autotune_scenario(args: &Args, json_path: Option<String>) -> fastmoe::Result<()> {
    use fastmoe::autotune::{score, search, KnobState, ModelFit};
    use fastmoe::config::{AutoConfig, CommConfig};
    use fastmoe::coordinator::MoeLayerTrainer;

    let preset = NetModel::preset(NetPreset::IbEdr);
    let current = KnobState::from_comm(&CommConfig::default());
    println!(
        "Figure 6 (autotune) — simulator-driven [comm] search, \
         predicted vs measured\n"
    );

    // ── modelled: three synthetic operating points over an 8 MiB
    // exchange and a 4 MiB gradient, searched from the default config ──
    let a2a = (8usize << 20) as f64;
    let grad = (4usize << 20) as f64;
    let regimes: [(&str, usize, f64, f64, f64); 3] = [
        // (name, workers, link B/s, compute s, optimiser s)
        ("comm-bound", 8, 1.0e9, 1.0e-3, 0.3e-3),
        ("balanced", 4, 12.5e9, 2.0e-3, 0.5e-3),
        ("opt-bound", 4, 12.5e9, 1.0e-3, 20.0e-3),
    ];
    let mut table = Table::new(&[
        "regime", "workers", "current_ms", "best_ms", "gain", "best [comm]",
    ]);
    let mut modelled_rows: Vec<Json> = Vec::new();
    for (name, w, beta, compute, opt) in regimes {
        let wire = preset.alpha * (w - 1) as f64 + a2a / beta;
        let fit = ModelFit::from_measurements(
            w, 2, wire + compute + opt, wire, compute, opt, 0.0, a2a, grad, a2a,
        );
        let outcome = search(&fit, &current);
        // the acceptance properties: bit-deterministic, and never worse
        // than staying put (current is always a candidate)
        assert!(
            outcome == search(&fit, &current),
            "search must be deterministic ({name})"
        );
        let cur = score(&fit, &current);
        assert!(
            outcome.best.predicted <= cur + 1e-15,
            "the searched best must not score above current \
             ({name}: {} vs {cur})",
            outcome.best.predicted
        );
        let k = outcome.best.knobs;
        let brief = format!(
            "overlap={} chunks={} {} grad_overlap={} bucket_kb={} zero={} hier={}",
            k.overlap,
            k.chunks,
            k.chunk_policy.as_str(),
            k.grad_overlap,
            k.bucket_kb,
            k.zero,
            k.hier,
        );
        table.row(vec![
            name.into(),
            w.to_string(),
            format!("{:.2}", cur * 1e3),
            format!("{:.2}", outcome.best.predicted * 1e3),
            format!("{:.2}x", cur / outcome.best.predicted.max(1e-12)),
            brief.clone(),
        ]);
        let mut row = BTreeMap::new();
        row.insert("regime".into(), Json::Str(name.into()));
        row.insert("workers".into(), Json::Num(w as f64));
        row.insert("link_bytes_per_s".into(), Json::Num(beta));
        row.insert("current_s".into(), Json::Num(cur));
        row.insert("best_s".into(), Json::Num(outcome.best.predicted));
        row.insert("best_config".into(), Json::Str(brief));
        modelled_rows.push(Json::Object(row));
    }
    println!("{}", table.render());

    // ── measured: a real thread-backend calibration when artifacts
    // exist — assert the fit agrees bitwise on every rank, then compare
    // the model's prediction for the running config with the measured
    // step time ──
    let mut measured: Option<Json> = None;
    match Runtime::open_default() {
        Err(e) => println!("measured section skipped (runtime unavailable: {e})"),
        Ok(rt) => {
            let rt = Arc::new(rt);
            let w = args.usize_or("workers", 2)?.max(2);
            let calib_steps = args.usize_or("calib-steps", 4)?.max(1);
            let cfg = CommConfig::default();
            let auto_cfg = AutoConfig {
                enabled: true,
                calib_steps,
                ..AutoConfig::default()
            };
            // one warm-up observe opens the window, calib_steps fill it
            let steps = calib_steps + 1;
            let results = run_workers(w, move |mut h| {
                let layer = MoeLayerBuilder::new()
                    .seed(11)
                    .comm_config(&cfg)
                    .build(rt.clone(), w, h.rank())?;
                layer.warm()?;
                let mut tr = MoeLayerTrainer::new(layer, 1e-3)
                    .with_autotune(auto_cfg.clone(), &cfg)?;
                let mut counters = Counters::new();
                let mut rng = Rng::new(100 + h.rank() as u64);
                for _ in 0..steps {
                    let mut x = TensorF32::zeros(&[tr.layer.nb, tr.layer.dm]);
                    rng.fill_normal(&mut x.data, 1.0);
                    tr.train_step(&mut h, x, &mut counters)?;
                }
                Ok(match tr.autotuner() {
                    Some(t) => (t.fit, t.outcome),
                    None => (None, None),
                })
            })?;
            // rank symmetry: the all-reduced fit (and hence the search
            // run on it) must be bit-identical everywhere
            for r in &results[1..] {
                assert!(
                    *r == results[0],
                    "calibration fit must agree bitwise across ranks"
                );
            }
            let (Some(fit), Some(outcome)) = results[0] else {
                return Err(fastmoe::Error::msg("calibration produced no fit"));
            };
            let predicted = score(&fit, &current);
            println!(
                "measured ({w} workers, {calib_steps} calib steps): step \
                 {:.2} ms, model-predicted comm+compute+opt terms {:.2} ms, \
                 fitted link {:.2} GB/s\nrecommended:\n{}",
                fit.step_time * 1e3,
                predicted * 1e3,
                fit.beta / 1e9,
                outcome.best.toml_snippet(),
            );
            let mut row = BTreeMap::new();
            row.insert("workers".into(), Json::Num(w as f64));
            row.insert("calib_steps".into(), Json::Num(calib_steps as f64));
            row.insert("measured_step_s".into(), Json::Num(fit.step_time));
            row.insert("predicted_current_s".into(), Json::Num(predicted));
            row.insert("fitted_beta".into(), Json::Num(fit.beta));
            row.insert("fitted_compute_s".into(), Json::Num(fit.compute));
            row.insert("fitted_opt_s".into(), Json::Num(fit.opt));
            row.insert(
                "best_predicted_s".into(),
                Json::Num(outcome.best.predicted),
            );
            row.insert(
                "best_snippet".into(),
                Json::Str(outcome.best.toml_snippet()),
            );
            measured = Some(Json::Object(row));
        }
    }

    if let Some(path) = json_path {
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Json::Str("fig6_scale".into()));
        root.insert("mode".into(), Json::Str("autotune".into()));
        root.insert("modelled".into(), Json::Array(modelled_rows));
        if let Some(m) = measured {
            root.insert("measured".into(), m);
        }
        std::fs::write(&path, Json::Object(root).to_string())?;
        println!("{path} written");
    }
    Ok(())
}
