//! Expert-parallel MoE layer across workers — the Figure-2 machinery
//! live, with pluggable gates and per-worker load / traffic statistics.
//!
//! ```bash
//! cargo run --release --example distributed_moe -- --workers 4 --iters 8
//! # compare routing policies on the same seed:
//! cargo run --release --example distributed_moe -- --gate switch --capacity-factor 1.25
//! cargo run --release --example distributed_moe -- --gate noisy_topk --noise-std 0.5
//! # pipeline the exchanges against expert compute (§4 overlap):
//! cargo run --release --example distributed_moe -- --overlap --chunks 4
//! # node-aware collectives (two nodes): hier a2a + tree all-reduce:
//! cargo run --release --example distributed_moe -- --topology hier --nodes 2
//! # or select everything from a config file's [moe]/[comm] sections:
//! cargo run --release --example distributed_moe -- --config moe.toml
//! ```
//!
//! Each worker thread owns `ne_local` experts; the layer is assembled
//! by `MoeLayerBuilder` from the `[moe]` config section (CLI flags
//! override).  Every iteration: gate GEMM → `Gate::route` → count
//! exchange → row exchange → bucketed `ExpertShard::forward` → reverse
//! exchange → weighted combine, then the mirrored backward chain and
//! an Adam step over all layer parameters.  The per-step stats include
//! the GShard balance loss, so gates can be compared on load balance.

use std::sync::Arc;

use fastmoe::bench::Table;
use fastmoe::cli::Args;
use fastmoe::comm::{run_workers, Comm, TopoComm};
use fastmoe::config::{CommConfig, MoeConfig};
use fastmoe::coordinator::{MoeLayerBuilder, MoeLayerTrainer};
use fastmoe::metrics::{Counters, Stopwatch};
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::sim::{NetModel, NetPreset};
use fastmoe::tensor::TensorF32;
use fastmoe::util;

fn main() -> fastmoe::Result<()> {
    let args = Args::from_env(&["overlap", "no-overlap", "no-pool", "progress", "no-progress"])?;
    let workers = args.usize_or("workers", 4)?;
    let iters = args.usize_or("iters", 8)?;
    let seed = args.u64_or("seed", 7)?;
    let lr = args.f64_or("lr", 1e-3)? as f32;
    let net = NetModel::preset(
        NetPreset::parse(&args.str_or("net", "ib-edr")).unwrap_or(NetPreset::IbEdr),
    );

    // [moe]/[comm] sections (if a config is given) + CLI overrides:
    // this is the whole story of selecting a non-default gate or the
    // pipelined exchange schedule.
    let moe_cfg = MoeConfig::from_args(&args)?;
    let comm_cfg = CommConfig::from_args(&args)?;

    let rt = Arc::new(Runtime::open_default()?);
    println!(
        "distributed MoE layer: {workers} workers, {iters} iters, gate `{}`, overlap {}",
        moe_cfg.gate,
        if comm_cfg.overlap {
            format!("on ({} chunks)", comm_cfg.chunks)
        } else {
            "off".into()
        }
    );

    let builder = MoeLayerBuilder::from_config(&moe_cfg)
        .comm_config(&comm_cfg)
        .seed(seed);
    let topo_cfg = comm_cfg.clone();
    let results = run_workers(workers, {
        let rt = rt.clone();
        move |h| {
            // the collective policy ([comm] topology) rides the comm
            // wrapper; flat is a pure pass-through
            let mut h = TopoComm::new(h, topo_cfg.topology_for(workers)?)?;
            let layer = builder.build_for(rt.clone(), &h)?;
            layer.warm()?;
            let mut tr = MoeLayerTrainer::new(layer, lr);
            let mut counters = Counters::new();
            let mut rng = Rng::new(seed ^ (h.rank() as u64 + 1));
            let mut flops = 0.0f64;
            let mut balance = 0.0f64;
            h.barrier()?;
            let watch = Stopwatch::start();
            for _ in 0..iters {
                let mut x = TensorF32::zeros(&[tr.layer.nb, tr.layer.dm]);
                rng.fill_normal(&mut x.data, 1.0);
                let s = tr.train_step(&mut h, x, &mut counters)?;
                flops += s.flops;
                balance += s.balance;
                debug_assert!(s.loss.is_finite());
            }
            h.barrier()?;
            let secs = watch.secs();
            counters.merge(&h.inner().counters);
            let totals = tr.monitor.totals().to_vec();
            Ok((h.rank(), secs, flops, counters, balance / iters.max(1) as f64, totals))
        }
    })?;

    let mut table = Table::new(&[
        "worker", "time_s", "GFLOP/s", "a2a_traffic", "copied", "pool_hit/miss",
        "sim_wire_ms", "pad_overhead", "balance_loss",
    ]);
    let ne_global = results[0].5.len();
    let mut totals_all = vec![0u64; ne_global];
    for (rank, secs, flops, counters, balance, totals) in &results {
        let bytes = counters.get("moe_a2a_bytes") as usize;
        let wire = net.all_to_all(workers, bytes) * 1e3;
        let pad = 1.0
            - counters.get("moe_real_rows") as f64
                / counters.get("moe_bucket_rows").max(1) as f64;
        table.row(vec![
            rank.to_string(),
            format!("{secs:.2}"),
            format!("{:.2}", util::gflops(*flops, *secs)),
            util::fmt_bytes(bytes),
            util::fmt_bytes(counters.get("moe_copy_bytes") as usize),
            format!(
                "{}/{}",
                counters.get("pool_hits"),
                counters.get("pool_misses")
            ),
            format!("{wire:.2}"),
            format!("{:.1}%", pad * 100.0),
            format!("{balance:.3}"),
        ]);
        for (e, &c) in totals.iter().enumerate() {
            totals_all[e] += c;
        }
    }
    println!("\n{}", table.render());

    println!("global expert load (tokens over all iterations):");
    let max = *totals_all.iter().max().unwrap_or(&1) as f64;
    for (e, &c) in totals_all.iter().enumerate() {
        let bar = "#".repeat((40.0 * c as f64 / max.max(1.0)) as usize);
        println!(
            "  expert {e:>3} [worker {}] {c:>8} {bar}",
            e / (ne_global / workers)
        );
    }
    let mean = totals_all.iter().sum::<u64>() as f64 / ne_global.max(1) as f64;
    println!(
        "imbalance (max/mean over run): {:.2}",
        if mean > 0.0 { max / mean } else { 1.0 }
    );
    Ok(())
}
