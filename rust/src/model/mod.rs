//! Model state owned by the coordinator: parameter store (with the
//! FastMoE sync tags), host-side Adam, and checkpointing.
//!
//! The fused fig-7 path keeps Adam *inside* the train-step HLO; the
//! distributed path computes gradients per worker (`grad_step`
//! artifact), synchronises them via [`crate::coordinator::GradSync`],
//! and applies [`Adam`] here on the host.  Both produce identical math
//! (pinned against each other in `rust/tests/`).

mod adam;
mod checkpoint;

pub use adam::Adam;
pub use checkpoint::{
    load_checkpoint, load_tensors, pack_expert_slot, save_checkpoint,
    save_tensors, unpack_expert_slot,
};

use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::runtime::{ModelEntry, ParamEntry, SyncTag};
use crate::tensor::TensorF32;

/// Named, ordered parameter tensors with sync tags.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub entries: Vec<ParamEntry>,
    pub tensors: Vec<TensorF32>,
}

impl ParamStore {
    /// Initialise from the manifest registry, python-free.
    ///
    /// `normal:<std>` draws are derived from `seed` *per parameter name*
    /// so initialisation is independent of registry order and identical
    /// across workers (FastMoE replicates non-expert params everywhere).
    pub fn init(model: &ModelEntry, seed: u64) -> Result<ParamStore> {
        let mut tensors = Vec::with_capacity(model.params.len());
        for p in &model.params {
            let mut t = TensorF32::zeros(&p.shape);
            if p.init == "zeros" {
                // already zero
            } else if p.init == "ones" {
                t.data.fill(1.0);
            } else if let Some(stds) = p.init.strip_prefix("normal:") {
                let std: f32 = stds
                    .parse()
                    .map_err(|_| Error::Manifest(format!("bad init `{}`", p.init)))?;
                let mut rng = Rng::new(seed ^ name_hash(&p.name));
                rng.fill_normal(&mut t.data, std);
            } else {
                return Err(Error::Manifest(format!("unknown init `{}`", p.init)));
            }
            tensors.push(t);
        }
        Ok(ParamStore { entries: model.params.clone(), tensors })
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn n_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.entries.iter().position(|e| e.name == name)
    }

    pub fn by_name(&self, name: &str) -> Option<&TensorF32> {
        self.index_of(name).map(|i| &self.tensors[i])
    }

    /// Indices of parameters with a given sync tag.
    pub fn tagged(&self, tag: SyncTag) -> Vec<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.tag == tag)
            .map(|(i, _)| i)
            .collect()
    }

    /// Zero-filled gradient/optimizer buffers with matching shapes.
    pub fn zeros_like(&self) -> Vec<TensorF32> {
        self.tensors
            .iter()
            .map(|t| TensorF32::zeros(&t.shape))
            .collect()
    }

    /// Sanity check: all tensors finite (failure-injection tests poke this).
    pub fn all_finite(&self) -> bool {
        self.tensors
            .iter()
            .all(|t| t.data.iter().all(|v| v.is_finite()))
    }
}

fn name_hash(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn sample_model() -> ModelEntry {
        let text = r#"{
          "preset": "t", "artifacts": [],
          "models": {"m": {
            "config": {},
            "params": [
              {"name": "gate/w", "shape": [4, 2], "init": "normal:0.5", "tag": "world"},
              {"name": "expert/w", "shape": [2, 3], "init": "normal:0.5", "tag": "none"},
              {"name": "ln/g", "shape": [4], "init": "ones", "tag": "data_parallel"},
              {"name": "ln/b", "shape": [4], "init": "zeros", "tag": "data_parallel"}
            ],
            "train_step": "", "eval_step": "", "grad_step": ""}}
        }"#;
        Manifest::parse(text).unwrap().model("m").unwrap().clone()
    }

    #[test]
    fn init_respects_specs() {
        let ps = ParamStore::init(&sample_model(), 1).unwrap();
        assert_eq!(ps.len(), 4);
        assert!(ps.by_name("ln/g").unwrap().data.iter().all(|&x| x == 1.0));
        assert!(ps.by_name("ln/b").unwrap().data.iter().all(|&x| x == 0.0));
        let w = ps.by_name("gate/w").unwrap();
        assert!(w.data.iter().any(|&x| x != 0.0));
        // std≈0.5: values should mostly be within 3σ
        assert!(w.data.iter().all(|&x| x.abs() < 3.0));
        assert_eq!(ps.n_elements(), 8 + 6 + 4 + 4);
        assert!(ps.all_finite());
    }

    #[test]
    fn init_is_deterministic_and_order_independent() {
        let a = ParamStore::init(&sample_model(), 7).unwrap();
        let b = ParamStore::init(&sample_model(), 7).unwrap();
        assert_eq!(a.tensors, b.tensors);
        let c = ParamStore::init(&sample_model(), 8).unwrap();
        assert_ne!(a.by_name("gate/w"), c.by_name("gate/w"));
    }

    #[test]
    fn tags_partition() {
        let ps = ParamStore::init(&sample_model(), 1).unwrap();
        let w = ps.tagged(SyncTag::World);
        let d = ps.tagged(SyncTag::DataParallel);
        let n = ps.tagged(SyncTag::None);
        assert_eq!(w, vec![0]);
        assert_eq!(n, vec![1]);
        assert_eq!(d, vec![2, 3]);
        assert_eq!(w.len() + d.len() + n.len(), ps.len());
    }
}
