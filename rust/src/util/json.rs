//! Minimal JSON parser + writer (substrate: no serde in the offline
//! registry).  Parses the AOT `manifest.json` and writes metrics files.
//!
//! Supports the full JSON grammar minus `\u` surrogate pairs outside the
//! BMP (the manifest never emits them).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing key `{key}`")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    // -- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(m)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(a)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos += len - 1;
                    let chunk = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| self.err("bad utf8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = j.get("a").unwrap().as_array().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(j.get("d").is_some());
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true],"s":"x\"y","n":-7}"#;
        let j = Json::parse(src).unwrap();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn utf8_strings() {
        let j = Json::parse(r#""héllo ☃""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let j = Json::parse(&text).unwrap();
            assert!(j.get("artifacts").unwrap().as_array().unwrap().len() > 10);
        }
    }
}
