//! The zero-copy PR's contract, pinned three ways:
//!
//! * **machinery level** (no artifacts needed) — the pooled dispatch
//!   choreography (pooled shell → single landing → slice-view gather →
//!   pooled split) reaches a zero-miss steady state after one warm-up
//!   step, and the per-chunk staging bucket never exceeds the blocking
//!   bucket (no Σ-bucket inflation);
//! * **layer level, thread backend** (runtime-gated) — a real
//!   `DistMoeLayer` step allocates nothing from the pool after warm-up
//!   on both the blocking and the overlapped schedule, and the
//!   overlapped forward's copy counter exceeds blocking by *exactly*
//!   one stage copy of the landed rows (the ROADMAP "overlap padding
//!   overhead" double-copy is gone); backward copy volumes are equal;
//! * **layer level, TCP backend** (runtime-gated) — the same
//!   steady-state property over real sockets with the progress engine
//!   draining arrivals;
//! * **pooled TCP receive path** (no artifacts needed) — frame readers
//!   draw payload buffers from the [`Comm::recycle`]-fed freelist, so
//!   a caller that recycles consumed buffers makes steady-state frame
//!   reads allocation-free (zero `recv_buffer_allocs` growth after
//!   warm-up).

use std::sync::Arc;

use fastmoe::comm::tcp::TcpGroup;
use fastmoe::comm::{run_workers, Comm};
use fastmoe::coordinator::MoeLayerBuilder;
use fastmoe::metrics::Counters;
use fastmoe::moe::ExpertBatch;
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::tensor::{BufferPool, TensorF32};
use fastmoe::testing::{check, prop_assert};

fn runtime() -> Option<Arc<Runtime>> {
    Runtime::open_default().ok().map(Arc::new)
}

#[test]
fn pooled_dispatch_machinery_reaches_zero_miss_steady_state() {
    let dm = 3usize;
    let ne = 2usize;
    let buckets = [8usize, 16, 32];
    let recv_counts = vec![vec![3u32, 1], vec![2, 2], vec![0, 4]];
    let chunk_groups = [vec![0usize], vec![1usize, 2]];
    let full = ExpertBatch::shell(recv_counts.clone(), ne, dm, &buckets).unwrap();
    let full_bucket_bytes = ne * full.bucket * dm * 4;

    let mut pool = BufferPool::new(true);
    let mut after_warmup = None;
    for step in 0..5u32 {
        // pretend wire arrivals (sizes repeat step over step)
        let parts: Vec<Vec<f32>> = recv_counts
            .iter()
            .map(|cs| {
                let rows: u32 = cs.iter().sum();
                let mut b = pool.take_vec("wire", rows as usize * dm);
                b.resize(rows as usize * dm, step as f32);
                b
            })
            .collect();
        // single landing into the pooled full-batch shell
        let mut eb = ExpertBatch::shell_pooled(
            recv_counts.clone(),
            ne,
            dm,
            &buckets,
            &mut pool,
            "batch",
        )
        .unwrap();
        for (p, part) in parts.iter().enumerate() {
            eb.fill_peer(p, part).unwrap();
        }
        pool.give_all("wire", parts);
        // per-chunk slice-view staging, recycled chunk over chunk
        for peers in &chunk_groups {
            let slice = eb.chunk_slice(peers, &buckets).unwrap();
            assert!(
                slice.bucket <= eb.bucket,
                "chunk staging bucket must not exceed the blocking bucket"
            );
            let mut staging =
                pool.take_tensor("stage", &[ne, slice.bucket, dm]).unwrap();
            eb.gather_chunk(&slice, &mut staging).unwrap();
            let (ret, _) = slice
                .split_outputs_pooled(&staging, dm, &mut pool, "wire")
                .unwrap();
            pool.give_tensor("stage", staging);
            pool.give_all("wire", ret);
        }
        pool.give_tensor("batch", eb.xs);
        if step == 0 {
            after_warmup = Some(pool.stats());
        }
    }
    let d = pool.stats().since(&after_warmup.unwrap());
    assert_eq!(d.misses, 0, "steady-state steps must not allocate");
    assert_eq!(d.alloc_bytes, 0);
    // no Σ-bucket inflation: the staging arena holds at most one
    // blocking bucket's worth of padded bytes
    assert!(
        pool.resident_bytes("stage") <= full_bucket_bytes,
        "staging arena ({} B) exceeds the blocking bucket ({} B)",
        pool.resident_bytes("stage"),
        full_bucket_bytes
    );
}

#[test]
fn prop_chunk_bucket_never_exceeds_full_bucket() {
    check("chunk staging ≤ blocking bucket, all partitions", 40, |g| {
        let peers = g.usize_in(1, 5);
        let ne = g.usize_in(1, 4);
        let dm = g.usize_in(1, 4);
        let buckets = [4usize, 8, 16, 64, 256];
        let counts: Vec<Vec<u32>> = (0..peers)
            .map(|_| (0..ne).map(|_| g.usize_in(0, 60) as u32).collect())
            .collect();
        let eb = ExpertBatch::shell(counts, ne, dm, &buckets)
            .map_err(|e| e.to_string())?;
        // random contiguous partition of the peer list into chunks
        let mut order: Vec<usize> = (0..peers).collect();
        // rotate for some variety (peers need not be contiguous)
        let rot = g.usize_in(0, peers - 1);
        order.rotate_left(rot);
        let cut = g.usize_in(1, peers);
        let mut staged_rows = 0usize;
        for part in [&order[..cut], &order[cut..]] {
            if part.is_empty() {
                continue;
            }
            let slice = eb.chunk_slice(part, &buckets).map_err(|e| e.to_string())?;
            prop_assert(
                slice.bucket <= eb.bucket,
                format!("chunk bucket {} > full {}", slice.bucket, eb.bucket),
            )?;
            staged_rows += slice.rows_per_expert.iter().sum::<usize>();
        }
        // every landed row is staged exactly once across the partition
        prop_assert(
            staged_rows == eb.rows_per_expert.iter().sum::<usize>(),
            format!("staged {staged_rows} rows, landed {:?}", eb.rows_per_expert),
        )?;
        Ok(())
    });
}

#[test]
fn tcp_receive_path_is_allocation_free_in_steady_state() {
    // Lock-step ping-pong with fixed payloads: each side recycles every
    // consumed frame, so after warm-up (two rounds bound the in-flight
    // window) the readers never touch the allocator again.
    let workers = 2usize;
    let joins: Vec<_> = (0..workers)
        .map(|rank| {
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, workers, 47910).unwrap();
                g.enable_progress();
                let other = 1 - rank;
                let mut baseline = 0u64;
                for round in 0..8 {
                    let tag = (g.next_seq() << 8) | 1;
                    g.isend(other, tag, vec![rank as f32; 2048]).unwrap();
                    let data = g.recv(other, tag).unwrap();
                    assert_eq!(data.len(), 2048);
                    // hand the consumed frame back to the readers
                    assert!(
                        g.recycle(vec![data]).is_empty(),
                        "tcp must keep frames it handed out"
                    );
                    if round == 2 {
                        baseline = g.recv_buffer_allocs();
                    }
                }
                assert_eq!(
                    g.recv_buffer_allocs(),
                    baseline,
                    "rank {rank}: steady-state receive path allocated"
                );
                assert!(g.recv_buffer_hits() > 0, "rank {rank}: freelist never used");
                g.barrier().unwrap();
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
}

/// One config's per-rank step record.
#[allow(clippy::type_complexity)]
fn run_layer_steps(
    rt: Arc<Runtime>,
    workers: usize,
    overlap: bool,
    chunks: usize,
    pool_on: bool,
    steps: usize,
) -> Vec<(Vec<f32>, u64, u64, u64, u64, u64)> {
    run_workers(workers, move |mut h| {
        let layer = MoeLayerBuilder::new()
            .seed(3)
            .overlap(overlap)
            .chunks(chunks)
            .pool(pool_on)
            .build(rt.clone(), workers, h.rank())?;
        let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
        Rng::new(77 + h.rank() as u64).fill_normal(&mut x.data, 1.0);
        let mut y_bits = Vec::new();
        let (mut cf_copy, mut cb_copy, mut rows_bytes) = (0u64, 0u64, 0u64);
        let mut late_misses = 0u64;
        for step in 0..steps {
            let mut cf = Counters::new();
            let (y, state) = layer.forward(&mut h, x.clone(), &mut cf)?;
            let mut cb = Counters::new();
            let dy = TensorF32::full(&[layer.nb, layer.dm], 1e-3);
            let _ = layer.backward(&mut h, &state, &dy, &mut cb)?;
            if step + 1 == steps {
                y_bits = y.data.clone();
                cf_copy = cf.get("moe_copy_bytes");
                cb_copy = cb.get("moe_copy_bytes");
                rows_bytes = state.eb.rows_per_expert.iter().sum::<usize>() as u64
                    * layer.dm as u64
                    * 4;
            }
            if step >= 2 {
                late_misses += cf.get("pool_misses") + cb.get("pool_misses");
            }
            layer.recycle(state);
        }
        Ok((y_bits, cf_copy, cb_copy, rows_bytes, late_misses, layer.pool_stats().hits))
    })
    .unwrap()
}

#[test]
fn layer_steady_state_and_copy_counters_thread_backend() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 4usize;
    if rt
        .manifest
        .artifact(&format!("gate_fwd_w{workers}"))
        .is_none()
    {
        return;
    }
    let steps = 4usize;
    let blocking = run_layer_steps(rt.clone(), workers, false, 1, true, steps);
    let overlapped = run_layer_steps(rt.clone(), workers, true, 4, true, steps);
    let pool_off = run_layer_steps(rt.clone(), workers, true, 4, false, steps);
    let adaptive = run_layer_steps(rt.clone(), workers, true, 0, true, steps);

    for rank in 0..workers {
        let b = &blocking[rank];
        let o = &overlapped[rank];
        // identical routing ⇒ identical bits, pool or no pool, any path
        assert_eq!(b.0, o.0, "rank {rank}: overlapped forward bits");
        assert_eq!(b.0, pool_off[rank].0, "rank {rank}: pool-off bits");
        assert_eq!(b.0, adaptive[rank].0, "rank {rank}: adaptive bits");
        // zero steady-state allocations on both schedules
        assert_eq!(b.4, 0, "rank {rank}: blocking steady-state pool misses");
        assert_eq!(o.4, 0, "rank {rank}: overlapped steady-state pool misses");
        assert!(b.5 > 0 && o.5 > 0, "rank {rank}: pool never hit");
        // the ROADMAP double-copy is gone: overlapped forward copies
        // exactly one extra stage pass over the landed rows (the
        // slice-view gather into the bucketed executable's staging),
        // not two; backward copy volumes are identical
        assert_eq!(
            o.1,
            b.1 + o.3,
            "rank {rank}: overlapped fwd copies != blocking + one row pass"
        );
        assert_eq!(o.2, b.2, "rank {rank}: backward copy volumes diverged");
    }
}

#[test]
fn layer_steady_state_tcp_backend_with_progress() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 2usize;
    if rt
        .manifest
        .artifact(&format!("gate_fwd_w{workers}"))
        .is_none()
    {
        return;
    }
    let joins: Vec<_> = (0..workers)
        .map(|rank| {
            let rt = rt.clone();
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, workers, 47810).unwrap();
                g.enable_progress();
                let layer = MoeLayerBuilder::new()
                    .seed(3)
                    .overlap(true)
                    .chunks(2)
                    .build(rt, workers, rank)
                    .unwrap();
                let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
                Rng::new(90 + rank as u64).fill_normal(&mut x.data, 1.0);
                for step in 0..4 {
                    let mut cf = Counters::new();
                    let (y, state) = layer.forward(&mut g, x.clone(), &mut cf).unwrap();
                    let mut cb = Counters::new();
                    let dy = TensorF32::full(&[layer.nb, layer.dm], 1e-3);
                    let _ = layer.backward(&mut g, &state, &dy, &mut cb).unwrap();
                    layer.recycle(state);
                    assert!(y.data.iter().all(|v| v.is_finite()));
                    if step >= 2 {
                        assert_eq!(
                            cf.get("pool_misses") + cb.get("pool_misses"),
                            0,
                            "rank {rank} step {step}: tcp steady state allocated"
                        );
                    }
                }
                g.barrier().unwrap();
                assert!(g.progress_arrivals() > 0);
                // the layer recycles consumed receive buffers into the
                // backend's freelist, so the readers reuse them
                assert!(
                    g.recv_buffer_hits() > 0,
                    "rank {rank}: receive freelist never used by the layer path"
                );
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
}
