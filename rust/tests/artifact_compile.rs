//! Every artifact in the manifest must parse and compile on the PJRT
//! CPU client — catches HLO-dialect drift between jax and the pinned
//! XLA 0.5.1 text parser wholesale.

use fastmoe::runtime::Runtime;

#[test]
fn every_artifact_compiles() {
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(_) => {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
    };
    let names: Vec<String> = rt
        .manifest
        .artifacts
        .iter()
        .map(|a| a.name.clone())
        .collect();
    assert!(names.len() >= 30, "suspiciously few artifacts: {}", names.len());
    let mut failures = Vec::new();
    for name in &names {
        if let Err(e) = rt.executable(name) {
            failures.push(format!("{name}: {e}"));
        }
    }
    assert!(
        failures.is_empty(),
        "{} artifacts failed to compile:\n{}",
        failures.len(),
        failures.join("\n")
    );
}

#[test]
fn manifest_families_complete() {
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(_) => return,
    };
    let m = &rt.manifest;
    for fam in ["fig5", "fig3", "stage", "fig7", "quickstart"] {
        assert!(!m.family(fam).is_empty(), "family {fam} missing");
    }
    assert!(!m.buckets().is_empty());
    // every fig-5 expert count has all four variants
    let fig5 = m.family("fig5");
    let counts: std::collections::BTreeSet<usize> = fig5
        .iter()
        .filter_map(|a| a.meta_usize("n_expert"))
        .collect();
    for e in &counts {
        for kind in ["moe_fwd", "moe_grad", "naive_fwd", "naive_grad"] {
            assert!(
                m.artifact(&format!("{kind}_e{e}")).is_some(),
                "missing {kind}_e{e}"
            );
        }
    }
}
