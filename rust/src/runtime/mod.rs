//! PJRT runtime: load AOT artifacts, compile once, execute from Rust.
//!
//! The build path (`make artifacts`) lowers every Layer-1/2 program to
//! HLO **text** plus a `manifest.json` describing each program's ABI.
//! This module is the only place that touches the `xla` crate:
//!
//! * [`Manifest`] — parsed manifest: artifact ABIs + model registries.
//! * [`Runtime`] — a PJRT CPU client plus a compile-once executable
//!   cache keyed by artifact name.
//! * [`Executable::run`] — positional `HostTensor` in / out with full
//!   ABI checking, so an artifact/coordinator mismatch is a typed error
//!   rather than a segfault three layers down.

mod manifest;

pub use manifest::{ArtifactMeta, Manifest, ModelEntry, ParamEntry, SyncTag, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::tensor::{HostTensor, HostTensorRef, TensorF32, TensorI32};

/// A compiled artifact with its ABI.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

// Safety: the PJRT CPU client is thread-safe for compilation and
// execution (it is driven from many threads inside TF/JAX); the xla
// crate just hasn't marked its wrappers. All mutation is behind the
// C++ API's own synchronisation.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

/// The PJRT runtime: client + artifact registry + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Open the artifact directory (compiles nothing yet).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} ({e}); run `make artifacts`",
                manifest_path.display()
            ))
        })?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, manifest, dir, cache: Mutex::new(HashMap::new()) })
    }

    /// Locate the artifacts directory: `$FASTMOE_ARTIFACTS`, then
    /// `./artifacts`, then `<crate root>/artifacts`.
    pub fn open_default() -> Result<Self> {
        if let Ok(dir) = std::env::var("FASTMOE_ARTIFACTS") {
            return Self::open(dir);
        }
        for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
            if Path::new(cand).join("manifest.json").exists() {
                return Self::open(cand);
            }
        }
        Err(Error::Manifest(
            "no artifacts directory found (run `make artifacts` or set \
             FASTMOE_ARTIFACTS)"
                .into(),
        ))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifact(name)
            .ok_or_else(|| Error::ArtifactNotFound(name.to_string()))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::msg("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let e = Arc::new(Executable { meta, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Pre-compile a set of artifacts (worker warm-up).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Build + compile a computation with the XlaBuilder (fig-3 GEMM
    /// sweep builds matmuls of arbitrary shapes at run time).
    pub fn compile_computation(
        &self,
        comp: &xla::XlaComputation,
    ) -> Result<xla::PjRtLoadedExecutable> {
        Ok(self.client.compile(comp)?)
    }

    /// Transfer a host tensor to a device-resident buffer.
    ///
    /// The buffer-based execute path (`Executable::run_buffers`) is both
    /// the fast path (no host→device transfer per call for persistent
    /// state) and the *leak-free* path: the pinned xla_extension's
    /// literal-argument `execute` leaks its implicit transfer buffers
    /// (~40 KiB/call measured — EXPERIMENTS.md §Perf), while
    /// `execute_b` with explicit buffers is clean.
    pub fn to_buffer(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let lit = to_literal(t)?;
        Ok(self.client.buffer_from_host_literal(None, &lit)?)
    }
}

impl Executable {
    /// Execute with positional host tensors; checks the ABI both ways.
    ///
    /// Convenience over [`Executable::run_refs`] for callers that
    /// already own their argument tensors.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<HostTensorRef> = inputs.iter().map(HostTensorRef::from).collect();
        self.run_refs(&refs)
    }

    /// Execute with *borrowed* host tensors — the hot-path entry: no
    /// caller-side clone just to build the argument list; each input
    /// goes host→literal exactly once.
    ///
    /// Arguments go through explicit device buffers + `execute_b`: the
    /// pinned xla_extension's literal-argument `execute` leaks its
    /// implicit transfer buffers (~40 KiB/call, which OOM-killed a
    /// 300-step training run — EXPERIMENTS.md §Perf iteration 2);
    /// the explicit-buffer path is leak-free and lets callers keep
    /// persistent state device-side.
    pub fn run_refs(&self, inputs: &[HostTensorRef]) -> Result<Vec<HostTensor>> {
        self.check_inputs(inputs)?;
        let client = self.exe.client();
        // literals must outlive execution: the CPU PJRT host→device
        // transfer is asynchronous and reads the literal's memory.
        let mut literals = Vec::with_capacity(inputs.len());
        let mut bufs = Vec::with_capacity(inputs.len());
        for t in inputs {
            let lit = to_literal_ref(*t)?;
            bufs.push(client.buffer_from_host_literal(None, &lit)?);
            literals.push(lit);
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let out = self.run_buffers(&refs)?;
        let tuple = out[0].to_literal_sync()?;
        drop(literals);
        self.decode_outputs(tuple)
    }

    /// Execute raw literals (perf path: callers may keep literals around).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(literals)?;
        Ok(result[0][0].to_literal_sync()?)
    }

    /// Execute with device-resident argument buffers (see
    /// [`Runtime::to_buffer`]); returns the raw output buffers of the
    /// result tuple — callers keep state device-side across calls.
    pub fn run_buffers(
        &self,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut result = self.exe.execute_b(args)?;
        Ok(std::mem::take(&mut result[0]))
    }

    /// Decode one output buffer per the manifest output spec at `idx`.
    pub fn buffer_to_host(
        &self,
        idx: usize,
        buf: &xla::PjRtBuffer,
    ) -> Result<HostTensor> {
        let lit = buf.to_literal_sync()?;
        from_literal(lit, &self.meta.outputs[idx])
    }

    fn check_inputs(&self, inputs: &[HostTensorRef]) -> Result<()> {
        let spec = &self.meta.inputs;
        if inputs.len() != spec.len() {
            return Err(Error::Abi {
                artifact: self.meta.name.clone(),
                msg: format!("expected {} inputs, got {}", spec.len(), inputs.len()),
            });
        }
        for (i, (t, s)) in inputs.iter().zip(spec).enumerate() {
            if t.shape() != s.shape.as_slice() || t.dtype() != s.dtype {
                return Err(Error::Abi {
                    artifact: self.meta.name.clone(),
                    msg: format!(
                        "input {i} (`{}`): expected {:?} {}, got {:?} {}",
                        s.name, s.shape, s.dtype, t.shape(), t.dtype()
                    ),
                });
            }
        }
        Ok(())
    }

    fn decode_outputs(&self, tuple: xla::Literal) -> Result<Vec<HostTensor>> {
        let mut tuple = tuple;
        let parts = tuple.decompose_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            return Err(Error::Abi {
                artifact: self.meta.name.clone(),
                msg: format!(
                    "expected {} outputs, got {}",
                    self.meta.outputs.len(),
                    parts.len()
                ),
            });
        }
        parts
            .into_iter()
            .zip(&self.meta.outputs)
            .map(|(lit, spec)| from_literal(lit, spec))
            .collect()
    }
}

/// HostTensor -> PJRT literal.
pub fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    to_literal_ref(t.into())
}

/// Borrowed host tensor -> PJRT literal (the bytes are copied into the
/// literal here — the one unavoidable staging copy of the execute path).
pub fn to_literal_ref(t: HostTensorRef) -> Result<xla::Literal> {
    let (ty, dims, bytes) = match t {
        HostTensorRef::F32(t) => (xla::ElementType::F32, &t.shape, t.as_bytes()),
        HostTensorRef::I32(t) => (xla::ElementType::S32, &t.shape, t.as_bytes()),
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        ty, dims, bytes,
    )?)
}

/// PJRT literal -> HostTensor, validated against the manifest spec.
pub fn from_literal(lit: xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
    let shape = spec.shape.clone();
    match spec.dtype.as_str() {
        "f32" => {
            let data = lit.to_vec::<f32>()?;
            Ok(TensorF32::from_vec(&shape, data)?.into())
        }
        "i32" => {
            let data = lit.to_vec::<i32>()?;
            Ok(TensorI32::from_vec(&shape, data)?.into())
        }
        other => Err(Error::Manifest(format!("unsupported dtype `{other}`"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        Runtime::open_default().ok()
    }

    #[test]
    fn manifest_loads_and_lists_artifacts() {
        let Some(rt) = runtime() else { return };
        assert!(rt.manifest.artifacts.len() >= 10);
        assert!(rt.manifest.artifact("quickstart_moe").is_some());
        assert!(rt.manifest.artifact("definitely_missing").is_none());
    }

    #[test]
    fn unknown_artifact_is_typed_error() {
        let Some(rt) = runtime() else { return };
        match rt.executable("nope") {
            Err(Error::ArtifactNotFound(n)) => assert_eq!(n, "nope"),
            Err(other) => panic!("expected ArtifactNotFound, got {other}"),
            Ok(_) => panic!("expected ArtifactNotFound, got Ok"),
        }
    }

    #[test]
    fn quickstart_artifact_runs_and_matches_host_gate() {
        let Some(rt) = runtime() else { return };
        let exe = rt.executable("quickstart_moe").unwrap();
        let meta = exe.meta.clone();
        let mut rng = crate::rng::Rng::new(3);
        let inputs: Vec<HostTensor> = meta
            .inputs
            .iter()
            .map(|s| {
                let mut t = TensorF32::zeros(&s.shape);
                rng.fill_normal(&mut t.data, 0.3);
                HostTensor::F32(t)
            })
            .collect();
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        let y = out[0].as_f32().unwrap();
        assert_eq!(y.shape, meta.inputs[0].shape); // same shape as x
        assert!(y.data.iter().all(|v| v.is_finite()));
        // executable cache: second fetch hits the cache
        let before = rt.cached();
        let _ = rt.executable("quickstart_moe").unwrap();
        assert_eq!(rt.cached(), before);
    }

    #[test]
    fn run_refs_matches_run_bitwise() {
        let Some(rt) = runtime() else { return };
        let exe = rt.executable("quickstart_moe").unwrap();
        let mut rng = crate::rng::Rng::new(9);
        let inputs: Vec<HostTensor> = exe
            .meta
            .inputs
            .iter()
            .map(|s| {
                let mut t = TensorF32::zeros(&s.shape);
                rng.fill_normal(&mut t.data, 0.3);
                HostTensor::F32(t)
            })
            .collect();
        let owned = exe.run(&inputs).unwrap();
        let refs: Vec<HostTensorRef> = inputs.iter().map(HostTensorRef::from).collect();
        let borrowed = exe.run_refs(&refs).unwrap();
        assert_eq!(owned.len(), borrowed.len());
        for (a, b) in owned.iter().zip(&borrowed) {
            assert_eq!(
                a.as_f32().unwrap().data,
                b.as_f32().unwrap().data,
                "run vs run_refs must be the same execution"
            );
        }
    }

    #[test]
    fn abi_mismatch_is_rejected() {
        let Some(rt) = runtime() else { return };
        let exe = rt.executable("quickstart_moe").unwrap();
        // wrong arity
        assert!(matches!(exe.run(&[]), Err(Error::Abi { .. })));
        // wrong shape
        let bad: Vec<HostTensor> = exe
            .meta
            .inputs
            .iter()
            .map(|_| HostTensor::F32(TensorF32::zeros(&[1, 1])))
            .collect();
        assert!(matches!(exe.run(&bad), Err(Error::Abi { .. })));
    }
}
