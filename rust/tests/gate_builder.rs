//! The hierarchical layer API end-to-end: `MoeLayerBuilder` must
//! reproduce the seed layer bit-for-bit on the default gate, and a
//! config-selected `SwitchGate` must train while honouring its
//! capacity invariants on the live dispatch path.

use std::sync::Arc;

use fastmoe::comm::{run_workers, Comm};
use fastmoe::config::ConfigFile;
use fastmoe::coordinator::{DistMoeLayer, MoeLayerBuilder, MoeLayerTrainer};
use fastmoe::metrics::Counters;
use fastmoe::moe::SwitchGate;
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::tensor::TensorF32;

fn runtime() -> Option<Arc<Runtime>> {
    Runtime::open_default().ok().map(Arc::new)
}

fn has_stage_artifacts(rt: &Runtime, workers: usize) -> bool {
    rt.manifest
        .artifact(&format!("gate_fwd_w{workers}"))
        .is_some()
}

#[test]
fn builder_default_is_bit_identical_to_init() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 2usize;
    if !has_stage_artifacts(&rt, workers) {
        return;
    }
    let seed = 0xBEEF;
    let results = run_workers(workers, {
        let rt = rt.clone();
        move |mut h| {
            let old = DistMoeLayer::init(rt.clone(), workers, h.rank(), seed)?;
            let new = MoeLayerBuilder::new()
                .seed(seed)
                .build(rt.clone(), workers, h.rank())?;
            let mut x = TensorF32::zeros(&[old.nb, old.dm]);
            Rng::new(5).fill_normal(&mut x.data, 1.0);
            let mut c = Counters::new();
            // interleaved collectives are symmetric across workers:
            // every worker runs old.forward then new.forward
            let (y_old, st_old) = old.forward(&mut h, x.clone(), &mut c)?;
            let (y_new, st_new) = new.forward(&mut h, x.clone(), &mut c)?;
            let mut dy = y_old.clone();
            let n = dy.data.len() as f32;
            for v in dy.data.iter_mut() {
                *v /= n;
            }
            let g_old = old.backward(&mut h, &st_old, &dy, &mut c)?;
            let g_new = new.backward(&mut h, &st_new, &dy, &mut c)?;
            Ok((y_old, y_new, st_old.counts_global, st_new.counts_global, g_old, g_new))
        }
    })
    .unwrap();
    for (y_old, y_new, c_old, c_new, g_old, g_new) in &results {
        // identical gate + identical weights ⇒ bitwise-equal everything
        assert_eq!(y_old.data, y_new.data, "forward outputs diverge");
        assert_eq!(c_old, c_new, "routing counts diverge");
        assert_eq!(g_old.dwg.data, g_new.dwg.data, "gate grads diverge");
        assert_eq!(g_old.dx.data, g_new.dx.data, "input grads diverge");
        for (name, g) in &g_old.expert {
            assert_eq!(
                &g.data,
                &g_new.expert_grad(name).unwrap().data,
                "expert grad `{name}` diverges"
            );
        }
    }
}

#[test]
fn config_selected_switch_gate_trains_within_capacity() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 2usize;
    if !has_stage_artifacts(&rt, workers) {
        return;
    }
    let cf = 1.0f64;
    let cfg = ConfigFile::parse(&format!(
        "[moe]\ngate = \"switch\"\ncapacity_factor = {cf}\n"
    ))
    .unwrap()
    .moe()
    .unwrap();
    assert_eq!(cfg.gate, "switch");

    let builder = MoeLayerBuilder::from_config(&cfg).seed(11);
    let results = run_workers(workers, {
        let rt = rt.clone();
        move |mut h| {
            let layer = builder.build_for(rt.clone(), &h)?;
            let (nb, dm, k) = (layer.nb, layer.dm, layer.k);
            let ne = layer.workers * layer.ne_local;
            let cap = SwitchGate::new(cf as f32).unwrap().capacity(nb, ne);

            // --- capacity invariants on the live routing path ---
            let mut x = TensorF32::zeros(&[nb, dm]);
            Rng::new(50 + h.rank() as u64).fill_normal(&mut x.data, 1.0);
            let mut c = Counters::new();
            let (y, state) = layer.forward(&mut h, x.clone(), &mut c)?;
            assert!(y.data.iter().all(|v| v.is_finite()));
            let mut kept = vec![0usize; ne];
            for i in 0..nb {
                for j in 1..k {
                    assert_eq!(
                        state.assign.w[i * k + j],
                        0.0,
                        "filler slot carries weight"
                    );
                }
                let w0 = state.assign.w[i * k];
                if w0 > 0.0 {
                    kept[state.assign.idx[i * k] as usize] += 1;
                } else {
                    assert_eq!(w0, 0.0, "dropped token must be zero-weighted");
                }
            }
            for (e, &cnt) in kept.iter().enumerate() {
                assert!(cnt <= cap, "expert {e}: {cnt} kept > capacity {cap}");
            }
            // the layer's own kept histogram agrees with the manual one
            let kept_u32: Vec<u32> = kept.iter().map(|&c| c as u32).collect();
            assert_eq!(state.counts_kept, kept_u32);
            // every slot (kept, dropped, filler) still transits the
            // exchange: the substrate's shape never changes
            assert_eq!(
                state.counts_global.iter().sum::<u32>() as usize,
                nb * k
            );
            assert!(state.balance >= 0.9, "balance loss implausibly low");

            // --- a short training run completes and reduces energy ---
            let mut tr = MoeLayerTrainer::new(layer, 1e-2);
            let mut first = f32::NAN;
            let mut last = f32::NAN;
            for s in 0..5 {
                let stats = tr.train_step(&mut h, x.clone(), &mut c)?;
                assert!(stats.loss.is_finite());
                assert!(stats.balance.is_finite());
                if s == 0 {
                    first = stats.loss;
                }
                last = stats.loss;
            }
            Ok((first, last))
        }
    })
    .unwrap();
    for (first, last) in &results {
        assert!(
            last < first,
            "switch-gate training did not reduce the objective: {first} -> {last}"
        );
    }
}

#[test]
fn noisy_gate_layers_agree_across_workers() {
    let Some(rt) = runtime() else { return };
    let workers = 2usize;
    if !has_stage_artifacts(&rt, workers) {
        return;
    }
    let builder = MoeLayerBuilder::new()
        .gate("noisy_topk")
        .noise_std(0.5)
        .seed(23);
    let ys = run_workers(workers, {
        let rt = rt.clone();
        move |mut h| {
            let layer = builder.build_for(rt.clone(), &h)?;
            // identical batch everywhere: the layer computes one global
            // function, so outputs must match across workers — which
            // also proves the seeded noise stream is identical on every
            // worker's independent gate instance.
            let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
            Rng::new(99).fill_normal(&mut x.data, 1.0);
            let mut c = Counters::new();
            let (y, _) = layer.forward(&mut h, x, &mut c)?;
            Ok(y)
        }
    })
    .unwrap();
    for y in &ys[1..] {
        assert_eq!(ys[0].data, y.data, "noisy routing diverged across workers");
    }
}
