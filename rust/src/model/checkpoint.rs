//! Checkpoint save/load — the paper's "loading and saving of MoE
//! models" utility (§6 future work), as a small self-describing binary
//! format:
//!
//! ```text
//! magic "FMOE" | version u32 | count u32 |
//!   per tensor: name_len u32 | name bytes | rank u32 | dims u64… | f32 data
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::ParamStore;
use crate::error::{Error, Result};
use crate::tensor::TensorF32;

const MAGIC: &[u8; 4] = b"FMOE";
const VERSION: u32 = 1;

/// Write all parameters (names + shapes + data).
pub fn save_checkpoint(path: impl AsRef<Path>, store: &ParamStore) -> Result<()> {
    let named: Vec<(String, &TensorF32)> = store
        .entries
        .iter()
        .zip(&store.tensors)
        .map(|(e, t)| (e.name.clone(), t))
        .collect();
    save_tensors(path, &named)
}

/// Write an arbitrary named-tensor set **atomically**: bytes stream to
/// a `.tmp` sibling first and a single `fs::rename` publishes them, so
/// a crash mid-save never corrupts the previous file at `path` — the
/// property the periodic `[fault] ckpt_interval` checkpoints rely on.
pub fn save_tensors(path: impl AsRef<Path>, tensors: &[(String, &TensorF32)]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(tensors.len() as u32).to_le_bytes())?;
        for (name, t) in tensors {
            let name = name.as_bytes();
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name)?;
            w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            w.write_all(t.as_bytes())?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Read a named-tensor file written by [`save_tensors`] (or
/// [`save_checkpoint`] — same format), tensors in file order.
pub fn load_tensors(path: impl AsRef<Path>) -> Result<Vec<(String, TensorF32)>> {
    let mut r = BufReader::new(std::fs::File::open(&path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Checkpoint("bad magic".into()));
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(Error::Checkpoint(format!("unsupported version {version}")));
    }
    let count = read_u32(&mut r)? as usize;
    if count > 1 << 20 {
        return Err(Error::Checkpoint("implausible tensor count".into()));
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            return Err(Error::Checkpoint("implausible name length".into()));
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)
            .map_err(|_| Error::Checkpoint("bad name utf8".into()))?;
        let rank = read_u32(&mut r)? as usize;
        if rank > 16 {
            return Err(Error::Checkpoint("implausible tensor rank".into()));
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut r)? as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        // Safety: reading LE f32s into the vec's byte view.
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
        };
        r.read_exact(bytes)?;
        out.push((name, TensorF32::from_vec(&shape, data)?));
    }
    Ok(out)
}

/// Load a checkpoint *into* an initialised store; names and shapes must
/// match the store's registry exactly (order-independent).
pub fn load_checkpoint(path: impl AsRef<Path>, store: &mut ParamStore) -> Result<()> {
    let mut r = BufReader::new(std::fs::File::open(&path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Checkpoint("bad magic".into()));
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(Error::Checkpoint(format!("unsupported version {version}")));
    }
    let count = read_u32(&mut r)? as usize;
    if count != store.len() {
        return Err(Error::Checkpoint(format!(
            "checkpoint has {count} tensors, model has {}",
            store.len()
        )));
    }
    let mut seen = vec![false; store.len()];
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            return Err(Error::Checkpoint("implausible name length".into()));
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)
            .map_err(|_| Error::Checkpoint("bad name utf8".into()))?;
        let rank = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut r)? as usize);
        }
        let idx = store
            .index_of(&name)
            .ok_or_else(|| Error::Checkpoint(format!("unknown tensor `{name}`")))?;
        if seen[idx] {
            return Err(Error::Checkpoint(format!("duplicate tensor `{name}`")));
        }
        seen[idx] = true;
        if store.tensors[idx].shape != shape {
            return Err(Error::Checkpoint(format!(
                "`{name}`: checkpoint shape {:?} vs model {:?}",
                shape, store.tensors[idx].shape
            )));
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        // Safety: reading LE f32s into the vec's byte view.
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
        };
        r.read_exact(bytes)?;
        store.tensors[idx] = TensorF32::from_vec(&shape, data)?;
    }
    Ok(())
}

/// Flatten one expert's dim-0 slot out of a set of shard tensors, in
/// tensor order — the wire/migration format for moving a single
/// expert's parameters (or Adam moments) between ranks.  Every tensor
/// must be `[ne_local, ...]`-shaped with the same `ne_local`; the slot
/// slice of tensor `[n, d...]` is its contiguous `numel / n` elements
/// starting at `slot * numel / n`.
pub fn pack_expert_slot(tensors: &[&TensorF32], slot: usize) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    for t in tensors {
        let n = *t.shape.first().ok_or_else(|| {
            Error::Shape("pack_expert_slot: rank-0 tensor".into())
        })?;
        if slot >= n {
            return Err(Error::Shape(format!(
                "pack_expert_slot: slot {slot} of {n}"
            )));
        }
        let stride = t.data.len() / n;
        out.extend_from_slice(&t.data[slot * stride..(slot + 1) * stride]);
    }
    Ok(out)
}

/// Inverse of [`pack_expert_slot`]: scatter a packed payload back into
/// the `slot` slice of each tensor, consuming the payload in tensor
/// order.  The payload length must match the slot slices exactly.
pub fn unpack_expert_slot(
    payload: &[f32],
    tensors: &mut [&mut TensorF32],
    slot: usize,
) -> Result<()> {
    let mut pos = 0usize;
    for t in tensors.iter_mut() {
        let n = *t.shape.first().ok_or_else(|| {
            Error::Shape("unpack_expert_slot: rank-0 tensor".into())
        })?;
        if slot >= n {
            return Err(Error::Shape(format!(
                "unpack_expert_slot: slot {slot} of {n}"
            )));
        }
        let stride = t.data.len() / n;
        if pos + stride > payload.len() {
            return Err(Error::Shape(format!(
                "unpack_expert_slot: payload too short ({} < {})",
                payload.len(),
                pos + stride
            )));
        }
        t.data[slot * stride..(slot + 1) * stride]
            .copy_from_slice(&payload[pos..pos + stride]);
        pos += stride;
    }
    if pos != payload.len() {
        return Err(Error::Shape(format!(
            "unpack_expert_slot: {} payload floats left over",
            payload.len() - pos
        )));
    }
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn store() -> ParamStore {
        let text = r#"{
          "preset": "t", "artifacts": [],
          "models": {"m": {"config": {}, "params": [
              {"name": "a", "shape": [2, 2], "init": "normal:1.0", "tag": "none"},
              {"name": "b", "shape": [3], "init": "ones", "tag": "world"}
            ], "train_step": "", "eval_step": "", "grad_step": ""}}}"#;
        let m = Manifest::parse(text).unwrap();
        ParamStore::init(m.model("m").unwrap(), 5).unwrap()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fastmoe_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let src = store();
        let path = tmp("rt");
        save_checkpoint(&path, &src).unwrap();
        let mut dst = store();
        // perturb, then restore
        dst.tensors[0].data[0] += 99.0;
        load_checkpoint(&path, &mut dst).unwrap();
        assert_eq!(src.tensors, dst.tensors);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn shape_mismatch_detected() {
        let src = store();
        let path = tmp("shape");
        save_checkpoint(&path, &src).unwrap();
        // corrupt one dim in the file: easier — load into a store with a
        // different registry
        let text = r#"{
          "preset": "t", "artifacts": [],
          "models": {"m": {"config": {}, "params": [
              {"name": "a", "shape": [4], "init": "zeros", "tag": "none"},
              {"name": "b", "shape": [3], "init": "ones", "tag": "world"}
            ], "train_step": "", "eval_step": "", "grad_step": ""}}}"#;
        let m = Manifest::parse(text).unwrap();
        let mut other = ParamStore::init(m.model("m").unwrap(), 1).unwrap();
        let err = load_checkpoint(&path, &mut other).unwrap_err();
        assert!(matches!(err, Error::Checkpoint(_)), "{err}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn truncated_file_detected() {
        let src = store();
        let path = tmp("trunc");
        save_checkpoint(&path, &src).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let mut dst = store();
        assert!(load_checkpoint(&path, &mut dst).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn expert_slot_roundtrip() {
        // two shard tensors over 3 experts: [3, 2] and [3]
        let a = TensorF32::from_vec(&[3, 2], (0..6).map(|i| i as f32).collect())
            .unwrap();
        let b = TensorF32::from_vec(&[3], vec![10.0, 11.0, 12.0]).unwrap();
        let payload = pack_expert_slot(&[&a, &b], 1).unwrap();
        assert_eq!(payload, vec![2.0, 3.0, 11.0]);
        // scatter into a different slot of fresh tensors
        let mut a2 = TensorF32::zeros(&[3, 2]);
        let mut b2 = TensorF32::zeros(&[3]);
        unpack_expert_slot(&payload, &mut [&mut a2, &mut b2], 2).unwrap();
        assert_eq!(&a2.data[4..6], &[2.0, 3.0]);
        assert_eq!(b2.data[2], 11.0);
        assert_eq!(&a2.data[..4], &[0.0; 4]);
        // guards: bad slot, short payload
        assert!(pack_expert_slot(&[&a], 3).is_err());
        assert!(unpack_expert_slot(&[1.0], &mut [&mut a2], 0).is_err());
    }

    #[test]
    fn named_tensor_roundtrip_is_atomic() {
        let a = TensorF32::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = TensorF32::from_vec(&[3], vec![5.0, 6.0, 7.0]).unwrap();
        let path = tmp("named");
        save_tensors(&path, &[("x".into(), &a), ("meta".into(), &b)]).unwrap();
        // a successful save leaves no tmp sibling behind
        assert!(!path.with_extension("tmp").exists());
        let got = load_tensors(&path).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, "x");
        assert_eq!(got[0].1, a);
        assert_eq!(got[1].0, "meta");
        assert_eq!(got[1].1, b);
        // overwriting through the same rename path keeps the file valid
        save_tensors(&path, &[("x".into(), &b)]).unwrap();
        let got = load_tensors(&path).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].1, b);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn garbage_rejected() {
        let path = tmp("garbage");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let mut dst = store();
        assert!(load_checkpoint(&path, &mut dst).is_err());
        let _ = std::fs::remove_file(path);
    }
}
