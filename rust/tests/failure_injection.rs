//! Failure injection: every operational failure mode must surface as a
//! typed error (or a contained worker failure), never a hang or UB —
//! and, since PR 8, the elastic-recovery pins: an injected death under
//! `recover = "degrade"` continues bitwise-deterministically on the
//! survivors, and `rejoin` restores the full topology from checkpoints
//! plus live shadow state.  All chaos is a deterministic
//! [`ChaosSchedule`] — no sleeps-and-hope.
//!
//! Ports: 47870 / 47970 (worker-death containment over tcp), 48070
//! (serve client disconnect), 49170 / 49190 (tcp degrade pins,
//! deferred / progress), 49270 (recv timeout feeds suspicion), 49370
//! (serve worker-death reject drain), 49470 / 49490 (mid-collective
//! timeout recovery, deferred / progress).

use std::sync::Arc;
use std::time::Duration;

use fastmoe::comm::tcp::TcpGroup;
use fastmoe::comm::{run_workers, Comm, TopoComm, Topology};
use fastmoe::config::{CommConfig, MoeConfig, ServeConfig};
use fastmoe::coordinator::{MoeLayerBuilder, MoeLayerTrainer, ServeLoop, CTL_STEP, CTL_TAG};
use fastmoe::error::Error;
use fastmoe::fault::{ChaosSchedule, Membership, RecoverMode, Recovery, RecoveryAction};
use fastmoe::metrics::Counters;
use fastmoe::moe::bucket_for;
use fastmoe::placement::PlanDelta;
use fastmoe::rng::Rng;
use fastmoe::runtime::{Manifest, Runtime};
use fastmoe::serve::{run_thread_daemon, ClientConn, Reply, ServeDaemon};
use fastmoe::tensor::TensorF32;

#[test]
fn worker_panic_is_contained_and_attributed() {
    let res = run_workers(4, |h| {
        if h.rank() == 2 {
            panic!("injected crash");
        }
        Ok(h.rank())
    });
    match res {
        Err(Error::Worker { rank: 2, msg }) => assert!(msg.contains("panicked")),
        other => panic!("expected contained worker failure, got {other:?}"),
    }
}

#[test]
fn corrupt_artifact_file_is_typed_error() {
    let Ok(rt) = Runtime::open_default() else { return };
    // stage a corrupt copy of the artifact dir with a poisoned file
    let tmp = std::env::temp_dir().join(format!("fastmoe_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let manifest_src = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json"),
    )
    .unwrap();
    std::fs::write(tmp.join("manifest.json"), &manifest_src).unwrap();
    let name = rt.manifest.artifacts[0].name.clone();
    let file = rt.manifest.artifacts[0].file.clone();
    std::fs::write(tmp.join(&file), "HloModule garbage !!!!").unwrap();
    let rt2 = Runtime::open(&tmp).unwrap();
    match rt2.executable(&name) {
        Err(Error::Xla(_)) => {}
        Err(Error::Io(_)) => {}
        other => panic!("expected xla/io error, got {:?}", other.map(|_| "ok")),
    }
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn missing_artifact_file_is_io_error() {
    let Ok(rt) = Runtime::open_default() else { return };
    let tmp = std::env::temp_dir().join(format!("fastmoe_missing_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let manifest_src = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json"),
    )
    .unwrap();
    std::fs::write(tmp.join("manifest.json"), &manifest_src).unwrap();
    let name = rt.manifest.artifacts[0].name.clone();
    let rt2 = Runtime::open(&tmp).unwrap();
    assert!(rt2.executable(&name).is_err());
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn malformed_manifest_is_rejected() {
    assert!(Manifest::parse("{ not json").is_err());
    assert!(Manifest::parse(r#"{"artifacts": 5}"#).is_err());
    // well-formed JSON but bad schema
    assert!(Manifest::parse(r#"{"artifacts": [{"name": 1}]}"#).is_err());
}

#[test]
fn bucket_overflow_is_actionable_error() {
    let err = bucket_for(5000, &[64, 128]).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("5000") && msg.contains("aot.py"), "{msg}");
}

#[test]
fn worker_death_mid_bucketed_sync_is_contained() {
    // A worker dying while its peers run the bucketed nonblocking
    // all-reduce must surface as a typed error on the survivors (the
    // thread backend's death-aware receives), contained by run_workers
    // as Error::Worker — never a deadlock in the ring.
    let res = run_workers(4, |mut h| {
        if h.rank() == 2 {
            return Err(Error::msg("injected death"));
        }
        let bufs: Vec<Vec<f32>> =
            (0..3).map(|b| vec![h.rank() as f32 + b as f32; 129]).collect();
        // survivors keep syncing until the dead ring edge surfaces
        for _ in 0..8 {
            let pending = h.all_reduce_start(bufs.clone())?;
            let _ = pending.finish(&mut h)?;
        }
        Ok(())
    });
    match res {
        Err(Error::Worker { .. }) => {}
        other => panic!("expected contained worker failure, got {other:?}"),
    }
}

#[test]
fn tcp_worker_death_mid_bucketed_sync_errors_survivors() {
    // Same failure over real sockets with the progress engine: the
    // dead peer's reader marks the connection closed, and survivors'
    // bucketed sync errors out instead of hanging.
    const WORKERS: usize = 3;
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, WORKERS, 47870).unwrap();
                if rank == 1 {
                    // connect (the mesh needs every rank), then die
                    return true;
                }
                g.enable_progress();
                let bufs: Vec<Vec<f32>> =
                    (0..2).map(|b| vec![rank as f32 + b as f32; 65]).collect();
                for _ in 0..4 {
                    let pending = match g.all_reduce_start(bufs.clone()) {
                        Ok(p) => p,
                        Err(_) => return true, // send into the closed socket
                    };
                    if pending.finish(&mut g).is_err() {
                        return true;
                    }
                }
                false
            })
        })
        .collect();
    for (rank, j) in joins.into_iter().enumerate() {
        assert!(
            j.join().unwrap(),
            "rank {rank}: survivor completed a sync through a dead peer"
        );
    }
}

/// Worker `victim` dies while the others drive the hierarchical
/// (2-node) bucketed all-reduce; every survivor must error (the
/// death-aware receives cascade through gather, ring and broadcast
/// edges), contained by `run_workers` as `Error::Worker`.
fn hier_death_is_contained(victim: usize) {
    let res = run_workers(4, move |h| {
        if h.rank() == victim {
            return Err(Error::msg("injected death"));
        }
        let mut c = TopoComm::new(h, Topology::new(4, 2).unwrap())?;
        let bufs: Vec<Vec<f32>> =
            (0..3).map(|b| vec![c.rank() as f32 + b as f32; 129]).collect();
        for _ in 0..8 {
            let pending = c.all_reduce_start(bufs.clone())?;
            let _ = pending.finish(&mut c)?;
        }
        Ok(())
    });
    match res {
        Err(Error::Worker { .. }) => {}
        other => panic!(
            "victim {victim}: expected contained worker failure, got {other:?}"
        ),
    }
}

#[test]
fn hier_leader_death_mid_tree_all_reduce_is_contained() {
    // rank 0 leads node 0: its member starves on the broadcast, the
    // other leader starves on the ring — both must error, not hang
    hier_death_is_contained(0);
}

#[test]
fn hier_member_death_mid_tree_all_reduce_is_contained() {
    // rank 1 is a plain member: its leader starves on the gather, and
    // the error cascades across the leader ring to the other node
    hier_death_is_contained(1);
}

#[test]
fn tcp_deferred_flush_death_is_detected() {
    // No progress engine: the deferred-flush receive path must surface
    // a dead peer as a typed error — via EOF when the OS delivers it,
    // via the keepalive probe when it doesn't — never a hang.
    const WORKERS: usize = 3;
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, WORKERS, 47970).unwrap();
                if rank == 1 {
                    // connect (the mesh needs every rank), then die
                    return true;
                }
                // survivors block on a message the dead peer never
                // sends; the deferred-flush liveness machinery must
                // error them out
                g.recv(1, 12345).is_err()
            })
        })
        .collect();
    for (rank, j) in joins.into_iter().enumerate() {
        assert!(
            j.join().unwrap(),
            "rank {rank}: survived a recv from a dead peer"
        );
    }
}

#[test]
fn serve_client_disconnect_is_contained() {
    // A client that vanishes mid-request must cost the daemon nothing
    // but an accounting entry: its session reader exits on the socket
    // error, its queued work's response write fails *contained* in
    // `ServeDaemon::respond`, and every other session keeps getting
    // served bitwise-normally until an orderly shutdown.
    let Ok(rt) = Runtime::open_default() else { return };
    let rt = Arc::new(rt);
    const WORKERS: usize = 2;
    let Some(gate) = rt.manifest.artifact(&format!("gate_fwd_w{WORKERS}")) else {
        return;
    };
    let dm = gate.inputs[0].shape[1];
    let cfg = ServeConfig { port: 48070, max_batch: 0, queue_depth: 1024, idle_ms: 20 };
    let daemon = {
        let rt = rt.clone();
        std::thread::spawn(move || {
            run_thread_daemon(
                rt,
                WORKERS,
                5,
                MoeConfig::default(),
                CommConfig::default(),
                cfg,
            )
        })
    };
    let addr = "127.0.0.1:48070";
    let mut data = vec![0f32; dm];
    Rng::new(11).fill_normal(&mut data, 1.0);

    // all three sessions prove themselves live first
    let mut victim = ClientConn::connect(addr).unwrap();
    let mut survivors = [
        ClientConn::connect(addr).unwrap(),
        ClientConn::connect(addr).unwrap(),
    ];
    for (i, s) in survivors.iter_mut().enumerate() {
        s.request(i as u32, 1, &data).unwrap();
        assert!(matches!(s.recv_reply().unwrap(), Reply::Ok { .. }));
    }
    victim.request(100, 1, &data).unwrap();
    assert!(matches!(victim.recv_reply().unwrap(), Reply::Ok { .. }));

    // mid-request disconnect: fire a request and slam the socket shut
    // without reading the reply
    victim.request(101, 1, &data).unwrap();
    drop(victim);

    // the remaining sessions must keep round-tripping afterwards
    for round in 0..3u32 {
        for (i, s) in survivors.iter_mut().enumerate() {
            let id = 10 + round * 2 + i as u32;
            s.request(id, 1, &data).unwrap();
            match s.recv_reply().unwrap() {
                Reply::Ok { id: got, data: y } => {
                    assert_eq!(got, id);
                    assert_eq!(y.len(), dm);
                    assert!(y.iter().all(|v| v.is_finite()));
                }
                Reply::Rejected { id } => panic!("request {id} rejected"),
            }
        }
    }
    let mut stop = ClientConn::connect(addr).unwrap();
    stop.shutdown().unwrap();
    let stats = daemon.join().unwrap().unwrap();
    // 3 warm-ups + 6 survivor rounds answered for sure; the victim's
    // in-flight request lands as either a served request (the response
    // write won the race with the close) or a counted disconnect
    assert!(stats.requests >= 9, "{stats:?}");
    assert_eq!(stats.requests + stats.disconnects, 10, "{stats:?}");
}

#[test]
fn oversized_collective_disagreement_detected() {
    // a peer that lies about its payload size must be caught by phase-2
    // validation of the Figure-2 protocol (not deadlock) — emulate by
    // sending ragged all_gather inputs
    let res = run_workers(2, |mut h| {
        let mine = vec![0.0f32; 4 + h.rank()]; // ragged!
        match h.all_gather(&mine) {
            Err(_) => Ok(true), // detected
            Ok(_) => Ok(false),
        }
    });
    match res {
        Ok(flags) => assert!(flags.iter().any(|&f| f)),
        Err(_) => {} // a contained worker error is also acceptable
    }
}

// ---------------------------------------------------------------------------
// Elastic fault recovery (PR 8): the acceptance pins.
// ---------------------------------------------------------------------------

const FWORKERS: usize = 2;
const FSTEPS: usize = 6;
const KILL_AT: usize = 3;

fn frt() -> Option<Arc<Runtime>> {
    Runtime::open_default().ok().map(Arc::new)
}

fn fault_trainer(rt: Arc<Runtime>, rank: usize) -> fastmoe::Result<MoeLayerTrainer> {
    let layer = MoeLayerBuilder::new()
        .gate("topk")
        .seed(91)
        .build(rt, FWORKERS, rank)?;
    layer.warm()?;
    Ok(MoeLayerTrainer::new(layer, 1e-3))
}

/// The same deterministic batch on every run for a given (rank, step).
fn fstep_input(nb: usize, dm: usize, rank: usize, step: usize) -> TensorF32 {
    let mut x = TensorF32::zeros(&[nb, dm]);
    Rng::new(6000 + (step * FWORKERS + rank) as u64).fill_normal(&mut x.data, 1.0);
    x
}

/// Every trainable tensor's bits: the `P` layer params, then the `P`
/// Adam first moments, then the `P` second moments (expert-shard
/// tensors sit at indices `2..P` within each third).
fn dump_bits(tr: &MoeLayerTrainer) -> Vec<Vec<u32>> {
    let mut out: Vec<Vec<u32>> = tr
        .layer
        .params()
        .iter()
        .map(|(_, t)| t.data.iter().map(|v| v.to_bits()).collect())
        .collect();
    for t in tr.optimizer().m.iter().chain(tr.optimizer().v.iter()) {
        out.push(t.data.iter().map(|v| v.to_bits()).collect());
    }
    out
}

/// The degrade acceptance pin, on any backend.  Three sequential runs
/// on one comm handle, each with every rank-1 expert shadow-replicated
/// onto rank 0 before training starts:
///
/// * run A never fails;
/// * run B enters degraded mode by planned handover
///   ([`Membership::assume`]) at the `KILL_AT` boundary;
/// * run C is driven through [`Recovery::poll`] by the chaos schedule
///   `kill@3:r1` — detection, membership agreement, quarantine.
///
/// Pins: the survivor's loss at the failover step matches the
/// never-failed run bit-for-bit (every dead-owned expert has a
/// bit-exact replica and expert math is row-independent, so routing
/// around the corpse changes nothing), and run C matches run B bitwise
/// in every loss and in the final params + Adam moments.
fn assert_degrade_bitwise_pin(
    comm: &mut impl Comm,
    rt: Arc<Runtime>,
) -> fastmoe::Result<()> {
    let rank = comm.rank();
    let mut run = |mode: u8| -> fastmoe::Result<(Vec<u32>, Vec<Vec<u32>>)> {
        let mut tr = fault_trainer(rt.clone(), rank)?;
        let ne_local = tr.layer.ne_local;
        for e in ne_local..2 * ne_local {
            tr.force_delta(comm, &PlanDelta::AddShadow { expert: e, host: 0 })?;
        }
        let mut rec = Recovery::new(
            RecoverMode::Degrade,
            ChaosSchedule::parse(&format!("kill@{KILL_AT}:r1"))?,
        );
        let mut counters = Counters::new();
        let mut losses = Vec::with_capacity(FSTEPS);
        for i in 0..FSTEPS {
            match mode {
                0 => {} // never fails
                1 if i == KILL_AT => {
                    tr.degrade(&Membership::assume(FWORKERS, &[1]))?;
                }
                1 => {}
                _ => match rec.poll(comm, i as u64)? {
                    Some(RecoveryAction::Degrade(m)) => tr.degrade(&m)?,
                    Some(a) => panic!("unexpected recovery action {a:?}"),
                    None => {}
                },
            }
            let x = fstep_input(tr.layer.nb, tr.layer.dm, rank, i);
            losses.push(tr.train_step(comm, x, &mut counters)?.loss.to_bits());
        }
        assert_eq!(tr.degraded().is_some(), mode != 0, "mode {mode}");
        Ok((losses, dump_bits(&tr)))
    };
    let a = run(0)?;
    let b = run(1)?;
    let c = run(2)?;
    // the pre-failure prefix is the same trajectory...
    assert_eq!(a.0[..KILL_AT], b.0[..KILL_AT], "rank {rank}: prefix");
    // ...and on the survivor the failover step itself is bit-identical
    if rank == 0 {
        assert_eq!(a.0[KILL_AT], b.0[KILL_AT], "survivor loss at failover step");
    }
    // chaos-driven detection ≡ planned handover, to the last bit
    assert_eq!(b.0, c.0, "rank {rank}: losses");
    assert_eq!(b.1, c.1, "rank {rank}: params + Adam moments");
    Ok(())
}

#[test]
fn degrade_with_shadow_cover_is_bitwise_pinned_thread() {
    let Some(rt) = frt() else { return };
    run_workers(FWORKERS, move |mut h| {
        assert_degrade_bitwise_pin(&mut h, rt.clone())
    })
    .unwrap();
}

fn tcp_degrade_pin(port: u16, progress: bool) {
    let Some(rt) = frt() else { return };
    let joins: Vec<_> = (0..FWORKERS)
        .map(|rank| {
            let rt = rt.clone();
            std::thread::spawn(move || -> fastmoe::Result<()> {
                let mut g = TcpGroup::connect_local(rank, FWORKERS, port)?;
                if progress {
                    g.enable_progress();
                }
                assert_degrade_bitwise_pin(&mut g, rt)?;
                g.barrier()
            })
        })
        .collect();
    for (rank, j) in joins.into_iter().enumerate() {
        j.join()
            .unwrap_or_else(|_| panic!("tcp rank {rank} panicked"))
            .unwrap();
    }
}

#[test]
fn tcp_degrade_chaos_matches_planned_deferred() {
    tcp_degrade_pin(49170, false);
}

#[test]
fn tcp_degrade_chaos_matches_planned_progress() {
    tcp_degrade_pin(49190, true);
}

/// The rejoin acceptance pin: `kill@3:r1,rejoin@5:r1` with interval-2
/// checkpointing and rank 1's first expert shadow-covered.  After
/// [`MoeLayerTrainer::rejoin_restore`] the rejoined rank must carry
///
/// * the covered expert's *live* pre-rejoin state (its replica kept
///   training past the checkpoint and streamed back), strictly newer
///   than the checkpoint;
/// * every uncovered expert exactly as the step-2 checkpoint froze it;
/// * the survivors' gate (+ its Adam slots and step counters)
///   bit-for-bit, via the rejoin broadcast —
///
/// and training continues at full strength with finite losses.
#[test]
fn rejoin_restores_live_covered_state_and_checkpointed_rest() {
    let Some(rt) = frt() else { return };
    let dir = std::env::temp_dir().join(format!("fastmoe_rejoin_{}", std::process::id()));
    let dir_s = dir.to_str().unwrap().to_string();
    let out = run_workers(FWORKERS, move |mut h| {
        let rank = h.rank();
        let mut tr = fault_trainer(rt.clone(), rank)?.with_checkpointing(2, &dir_s);
        let ne_local = tr.layer.ne_local;
        tr.force_delta(&mut h, &PlanDelta::AddShadow { expert: ne_local, host: 0 })?;
        let mut rec = Recovery::new(
            RecoverMode::Rejoin,
            ChaosSchedule::parse("kill@3:r1,rejoin@5:r1")?,
        );
        let mut counters = Counters::new();
        let (mut ckpt, mut pre, mut post) = (Vec::new(), Vec::new(), Vec::new());
        for i in 0..7u64 {
            match rec.poll(&mut h, i)? {
                Some(RecoveryAction::Degrade(m)) => tr.degrade(&m)?,
                Some(RecoveryAction::Rejoin(r)) => {
                    assert_eq!(r, 1);
                    pre = dump_bits(&tr);
                    tr.rejoin_restore(&mut h, Some(&dir_s))?;
                    post = dump_bits(&tr);
                    assert!(tr.degraded().is_none(), "quarantine must lift");
                }
                Some(RecoveryAction::Abort(r)) => panic!("unexpected abort of rank {r}"),
                None => {}
            }
            let x = fstep_input(tr.layer.nb, tr.layer.dm, rank, i as usize);
            let s = tr.train_step(&mut h, x, &mut counters)?;
            assert!(s.loss.is_finite(), "step {i} rank {rank}");
            if i == 1 {
                // the interval-2 checkpoint just landed — remember the
                // exact state it froze (maybe_checkpoint is the last
                // state-touching op of a step)
                ckpt = dump_bits(&tr);
            }
        }
        Ok((ne_local, ckpt, pre, post, tr.optimizer().step))
    })
    .unwrap();
    let _ = std::fs::remove_dir_all(&dir);

    let ne = out[0].0;
    let p = out[0].3.len() / 3; // tensor count per third
    // the rejoined rank fast-forwarded to the survivors' gate trajectory
    for slot in [0, 1, p, p + 1, 2 * p, 2 * p + 1] {
        assert_eq!(out[0].3[slot], out[1].3[slot], "gate slot {slot}");
    }
    assert_eq!(out[0].4, out[1].4, "Adam step counters");
    // rank 1's expert slots: covered == live pre-rejoin state (and it
    // moved past the checkpoint), uncovered == the checkpoint
    let (_, ckpt, pre, post, _) = &out[1];
    let mut covered_advanced = false;
    for t in 2..p {
        for part in [t, p + t, 2 * p + t] {
            let stride = post[part].len() / ne;
            assert_eq!(
                post[part][..stride],
                pre[part][..stride],
                "covered slot, tensor {part}"
            );
            covered_advanced |= post[part][..stride] != ckpt[part][..stride];
            for s in 1..ne {
                assert_eq!(
                    post[part][s * stride..(s + 1) * stride],
                    ckpt[part][s * stride..(s + 1) * stride],
                    "uncovered slot {s}, tensor {part}"
                );
            }
        }
    }
    assert!(covered_advanced, "the replica must have advanced past the checkpoint");
}

/// A recv deadline on a silent-but-alive peer surfaces as the typed,
/// attributed [`Error::Timeout`], which feeds [`Recovery::suspect`]:
/// the next poll runs membership agreement (the suspect is skipped in
/// gossip, so a two-rank world degrades without any traffic) and hands
/// the trainer a quarantine order.  Disarming the deadline restores a
/// fully working link.
#[test]
fn tcp_recv_timeout_feeds_suspicion_into_recovery() {
    const PORT: u16 = 49270;
    let joins: Vec<_> = (0..2)
        .map(|rank| {
            std::thread::spawn(move || -> fastmoe::Result<()> {
                let mut g = TcpGroup::connect_local(rank, 2, PORT)?;
                if rank == 0 {
                    g.set_recv_timeout(Some(Duration::from_millis(200)));
                    let mut rec =
                        Recovery::new(RecoverMode::Degrade, ChaosSchedule::parse("")?);
                    match g.recv(1, (1u64 << 41) | 9) {
                        Err(Error::Timeout { peer: 1, .. }) => rec.suspect(1),
                        other => panic!("expected Timeout from peer 1, got {other:?}"),
                    }
                    match rec.poll(&mut g, 0)? {
                        Some(RecoveryAction::Degrade(m)) => {
                            assert_eq!(m.dead, vec![1]);
                            assert_eq!(m.survivors(), vec![0]);
                        }
                        other => panic!("expected Degrade, got {other:?}"),
                    }
                    g.set_recv_timeout(None);
                    g.send(1, 606, vec![1.0])?;
                    assert_eq!(g.recv(1, 607)?, vec![2.0]);
                } else {
                    assert_eq!(g.recv(0, 606)?, vec![1.0]);
                    g.send(0, 607, vec![2.0])?;
                }
                g.barrier()
            })
        })
        .collect();
    for (rank, j) in joins.into_iter().enumerate() {
        j.join()
            .unwrap_or_else(|_| panic!("tcp rank {rank} panicked"))
            .unwrap();
    }
}

/// All-clear tag of the mid-collective timeout pin (bit 41: outside
/// the `seq << 8 | code` collective band, the FAULT_TAG band and every
/// salt bit, so it can never match a stale frame).
const TMO_CLEAR: u64 = (1 << 41) | 77;

/// The mid-collective timeout audit pin, on any backend.  Rank 2 is
/// silent-but-alive: it joins the mesh, never enters the collective,
/// and holds its link open until the survivors' all-clear.  Ranks 0/1
/// arm the `recv_timeout_ms` deadline and start a two-bucket all-reduce
/// that cannot complete: rank 0 starves directly on ring predecessor 2,
/// rank 1 one hop later (round 1 from rank 0, which cannot forward what
/// never arrived).  The abandoned [`PendingAllReduce`] is dropped with
/// both rings mid-flight — outstanding requests, in-flight frames the
/// peer never consumed, and world sequence counters advanced on the
/// survivors only.
///
/// The audit's contract, pinned here: none of that leakage can deadlock
/// or tag-collide recovery.  Membership gossip runs in the reserved
/// `FAULT_TAG` band (rank 1's gossip receive parks the stale bucket-1
/// round frame it never consumed), the survivor group re-binds
/// collectives into the disjoint `FAULT_SALT` band with its *own*
/// sequence counter (the world counters now disagree across ranks and
/// are never used again in degraded mode), and plain tagged sends on
/// the world handle still work — so a full bucketed all-reduce
/// completes on the survivor group over the very link the dead
/// collective still litters.
///
/// [`PendingAllReduce`]: fastmoe::comm::PendingAllReduce
fn timeout_mid_collective_pin<C: Comm>(
    g: &mut C,
    arm: &dyn Fn(&mut C, Option<Duration>),
) -> fastmoe::Result<()> {
    let rank = g.rank();
    if rank == 2 {
        assert_eq!(g.recv(0, TMO_CLEAR)?, vec![9.0]);
        return Ok(());
    }
    arm(g, Some(Duration::from_millis(200)));
    let bufs: Vec<Vec<f32>> = (0..2).map(|b| vec![(rank + b) as f32; 67]).collect();
    let mut pending = g.all_reduce_start(bufs)?;
    match pending.wait_bucket(g, 0) {
        Err(Error::Timeout { peer, .. }) => {
            assert_eq!(peer, if rank == 0 { 2 } else { 0 }, "rank {rank} attribution");
        }
        other => panic!("rank {rank}: expected mid-collective Timeout, got {other:?}"),
    }
    assert_eq!(pending.pending(), 2, "both rings abandoned mid-flight");
    drop(pending);
    // deadline off before gossip: agreement runs between live survivors
    // and must not race the 200ms budget under scheduler skew
    arm(g, None);
    let mut rec = Recovery::new(RecoverMode::Degrade, ChaosSchedule::parse("")?);
    rec.suspect(2);
    let m = match rec.poll(g, 0)? {
        Some(RecoveryAction::Degrade(m)) => m,
        other => panic!("rank {rank}: expected Degrade, got {other:?}"),
    };
    assert_eq!(m.dead, vec![2]);
    assert_eq!(m.survivors(), vec![0, 1]);
    let mut pg = m.survivor_group(rank)?;
    let mut sg = pg.bind(&mut *g);
    let sbufs: Vec<Vec<f32>> =
        (0..2).map(|b| vec![(rank + 1) as f32 * (b + 1) as f32; 33]).collect();
    let out = sg.all_reduce_start(sbufs)?.finish(&mut sg)?;
    for (b, buf) in out.iter().enumerate() {
        let want = 3.0 * (b + 1) as f32; // (1 + 2) · (b + 1)
        assert!(
            buf.iter().all(|&v| v == want),
            "rank {rank} bucket {b}: survivor all-reduce corrupted"
        );
    }
    drop(sg);
    if rank == 0 {
        // flush: the deferred tcp path buffers sends until a read, and
        // rank 0 exits right after this all-clear
        g.send(2, TMO_CLEAR, vec![9.0])?;
        g.flush()?;
    }
    Ok(())
}

#[test]
fn thread_timeout_mid_collective_degrades_to_survivor_group() {
    run_workers(3, |mut h| {
        timeout_mid_collective_pin(&mut h, &|h, t| h.set_recv_timeout(t))
    })
    .unwrap();
}

fn tcp_timeout_mid_collective_pin(port: u16, progress: bool) {
    let joins: Vec<_> = (0..3)
        .map(|rank| {
            std::thread::spawn(move || -> fastmoe::Result<()> {
                let mut g = TcpGroup::connect_local(rank, 3, port)?;
                if progress {
                    g.enable_progress();
                }
                timeout_mid_collective_pin(&mut g, &|g, t| g.set_recv_timeout(t))
            })
        })
        .collect();
    for (rank, j) in joins.into_iter().enumerate() {
        j.join()
            .unwrap_or_else(|_| panic!("tcp rank {rank} panicked"))
            .unwrap();
    }
}

#[test]
fn tcp_timeout_mid_collective_recovers_deferred() {
    tcp_timeout_mid_collective_pin(49470, false);
}

#[test]
fn tcp_timeout_mid_collective_recovers_progress() {
    tcp_timeout_mid_collective_pin(49490, true);
}

/// Satellite pin: a worker dying mid-serve must never strand clients.
/// Rank 1 runs a scripted worker — one good step, then it acks the next
/// step signal and dies without joining the collective forward — so the
/// daemon's step errors.  The client that caused that step must receive
/// a typed reject (the `reject_drain` path), not hang on a response
/// that cannot come, and `ServeDaemon::run` surfaces the error.
#[test]
fn serve_worker_death_rejects_queued_requests_not_hangs() {
    let Ok(rt) = Runtime::open_default() else { return };
    let rt = Arc::new(rt);
    let Some(gate) = rt.manifest.artifact(&format!("gate_fwd_w{FWORKERS}")) else {
        return;
    };
    let dm = gate.inputs[0].shape[1];
    let cfg = ServeConfig { port: 49370, max_batch: 0, queue_depth: 64, idle_ms: 10 };
    let daemon = {
        let rt = rt.clone();
        std::thread::spawn(move || {
            run_workers(FWORKERS, move |mut h| {
                let layer = MoeLayerBuilder::new()
                    .seed(5)
                    .build(rt.clone(), FWORKERS, h.rank())?;
                layer.warm()?;
                let mut counters = Counters::new();
                if h.rank() == 0 {
                    let lp = ServeLoop::new(layer);
                    let mut d = ServeDaemon::bind(&cfg, lp.layer().nb, lp.layer().dm)?;
                    assert!(d.run(&lp, &mut h, &mut counters).is_err());
                    Ok(())
                } else {
                    // scripted worker: serve exactly one step, ack the
                    // second step signal, then die without the forward
                    assert_eq!(h.recv(0, CTL_TAG)?, vec![CTL_STEP]);
                    let zero = TensorF32::zeros(&[layer.nb, layer.dm]);
                    layer.forward_infer(&mut h, zero, &mut counters)?;
                    assert_eq!(h.recv(0, CTL_TAG)?, vec![CTL_STEP]);
                    Ok(())
                }
            })
        })
    };
    let addr = "127.0.0.1:49370";
    let mut c = ClientConn::connect(addr).unwrap();
    let mut data = vec![0f32; dm];
    Rng::new(17).fill_normal(&mut data, 1.0);
    // request 1 round-trips while the worker lives
    c.request(1, 1, &data).unwrap();
    match c.recv_reply().unwrap() {
        Reply::Ok { id, data: y } => {
            assert_eq!(id, 1);
            assert_eq!(y.len(), dm);
        }
        Reply::Rejected { id } => panic!("request {id} rejected while healthy"),
    }
    // request 2's step hits the dead worker: a typed reject, not a hang
    c.request(2, 1, &data).unwrap();
    match c.recv_reply() {
        Ok(Reply::Rejected { id }) => assert_eq!(id, 2),
        other => panic!("expected typed reject, got {other:?}"),
    }
    daemon.join().unwrap().unwrap();
}
