//! Pluggable expert shards — the bottom of the paper's §3.1 hierarchy.
//!
//! An [`ExpertShard`] owns one worker's expert parameters and knows how
//! to run the bucketed HLO executables over an [`ExpertBatch`]: forward
//! (`[ne_local, bucket, dm] -> [ne_local, bucket, dm]`), backward
//! (input cotangents + parameter gradients), and parameter access as
//! *named tensor slots* so optimisers and checkpoints never hardcode an
//! expert architecture.
//!
//! [`FfnExpertShard`] is the seed architecture: the two-GEMM FFN
//! (`w1/b1` → GeLU → `w2/b2`) compiled per capacity bucket as
//! `expert_fwd_b{B}` / `expert_bwd_b{B}` artifacts.

use std::sync::Arc;

use super::ExpertBatch;
use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor::TensorF32;

/// One worker's expert shard: parameters + bucketed HLO execution.
///
/// Gradients and parameters travel as `(slot name, tensor)` pairs in a
/// stable order: `grads()` names from [`ExpertShard::backward`] must
/// align 1:1 with [`ExpertShard::params`].
pub trait ExpertShard: Send + Sync {
    /// Short architecture name for logs ("ffn", …).
    fn name(&self) -> &'static str;

    /// Local expert count of this shard.
    fn ne_local(&self) -> usize;

    /// Token feature width.
    fn dm(&self) -> usize;

    /// Pre-compile every executable this shard can touch.
    fn warm(&self) -> Result<()>;

    /// Run the shard over a padded batch; returns `[ne_local, bucket, dm]`.
    fn forward(&self, eb: &ExpertBatch) -> Result<TensorF32>;

    /// Backward over the same batch: output cotangents
    /// `dys: [ne_local, bucket, dm]` → (input cotangents of the same
    /// shape, named parameter gradients in [`ExpertShard::params`] order).
    /// Borrows `dys` so pooled cotangent containers can be recycled by
    /// the caller afterwards.
    fn backward(
        &self,
        eb: &ExpertBatch,
        dys: &TensorF32,
    ) -> Result<(TensorF32, Vec<(&'static str, TensorF32)>)>;

    /// Named parameter slots, in gradient order.
    fn params(&self) -> Vec<(&'static str, &TensorF32)>;

    /// Mutable named parameter slots (optimiser application).
    fn params_mut(&mut self) -> Vec<(&'static str, &mut TensorF32)>;

    /// Look one parameter up by slot name.
    fn param(&self, name: &str) -> Option<&TensorF32> {
        self.params().into_iter().find(|(n, _)| *n == name).map(|(_, t)| t)
    }

    /// Matmul FLOPs for `rows` real (unpadded) token rows through the
    /// shard, forward only.
    fn flops(&self, rows: usize) -> f64;
}

/// The seed FFN expert shard (w1/b1 → GeLU → w2/b2 per local expert).
pub struct FfnExpertShard {
    rt: Arc<Runtime>,
    ne_local: usize,
    dm: usize,
    pub dh: usize,
    buckets: Vec<usize>,
    pub w1: TensorF32,
    pub b1: TensorF32,
    pub w2: TensorF32,
    pub b2: TensorF32,
}

impl FfnExpertShard {
    /// Initialise a shard from `(seed, rank)` — the exact seed-path
    /// derivation of the original `DistMoeLayer::init` (weights are
    /// bit-identical for a given `(seed, rank)`).
    pub fn init(
        rt: Arc<Runtime>,
        ne_local: usize,
        dm: usize,
        dh: usize,
        buckets: Vec<usize>,
        seed: u64,
        rank: usize,
    ) -> FfnExpertShard {
        let mut erng = Rng::new(seed ^ (0xe0 + rank as u64));
        let mut w1 = TensorF32::zeros(&[ne_local, dm, dh]);
        erng.fill_normal(&mut w1.data, 0.02);
        let b1 = TensorF32::zeros(&[ne_local, dh]);
        let mut w2 = TensorF32::zeros(&[ne_local, dh, dm]);
        erng.fill_normal(&mut w2.data, 0.02);
        let b2 = TensorF32::zeros(&[ne_local, dm]);
        FfnExpertShard { rt, ne_local, dm, dh, buckets, w1, b1, w2, b2 }
    }
}

impl ExpertShard for FfnExpertShard {
    fn name(&self) -> &'static str {
        "ffn"
    }

    fn ne_local(&self) -> usize {
        self.ne_local
    }

    fn dm(&self) -> usize {
        self.dm
    }

    fn warm(&self) -> Result<()> {
        for &b in &self.buckets {
            self.rt.executable(&format!("expert_fwd_b{b}"))?;
            self.rt.executable(&format!("expert_bwd_b{b}"))?;
        }
        Ok(())
    }

    fn forward(&self, eb: &ExpertBatch) -> Result<TensorF32> {
        if eb.ne_local != self.ne_local || eb.dm != self.dm {
            return Err(Error::Shape(format!(
                "ffn shard: batch is {}×…×{}, shard wants {}×…×{}",
                eb.ne_local, eb.dm, self.ne_local, self.dm
            )));
        }
        let efwd = self.rt.executable(&format!("expert_fwd_b{}", eb.bucket))?;
        // run_refs: the padded batch and the (step-invariant) weights
        // are borrowed, not cloned, on every call — the zero-copy PR's
        // single-device win.
        let out = efwd.run_refs(&[
            (&eb.xs).into(),
            (&self.w1).into(),
            (&self.b1).into(),
            (&self.w2).into(),
            (&self.b2).into(),
        ])?;
        out.into_iter().next().unwrap().into_f32()
    }

    fn backward(
        &self,
        eb: &ExpertBatch,
        dys: &TensorF32,
    ) -> Result<(TensorF32, Vec<(&'static str, TensorF32)>)> {
        let ebwd = self.rt.executable(&format!("expert_bwd_b{}", eb.bucket))?;
        let out = ebwd.run_refs(&[
            (&eb.xs).into(),
            (&self.w1).into(),
            (&self.b1).into(),
            (&self.w2).into(),
            (&self.b2).into(),
            dys.into(),
        ])?;
        let mut it = out.into_iter();
        let dxs = it.next().unwrap().into_f32()?;
        let dw1 = it.next().unwrap().into_f32()?;
        let db1 = it.next().unwrap().into_f32()?;
        let dw2 = it.next().unwrap().into_f32()?;
        let db2 = it.next().unwrap().into_f32()?;
        Ok((dxs, vec![("w1", dw1), ("b1", db1), ("w2", dw2), ("b2", db2)]))
    }

    fn params(&self) -> Vec<(&'static str, &TensorF32)> {
        vec![
            ("w1", &self.w1),
            ("b1", &self.b1),
            ("w2", &self.w2),
            ("b2", &self.b2),
        ]
    }

    fn params_mut(&mut self) -> Vec<(&'static str, &mut TensorF32)> {
        vec![
            ("w1", &mut self.w1),
            ("b1", &mut self.b1),
            ("w2", &mut self.w2),
            ("b2", &mut self.b2),
        ]
    }

    fn flops(&self, rows: usize) -> f64 {
        2.0 * 2.0 * rows as f64 * self.dm as f64 * self.dh as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime-dependent behaviour is covered by the integration tests;
    // here we pin the seed-path parameter derivation and the named-slot
    // contract, which need no artifacts beyond an openable runtime.

    #[test]
    fn seed_path_matches_original_derivation() {
        // Mirror the original DistMoeLayer::init expert-weight loop and
        // check FfnExpertShard::init reproduces it bit-for-bit.
        let (ne_local, dm, dh, seed, rank) = (2usize, 4usize, 8usize, 77u64, 1usize);
        let mut erng = Rng::new(seed ^ (0xe0 + rank as u64));
        let mut want_w1 = TensorF32::zeros(&[ne_local, dm, dh]);
        erng.fill_normal(&mut want_w1.data, 0.02);
        let mut want_w2 = TensorF32::zeros(&[ne_local, dh, dm]);
        erng.fill_normal(&mut want_w2.data, 0.02);

        let Ok(rt) = Runtime::open_default() else {
            // No artifacts in this environment: the derivation above is
            // still the contract; nothing further to execute.
            return;
        };
        let s = FfnExpertShard::init(
            Arc::new(rt),
            ne_local,
            dm,
            dh,
            vec![16],
            seed,
            rank,
        );
        assert_eq!(s.w1.data, want_w1.data);
        assert_eq!(s.w2.data, want_w2.data);
        assert!(s.b1.data.iter().all(|&v| v == 0.0));
        assert!(s.b2.data.iter().all(|&v| v == 0.0));
        assert_eq!(s.params().iter().map(|(n, _)| *n).collect::<Vec<_>>(),
                   vec!["w1", "b1", "w2", "b2"]);
        assert_eq!(s.param("w2").unwrap().shape, vec![ne_local, dh, dm]);
        assert!(s.param("nope").is_none());
    }
}
