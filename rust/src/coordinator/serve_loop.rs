//! The serving-side coordinator: resident expert-parallel workers
//! stepped in lockstep by a front end.
//!
//! Training drives every rank through the same loop with the same
//! iteration count, so the collectives line up by construction.  A
//! serving daemon is different: only rank 0 (the front end) knows when
//! the next batch exists — requests arrive whenever clients send them —
//! yet the MoE forward is collective, so *every* rank must enter
//! `forward` together or the Figure-2 exchange deadlocks.
//!
//! [`ServeLoop`] closes that gap with a one-float control frame on a
//! reserved point-to-point tag: before each forward, rank 0 sends
//! every peer [`CTL_STEP`]; peers block on that tag
//! ([`ServeLoop::serve_worker`]), then run the same forward-only step
//! on an all-zero local batch (the daemon holds all client tokens on
//! rank 0 — peers contribute capacity, not rows).  [`CTL_STOP`] shuts
//! the loop down cleanly.  The data path is
//! [`DistMoeLayer::forward_infer`]: forward + immediate recycle, no
//! gradients, no cotangent pool roles — the PR 3 zero-copy machinery
//! with the training half dormant.
//!
//! Tag-space note: collective tags are `seq << 8 | code` (far below
//! [`CTL_TAG`] for any realistic sequence count), sub-group salts sit
//! at `1 << 61` / `1 << 62`, and the TCP keepalive uses `u64::MAX` —
//! the control band `1 << 59` collides with none of them.

use crate::comm::Comm;
use crate::coordinator::DistMoeLayer;
use crate::error::{Error, Result};
use crate::metrics::Counters;
use crate::tensor::TensorF32;

/// Reserved point-to-point tag of serve control frames.
pub const CTL_TAG: u64 = (1 << 59) | 1;

/// Control payload: run one forward step.
pub const CTL_STEP: f32 = 1.0;

/// Control payload: leave the serve loop.
pub const CTL_STOP: f32 = 0.0;

/// The inference-side sibling of the trainers: owns the resident
/// [`DistMoeLayer`] and keeps all ranks' collective schedules aligned
/// while batches arrive at the front end's pace.
pub struct ServeLoop {
    layer: DistMoeLayer,
}

impl ServeLoop {
    pub fn new(layer: DistMoeLayer) -> ServeLoop {
        ServeLoop { layer }
    }

    pub fn layer(&self) -> &DistMoeLayer {
        &self.layer
    }

    /// An all-zero local batch of the layer's geometry — what peers
    /// (and an idle front end) contribute to a step.
    pub fn zero_batch(&self) -> TensorF32 {
        TensorF32::zeros(&[self.layer.nb, self.layer.dm])
    }

    /// Front-end step (rank 0 only): release every peer into the
    /// collective forward, then run it with the coalesced batch `x`
    /// (`[nb, dm]`; unfilled rows zero).
    pub fn step(
        &self,
        comm: &mut impl Comm,
        x: TensorF32,
        counters: &mut Counters,
    ) -> Result<TensorF32> {
        self.signal(comm, CTL_STEP)?;
        self.layer.forward_infer(comm, x, counters)
    }

    /// Front-end shutdown (rank 0 only): release every peer out of
    /// [`ServeLoop::serve_worker`].
    pub fn stop(&self, comm: &mut impl Comm) -> Result<()> {
        self.signal(comm, CTL_STOP)
    }

    fn signal(&self, comm: &mut impl Comm, code: f32) -> Result<()> {
        if comm.rank() != 0 {
            return Err(Error::Comm(
                "serve: only rank 0 drives the control channel".into(),
            ));
        }
        for peer in 1..comm.size() {
            comm.send(peer, CTL_TAG, vec![code])?;
        }
        Ok(())
    }

    /// Worker loop (ranks > 0): block on the control tag, join each
    /// step with a zero batch, leave on [`CTL_STOP`].  Returns the
    /// number of steps served.
    pub fn serve_worker(
        &self,
        comm: &mut impl Comm,
        counters: &mut Counters,
    ) -> Result<u64> {
        let mut steps = 0u64;
        loop {
            let ctl = comm.recv(0, CTL_TAG)?;
            match ctl.first().copied() {
                Some(c) if c == CTL_STOP => return Ok(steps),
                Some(c) if c == CTL_STEP => {
                    self.layer.forward_infer(comm, self.zero_batch(), counters)?;
                    steps += 1;
                }
                other => {
                    return Err(Error::Comm(format!(
                        "serve: bad control frame {other:?}"
                    )))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_workers;
    use crate::coordinator::MoeLayerBuilder;
    use crate::runtime::Runtime;
    use std::sync::Arc;

    #[test]
    fn control_tag_stays_clear_of_other_bands() {
        use crate::fault::{gossip_tag, FAULT_SALT, FAULT_TAG};
        // collective tags: seq << 8 | code — reaching the control band
        // would take 2^51 collectives
        assert!(CTL_TAG > (1u64 << 40) << 8);
        // sub-group salt bands and the TCP keepalive sit above it
        assert!(CTL_TAG < 1 << 61);
        assert!(CTL_TAG < u64::MAX);
        // the fault bands share bit 59 but never the exact tag: the
        // gossip low byte is 2 (control is 1), and the survivor-group
        // salt lives at bit 58
        assert_ne!(FAULT_TAG, CTL_TAG);
        assert_eq!(FAULT_TAG & CTL_TAG, 1 << 59);
        for (epoch, round) in [(1u64, 0u64), (2, 3), (7, 11)] {
            let t = gossip_tag(epoch, round);
            assert_ne!(t, CTL_TAG);
            assert_eq!(t & 0xff, 2, "gossip keeps its own low byte");
        }
        assert_eq!(FAULT_SALT & CTL_TAG, 0);
    }

    #[test]
    fn serve_loop_steps_and_stops_workers() {
        let Ok(rt) = Runtime::open_default() else { return };
        let rt = Arc::new(rt);
        const W: usize = 2;
        const STEPS: u64 = 3;
        let res = run_workers(W, move |mut h| {
            let layer = MoeLayerBuilder::new().seed(5).build(rt.clone(), W, h.rank())?;
            layer.warm()?;
            let lp = ServeLoop::new(layer);
            let mut counters = Counters::new();
            if h.rank() == 0 {
                for _ in 0..STEPS {
                    let y = lp.step(&mut h, lp.zero_batch(), &mut counters)?;
                    assert_eq!(y.shape, vec![lp.layer().nb, lp.layer().dm]);
                }
                lp.stop(&mut h)?;
                Ok(STEPS)
            } else {
                lp.serve_worker(&mut h, &mut counters)
            }
        })
        .unwrap();
        assert!(res.iter().all(|&s| s == STEPS), "{res:?}");
    }

    #[test]
    fn control_frames_travel_point_to_point() {
        // the control band is plain p2p traffic — no collective
        // machinery, so it can never desynchronise sequence counters,
        // and ordering per peer pair is FIFO
        run_workers(2, |mut h| {
            if h.rank() == 0 {
                h.send(1, CTL_TAG, vec![CTL_STEP])?;
                h.send(1, CTL_TAG, vec![CTL_STOP])?;
            } else {
                assert_eq!(h.recv(0, CTL_TAG)?, vec![CTL_STEP]);
                assert_eq!(h.recv(0, CTL_TAG)?, vec![CTL_STOP]);
            }
            Ok(())
        })
        .unwrap();
    }
}
