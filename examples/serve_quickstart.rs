//! Serve quickstart: a resident inference daemon with continuous
//! batching, in one process.
//!
//! ```bash
//! make artifacts            # once: python lowers the HLO programs
//! cargo run --release --example serve_quickstart
//! ```
//!
//! The same thing split across processes (and machines, via `--hosts`):
//!
//! ```bash
//! fastmoe serve --workers 2 --serve-port 47800 --max-batch 8 &
//! fastmoe client --addr 127.0.0.1:47800 --rows 4 --dm 64 \
//!                --requests 32 --concurrency 3 --shutdown
//! ```
//!
//! Here, `serve::run_thread_daemon` keeps two expert-parallel workers
//! resident: rank 0 carries the TCP front end (listener → session
//! readers → `Batcher`), ranks ≥ 1 sit in `ServeLoop::serve_worker`
//! waiting on the control tag.  Client requests are coalesced into one
//! forward-only step per batch window and demultiplexed back with
//! per-request latency tracked in a `metrics::Histogram`.

use std::sync::Arc;

use fastmoe::config::{CommConfig, MoeConfig, ServeConfig};
use fastmoe::runtime::Runtime;
use fastmoe::serve::{run_thread_daemon, ClientConn, Reply};

fn main() -> fastmoe::Result<()> {
    let rt = Arc::new(Runtime::open_default()?);
    let workers = 2;
    // the client sizes payloads from the served model's hidden dim —
    // probe it from the gate artifact the layer will be built from
    let Some(gate) = rt.manifest.artifact(&format!("gate_fwd_w{workers}")) else {
        println!("(no {workers}-worker stage artifacts; skipping serve demo)");
        println!("serve quickstart OK");
        return Ok(());
    };
    let dm = gate.inputs[0].shape[1];

    // 1. The daemon: two resident expert-parallel workers, admission
    //    control at 4 rows/step, a shallow queue, a 5 ms batch window.
    let cfg = ServeConfig { port: 48370, max_batch: 4, queue_depth: 64, idle_ms: 5 };
    let addr = format!("127.0.0.1:{}", cfg.port);
    let daemon = std::thread::spawn(move || {
        run_thread_daemon(rt, workers, 7, MoeConfig::default(), CommConfig::default(), cfg)
    });

    // 2. A client session: three pipelined 2-row requests.  The
    //    batcher coalesces whatever lands inside one idle window into
    //    a single collective forward.
    let mut conn = ClientConn::connect(&addr)?;
    for id in 0..3u32 {
        let x = vec![0.1 * (id + 1) as f32; 2 * dm];
        conn.request(id, 2, &x)?;
    }
    for _ in 0..3 {
        match conn.recv_reply()? {
            Reply::Ok { id, data } => {
                println!("request {id}: {} output floats, y[0] = {:.4}", data.len(), data[0])
            }
            Reply::Rejected { id } => println!("request {id}: rejected (queue full)"),
        }
    }

    // 3. Orderly shutdown: the daemon drains its queue, stops the
    //    resident workers over the control tag, and reports stats.
    conn.shutdown()?;
    let stats = daemon
        .join()
        .map_err(|_| fastmoe::Error::msg("daemon thread panicked"))??;
    println!(
        "served {} requests ({} rows) in {} steps; latency p50 {:.2} ms, p99 {:.2} ms",
        stats.requests,
        stats.rows,
        stats.steps,
        stats.latency.p50() * 1e3,
        stats.latency.p99() * 1e3,
    );
    println!("serve quickstart OK");
    Ok(())
}
