//! Small shared utilities (JSON, formatting, file helpers).

pub mod json;

/// Human-readable byte count.
pub fn fmt_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable duration.
pub fn fmt_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

/// GFLOP/s given flops and seconds.
pub fn gflops(flops: f64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    flops / secs / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert!(fmt_bytes(3 * 1024 * 1024).starts_with("3.00 MiB"));
    }

    #[test]
    fn gflops_zero_guard() {
        assert_eq!(gflops(1e9, 0.0), 0.0);
        assert!((gflops(2e9, 1.0) - 2.0).abs() < 1e-9);
    }
}
