//! Host-side Adam — bit-compatible with `python/compile/train.py`.
//!
//! Used on the distributed path (grad_step artifact + GradSync + this);
//! the fused path runs the same update inside the train-step HLO.

use crate::error::{Error, Result};
use crate::tensor::TensorF32;

pub const B1: f32 = 0.9;
pub const B2: f32 = 0.999;
pub const EPS: f32 = 1e-8;

/// Adam state for one parameter set.
///
/// Under ZeRO sharding (`[comm] grad_shard = "zero"`) a slot's moments
/// cover only the contiguous shard this rank owns — `shard[slot]`
/// records the owned float range within the full tensor, and the slot
/// is stepped through [`Adam::update_shard`] instead of
/// [`Adam::update_slot`].  Unsharded slots (`shard[slot] == None`, the
/// only kind [`Adam::new`] makes) hold full-tensor moments.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub weight_decay: f32,
    pub m: Vec<TensorF32>,
    pub v: Vec<TensorF32>,
    pub step: u64,
    /// Owned float range per slot (`None` = full tensor, replicated).
    pub shard: Vec<Option<std::ops::Range<usize>>>,
}

impl Adam {
    pub fn new(shapes: &[TensorF32], lr: f32) -> Adam {
        Adam {
            lr,
            weight_decay: 0.0,
            m: shapes.iter().map(|t| TensorF32::zeros(&t.shape)).collect(),
            v: shapes.iter().map(|t| TensorF32::zeros(&t.shape)).collect(),
            step: 0,
            shard: shapes.iter().map(|_| None).collect(),
        }
    }

    /// Adam state with ZeRO-sharded slots: where `shard[i]` is `Some`,
    /// slot `i`'s moments are sized to the owned range alone (the ~1/w
    /// optimizer-memory cut), flat-shaped — checkpoints save them as
    /// slice-sized `m{i}`/`v{i}` tensors, so a resume must use the same
    /// world size and topology for the shapes to reconcile.
    pub fn new_sharded(
        shapes: &[TensorF32],
        lr: f32,
        shard: &[Option<std::ops::Range<usize>>],
    ) -> Result<Adam> {
        if shard.len() != shapes.len() {
            return Err(Error::Shape("adam: shard arity".into()));
        }
        let moments = || -> Result<Vec<TensorF32>> {
            shapes
                .iter()
                .zip(shard)
                .map(|(t, s)| match s {
                    None => Ok(TensorF32::zeros(&t.shape)),
                    Some(r) if r.end <= t.data.len() && r.start <= r.end => {
                        Ok(TensorF32::zeros(&[r.len()]))
                    }
                    Some(r) => Err(Error::Shape(format!(
                        "adam: shard {r:?} outside param of {} floats",
                        t.data.len()
                    ))),
                })
                .collect()
        };
        Ok(Adam {
            lr,
            weight_decay: 0.0,
            m: moments()?,
            v: moments()?,
            step: 0,
            shard: shard.to_vec(),
        })
    }

    /// Apply one update over all parameters given their gradients.
    pub fn update(&mut self, params: &mut [TensorF32], grads: &[TensorF32]) -> Result<()> {
        let mut ps: Vec<&mut TensorF32> = params.iter_mut().collect();
        let gs: Vec<&TensorF32> = grads.iter().collect();
        self.update_refs(&mut ps, &gs)
    }

    /// Same update over *borrowed* parameters — lets callers whose
    /// tensors live in different owners (gate params on the layer,
    /// expert params behind the `ExpertShard` trait's named slots)
    /// drive one optimiser without copying into a contiguous vec.
    pub fn update_refs(
        &mut self,
        params: &mut [&mut TensorF32],
        grads: &[&TensorF32],
    ) -> Result<()> {
        if params.len() != self.m.len() || grads.len() != self.m.len() {
            return Err(Error::Shape("adam arity".into()));
        }
        self.begin_step();
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            self.update_slot(i, p, g)?;
        }
        Ok(())
    }

    /// Advance the shared step counter: every [`Adam::update_slot`]
    /// call until the next `begin_step` applies this step's bias
    /// correction.  `update` / `update_refs` call it internally — use
    /// it directly only when stepping disjoint parameter subsets as
    /// their gradient buckets complete (the overlapped trainer path),
    /// making sure each slot is updated exactly once per step.
    pub fn begin_step(&mut self) {
        self.step += 1;
    }

    /// Update one parameter slot under the current step — bit-identical
    /// to the same slot's update inside [`Adam::update_refs`].
    pub fn update_slot(
        &mut self,
        slot: usize,
        p: &mut TensorF32,
        g: &TensorF32,
    ) -> Result<()> {
        if slot >= self.m.len() {
            return Err(Error::Shape(format!(
                "adam: slot {slot} of {}",
                self.m.len()
            )));
        }
        if self.step == 0 {
            return Err(Error::Shape("adam: update_slot before begin_step".into()));
        }
        if p.shape != g.shape {
            return Err(Error::Shape(format!(
                "adam: param {:?} vs grad {:?}",
                p.shape, g.shape
            )));
        }
        if self.shard[slot].is_some() {
            return Err(Error::Shape(format!(
                "adam: slot {slot} is ZeRO-sharded; use update_shard"
            )));
        }
        let t = self.step as f32;
        let bc1 = 1.0 - B1.powf(t);
        let bc2 = 1.0 - B2.powf(t);
        let (m, v) = (&mut self.m[slot], &mut self.v[slot]);
        for i in 0..p.data.len() {
            let gi = g.data[i];
            m.data[i] = B1 * m.data[i] + (1.0 - B1) * gi;
            v.data[i] = B2 * v.data[i] + (1.0 - B2) * gi * gi;
            let mhat = m.data[i] / bc1;
            let vhat = v.data[i] / bc2;
            p.data[i] -=
                self.lr * (mhat / (vhat.sqrt() + EPS) + self.weight_decay * p.data[i]);
        }
        Ok(())
    }

    /// Update the owned shard of a ZeRO-sharded slot: `p` and `g` are
    /// the parameter / reduced-gradient slices covering exactly
    /// `shard[slot]`.  Bit-identical, element for element, to what
    /// [`Adam::update_slot`] computes for those positions on a
    /// replicated rank — the moment recurrence and bias correction are
    /// per-element, so slicing changes nothing.
    pub fn update_shard(&mut self, slot: usize, p: &mut [f32], g: &[f32]) -> Result<()> {
        if slot >= self.m.len() {
            return Err(Error::Shape(format!(
                "adam: slot {slot} of {}",
                self.m.len()
            )));
        }
        if self.step == 0 {
            return Err(Error::Shape("adam: update_shard before begin_step".into()));
        }
        let Some(range) = self.shard[slot].clone() else {
            return Err(Error::Shape(format!(
                "adam: slot {slot} is not ZeRO-sharded; use update_slot"
            )));
        };
        if p.len() != range.len() || g.len() != range.len() {
            return Err(Error::Shape(format!(
                "adam: shard slices {}/{} floats, owned range is {}",
                p.len(),
                g.len(),
                range.len()
            )));
        }
        let t = self.step as f32;
        let bc1 = 1.0 - B1.powf(t);
        let bc2 = 1.0 - B2.powf(t);
        let (m, v) = (&mut self.m[slot], &mut self.v[slot]);
        for i in 0..p.len() {
            let gi = g[i];
            m.data[i] = B1 * m.data[i] + (1.0 - B1) * gi;
            v.data[i] = B2 * v.data[i] + (1.0 - B2) * gi * gi;
            let mhat = m.data[i] / bc1;
            let vhat = v.data[i] / bc2;
            p[i] -= self.lr * (mhat / (vhat.sqrt() + EPS) + self.weight_decay * p[i]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_closed_form() {
        // With zero state, step 1 gives p -= lr * g/(|g| + eps·√bc2/…)
        // ≈ p -= lr * sign(g) for any g (bias corrections cancel).
        let mut p = vec![TensorF32::from_vec(&[2], vec![1.0, -2.0]).unwrap()];
        let g = vec![TensorF32::from_vec(&[2], vec![0.5, -0.25]).unwrap()];
        let mut opt = Adam::new(&p, 0.1);
        opt.update(&mut p, &g).unwrap();
        assert!((p[0].data[0] - (1.0 - 0.1)).abs() < 1e-4, "{}", p[0].data[0]);
        assert!((p[0].data[1] - (-2.0 + 0.1)).abs() < 1e-4);
        assert_eq!(opt.step, 1);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimise f(x) = (x-3)², grad = 2(x-3)
        let mut p = vec![TensorF32::from_vec(&[1], vec![0.0]).unwrap()];
        let mut opt = Adam::new(&p, 0.1);
        for _ in 0..300 {
            let g = vec![TensorF32::from_vec(&[1], vec![2.0 * (p[0].data[0] - 3.0)]).unwrap()];
            opt.update(&mut p, &g).unwrap();
        }
        assert!((p[0].data[0] - 3.0).abs() < 0.05, "x={}", p[0].data[0]);
    }

    #[test]
    fn update_refs_matches_update_bitwise() {
        let mut pa = vec![
            TensorF32::from_vec(&[2], vec![1.0, -2.0]).unwrap(),
            TensorF32::from_vec(&[3], vec![0.5, 0.0, -0.5]).unwrap(),
        ];
        let mut pb = pa.clone();
        let g = vec![
            TensorF32::from_vec(&[2], vec![0.5, -0.25]).unwrap(),
            TensorF32::from_vec(&[3], vec![-0.1, 0.2, 0.3]).unwrap(),
        ];
        let mut oa = Adam::new(&pa, 0.05);
        let mut ob = oa.clone();
        for _ in 0..3 {
            oa.update(&mut pa, &g).unwrap();
            let (b0, b1) = pb.split_at_mut(1);
            let mut refs = vec![&mut b0[0], &mut b1[0]];
            ob.update_refs(&mut refs, &[&g[0], &g[1]]).unwrap();
        }
        assert_eq!(pa[0].data, pb[0].data);
        assert_eq!(pa[1].data, pb[1].data);
        assert_eq!(oa.step, ob.step);
    }

    #[test]
    fn slotwise_update_matches_update_bitwise() {
        // the overlapped trainer steps buckets out of order as they
        // complete — per-slot updates under one begin_step must be
        // bit-identical to the all-at-once update
        let mut pa = vec![
            TensorF32::from_vec(&[2], vec![1.0, -2.0]).unwrap(),
            TensorF32::from_vec(&[3], vec![0.5, 0.0, -0.5]).unwrap(),
            TensorF32::from_vec(&[1], vec![4.0]).unwrap(),
        ];
        let mut pb = pa.clone();
        let g = vec![
            TensorF32::from_vec(&[2], vec![0.5, -0.25]).unwrap(),
            TensorF32::from_vec(&[3], vec![-0.1, 0.2, 0.3]).unwrap(),
            TensorF32::from_vec(&[1], vec![-1.0]).unwrap(),
        ];
        let mut oa = Adam::new(&pa, 0.05);
        let mut ob = oa.clone();
        for _ in 0..3 {
            oa.update(&mut pa, &g).unwrap();
            ob.begin_step();
            // buckets complete out of order
            for i in [2usize, 0, 1] {
                ob.update_slot(i, &mut pb[i], &g[i]).unwrap();
            }
        }
        for (a, b) in pa.iter().zip(&pb) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(oa.step, ob.step);
        // guard rails
        let mut fresh = Adam::new(&pa, 0.05);
        assert!(fresh.update_slot(0, &mut pa[0], &g[0]).is_err(), "no begin_step");
        fresh.begin_step();
        assert!(fresh.update_slot(9, &mut pa[0], &g[0]).is_err(), "slot range");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut p = vec![TensorF32::zeros(&[2])];
        let g = vec![TensorF32::zeros(&[3])];
        let mut opt = Adam::new(&p, 0.1);
        assert!(opt.update(&mut p, &g).is_err());
    }

    #[test]
    fn sharded_update_matches_replicated_bitwise() {
        // two "ranks" each own half the tensor's moments; stepping each
        // owned slice must reproduce the replicated update's bits, over
        // several steps (the moment recurrences are per-element)
        let full = TensorF32::from_vec(&[6], vec![1.0, -2.0, 0.5, 3.0, -0.25, 0.75])
            .unwrap();
        let g =
            TensorF32::from_vec(&[6], vec![0.5, -0.25, -0.1, 0.2, 0.3, -1.0]).unwrap();
        let mut rep_p = vec![full.clone()];
        let mut rep = Adam::new(&rep_p, 0.05);
        let shards = [0usize..3, 3..6];
        let mut owners: Vec<Adam> = shards
            .iter()
            .map(|r| {
                Adam::new_sharded(
                    std::slice::from_ref(&full),
                    0.05,
                    &[Some(r.clone())],
                )
                .unwrap()
            })
            .collect();
        assert!(owners.iter().all(|o| o.m[0].data.len() == 3));
        let mut p_sh = full.data.clone();
        for _ in 0..3 {
            rep.update(&mut rep_p, std::slice::from_ref(&g)).unwrap();
            for (o, r) in owners.iter_mut().zip(&shards) {
                o.begin_step();
                o.update_shard(0, &mut p_sh[r.clone()], &g.data[r.clone()]).unwrap();
            }
        }
        assert_eq!(rep_p[0].data, p_sh);
        // guard rails: sharded slots refuse the full-tensor path and
        // vice versa; slice lengths must match the owned range
        let mut o = owners.pop().unwrap();
        let mut pt = full.clone();
        assert!(o.update_slot(0, &mut pt, &g).is_err(), "sharded via update_slot");
        assert!(
            o.update_shard(0, &mut p_sh[0..2], &g.data[0..2]).is_err(),
            "wrong slice len"
        );
        rep.begin_step();
        let mut buf = [0.0f32; 3];
        assert!(
            rep.update_shard(0, &mut buf, &[0.0; 3]).is_err(),
            "unsharded via update_shard"
        );
        assert!(
            Adam::new_sharded(std::slice::from_ref(&full), 0.1, &[Some(2..9)]).is_err(),
            "shard outside param"
        );
    }

    #[test]
    fn matches_python_reference_values() {
        // Pinned against compile/train.py adam_update on a worked example:
        // p=1.0, g=0.3, m=v=0, step=1, lr=0.01 → m=0.03, v=9e-5,
        // mhat=0.3, vhat=0.09, p' = 1 - 0.01*0.3/(0.3+1e-8) ≈ 0.99
        let mut p = vec![TensorF32::from_vec(&[1], vec![1.0]).unwrap()];
        let g = vec![TensorF32::from_vec(&[1], vec![0.3]).unwrap()];
        let mut opt = Adam::new(&p, 0.01);
        opt.update(&mut p, &g).unwrap();
        assert!((p[0].data[0] - 0.99).abs() < 1e-6, "{}", p[0].data[0]);
        assert!((opt.m[0].data[0] - 0.03).abs() < 1e-8);
        assert!((opt.v[0].data[0] - 9e-5).abs() < 5e-9); // f32 (1-B2) rounding
    }
}
