"""Layer-1 Pallas kernels for the FastMoE reproduction.

Every kernel here is the compute hot-spot of one stage of an MoE layer:

* :mod:`gate`       — gate score GEMM (``x @ Wg + bg``), row-block tiled.
* :mod:`scatter`    — row scatter (tokens -> expert-contiguous slots) and
                      the weighted gather/combine that reverses it.
* :mod:`expert_ffn` — the grouped per-expert FFN (the ``FMoELinear``
                      analog): grid over (expert, row-block, hidden-block)
                      with f32 accumulation.

All kernels lower with ``interpret=True`` so the emitted HLO runs on the
CPU PJRT client; block shapes are nevertheless chosen for the TPU
MXU/VMEM mapping documented in DESIGN.md §7.  Numerical correctness is
pinned to the pure-jnp oracles in :mod:`ref` by ``python/tests``.
"""

from .gate import gate_scores
from .scatter import combine_rows, scatter_rows
from .expert_ffn import expert_ffn

__all__ = ["gate_scores", "scatter_rows", "combine_rows", "expert_ffn"]
