"""Shared fixtures: seeded numpy generators and common shape strategies."""

import os
import sys

# allow `pytest python/tests/` from the repo root (the `compile`
# package lives in python/)
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0xFA57)
