//! Failure injection: every operational failure mode must surface as a
//! typed error (or a contained worker failure), never a hang or UB.

use std::sync::Arc;

use fastmoe::comm::tcp::TcpGroup;
use fastmoe::comm::{run_workers, Comm, TopoComm, Topology};
use fastmoe::config::{CommConfig, MoeConfig, ServeConfig};
use fastmoe::error::Error;
use fastmoe::moe::bucket_for;
use fastmoe::rng::Rng;
use fastmoe::runtime::{Manifest, Runtime};
use fastmoe::serve::{run_thread_daemon, ClientConn, Reply};

#[test]
fn worker_panic_is_contained_and_attributed() {
    let res = run_workers(4, |h| {
        if h.rank() == 2 {
            panic!("injected crash");
        }
        Ok(h.rank())
    });
    match res {
        Err(Error::Worker { rank: 2, msg }) => assert!(msg.contains("panicked")),
        other => panic!("expected contained worker failure, got {other:?}"),
    }
}

#[test]
fn corrupt_artifact_file_is_typed_error() {
    let Ok(rt) = Runtime::open_default() else { return };
    // stage a corrupt copy of the artifact dir with a poisoned file
    let tmp = std::env::temp_dir().join(format!("fastmoe_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let manifest_src = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json"),
    )
    .unwrap();
    std::fs::write(tmp.join("manifest.json"), &manifest_src).unwrap();
    let name = rt.manifest.artifacts[0].name.clone();
    let file = rt.manifest.artifacts[0].file.clone();
    std::fs::write(tmp.join(&file), "HloModule garbage !!!!").unwrap();
    let rt2 = Runtime::open(&tmp).unwrap();
    match rt2.executable(&name) {
        Err(Error::Xla(_)) => {}
        Err(Error::Io(_)) => {}
        other => panic!("expected xla/io error, got {:?}", other.map(|_| "ok")),
    }
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn missing_artifact_file_is_io_error() {
    let Ok(rt) = Runtime::open_default() else { return };
    let tmp = std::env::temp_dir().join(format!("fastmoe_missing_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let manifest_src = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json"),
    )
    .unwrap();
    std::fs::write(tmp.join("manifest.json"), &manifest_src).unwrap();
    let name = rt.manifest.artifacts[0].name.clone();
    let rt2 = Runtime::open(&tmp).unwrap();
    assert!(rt2.executable(&name).is_err());
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn malformed_manifest_is_rejected() {
    assert!(Manifest::parse("{ not json").is_err());
    assert!(Manifest::parse(r#"{"artifacts": 5}"#).is_err());
    // well-formed JSON but bad schema
    assert!(Manifest::parse(r#"{"artifacts": [{"name": 1}]}"#).is_err());
}

#[test]
fn bucket_overflow_is_actionable_error() {
    let err = bucket_for(5000, &[64, 128]).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("5000") && msg.contains("aot.py"), "{msg}");
}

#[test]
fn worker_death_mid_bucketed_sync_is_contained() {
    // A worker dying while its peers run the bucketed nonblocking
    // all-reduce must surface as a typed error on the survivors (the
    // thread backend's death-aware receives), contained by run_workers
    // as Error::Worker — never a deadlock in the ring.
    let res = run_workers(4, |mut h| {
        if h.rank() == 2 {
            return Err(Error::msg("injected death"));
        }
        let bufs: Vec<Vec<f32>> =
            (0..3).map(|b| vec![h.rank() as f32 + b as f32; 129]).collect();
        // survivors keep syncing until the dead ring edge surfaces
        for _ in 0..8 {
            let pending = h.all_reduce_start(bufs.clone())?;
            let _ = pending.finish(&mut h)?;
        }
        Ok(())
    });
    match res {
        Err(Error::Worker { .. }) => {}
        other => panic!("expected contained worker failure, got {other:?}"),
    }
}

#[test]
fn tcp_worker_death_mid_bucketed_sync_errors_survivors() {
    // Same failure over real sockets with the progress engine: the
    // dead peer's reader marks the connection closed, and survivors'
    // bucketed sync errors out instead of hanging.
    const WORKERS: usize = 3;
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, WORKERS, 47870).unwrap();
                if rank == 1 {
                    // connect (the mesh needs every rank), then die
                    return true;
                }
                g.enable_progress();
                let bufs: Vec<Vec<f32>> =
                    (0..2).map(|b| vec![rank as f32 + b as f32; 65]).collect();
                for _ in 0..4 {
                    let pending = match g.all_reduce_start(bufs.clone()) {
                        Ok(p) => p,
                        Err(_) => return true, // send into the closed socket
                    };
                    if pending.finish(&mut g).is_err() {
                        return true;
                    }
                }
                false
            })
        })
        .collect();
    for (rank, j) in joins.into_iter().enumerate() {
        assert!(
            j.join().unwrap(),
            "rank {rank}: survivor completed a sync through a dead peer"
        );
    }
}

/// Worker `victim` dies while the others drive the hierarchical
/// (2-node) bucketed all-reduce; every survivor must error (the
/// death-aware receives cascade through gather, ring and broadcast
/// edges), contained by `run_workers` as `Error::Worker`.
fn hier_death_is_contained(victim: usize) {
    let res = run_workers(4, move |h| {
        if h.rank() == victim {
            return Err(Error::msg("injected death"));
        }
        let mut c = TopoComm::new(h, Topology::new(4, 2).unwrap())?;
        let bufs: Vec<Vec<f32>> =
            (0..3).map(|b| vec![c.rank() as f32 + b as f32; 129]).collect();
        for _ in 0..8 {
            let pending = c.all_reduce_start(bufs.clone())?;
            let _ = pending.finish(&mut c)?;
        }
        Ok(())
    });
    match res {
        Err(Error::Worker { .. }) => {}
        other => panic!(
            "victim {victim}: expected contained worker failure, got {other:?}"
        ),
    }
}

#[test]
fn hier_leader_death_mid_tree_all_reduce_is_contained() {
    // rank 0 leads node 0: its member starves on the broadcast, the
    // other leader starves on the ring — both must error, not hang
    hier_death_is_contained(0);
}

#[test]
fn hier_member_death_mid_tree_all_reduce_is_contained() {
    // rank 1 is a plain member: its leader starves on the gather, and
    // the error cascades across the leader ring to the other node
    hier_death_is_contained(1);
}

#[test]
fn tcp_deferred_flush_death_is_detected() {
    // No progress engine: the deferred-flush receive path must surface
    // a dead peer as a typed error — via EOF when the OS delivers it,
    // via the keepalive probe when it doesn't — never a hang.
    const WORKERS: usize = 3;
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, WORKERS, 47970).unwrap();
                if rank == 1 {
                    // connect (the mesh needs every rank), then die
                    return true;
                }
                // survivors block on a message the dead peer never
                // sends; the deferred-flush liveness machinery must
                // error them out
                g.recv(1, 12345).is_err()
            })
        })
        .collect();
    for (rank, j) in joins.into_iter().enumerate() {
        assert!(
            j.join().unwrap(),
            "rank {rank}: survived a recv from a dead peer"
        );
    }
}

#[test]
fn serve_client_disconnect_is_contained() {
    // A client that vanishes mid-request must cost the daemon nothing
    // but an accounting entry: its session reader exits on the socket
    // error, its queued work's response write fails *contained* in
    // `ServeDaemon::respond`, and every other session keeps getting
    // served bitwise-normally until an orderly shutdown.
    let Ok(rt) = Runtime::open_default() else { return };
    let rt = Arc::new(rt);
    const WORKERS: usize = 2;
    let Some(gate) = rt.manifest.artifact(&format!("gate_fwd_w{WORKERS}")) else {
        return;
    };
    let dm = gate.inputs[0].shape[1];
    let cfg = ServeConfig { port: 48070, max_batch: 0, queue_depth: 1024, idle_ms: 20 };
    let daemon = {
        let rt = rt.clone();
        std::thread::spawn(move || {
            run_thread_daemon(
                rt,
                WORKERS,
                5,
                MoeConfig::default(),
                CommConfig::default(),
                cfg,
            )
        })
    };
    let addr = "127.0.0.1:48070";
    let mut data = vec![0f32; dm];
    Rng::new(11).fill_normal(&mut data, 1.0);

    // all three sessions prove themselves live first
    let mut victim = ClientConn::connect(addr).unwrap();
    let mut survivors = [
        ClientConn::connect(addr).unwrap(),
        ClientConn::connect(addr).unwrap(),
    ];
    for (i, s) in survivors.iter_mut().enumerate() {
        s.request(i as u32, 1, &data).unwrap();
        assert!(matches!(s.recv_reply().unwrap(), Reply::Ok { .. }));
    }
    victim.request(100, 1, &data).unwrap();
    assert!(matches!(victim.recv_reply().unwrap(), Reply::Ok { .. }));

    // mid-request disconnect: fire a request and slam the socket shut
    // without reading the reply
    victim.request(101, 1, &data).unwrap();
    drop(victim);

    // the remaining sessions must keep round-tripping afterwards
    for round in 0..3u32 {
        for (i, s) in survivors.iter_mut().enumerate() {
            let id = 10 + round * 2 + i as u32;
            s.request(id, 1, &data).unwrap();
            match s.recv_reply().unwrap() {
                Reply::Ok { id: got, data: y } => {
                    assert_eq!(got, id);
                    assert_eq!(y.len(), dm);
                    assert!(y.iter().all(|v| v.is_finite()));
                }
                Reply::Rejected { id } => panic!("request {id} rejected"),
            }
        }
    }
    let mut stop = ClientConn::connect(addr).unwrap();
    stop.shutdown().unwrap();
    let stats = daemon.join().unwrap().unwrap();
    // 3 warm-ups + 6 survivor rounds answered for sure; the victim's
    // in-flight request lands as either a served request (the response
    // write won the race with the close) or a counted disconnect
    assert!(stats.requests >= 9, "{stats:?}");
    assert_eq!(stats.requests + stats.disconnects, 10, "{stats:?}");
}

#[test]
fn oversized_collective_disagreement_detected() {
    // a peer that lies about its payload size must be caught by phase-2
    // validation of the Figure-2 protocol (not deadlock) — emulate by
    // sending ragged all_gather inputs
    let res = run_workers(2, |mut h| {
        let mine = vec![0.0f32; 4 + h.rank()]; // ragged!
        match h.all_gather(&mine) {
            Err(_) => Ok(true), // detected
            Ok(_) => Ok(false),
        }
    });
    match res {
        Ok(flags) => assert!(flags.iter().any(|&f| f)),
        Err(_) => {} // a contained worker error is also acceptable
    }
}
