//! Comm-backend conformance: one shared test matrix, run over both
//! backends — the thread-channel `CommHandle` and the socket
//! `TcpGroup` — so the two implementations of the [`Comm`] trait can
//! never drift apart on the behaviours the MoE layer leans on:
//!
//! * out-of-order tag matching (arrivals park, never drop)
//! * empty buffers (zero-length p2p and ragged all-to-all)
//! * large payloads through the framing layer
//! * subgroup all-reduce
//! * nonblocking request handles (`isend`/`irecv`/`wait_all`)
//! * the decomposed all-to-all (`all_to_all_v_start`, arrivals
//!   consumed in any order)
//! * the bucketed nonblocking all-reduce (`all_reduce_start`): a
//!   bucket-count × payload (empty / ragged / large / non-divisible)
//!   matrix asserting **bitwise** equality with the blocking ring,
//!   completed both in order (`finish`) and in reverse bucket order
//!   (`wait_bucket`)
//! * both barrier algorithms (dissemination + legacy empty a2a)
//!
//! The TCP backend additionally runs the whole matrix under its
//! *progress engine* (`[comm] progress`), plus engine-specific cases:
//! `wait_all` completing in true arrival order, and arrivals draining
//! into user space during a compute window with no blocking comm call.
//!
//! A second, *topology* axis ([`topology_suite`]) runs on every
//! backend under a hierarchical 2-node [`Topology`]: the sub-group
//! seam (`Comm::split` — world collectives running unchanged on the
//! intra/inter groups), the leader-aggregated `all_to_all_v`
//! (element-identical to flat), and the two-level tree
//! `all_reduce_sum` (exact sums on integer-valued data — where f32
//! addition is associative, bitwise equal to flat — plus hier-blocking
//! == hier-bucketed bitwise on order-sensitive data, completed both in
//! order and in reverse bucket order).

use std::time::Duration;

use fastmoe::comm::tcp::TcpGroup;
use fastmoe::comm::{run_workers, Comm, TopoComm, Topology};
use fastmoe::Result;

const WORKERS: usize = 4;

/// The matrix: every entry must hold on every backend.
fn conformance_suite<C: Comm>(h: &mut C) -> Result<()> {
    out_of_order_tags(h)?;
    empty_buffers(h)?;
    large_payloads(h)?;
    subgroup_all_reduce(h)?;
    request_handles(h)?;
    decomposed_a2a(h)?;
    bucketed_all_reduce(h)?;
    barrier_variants(h)?;
    Ok(())
}

fn out_of_order_tags<C: Comm>(h: &mut C) -> Result<()> {
    let n = h.size();
    let r = h.rank();
    let base = h.next_seq() << 8;
    let next = (r + 1) % n;
    let prev = (r + n - 1) % n;
    // send tag 2 before tag 1; receive tag 1 first — the tag-2 frame
    // must park, not vanish
    h.send(next, base | 2, vec![r as f32, 2.0])?;
    h.send(next, base | 1, vec![r as f32, 1.0])?;
    let one = h.recv(prev, base | 1)?;
    let two = h.recv(prev, base | 2)?;
    assert_eq!(one, vec![prev as f32, 1.0]);
    assert_eq!(two, vec![prev as f32, 2.0]);
    Ok(())
}

fn empty_buffers<C: Comm>(h: &mut C) -> Result<()> {
    let n = h.size();
    let r = h.rank();
    // zero-length point-to-point
    let base = h.next_seq() << 8;
    h.send((r + 1) % n, base | 1, Vec::new())?;
    assert!(h.recv((r + n - 1) % n, base | 1)?.is_empty());
    // all-to-all of nothing at all
    let out = h.all_to_all_v((0..n).map(|_| Vec::new()).collect())?;
    assert!(out.iter().all(|b| b.is_empty()));
    // ragged: empty buffers only toward even ranks
    let send: Vec<Vec<f32>> = (0..n)
        .map(|p| if p % 2 == 0 { Vec::new() } else { vec![r as f32] })
        .collect();
    let out = h.all_to_all_v(send)?;
    for (p, buf) in out.iter().enumerate() {
        if r % 2 == 0 {
            assert!(buf.is_empty(), "peer {p} sent to an even rank");
        } else {
            assert_eq!(buf, &vec![p as f32]);
        }
    }
    Ok(())
}

fn large_payloads<C: Comm>(h: &mut C) -> Result<()> {
    let n = h.size();
    let r = h.rank();
    let len = 60_000; // 240 KB per peer buffer
    let send: Vec<Vec<f32>> = (0..n).map(|p| vec![(r * n + p) as f32; len]).collect();
    let out = h.all_to_all_v(send)?;
    for (p, buf) in out.iter().enumerate() {
        assert_eq!(buf.len(), len);
        assert!(buf.iter().all(|&v| v == (p * n + r) as f32));
    }
    Ok(())
}

fn subgroup_all_reduce<C: Comm>(h: &mut C) -> Result<()> {
    let n = h.size();
    let r = h.rank();
    let group: Vec<usize> = (0..n).filter(|p| p % 2 == r % 2).collect();
    let mut buf = vec![(r + 1) as f32; 6];
    h.all_reduce_sum_group(&mut buf, &group)?;
    let want: f32 = group.iter().map(|&p| (p + 1) as f32).sum();
    assert!(buf.iter().all(|&x| x == want), "got {buf:?}, want {want}");
    Ok(())
}

fn request_handles<C: Comm>(h: &mut C) -> Result<()> {
    let n = h.size();
    let r = h.rank();
    let tag = (h.next_seq() << 8) | 3;
    let mut reqs = Vec::new();
    for p in 0..n {
        if p != r {
            reqs.push(h.isend(p, tag, vec![r as f32; p + 1])?);
        }
    }
    // explicit flush between posting and waiting must be harmless on
    // every backend (and is what lets compute hide the flight on TCP)
    h.flush()?;
    // receives posted in *reverse* peer order: results must still line
    // up slot-for-slot with the requests
    let mut want = Vec::new();
    for p in (0..n).rev() {
        if p != r {
            reqs.push(h.irecv(p, tag)?);
            want.push(vec![p as f32; r + 1]);
        }
    }
    let results = h.wait_all(reqs)?;
    let sends = n - 1;
    for res in &results[..sends] {
        assert!(res.is_none(), "send request produced data");
    }
    for (res, want) in results[sends..].iter().zip(&want) {
        assert_eq!(res.as_ref(), Some(want));
    }
    Ok(())
}

fn decomposed_a2a<C: Comm>(h: &mut C) -> Result<()> {
    let n = h.size();
    let r = h.rank();
    let send: Vec<Vec<f32>> =
        (0..n).map(|p| vec![(r * 10 + p) as f32; r + p]).collect();
    let mut pending = h.all_to_all_v_start(send)?;
    // consume arrivals in reverse peer order
    for p in (0..n).rev() {
        assert_eq!(pending.expected(p), p + r);
        let buf = pending.wait_peer(h, p)?;
        assert_eq!(buf, vec![(p * 10 + r) as f32; p + r]);
    }
    Ok(())
}

fn bucketed_all_reduce<C: Comm>(h: &mut C) -> Result<()> {
    let r = h.rank();
    // bucket-count × payload matrix: single bucket, an empty bucket,
    // ragged sizes (incl. lengths not divisible by the worker count),
    // many small buckets, one large payload through the framing layer
    let sets: &[&[usize]] = &[
        &[4],
        &[0],
        &[7, 0, 129],
        &[1, 3, 2, 5, 8],
        &[60_000],
    ];
    for (si, lens) in sets.iter().enumerate() {
        // values whose sum depends on addition order, so a bitwise
        // match really pins the ring's reduction order
        let bufs: Vec<Vec<f32>> = lens
            .iter()
            .enumerate()
            .map(|(b, &l)| {
                (0..l)
                    .map(|i| {
                        (r + 1) as f32 * 1.1
                            + b as f32 * 0.3
                            + (i % 17) as f32 * 0.013
                            + si as f32 * 0.07
                    })
                    .collect()
            })
            .collect();
        let mut want = bufs.clone();
        for w in want.iter_mut() {
            h.all_reduce_sum(w)?;
        }
        // completed all at once, rings progressing concurrently
        let pending = h.all_reduce_start(bufs.clone())?;
        let got = pending.finish(h)?;
        assert_eq!(got, want, "set {si}: finish != blocking ring");
        // completed bucket-by-bucket in reverse order
        let mut pending = h.all_reduce_start(bufs)?;
        for b in (0..lens.len()).rev() {
            assert_eq!(pending.wait_bucket(h, b)?, want[b], "set {si} bucket {b}");
        }
    }
    Ok(())
}

fn barrier_variants<C: Comm>(h: &mut C) -> Result<()> {
    h.barrier()?;
    h.barrier_a2a()?;
    h.barrier()?;
    Ok(())
}

/// The topology axis, run over a consumed backend handle (the policy
/// wrapper owns it): sub-group collectives, hier a2a vs flat, hier
/// all-reduce vs flat and vs its own bucketed decomposition.
fn topology_suite<C: Comm>(mut h: C) -> Result<()> {
    let w = h.size();
    let r = h.rank();
    let topo = Topology::new(w, 2)?; // 4 workers → two nodes of two

    // ---- the sub-group seam: world collectives, unchanged, on the
    // intra and inter groups ----
    {
        let mut g = h.split(&topo)?;
        {
            let mut intra = g.intra.bind(&mut h);
            assert_eq!(intra.size(), 2);
            assert_eq!(intra.rank(), topo.local_of(r));
            let me = intra.rank();
            let send: Vec<Vec<f32>> =
                (0..2).map(|p| vec![(r * 10 + p) as f32; me + p + 1]).collect();
            let recv = intra.all_to_all_v(send)?;
            for (p, buf) in recv.iter().enumerate() {
                let peer = topo.node_ranks(topo.node_of(r)).nth(p).unwrap();
                assert_eq!(buf, &vec![(peer * 10 + me) as f32; p + me + 1]);
            }
            intra.barrier()?;
        }
        if let Some(inter) = g.inter.as_mut() {
            let mut inter = inter.bind(&mut h);
            assert_eq!(inter.size(), topo.nodes());
            let mut buf = vec![(r + 1) as f32; 6];
            inter.all_reduce_sum(&mut buf)?;
            let want: f32 = (0..topo.nodes())
                .map(|t| (topo.leader_of(t) + 1) as f32)
                .sum();
            assert!(buf.iter().all(|&x| x == want), "{buf:?} != {want}");
        }
    }
    h.barrier()?;

    // ---- hierarchical all-to-all: element-identical to flat ----
    let mut c = TopoComm::new(h, topo)?;
    // ragged (incl. empty) payloads with an analytic expectation
    let send: Vec<Vec<f32>> = (0..w)
        .map(|p| vec![(r * w + p) as f32; (r + 2 * p) % 5])
        .collect();
    let recv = c.all_to_all_v(send)?;
    for (p, buf) in recv.iter().enumerate() {
        assert_eq!(buf, &vec![(p * w + r) as f32; (p + 2 * r) % 5], "peer {p}");
    }
    // all-empty exchange
    let recv = c.all_to_all_v((0..w).map(|_| Vec::new()).collect())?;
    assert!(recv.iter().all(|b| b.is_empty()));
    // large payloads through the leader route (framing layer)
    let len = 60_000;
    let send: Vec<Vec<f32>> = (0..w).map(|p| vec![(r * w + p) as f32; len]).collect();
    let recv = c.all_to_all_v(send)?;
    for (p, buf) in recv.iter().enumerate() {
        assert_eq!(buf.len(), len);
        assert!(buf.iter().all(|&v| v == (p * w + r) as f32));
    }
    // the decomposed entry point hands back a prefilled pending
    let send: Vec<Vec<f32>> = (0..w).map(|p| vec![r as f32; p + 1]).collect();
    let mut pending = c.all_to_all_v_start(send)?;
    for p in (0..w).rev() {
        assert_eq!(pending.expected(p), r + 1);
        assert_eq!(pending.wait_peer(&mut c, p)?, vec![p as f32; r + 1]);
    }

    // ---- two-level tree all-reduce ----
    // integer-valued data: f32 addition is associative here, so the
    // tree's (documented, different) reduction order must still land
    // on the flat ring's bits exactly
    let mut buf: Vec<f32> = (0..37).map(|i| (r * 100 + i) as f32).collect();
    c.all_reduce_sum(&mut buf)?;
    let want: Vec<f32> = (0..37)
        .map(|i| (0..w).map(|q| (q * 100 + i) as f32).sum())
        .collect();
    assert_eq!(buf, want, "hier all-reduce broke exact integer sums");
    // order-sensitive data: blocking == bucketed bitwise, in-order
    // finish and reverse wait_bucket alike, over the payload matrix
    let sets: &[&[usize]] = &[&[4], &[0], &[7, 0, 129], &[1, 3, 2, 5, 8], &[60_000]];
    for (si, lens) in sets.iter().enumerate() {
        let bufs: Vec<Vec<f32>> = lens
            .iter()
            .enumerate()
            .map(|(b, &l)| {
                (0..l)
                    .map(|i| {
                        (r + 1) as f32 * 1.1
                            + b as f32 * 0.3
                            + (i % 17) as f32 * 0.013
                            + si as f32 * 0.07
                    })
                    .collect()
            })
            .collect();
        let mut want = bufs.clone();
        for wbuf in want.iter_mut() {
            c.all_reduce_sum(wbuf)?;
        }
        // determinism: a second blocking pass lands on the same bits
        let mut again = bufs.clone();
        for wbuf in again.iter_mut() {
            c.all_reduce_sum(wbuf)?;
        }
        assert_eq!(again, want, "set {si}: hier reduction not deterministic");
        let got = c.all_reduce_start(bufs.clone())?.finish(&mut c)?;
        assert_eq!(got, want, "set {si}: hier finish != hier blocking");
        let mut pending = c.all_reduce_start(bufs)?;
        for b in (0..lens.len()).rev() {
            assert_eq!(pending.wait_bucket(&mut c, b)?, want[b], "set {si} bucket {b}");
        }
    }
    c.barrier()?;
    Ok(())
}

#[test]
fn conformance_over_thread_channels() {
    run_workers(WORKERS, |mut h| conformance_suite(&mut h)).unwrap();
}

#[test]
fn topology_conformance_over_thread_channels() {
    run_workers(WORKERS, topology_suite).unwrap();
}

#[test]
fn topology_conformance_over_tcp_mesh() {
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            std::thread::spawn(move || {
                let g = TcpGroup::connect_local(rank, WORKERS, 47930).unwrap();
                topology_suite(g).unwrap();
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
}

#[test]
fn topology_conformance_over_tcp_mesh_with_progress_engine() {
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, WORKERS, 47950).unwrap();
                g.enable_progress();
                topology_suite(g).unwrap();
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
}

#[test]
fn conformance_over_tcp_mesh() {
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, WORKERS, 47710).unwrap();
                conformance_suite(&mut g).unwrap();
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
}

#[test]
fn conformance_over_tcp_mesh_with_progress_engine() {
    // the entire matrix must hold unchanged when arrivals are drained
    // by the reader threads instead of the caller's blocking reads
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, WORKERS, 47750).unwrap();
                g.enable_progress();
                assert!(g.progress_enabled());
                conformance_suite(&mut g).unwrap();
                assert!(g.progress_arrivals() > 0, "engine drained nothing");
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
}

#[test]
fn progress_engine_drains_during_compute() {
    // Each rank isends to its ring successor, then "computes" (sleeps)
    // WITHOUT issuing any blocking comm call.  With the progress
    // engine the frame must cross wire → user space inside that
    // window; pending_arrivals() observing it is exactly the
    // "drain during compute" property the overlap path needs.
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, WORKERS, 47770).unwrap();
                g.enable_progress();
                let next = (rank + 1) % WORKERS;
                let prev = (rank + WORKERS - 1) % WORKERS;
                let tag = (g.next_seq() << 8) | 1;
                g.isend(next, tag, vec![rank as f32; 1024]).unwrap();
                // compute window: no recv/wait/barrier on this thread
                let mut waited = Duration::ZERO;
                while g.pending_arrivals() == 0 && waited < Duration::from_secs(10) {
                    std::thread::sleep(Duration::from_millis(5));
                    waited += Duration::from_millis(5);
                }
                assert!(
                    g.pending_arrivals() > 0,
                    "rank {rank}: nothing drained during the compute window"
                );
                let data = g.recv(prev, tag).unwrap();
                assert_eq!(data, vec![prev as f32; 1024]);
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
}

#[test]
fn progress_wait_all_completes_in_arrival_order() {
    // Rank 0 receives from every peer, posting the requests with the
    // SLOW peer (1) first, while that peer withholds its send.  The
    // discriminating observation: the fast peers' frames must be
    // drained into user space (pending_arrivals) *before* peer 1 has
    // sent anything — a posted-order implementation blocked reading
    // peer 1's socket could never surface them.  wait_all must then
    // map every result to the right request and leave nothing parked.
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, WORKERS, 47790).unwrap();
                g.enable_progress();
                let seq = g.next_seq();
                let tag = (seq << 8) | 3;
                let go_tag = (seq << 8) | 4;
                let done_tag = (seq << 8) | 5;
                if rank == 0 {
                    let mut reqs = Vec::new();
                    let mut want = Vec::new();
                    for p in 1..WORKERS {
                        reqs.push(g.irecv(p, tag).unwrap());
                        want.push(vec![p as f32; p]);
                    }
                    // the fast peers' frames land while the slow peer
                    // (first posted!) hasn't sent — arrival precedes
                    // posted order observably.  No peer may touch the
                    // barrier yet (they wait on done_tag), so ONLY the
                    // fast data frames can be in the inbox here.
                    let mut waited = Duration::ZERO;
                    while g.pending_arrivals() < WORKERS - 2
                        && waited < Duration::from_secs(10)
                    {
                        std::thread::sleep(Duration::from_millis(5));
                        waited += Duration::from_millis(5);
                    }
                    assert!(
                        g.pending_arrivals() >= WORKERS - 2,
                        "fast peers' frames not drained while slow peer pending"
                    );
                    // only now release the slow peer
                    g.isend(1, go_tag, vec![1.0]).unwrap();
                    let got = g.wait_all(reqs).unwrap();
                    for (res, want) in got.iter().zip(&want) {
                        assert_eq!(res.as_ref(), Some(want));
                    }
                    assert_eq!(g.pending_arrivals(), 0, "stray frames left behind");
                    for p in 1..WORKERS {
                        g.isend(p, done_tag, vec![0.0]).unwrap();
                    }
                } else {
                    if rank == 1 {
                        // withhold until rank 0 has observed the others
                        let _ = g.recv(0, go_tag).unwrap();
                    }
                    g.isend(0, tag, vec![rank as f32; rank]).unwrap();
                    // keep barrier traffic out of rank 0's inbox until
                    // its assertions are done
                    let _ = g.recv(0, done_tag).unwrap();
                }
                g.barrier().unwrap();
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
}
