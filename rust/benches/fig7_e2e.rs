//! Figure 7 (throughput half): end-to-end train-step time of the MoE
//! GPT vs the equal-FLOPs dense GPT.
//!
//! ```bash
//! cargo bench --bench fig7_e2e
//! ```
//!
//! Expected shape (paper §5.4): the MoE model trains slower per step —
//! the paper reports ≈3× at 96 experts/12 layers; at this preset's
//! scale expect 1.5–3× — while carrying ~an order of magnitude more
//! parameters.  The loss-curve half of Figure 7 is produced by
//! `cargo run --release --example train_gpt`.

use fastmoe::bench::{bench, BenchOpts, Table};
use fastmoe::coordinator::Trainer;
use fastmoe::data::{BatchIter, Corpus};
use fastmoe::metrics::CsvWriter;
use fastmoe::runtime::Runtime;
use fastmoe::util::gflops;

fn main() -> fastmoe::Result<()> {
    let rt = Runtime::open_default()?;
    let opts = BenchOpts::from_env();
    println!("Figure 7 — train-step time, MoE vs dense at equal FLOPs\n");

    let mut table = Table::new(&[
        "model", "params", "step_ms", "tokens/s", "GFLOP/s", "rel_step",
    ]);
    let mut csv = CsvWriter::create(
        "runs/fig7_e2e.csv",
        &["model", "params", "step_ms", "tokens_per_s"],
    )?;
    let mut dense_ms = 0.0f64;
    let mut rows = Vec::new();

    for model in ["gpt_dense", "gpt_moe"] {
        let mut tr = Trainer::new(&rt, model, 3)?;
        let vocab = tr.entry.config_usize("vocab").unwrap();
        let seq = tr.entry.config_usize("seq").unwrap();
        let batch = tr.entry.config_usize("batch").unwrap();
        let corpus = Corpus::synthetic(vocab, 200_000, 8);
        let mut it = BatchIter::new(&corpus, batch, seq, 4);
        let batches: Vec<_> = (0..opts.iters + opts.warmup).map(|_| it.next_batch()).collect();
        let mut i = 0;
        let r = bench(model, &opts, || {
            let _ = tr.train_step(&batches[i % batches.len()]).unwrap();
            i += 1;
        });
        let step_s = r.mean_secs();
        let tokens = (batch * seq) as f64;
        rows.push((
            model.to_string(),
            tr.params.n_elements(),
            step_s,
            tokens / step_s,
            gflops(tr.step_flops(), step_s),
        ));
        if model == "gpt_dense" {
            dense_ms = step_s;
        }
    }

    for (model, params, step_s, tps, gf) in &rows {
        table.row(vec![
            model.clone(),
            params.to_string(),
            format!("{:.1}", step_s * 1e3),
            format!("{tps:.0}"),
            format!("{gf:.2}"),
            format!("{:.2}x", step_s / dense_ms),
        ]);
        csv.row(&[
            model.clone(),
            params.to_string(),
            format!("{:.2}", step_s * 1e3),
            format!("{tps:.0}"),
        ])?;
    }
    println!("{}", table.render());
    println!(
        "MoE carries {:.1}x the parameters at a {:.2}x step-time cost \
         (paper: ~3x slower at 96 experts, repaid in loss — see \
         `cargo run --release --example train_gpt`).",
        rows[1].1 as f64 / rows[0].1 as f64,
        rows[1].2 / rows[0].2
    );
    Ok(())
}
