//! `fastmoe` — the launcher binary.
//!
//! ```text
//! fastmoe info                         # artifact + model inventory
//! fastmoe train [--model gpt_moe] [--steps N] [--config cfg.toml] …
//! fastmoe dist-train [--workers W] …   # DP-emulated multi-worker run
//! fastmoe dist-moe [--workers W] [--gate topk|switch|noisy_topk]
//!                  [--overlap --chunks N] …
//!                                      # expert-parallel layer demo
//! fastmoe fmoefy --experts N           # Listing-1 config transform
//! fastmoe tune [--workers W] [--calib-steps N] …
//!                                      # calibrate α-β model, print the
//!                                      # recommended [comm] settings
//! fastmoe serve [--workers W] [--serve-port P] [--max-batch N]
//!               [--queue-depth N] [--idle-ms N] [--backend local|tcp]
//!                                      # resident inference daemon
//! fastmoe client [--addr host:port] [--requests N] [--rows R]
//!                [--concurrency C] [--shutdown]
//!                                      # load generator for `serve`
//! ```
//!
//! `dist-moe --backend tcp` and `serve --backend tcp` accept
//! `--hosts a:p,b:p,…` (one `host:port` per rank); repeated addresses
//! mark ranks sharing a node, from which the hierarchical topology is
//! discovered.  The launcher still spawns every worker process locally
//! — on a real cluster, run `_tcp-worker` / `_serve-worker` with the
//! same `--hosts` list and a distinct `--rank` on each machine.
//!
//! Benchmarks live under `cargo bench` (one binary per paper figure);
//! examples under `cargo run --example …`.

use std::sync::Arc;

use fastmoe::cli::{Args, Usage};
use fastmoe::comm::{self, Comm, TopoComm};
use fastmoe::config::{
    fmoefy, AutoConfig, CommConfig, ConfigFile, FaultConfig, ModelConfig,
    MoeConfig, PlacementConfig, ServeConfig, TrainConfig,
};
use fastmoe::coordinator::{
    DistTrainer, MoeLayerBuilder, MoeLayerTrainer, ServeLoop, Trainer,
};
use fastmoe::data::{BatchIter, Corpus};
use fastmoe::error::Result;
use fastmoe::fault::{Recovery, RecoveryAction};
use fastmoe::metrics::{Counters, CsvWriter, Histogram, Stopwatch};
use fastmoe::serve::{run_thread_daemon, ClientConn, Reply, ServeDaemon};
use fastmoe::model::save_checkpoint;
use fastmoe::placement::Rebalancer;
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::tensor::TensorF32;
use fastmoe::util;

fn main() {
    let usage = Usage {
        name: "fastmoe",
        about: "FastMoE reproduction — Rust coordinator over AOT XLA artifacts",
        commands: vec![
            ("info", "print artifact and model inventory"),
            ("train", "single-worker fused training loop (Figure 7)"),
            ("dist-train", "multi-worker training with tag-aware grad sync (--grad-overlap --bucket-kb N --grad-shard none|zero --topology flat|hier --nodes N --ckpt-interval N --ckpt-dir D --resume D --auto --calib-steps N --retune-drift R --auto-apply report|live)"),
            ("dist-moe", "expert-parallel MoE layer demo (Figure 2; --gate topk|switch|noisy_topk, --overlap --chunks N [0=adaptive] --chunk-policy mean|max --no-pool --progress --grad-overlap --topology flat|hier --nodes N --local-size N --placement static|shadow|migrate --placement-threshold R --placement-window N --recover abort|degrade|rejoin --ckpt-interval N --ckpt-dir D --resume D --recv-timeout-ms N --chaos \"kill@N:rR,…\" --auto --calib-steps N --retune-drift R --auto-apply report|live)"),
            ("fmoefy", "Listing-1: dense config -> MoE config at equal FLOPs"),
            ("tune", "calibrate the α-β network model on a short instrumented run and print the recommended [comm] settings (--workers W --calib-steps N --gate …; all dist-moe knobs accepted)"),
            ("serve", "long-lived inference daemon: continuous batching over resident expert-parallel workers (--workers W --serve-port P --max-batch N --queue-depth N --idle-ms N --backend local|tcp --hosts a:p,b:p)"),
            ("client", "load generator for `serve` (--addr host:port --requests N --rows R --dm D --concurrency C --shutdown)"),
        ],
    };
    let args = match Args::from_env(&[
        "verbose", "moe", "dense", "overlap", "no-overlap", "no-pool", "progress",
        "no-progress", "grad-overlap", "no-grad-overlap", "shutdown", "auto",
        "no-auto",
    ]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage.render());
            std::process::exit(2);
        }
    };
    let cmd = args.subcommand.clone().unwrap_or_else(|| "help".into());
    let code = match cmd.as_str() {
        "info" => run(info(&args)),
        "train" => run(train(&args)),
        "dist-train" => run(dist_train(&args)),
        "dist-moe" => run(dist_moe(&args)),
        "_tcp-worker" => run(tcp_worker(&args)),
        "serve" => run(serve(&args)),
        "_serve-worker" => run(serve_worker_proc(&args)),
        "client" => run(client(&args)),
        "fmoefy" => run(cmd_fmoefy(&args)),
        "tune" => run(tune(&args)),
        _ => {
            println!("{}", usage.render());
            0
        }
    };
    std::process::exit(code);
}

fn run(r: Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn info(_args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("platform: {}", rt.platform());
    println!("preset:   {}", rt.manifest.preset);
    println!("\nmodels:");
    for (name, m) in &rt.manifest.models {
        println!(
            "  {name:<12} params={:>12}  train={} eval={} grad={}",
            m.n_params(),
            m.train_step,
            m.eval_step,
            m.grad_step
        );
    }
    println!("\nartifacts ({}):", rt.manifest.artifacts.len());
    for a in &rt.manifest.artifacts {
        println!(
            "  {:<22} {:<10} in={} out={}",
            a.name,
            a.family(),
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}

fn train_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        ConfigFile::load(path)?.train()?
    } else {
        TrainConfig::default()
    };
    cfg.model = args.str_or("model", &cfg.model);
    cfg.steps = args.usize_or("steps", cfg.steps)?;
    cfg.lr = args.f64_or("lr", cfg.lr)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.log_every = args.usize_or("log-every", cfg.log_every)?;
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every)?;
    cfg.checkpoint_every = args.usize_or("checkpoint-every", cfg.checkpoint_every)?;
    cfg.out_dir = args.str_or("out", &cfg.out_dir);
    Ok(cfg)
}

fn train(args: &Args) -> Result<()> {
    let cfg = train_config(args)?;
    let rt = Runtime::open_default()?;
    let mut tr = Trainer::new(&rt, &cfg.model, cfg.seed)?;
    let vocab = tr.entry.config_usize("vocab").unwrap_or(256);
    let seq = tr.entry.config_usize("seq").unwrap_or(128);
    let batch = tr.entry.config_usize("batch").unwrap_or(4);
    println!(
        "training {} ({} params) for {} steps, batch {}x{}, lr {}",
        cfg.model,
        tr.params.n_elements(),
        cfg.steps,
        batch,
        seq,
        cfg.lr
    );
    let corpus = Corpus::synthetic(vocab, 2_000_000.min(200 * batch * seq * cfg.steps.max(1)), cfg.seed);
    let mut train_it = BatchIter::new(&corpus, batch, seq, cfg.seed ^ 1);
    let mut eval_it = BatchIter::new(&corpus, batch, seq, cfg.seed ^ 2);
    let csv_path = format!("{}/{}_loss.csv", cfg.out_dir, cfg.model);
    let mut csv = CsvWriter::create(&csv_path, &["step", "wall_s", "loss", "eval_loss"])?;
    let watch = Stopwatch::start();
    let mut eval_loss = f64::NAN;
    for _ in 0..cfg.steps {
        let stats = tr.train_step(&train_it.next_batch())?;
        if stats.step % cfg.eval_every as u64 == 0 {
            eval_loss = tr.eval(&eval_it.next_batch())? as f64;
        }
        if stats.step % cfg.log_every as u64 == 0 || stats.step == 1 {
            println!(
                "step {:>5}  loss {:.4}  eval {:.4}  {:>8}/step  ({:.1} GFLOP/s)",
                stats.step,
                stats.loss,
                eval_loss,
                util::fmt_duration(std::time::Duration::from_secs_f64(stats.secs)),
                util::gflops(tr.step_flops(), stats.secs),
            );
        }
        csv.rowf(&[stats.step as f64, watch.secs(), stats.loss as f64, eval_loss])?;
        if cfg.checkpoint_every > 0 && stats.step % cfg.checkpoint_every as u64 == 0 {
            let p = format!("{}/{}_step{}.fmoe", cfg.out_dir, cfg.model, stats.step);
            save_checkpoint(&p, &tr.params)?;
            println!("checkpoint: {p}");
        }
    }
    println!("done in {}; loss curve: {csv_path}", util::fmt_duration(watch.elapsed()));
    Ok(())
}

fn dist_train(args: &Args) -> Result<()> {
    let cfg = train_config(args)?;
    let workers = args.usize_or("workers", 2)?;
    let comm_cfg = CommConfig::from_args(args)?;
    let fault_cfg = FaultConfig::from_args(args)?;
    let auto_cfg = AutoConfig::from_args(args)?;
    let resume = args.get("resume").map(String::from);
    let rt = Arc::new(Runtime::open_default()?);
    println!(
        "dist-train: {} workers, model {}, {} steps, grad sync {}",
        workers,
        cfg.model,
        cfg.steps,
        if comm_cfg.grad_shard == "zero" {
            format!("zero-sharded ({} KiB buckets)", comm_cfg.bucket_kb)
        } else if comm_cfg.grad_overlap {
            format!("overlapped ({} KiB buckets)", comm_cfg.bucket_kb)
        } else {
            "blocking".into()
        }
    );
    let model = cfg.model.clone();
    let steps = cfg.steps;
    let lr = cfg.lr as f32;
    let seed = cfg.seed;
    let losses = comm::run_workers(workers, move |h| {
        // [comm] topology selects the collective routing (hier = tree
        // all-reduce under the bucketed sync); flat is a pass-through
        let mut h = TopoComm::new(h, comm_cfg.topology_for(workers)?)?;
        let mut tr =
            DistTrainer::with_comm(&rt, &model, seed, workers, h.rank(), lr, &comm_cfg)?
                .with_checkpointing(fault_cfg.ckpt_interval, &fault_cfg.ckpt_dir);
        if auto_cfg.enabled {
            tr = tr.with_autotune(auto_cfg.clone(), &comm_cfg)?;
        }
        if let Some(dir) = &resume {
            tr.load_checkpoint(dir, h.rank())?;
        }
        let vocab = tr.entry.config_usize("vocab").unwrap_or(256);
        let seq = tr.entry.config_usize("seq").unwrap_or(128);
        let batch = tr.entry.config_usize("batch").unwrap_or(4);
        let corpus = Corpus::synthetic(vocab, 500_000, seed);
        let mut it = BatchIter::shard(&corpus, batch, seq, seed, h.rank());
        let mut hist = Vec::new();
        for step in 0..steps {
            let loss = tr.train_step(&mut h, &it.next_batch())?;
            if h.rank() == 0 && (step % 10 == 0 || step + 1 == steps) {
                println!("step {:>5}  global loss {:.4}", step + 1, loss);
            }
            hist.push(loss);
        }
        Ok(hist)
    })?;
    let last = losses[0].last().copied().unwrap_or(f32::NAN);
    println!("final global loss: {last:.4}");
    Ok(())
}

/// Parse `--hosts a:p,b:p,…` into one `host:port` per rank (`None`
/// when the flag is absent — callers fall back to localhost ports).
fn hosts_arg(args: &Args) -> Option<Vec<String>> {
    args.get("hosts").map(|h| {
        h.split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    })
}

/// The mesh address list a TCP worker dials: the explicit `--hosts`
/// ranks, or `127.0.0.1:base_port+rank` for localhost runs.
fn mesh_hosts(args: &Args, workers: usize, port: u16) -> Vec<String> {
    hosts_arg(args).unwrap_or_else(|| {
        (0..workers)
            .map(|r| format!("127.0.0.1:{}", port + r as u16))
            .collect()
    })
}

/// `dist-moe --backend tcp`: spawn one OS *process* per worker (the
/// paper's multi-node topology on localhost); each child runs
/// `_tcp-worker` and joins a TCP full mesh.
fn dist_moe_tcp(args: &Args) -> Result<()> {
    let hosts = hosts_arg(args);
    let workers = match &hosts {
        Some(h) => h.len(),
        None => args.usize_or("workers", 2)?,
    };
    let iters = args.usize_or("iters", 2)?;
    let seed = args.u64_or("seed", 7)?;
    let port = args.usize_or("port", 47500)? as u16;
    let moe_cfg = MoeConfig::from_args(args)?;
    let comm_cfg = CommConfig::from_args(args)?;
    let place_cfg = PlacementConfig::from_args(args)?;
    let fault_cfg = FaultConfig::from_args(args)?;
    let auto_cfg = AutoConfig::from_args(args)?;
    let exe = std::env::current_exe()?;
    println!("dist-moe (tcp): spawning {workers} worker processes on ports {port}..");
    let mut children = Vec::new();
    for rank in 0..workers {
        let mut argv = vec![
            "_tcp-worker".to_string(),
            "--rank".into(), rank.to_string(),
            "--workers".into(), workers.to_string(),
            "--iters".into(), iters.to_string(),
            "--seed".into(), seed.to_string(),
            "--port".into(), port.to_string(),
            "--gate".into(), moe_cfg.gate.clone(),
            "--capacity-factor".into(), moe_cfg.capacity_factor.to_string(),
            "--noise-std".into(), moe_cfg.noise_std.to_string(),
            "--balance-coef".into(), moe_cfg.balance_coef.to_string(),
            "--chunks".into(), comm_cfg.chunks.to_string(),
            "--chunk-policy".into(), comm_cfg.chunk_policy.clone(),
            "--bucket-kb".into(), comm_cfg.bucket_kb.to_string(),
            "--grad-shard".into(), comm_cfg.grad_shard.clone(),
            "--topology".into(), comm_cfg.topology.clone(),
            "--nodes".into(), comm_cfg.nodes.to_string(),
            "--local-size".into(), comm_cfg.local_size.to_string(),
            "--placement".into(), place_cfg.policy.clone(),
            "--placement-threshold".into(), place_cfg.threshold.to_string(),
            "--placement-window".into(), place_cfg.window.to_string(),
            "--lr".into(), args.f64_or("lr", 1e-3)?.to_string(),
            "--recover".into(), fault_cfg.recover.clone(),
            "--ckpt-interval".into(), fault_cfg.ckpt_interval.to_string(),
            "--ckpt-dir".into(), fault_cfg.ckpt_dir.clone(),
            "--recv-timeout-ms".into(), fault_cfg.recv_timeout_ms.to_string(),
            "--calib-steps".into(), auto_cfg.calib_steps.to_string(),
            "--retune-drift".into(), auto_cfg.retune_drift.to_string(),
            "--auto-apply".into(), auto_cfg.apply.clone(),
        ];
        if auto_cfg.enabled {
            argv.push("--auto".into());
        }
        if !fault_cfg.chaos.is_empty() {
            argv.push("--chaos".into());
            argv.push(fault_cfg.chaos.clone());
        }
        if let Some(dir) = args.get("resume") {
            argv.push("--resume".into());
            argv.push(dir.to_string());
        }
        if let Some(h) = &hosts {
            argv.push("--hosts".into());
            argv.push(h.join(","));
        }
        if comm_cfg.overlap {
            argv.push("--overlap".into());
        }
        if !comm_cfg.pool {
            argv.push("--no-pool".into());
        }
        if comm_cfg.progress {
            argv.push("--progress".into());
        }
        if comm_cfg.grad_overlap {
            argv.push("--grad-overlap".into());
        }
        children.push(std::process::Command::new(&exe).args(&argv).spawn()?);
    }
    let mut failed = false;
    for (rank, mut c) in children.into_iter().enumerate() {
        let status = c.wait()?;
        if !status.success() {
            eprintln!("worker process {rank} failed: {status}");
            failed = true;
        }
    }
    if failed {
        return Err(fastmoe::Error::msg("a tcp worker process failed"));
    }
    println!("dist-moe (tcp) OK — {workers} processes completed");
    Ok(())
}

/// Hidden per-process worker entry point for `dist-moe --backend tcp`.
fn tcp_worker(args: &Args) -> Result<()> {
    let rank = args.usize_or("rank", 0)?;
    let iters = args.usize_or("iters", 2)?;
    let seed = args.u64_or("seed", 7)?;
    let port = args.usize_or("port", 47500)? as u16;
    let comm_cfg = CommConfig::from_args(args)?;
    let fault_cfg = FaultConfig::from_args(args)?;
    let hosts = mesh_hosts(args, args.usize_or("workers", 2)?, port);
    let workers = hosts.len();
    let mut group = fastmoe::comm::tcp::TcpGroup::connect(rank, &hosts)?;
    if fault_cfg.recv_timeout_ms > 0 {
        // a peer silent past the deadline surfaces Error::Timeout
        group.set_recv_timeout(Some(std::time::Duration::from_millis(
            fault_cfg.recv_timeout_ms,
        )));
    }
    if comm_cfg.progress {
        // drain socket arrivals during expert compute (reader threads)
        group.enable_progress();
    }
    // same address twice in --hosts ⇒ same node: the hierarchical
    // topology is discovered rather than hand-specified
    let mut group = TopoComm::new(group, comm_cfg.topology_for_hosts(&hosts)?)?;
    let rt = Arc::new(Runtime::open_default()?);
    let layer = MoeLayerBuilder::from_config(&MoeConfig::from_args(args)?)
        .comm_config(&comm_cfg)
        .seed(seed)
        .build(rt, workers, rank)?;
    layer.warm()?;
    let mut counters = Counters::new();
    let place_cfg = PlacementConfig::from_args(args)?;
    let auto_cfg = AutoConfig::from_args(args)?;
    let fault_active = fault_cfg.recover != "abort"
        || !fault_cfg.chaos.is_empty()
        || fault_cfg.ckpt_interval > 0
        || args.get("resume").is_some();
    if place_cfg.policy != "static" || fault_active || auto_cfg.enabled {
        // dynamic placement moves optimiser state with the experts,
        // fault recovery needs checkpoints + degraded-mode gate syncs,
        // and the tuner observes full train steps — all three need the
        // trainer loop rather than the raw fwd/bwd demo
        let lr = args.f64_or("lr", 1e-3)? as f32;
        let n_expert = workers * layer.ne_local;
        let mut tr = MoeLayerTrainer::new(layer, lr)
            .with_placement(Rebalancer::from_config(&place_cfg, n_expert)?)
            .with_checkpointing(fault_cfg.ckpt_interval, &fault_cfg.ckpt_dir);
        if auto_cfg.enabled {
            tr = tr.with_autotune(auto_cfg, &comm_cfg)?;
        }
        if let Some(dir) = args.get("resume") {
            tr.load_checkpoint(dir)?;
        }
        let mut rec = Recovery::from_config(&fault_cfg)?;
        let mut rng = Rng::new(seed ^ rank as u64);
        let watch = Stopwatch::start();
        let mut flops = 0.0;
        for i in 0..iters {
            // chaos/suspicion fires at the *start* of step i, so the
            // step executes under the post-event membership
            match rec.poll(&mut group, i as u64)? {
                Some(RecoveryAction::Degrade(m)) => tr.degrade(&m)?,
                Some(RecoveryAction::Rejoin(_)) => {
                    tr.rejoin_restore(&mut group, Some(&fault_cfg.ckpt_dir))?
                }
                Some(RecoveryAction::Abort(r)) => {
                    return Err(fastmoe::Error::msg(format!(
                        "rank {r} declared dead at step {i} (recover = abort)"
                    )));
                }
                None => {}
            }
            let mut x = TensorF32::zeros(&[tr.layer.nb, tr.layer.dm]);
            rng.fill_normal(&mut x.data, 1.0);
            flops += tr.train_step(&mut group, x, &mut counters)?.flops;
        }
        group.barrier()?;
        println!(
            "  [pid {}] tcp worker {rank}/{workers}: {:.2}s, {:.2} GFLOP/s, \
             placement `{}`, shadows {}, imbalance {:.2}, recover `{}`{}",
            std::process::id(),
            watch.secs(),
            util::gflops(flops, watch.secs()),
            place_cfg.policy,
            tr.layer.placement().shadow_width(),
            tr.monitor.imbalance(),
            fault_cfg.recover,
            match tr.degraded() {
                Some(m) => format!(", degraded (dead {:?})", m.dead),
                None => String::new(),
            },
        );
        return Ok(());
    }
    let mut rng = Rng::new(seed ^ rank as u64);
    let watch = Stopwatch::start();
    let mut flops = 0.0;
    for _ in 0..iters {
        let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
        rng.fill_normal(&mut x.data, 1.0);
        let (y, state) = layer.forward(&mut group, x, &mut counters)?;
        let dy = TensorF32::full(&[layer.nb, layer.dm], 1.0 / layer.nb as f32);
        let _ = layer.backward(&mut group, &state, &dy, &mut counters)?;
        flops += 3.0 * layer.flops(&state);
        if !y.data.iter().all(|v| v.is_finite()) {
            return Err(fastmoe::Error::msg("non-finite output"));
        }
        layer.recycle(state);
    }
    group.barrier()?;
    let pool = layer.pool_stats();
    println!(
        "  [pid {}] tcp worker {rank}/{workers}: {:.2}s, {:.2} GFLOP/s, sent {}, \
         copied {}, pool {}/{} hit/miss{}",
        std::process::id(),
        watch.secs(),
        util::gflops(flops, watch.secs()),
        util::fmt_bytes(group.inner().counters.get("bytes_sent") as usize),
        util::fmt_bytes(counters.get("moe_copy_bytes") as usize),
        pool.hits,
        pool.misses,
        if group.inner().progress_enabled() {
            format!(", progress drained {}", group.inner().progress_arrivals())
        } else {
            String::new()
        },
    );
    Ok(())
}

fn dist_moe(args: &Args) -> Result<()> {
    if args.str_or("backend", "local") == "tcp" {
        return dist_moe_tcp(args);
    }
    let workers = args.usize_or("workers", 4)?;
    let iters = args.usize_or("iters", 4)?;
    let seed = args.u64_or("seed", 7)?;
    let lr = args.f64_or("lr", 1e-3)? as f32;
    let moe_cfg = MoeConfig::from_args(args)?;
    let comm_cfg = CommConfig::from_args(args)?;
    let place_cfg = PlacementConfig::from_args(args)?;
    let fault_cfg = FaultConfig::from_args(args)?;
    let auto_cfg = AutoConfig::from_args(args)?;
    let resume = args.get("resume").map(String::from);
    let rt = Arc::new(Runtime::open_default()?);
    println!(
        "dist-moe: {workers} workers, {iters} iterations, gate `{}`, overlap {}, \
         placement `{}`, recover `{}`",
        moe_cfg.gate,
        if comm_cfg.overlap {
            format!("on ({} chunks)", comm_cfg.chunks)
        } else {
            "off".into()
        },
        place_cfg.policy,
        fault_cfg.recover,
    );
    let stats = comm::run_workers(workers, move |mut h| {
        if fault_cfg.recv_timeout_ms > 0 {
            h.set_recv_timeout(Some(std::time::Duration::from_millis(
                fault_cfg.recv_timeout_ms,
            )));
        }
        let mut h = TopoComm::new(h, comm_cfg.topology_for(workers)?)?;
        let layer = MoeLayerBuilder::from_config(&moe_cfg)
            .comm_config(&comm_cfg)
            .seed(seed)
            .build_for(rt.clone(), &h)?;
        layer.warm()?;
        let n_expert = workers * layer.ne_local;
        let mut tr = MoeLayerTrainer::new(layer, lr)
            .with_placement(Rebalancer::from_config(&place_cfg, n_expert)?)
            .with_checkpointing(fault_cfg.ckpt_interval, &fault_cfg.ckpt_dir);
        if auto_cfg.enabled {
            tr = tr.with_autotune(auto_cfg.clone(), &comm_cfg)?;
        }
        if let Some(dir) = &resume {
            tr.load_checkpoint(dir)?;
        }
        let mut rec = Recovery::from_config(&fault_cfg)?;
        let mut counters = Counters::new();
        let mut rng = Rng::new(seed ^ h.rank() as u64);
        let mut flops = 0.0;
        let mut balance = 0.0;
        let watch = Stopwatch::start();
        for i in 0..iters {
            // chaos/suspicion fires at the *start* of step i, so the
            // step executes under the post-event membership
            match rec.poll(&mut h, i as u64)? {
                Some(RecoveryAction::Degrade(m)) => tr.degrade(&m)?,
                Some(RecoveryAction::Rejoin(_)) => {
                    tr.rejoin_restore(&mut h, Some(&fault_cfg.ckpt_dir))?
                }
                Some(RecoveryAction::Abort(r)) => {
                    return Err(fastmoe::Error::msg(format!(
                        "rank {r} declared dead at step {i} (recover = abort)"
                    )));
                }
                None => {}
            }
            let mut x = TensorF32::zeros(&[tr.layer.nb, tr.layer.dm]);
            rng.fill_normal(&mut x.data, 1.0);
            let s = tr.train_step(&mut h, x, &mut counters)?;
            flops += s.flops;
            balance += s.balance;
        }
        let secs = watch.secs();
        let imbalance = tr.monitor.imbalance();
        Ok((h.rank(), secs, flops, counters, balance / iters.max(1) as f64, imbalance))
    })?;
    for (rank, secs, flops, counters, balance, imbalance) in &stats {
        println!(
            "worker {rank}: {:.2}s  {:.2} GFLOP/s  a2a {}  copied {}  \
             pool {}/{} hit/miss  padding {:.1}%  balance_loss {:.3}  imbalance {:.2}",
            secs,
            util::gflops(*flops, *secs),
            util::fmt_bytes(counters.get("moe_a2a_bytes") as usize),
            util::fmt_bytes(counters.get("moe_copy_bytes") as usize),
            counters.get("pool_hits"),
            counters.get("pool_misses"),
            100.0
                * (1.0
                    - counters.get("moe_real_rows") as f64
                        / counters.get("moe_bucket_rows").max(1) as f64),
            balance,
            imbalance,
        );
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    if args.str_or("backend", "local") == "tcp" {
        return serve_tcp(args);
    }
    let workers = args.usize_or("workers", 2)?;
    let seed = args.u64_or("seed", 7)?;
    let moe_cfg = MoeConfig::from_args(args)?;
    let comm_cfg = CommConfig::from_args(args)?;
    let serve_cfg = ServeConfig::from_args(args)?;
    let rt = Arc::new(Runtime::open_default()?);
    println!(
        "serve (local): {workers} resident workers, clients on :{}, \
         max_batch {}, queue_depth {}, idle {}ms — send `fastmoe client \
         --shutdown` to stop",
        serve_cfg.port,
        if serve_cfg.max_batch == 0 {
            "layer-batch".into()
        } else {
            serve_cfg.max_batch.to_string()
        },
        serve_cfg.queue_depth,
        serve_cfg.idle_ms,
    );
    let stats = run_thread_daemon(rt, workers, seed, moe_cfg, comm_cfg, serve_cfg)?;
    println!("serve stats: {}", stats.to_json().to_string());
    Ok(())
}

/// `serve --backend tcp`: one OS process per resident worker, exactly
/// the `dist-moe --backend tcp` topology; rank 0's process carries the
/// client-facing front end.
fn serve_tcp(args: &Args) -> Result<()> {
    let hosts = hosts_arg(args);
    let workers = match &hosts {
        Some(h) => h.len(),
        None => args.usize_or("workers", 2)?,
    };
    let seed = args.u64_or("seed", 7)?;
    let port = args.usize_or("port", 47500)?;
    let moe_cfg = MoeConfig::from_args(args)?;
    let comm_cfg = CommConfig::from_args(args)?;
    let serve_cfg = ServeConfig::from_args(args)?;
    let fault_cfg = FaultConfig::from_args(args)?;
    let exe = std::env::current_exe()?;
    println!(
        "serve (tcp): spawning {workers} worker processes, mesh ports {port}.., \
         clients on :{}",
        serve_cfg.port
    );
    let mut children = Vec::new();
    for rank in 0..workers {
        let mut argv = vec![
            "_serve-worker".to_string(),
            "--rank".into(), rank.to_string(),
            "--workers".into(), workers.to_string(),
            "--seed".into(), seed.to_string(),
            "--port".into(), port.to_string(),
            "--serve-port".into(), serve_cfg.port.to_string(),
            "--max-batch".into(), serve_cfg.max_batch.to_string(),
            "--queue-depth".into(), serve_cfg.queue_depth.to_string(),
            "--idle-ms".into(), serve_cfg.idle_ms.to_string(),
            "--gate".into(), moe_cfg.gate.clone(),
            "--capacity-factor".into(), moe_cfg.capacity_factor.to_string(),
            "--noise-std".into(), moe_cfg.noise_std.to_string(),
            "--balance-coef".into(), moe_cfg.balance_coef.to_string(),
            "--chunks".into(), comm_cfg.chunks.to_string(),
            "--chunk-policy".into(), comm_cfg.chunk_policy.clone(),
            "--topology".into(), comm_cfg.topology.clone(),
            "--nodes".into(), comm_cfg.nodes.to_string(),
            "--local-size".into(), comm_cfg.local_size.to_string(),
            "--recv-timeout-ms".into(), fault_cfg.recv_timeout_ms.to_string(),
        ];
        if let Some(h) = &hosts {
            argv.push("--hosts".into());
            argv.push(h.join(","));
        }
        if comm_cfg.overlap {
            argv.push("--overlap".into());
        }
        if !comm_cfg.pool {
            argv.push("--no-pool".into());
        }
        if comm_cfg.progress {
            argv.push("--progress".into());
        }
        children.push(std::process::Command::new(&exe).args(&argv).spawn()?);
    }
    let mut failed = false;
    for (rank, mut c) in children.into_iter().enumerate() {
        let status = c.wait()?;
        if !status.success() {
            eprintln!("serve worker process {rank} failed: {status}");
            failed = true;
        }
    }
    if failed {
        return Err(fastmoe::Error::msg("a serve worker process failed"));
    }
    println!("serve (tcp) OK — {workers} processes exited cleanly");
    Ok(())
}

/// Hidden per-process worker entry point for `serve --backend tcp`.
/// Rank 0 runs the front end (listener + drive loop); ranks > 0 sit in
/// [`ServeLoop::serve_worker`] until the front end signals stop.
fn serve_worker_proc(args: &Args) -> Result<()> {
    let rank = args.usize_or("rank", 0)?;
    let seed = args.u64_or("seed", 7)?;
    let port = args.usize_or("port", 47500)? as u16;
    let comm_cfg = CommConfig::from_args(args)?;
    let serve_cfg = ServeConfig::from_args(args)?;
    let fault_cfg = FaultConfig::from_args(args)?;
    let hosts = mesh_hosts(args, args.usize_or("workers", 2)?, port);
    let workers = hosts.len();
    let mut group = fastmoe::comm::tcp::TcpGroup::connect(rank, &hosts)?;
    if fault_cfg.recv_timeout_ms > 0 {
        // a wedged peer surfaces as Error::Timeout; the front end then
        // REJECT-drains its queue instead of hanging every client
        group.set_recv_timeout(Some(std::time::Duration::from_millis(
            fault_cfg.recv_timeout_ms,
        )));
    }
    if comm_cfg.progress {
        group.enable_progress();
    }
    let mut group = TopoComm::new(group, comm_cfg.topology_for_hosts(&hosts)?)?;
    let rt = Arc::new(Runtime::open_default()?);
    let layer = MoeLayerBuilder::from_config(&MoeConfig::from_args(args)?)
        .comm_config(&comm_cfg)
        .seed(seed)
        .build(rt, workers, rank)?;
    layer.warm()?;
    let lp = ServeLoop::new(layer);
    let mut counters = Counters::new();
    if rank == 0 {
        let mut daemon = ServeDaemon::bind(&serve_cfg, lp.layer().nb, lp.layer().dm)?;
        println!(
            "  [pid {}] serve front end up: {workers}-rank mesh, clients on :{}",
            std::process::id(),
            daemon.port()
        );
        let stats = daemon.run(&lp, &mut group, &mut counters)?;
        println!("serve stats: {}", stats.to_json().to_string());
    } else {
        let steps = lp.serve_worker(&mut group, &mut counters)?;
        println!(
            "  [pid {}] serve worker {rank}/{workers}: {steps} steps",
            std::process::id()
        );
    }
    Ok(())
}

/// `fastmoe client` — a thin load generator for the daemon: N sessions
/// in parallel, each firing `--requests` of `--rows` tokens and
/// reporting the client-observed latency percentiles.  `--dm` must
/// match the served model's hidden size (a mismatch comes back as
/// rejections, not a hang).
fn client(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:47800");
    let requests = args.usize_or("requests", 16)?;
    let rows = args.usize_or("rows", 4)?;
    let dm = args.usize_or("dm", 64)?;
    let concurrency = args.usize_or("concurrency", 1)?.max(1);
    let seed = args.u64_or("seed", 7)?;
    println!(
        "client: {concurrency} session(s) x {requests} request(s) of \
         {rows}x{dm} tokens -> {addr}"
    );
    let sessions: Vec<_> = (0..concurrency)
        .map(|s| {
            let addr = addr.clone();
            std::thread::spawn(move || -> Result<(Histogram, u64)> {
                let mut conn = ClientConn::connect(&addr)?;
                let mut rng = Rng::new(seed ^ s as u64);
                let mut lat = Histogram::latency();
                let mut rejected = 0u64;
                for i in 0..requests {
                    let mut x = vec![0f32; rows * dm];
                    rng.fill_normal(&mut x, 1.0);
                    let t = Stopwatch::start();
                    conn.request(i as u32, rows, &x)?;
                    match conn.recv_reply()? {
                        Reply::Ok { .. } => lat.record(t.secs()),
                        Reply::Rejected { .. } => rejected += 1,
                    }
                }
                Ok((lat, rejected))
            })
        })
        .collect();
    let mut lat = Histogram::latency();
    let mut rejected = 0u64;
    for s in sessions {
        let (l, r) = s
            .join()
            .map_err(|_| fastmoe::Error::msg("client session panicked"))??;
        lat.merge(&l);
        rejected += r;
    }
    println!(
        "done: {} ok, {rejected} rejected; latency p50 {:.2}ms p95 {:.2}ms \
         p99 {:.2}ms",
        lat.count(),
        1e3 * lat.p50(),
        1e3 * lat.p95(),
        1e3 * lat.p99(),
    );
    if args.has_flag("shutdown") {
        let mut c = ClientConn::connect(&addr)?;
        c.shutdown()?;
        println!("shutdown frame sent");
    }
    Ok(())
}

/// `fastmoe tune` — the offline entry point to the `[auto]` subsystem:
/// run a short instrumented calibration on the thread backend, fit the
/// α-β network model, search the `[comm]` knob lattice with it, and
/// print the winner as a pasteable TOML snippet.  Accepts the same
/// `[moe]`/`[comm]` knobs as `dist-moe`, so the calibration runs under
/// the config you intend to tune *from*.
fn tune(args: &Args) -> Result<()> {
    let workers = args.usize_or("workers", 4)?.max(1);
    let seed = args.u64_or("seed", 7)?;
    let lr = args.f64_or("lr", 1e-3)? as f32;
    let moe_cfg = MoeConfig::from_args(args)?;
    let comm_cfg = CommConfig::from_args(args)?;
    let mut auto_cfg = AutoConfig::from_args(args)?;
    // `tune` IS the opt-in; report-only by definition (nothing runs on)
    auto_cfg.enabled = true;
    auto_cfg.apply = "report".into();
    // one warm-up observe opens the window, then calib_steps fill it
    let steps = args
        .usize_or("iters", auto_cfg.calib_steps + 1)?
        .max(auto_cfg.calib_steps + 1);
    let rt = match Runtime::open_default() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!(
                "tune: runtime unavailable ({e}); build the AOT artifacts \
                 first — nothing to calibrate"
            );
            return Ok(());
        }
    };
    println!(
        "tune: {workers} thread workers, {} calibration steps (fit α-β model, \
         search the [comm] lattice)",
        auto_cfg.calib_steps
    );
    let results = comm::run_workers(workers, move |h| {
        let mut h = TopoComm::new(h, comm_cfg.topology_for(workers)?)?;
        let layer = MoeLayerBuilder::from_config(&moe_cfg)
            .comm_config(&comm_cfg)
            .seed(seed)
            .build_for(rt.clone(), &h)?;
        layer.warm()?;
        let mut tr = MoeLayerTrainer::new(layer, lr)
            .with_autotune(auto_cfg.clone(), &comm_cfg)?;
        let mut counters = Counters::new();
        let mut rng = Rng::new(seed ^ h.rank() as u64);
        for _ in 0..steps {
            let mut x = TensorF32::zeros(&[tr.layer.nb, tr.layer.dm]);
            rng.fill_normal(&mut x.data, 1.0);
            tr.train_step(&mut h, x, &mut counters)?;
        }
        Ok(match tr.autotuner() {
            Some(t) => (t.fit, t.outcome),
            None => (None, None),
        })
    })?;
    // fit + outcome are rank-agreed (all-reduced); rank 0's copy is the
    // fleet's
    let (fit, outcome) = results[0];
    let Some(fit) = fit else {
        return Err(fastmoe::Error::msg("calibration produced no model fit"));
    };
    let Some(outcome) = outcome else {
        return Err(fastmoe::Error::msg("calibration produced no tuned config"));
    };
    println!(
        "fitted: link {:.2} GB/s, compute {:.3} ms, optimiser {:.3} ms, \
         measured step {:.3} ms",
        fit.beta / 1e9,
        fit.compute * 1e3,
        fit.opt * 1e3,
        fit.step_time * 1e3,
    );
    println!(
        "predicted best: {:.3} ms/step — paste into your config:\n\n{}",
        outcome.best.predicted * 1e3,
        outcome.best.toml_snippet()
    );
    Ok(())
}

fn cmd_fmoefy(args: &Args) -> Result<()> {
    let experts = args.usize_or("experts", 16)?;
    let top_k = args.usize_or("top-k", 2)?;
    let dense = ModelConfig { moe: false, ..Default::default() };
    let moe = fmoefy(&dense, experts, top_k)?;
    println!("dense: d_hidden={} params={}", dense.d_hidden, dense.n_params());
    println!(
        "moe:   n_expert={} top_k={} d_hidden_expert={} params={} ({}x)",
        moe.n_expert,
        moe.top_k,
        moe.d_hidden_expert(),
        moe.n_params(),
        moe.n_params() / dense.n_params().max(1)
    );
    Ok(())
}
