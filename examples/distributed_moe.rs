//! Expert-parallel MoE layer across workers — the Figure-2 machinery
//! live, with per-worker load and traffic statistics.
//!
//! ```bash
//! cargo run --release --example distributed_moe -- --workers 4 --iters 8
//! ```
//!
//! Each worker thread owns `ne_local` experts and a PJRT executable
//! set.  Every iteration: gate → top-k → count exchange → row exchange
//! → bucketed grouped-FFN → reverse exchange → weighted combine, then
//! the mirrored backward chain.  The load monitor prints per-expert
//! token counts — the paper's future-work load-balance feature.

use std::sync::Arc;

use fastmoe::bench::Table;
use fastmoe::cli::Args;
use fastmoe::comm::{run_workers, Comm};
use fastmoe::coordinator::DistMoeLayer;
use fastmoe::metrics::{Counters, Stopwatch};
use fastmoe::moe::LoadMonitor;
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::sim::{NetModel, NetPreset};
use fastmoe::tensor::TensorF32;
use fastmoe::util;

fn main() -> fastmoe::Result<()> {
    let args = Args::from_env(&[])?;
    let workers = args.usize_or("workers", 4)?;
    let iters = args.usize_or("iters", 8)?;
    let seed = args.u64_or("seed", 7)?;
    let net = NetModel::preset(
        NetPreset::parse(&args.str_or("net", "ib-edr")).unwrap_or(NetPreset::IbEdr),
    );
    let rt = Arc::new(Runtime::open_default()?);

    println!("distributed MoE layer: {workers} workers × local experts, {iters} iters");
    let results = run_workers(workers, {
        let rt = rt.clone();
        move |mut h| {
            let layer = DistMoeLayer::init(rt.clone(), workers, h.rank(), seed)?;
            layer.warm()?;
            let ne_global = workers * layer.ne_local;
            let mut monitor = LoadMonitor::new(ne_global);
            let mut counters = Counters::new();
            let mut rng = Rng::new(seed ^ (h.rank() as u64 + 1));
            let mut flops = 0.0f64;
            h.barrier();
            let watch = Stopwatch::start();
            for _ in 0..iters {
                let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
                rng.fill_normal(&mut x.data, 1.0);
                let (y, state) = layer.forward(&mut h, x, &mut counters)?;
                monitor.record(&state.counts_global);
                let dy = TensorF32::full(&[layer.nb, layer.dm], 1.0 / layer.nb as f32);
                let grads = layer.backward(&mut h, &state, &dy, &mut counters)?;
                flops += 3.0 * layer.flops(&state);
                debug_assert!(y.data.iter().all(|v| v.is_finite()));
                debug_assert!(grads.dx.data.iter().all(|v| v.is_finite()));
            }
            h.barrier();
            let secs = watch.secs();
            counters.merge(&h.counters);
            Ok((h.rank(), secs, flops, counters, monitor))
        }
    })?;

    let mut table = Table::new(&[
        "worker", "time_s", "GFLOP/s", "a2a_traffic", "sim_wire_ms", "pad_overhead",
    ]);
    let mut monitor_all = LoadMonitor::new(results[0].4.n_expert);
    for (rank, secs, flops, counters, monitor) in &results {
        let bytes = counters.get("moe_a2a_bytes") as usize;
        let wire = net.all_to_all(workers, bytes) * 1e3;
        let pad = 1.0
            - counters.get("moe_real_rows") as f64
                / counters.get("moe_bucket_rows").max(1) as f64;
        table.row(vec![
            rank.to_string(),
            format!("{secs:.2}"),
            format!("{:.2}", util::gflops(*flops, *secs)),
            util::fmt_bytes(bytes),
            format!("{wire:.2}"),
            format!("{:.1}%", pad * 100.0),
        ]);
        for _ in 0..1 {
            // merge totals for a global view
            let totals: Vec<u32> = monitor.totals().iter().map(|&x| x as u32).collect();
            monitor_all.record(&totals);
        }
    }
    println!("\n{}", table.render());

    println!("global expert load (tokens over all iterations):");
    let totals = monitor_all.totals();
    let max = *totals.iter().max().unwrap_or(&1) as f64;
    for (e, &c) in totals.iter().enumerate() {
        let bar = "#".repeat((40.0 * c as f64 / max) as usize);
        println!("  expert {e:>3} [worker {}] {c:>8} {bar}", e / (totals.len() / workers));
    }
    println!(
        "imbalance (max/mean): {:.2}   cv: {:.3}",
        monitor_all.imbalance(),
        monitor_all.cv()
    );
    Ok(())
}
