//! Crate-wide error type.

use thiserror::Error;

/// All failure modes of the Layer-3 system.
#[derive(Error, Debug)]
pub enum Error {
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json parse error at byte {pos}: {msg}")]
    Json { pos: usize, msg: String },

    #[error("config error: {0}")]
    Config(String),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("artifact `{0}` not found (run `make artifacts`?)")]
    ArtifactNotFound(String),

    #[error("ABI mismatch for `{artifact}`: {msg}")]
    Abi { artifact: String, msg: String },

    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("communication error: {0}")]
    Comm(String),

    #[error("worker {rank} failed: {msg}")]
    Worker { rank: usize, msg: String },

    #[error("recv timeout: no message from peer {peer} on tag {tag:#x} within {ms}ms")]
    Timeout { peer: usize, tag: u64, ms: u64 },

    #[error("checkpoint error: {0}")]
    Checkpoint(String),

    #[error("cli error: {0}")]
    Cli(String),

    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
