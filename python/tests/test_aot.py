"""AOT pipeline integrity: manifest completeness, ABI descriptions,
round-trippable HLO text."""

import json
import os

import pytest

from compile import aot, gpt

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART_DIR, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def test_presets_well_formed():
    for name, p in aot.PRESETS.items():
        assert p.name == name
        assert p.top_k <= min(p.expert_counts) or min(p.expert_counts) == 1
        assert all(b > 0 for b in p.buckets)
        assert list(p.buckets) == sorted(p.buckets)
        # bucket list must cover the worst case: every token of the batch
        # routed to ONE local expert from every worker
        assert max(p.buckets) >= p.nb * p.top_k // p.ne_local


def test_artifact_registry_names_unique():
    arts = aot.build_artifacts(aot.PRESETS["tiny"])
    names = [a.name for a in arts]
    assert len(set(names)) == len(names)
    for a in arts:
        assert a.meta.get("family"), a.name


@needs_artifacts
def test_manifest_covers_every_family():
    with open(MANIFEST) as f:
        m = json.load(f)
    fams = {a["meta"]["family"] for a in m["artifacts"]}
    assert {"fig5", "fig3", "stage", "fig7", "quickstart"} <= fams
    # every artifact file exists and is non-trivial HLO text
    for a in m["artifacts"]:
        p = os.path.join(ART_DIR, a["file"])
        assert os.path.exists(p), a["name"]
        with open(p) as f:
            head = f.read(200)
        assert "HloModule" in head, a["name"]


@needs_artifacts
def test_manifest_abi_matches_param_registry():
    with open(MANIFEST) as f:
        m = json.load(f)
    for model_name, model in m["models"].items():
        cfg = model["config"]
        gcfg = gpt.GptConfig(
            vocab=cfg["vocab"], seq=cfg["seq"], n_layer=cfg["n_layer"],
            d_model=cfg["d_model"], n_head=cfg["n_head"],
            d_hidden=cfg["d_hidden"], moe=cfg["moe"],
            n_expert=cfg["n_expert"], top_k=cfg["top_k"],
        )
        specs = gpt.param_specs(gcfg)
        assert [p["name"] for p in model["params"]] == [s.name for s in specs]
        assert [tuple(p["shape"]) for p in model["params"]] == [
            s.shape for s in specs
        ]
        # the train step ABI: tokens, targets, step, params, m, v
        art = {a["name"]: a for a in m["artifacts"]}[model["train_step"]]
        n = len(specs)
        assert len(art["inputs"]) == 3 + 3 * n
        assert len(art["outputs"]) == 1 + 3 * n
        assert art["inputs"][0]["dtype"] == "i32"
        # param slots match registry shapes positionally
        for i, s in enumerate(specs):
            assert tuple(art["inputs"][3 + i]["shape"]) == s.shape


@needs_artifacts
def test_every_init_spec_is_parseable():
    with open(MANIFEST) as f:
        m = json.load(f)
    for model in m["models"].values():
        for p in model["params"]:
            init = p["init"]
            assert init in ("zeros", "ones") or init.startswith("normal:")
            if init.startswith("normal:"):
                float(init.split(":")[1])
            assert p["tag"] in ("world", "data_parallel", "none")
