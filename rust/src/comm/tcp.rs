//! TCP process group — the cross-process / cross-node backend.
//!
//! FastMoE runs "across multiple GPUs on multiple nodes"; this backend
//! gives the reproduction the same property: workers are separate OS
//! processes (or separate machines) connected by a full TCP mesh, and
//! every collective of the [`Comm`](super::Comm) trait runs unchanged
//! on top of framed socket messages.
//!
//! Wire format per message (little-endian):
//!
//! ```text
//! src u32 | tag u64 | len u64 | payload f32 × len
//! ```
//!
//! Mesh establishment: rank r listens on `base_port + r`; every rank
//! connects to all lower ranks and accepts from all higher ranks, then
//! identifies itself with its rank. A connect loop with retries makes
//! start-up order irrelevant.
//!
//! Nonblocking transport, two modes:
//!
//! * **Deferred flush** (default): `isend` writes the frame into the
//!   per-peer user-space buffer *without* flushing; the next blocking
//!   operation (`recv`, `wait`, `wait_all`, `barrier`) — or an explicit
//!   `Comm::flush` before a long compute — flushes every dirty writer
//!   in one batch, so a pipelined caller pays one syscall burst per
//!   chunk instead of one flush per message.
//! * **Progress engine** ([`TcpGroup::enable_progress`], the
//!   `[comm] progress` knob): one reader thread per peer drains socket
//!   arrivals into a shared inbox *while the expert shard computes*,
//!   and `isend` flushes eagerly so frames genuinely depart before the
//!   next blocking op.  `wait_all` then completes requests in **true
//!   arrival order** across peers (the default mode can only consume
//!   out-of-order within what the kernel already buffered), and a
//!   message whose receive hasn't even been posted yet still moves
//!   wire → user space concurrently with compute.
//!
//! Either way the backend copies each `isend` payload into the socket
//! writer and is then done with the caller's `Vec` — those buffers are
//! handed back through [`Comm::reclaim_spent`] so the MoE layer's
//! buffer pool can reuse them next step instead of reallocating.
//!
//! The *receive* path is pooled symmetrically: every frame reader
//! (the caller's blocking reads and the progress-engine threads alike)
//! draws its payload buffer from an inbox-side freelist fed by
//! [`Comm::recycle`], so a caller that hands consumed buffers back
//! makes steady-state frame reads allocation-free
//! ([`TcpGroup::recv_buffer_allocs`] pins it).
//!
//! Liveness: deferred-flush blocking reads tick every
//! `KEEPALIVE_POLL`; an *idle* tick (no bytes at a frame boundary)
//! writes an empty probe frame to the waited-on peer, and a failed
//! probe write surfaces the peer's death as a typed error — without
//! relying on the OS delivering EOF promptly.  Probe frames carry a
//! reserved tag and are discarded transparently on every read path,
//! and `keepalive_probes` counts them.  The progress engine keeps its
//! EOF-based per-reader detection.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::{Comm, CommRequest, Msg};
use crate::error::{Error, Result};
use crate::metrics::Counters;

/// Spent-send buffers retained for [`Comm::reclaim_spent`]; beyond
/// these caps they are dropped, so a caller that never drains cannot
/// pin more than `SPENT_CAP_BYTES` of payload memory.  Only `isend`
/// (the pooled hot path) retires buffers — blocking `send` frees its
/// payload immediately, as before.
const SPENT_CAP: usize = 256;
const SPENT_CAP_BYTES: usize = 32 << 20;

/// Pooled receive buffers retained for the frame readers; beyond these
/// caps, [`Comm::recycle`] declines buffers (returning them to the
/// caller) so an over-generous donor cannot pin unbounded memory.
const FRAME_POOL_CAP: usize = 256;
const FRAME_POOL_CAP_BYTES: usize = 32 << 20;

/// Reserved tag of keepalive probe frames (empty payload).  Discarded
/// transparently on every read path; never collides with real traffic
/// (collective tags are `seq << 8 | code`, sub-group tags add a high
/// salt bit — none reach all-ones).
const KEEPALIVE_TAG: u64 = u64::MAX;

/// Socket read timeout of the deferred-flush receive path — the
/// keepalive grace period.  A blocked `recv` that sees no bytes for
/// this long writes a probe frame to the peer it waits on: writing
/// into a dead connection fails at the socket layer long before the
/// OS delivers a (possibly delayed) EOF, so peer death surfaces as a
/// typed error instead of an indefinite hang.  An alive-but-slow peer
/// simply discards the probes.  (The progress engine has dedicated
/// reader threads per peer and keeps its EOF-based detection.)
const KEEPALIVE_POLL: Duration = Duration::from_millis(500);

/// Consecutive idle ticks tolerated *inside* a frame before the read
/// gives up.  A peer that sent a partial frame and then vanished
/// without FIN/RST (host death, partition) would otherwise retry
/// forever — mid-frame there is no probe, so the bound is the liveness
/// backstop.  Set generously high (1200 × 500 ms = ten minutes of
/// *zero bytes mid-frame*) because a legitimate stall is possible —
/// e.g. the sender blocked writing to a third rank whose socket buffer
/// is full during a long compute window — and the bound must only fire
/// when the connection is truly gone, orders of magnitude past any
/// compute window this system schedules.
const STALL_TICKS_MAX: u32 = 1200;

/// Whether an I/O error is the read-timeout tick (both kinds appear
/// across platforms) rather than a real failure.
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Typed mid-frame stall: surfaced as `InvalidData` (never a timeout
/// kind, so callers error out instead of probing and retrying).
fn stall_err(what: &str, got: usize, want: usize) -> std::io::Error {
    std::io::Error::new(
        std::io::ErrorKind::InvalidData,
        format!("peer stalled mid-frame ({what}: {got}/{want} bytes)"),
    )
}

/// Inbox-side freelist the frame readers draw payload buffers from,
/// fed by [`Comm::recycle`].  Shared between the main thread and the
/// progress-engine readers, hence the interior locking.
///
/// The pool only ever *accepts* as many buffers as it has handed out
/// (`outstanding`): callers recycle every consumed receive buffer
/// indiscriminately, but some of those (self-loopback messages) are
/// really the caller's own send staging — keeping the balance at zero
/// returns exactly that surplus to the caller, so its arena never
/// drains into ours.
#[derive(Default)]
struct FramePool {
    list: Mutex<FrameList>,
    /// Frames whose payload had to touch the allocator.
    allocs: AtomicU64,
    /// Frames served entirely from recycled buffers.
    hits: AtomicU64,
    /// Buffers handed out minus recycles accepted.
    outstanding: AtomicI64,
}

#[derive(Default)]
struct FrameList {
    bufs: Vec<Vec<f32>>,
    /// Capacity bytes currently parked in `bufs`.
    bytes: usize,
}

impl FramePool {
    /// A buffer of exactly `len` floats with arbitrary contents (the
    /// frame read overwrites every element): best-fit from the
    /// freelist, falling back to (and counting) a fresh allocation.
    fn take(&self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        let mut l = self.list.lock().unwrap();
        let mut best: Option<(usize, usize)> = None;
        for (i, b) in l.bufs.iter().enumerate() {
            if b.capacity() >= len && best.map(|(_, c)| b.capacity() < c).unwrap_or(true)
            {
                best = Some((i, b.capacity()));
            }
        }
        let out = match best {
            Some((i, _)) => {
                let mut b = l.bufs.swap_remove(i);
                l.bytes -= b.capacity() * 4;
                drop(l);
                self.hits.fetch_add(1, Ordering::Relaxed);
                if b.len() > len {
                    b.truncate(len);
                } else {
                    b.resize(len, 0.0);
                }
                b
            }
            None => {
                drop(l);
                self.allocs.fetch_add(1, Ordering::Relaxed);
                vec![0f32; len]
            }
        };
        self.outstanding.fetch_add(1, Ordering::Relaxed);
        out
    }

    /// Park a buffer for reuse; `Some(buf)` hands it back when the
    /// pool is owed nothing, is at capacity, or the buffer is
    /// worthless.
    fn give(&self, buf: Vec<f32>) -> Option<Vec<f32>> {
        let cap = buf.capacity() * 4;
        if cap == 0 {
            return None;
        }
        if self.outstanding.fetch_sub(1, Ordering::Relaxed) <= 0 {
            self.outstanding.fetch_add(1, Ordering::Relaxed);
            return Some(buf);
        }
        let mut l = self.list.lock().unwrap();
        if l.bufs.len() < FRAME_POOL_CAP && l.bytes + cap <= FRAME_POOL_CAP_BYTES {
            l.bytes += cap;
            l.bufs.push(buf);
            None
        } else {
            Some(buf)
        }
    }
}

/// Shared state between a rank's main thread and its progress readers.
struct ProgressShared {
    inbox: Mutex<Inbox>,
    cv: Condvar,
}

struct Inbox {
    /// Messages drained off the sockets, in arrival order.
    msgs: Vec<Msg>,
    /// Per-peer: `Some(reason)` once the reader stopped — a clean
    /// disconnect or the underlying I/O / corruption error, preserved
    /// so callers don't misdiagnose a bad frame as a peer shutdown.
    closed: Vec<Option<String>>,
    /// Total messages ever drained by the engine.
    arrivals: u64,
}

/// A rank's endpoint into a TCP full-mesh group.
pub struct TcpGroup {
    rank: usize,
    size: usize,
    writers: Vec<Option<BufWriter<TcpStream>>>,
    readers: Vec<Option<BufReader<TcpStream>>>,
    parked: Vec<Msg>,
    /// `isend` frames buffered but not yet flushed to the kernel.
    flush_needed: bool,
    /// Send buffers already framed into the writers (reclaimable).
    spent: Vec<Vec<f32>>,
    /// Capacity bytes currently held in `spent`.
    spent_bytes: usize,
    /// Progress engine state; `Some` after [`TcpGroup::enable_progress`].
    progress: Option<Arc<ProgressShared>>,
    /// Pooled receive buffers shared with the frame readers.
    frames: Arc<FramePool>,
    /// Optional deadline for blocking receives (`[fault]
    /// recv_timeout_ms`): a peer silent past it surfaces
    /// [`Error::Timeout`] instead of hanging.  Checked at
    /// [`KEEPALIVE_POLL`] granularity on the deferred-flush path and
    /// exactly on the progress path.  `None` (default) waits forever.
    recv_timeout: Option<Duration>,
    seq: u64,
    pub counters: Counters,
}

impl TcpGroup {
    /// Join a localhost mesh: rank `rank` of `size`, ports
    /// `base_port..base_port+size`.
    pub fn connect_local(rank: usize, size: usize, base_port: u16) -> Result<TcpGroup> {
        let hosts: Vec<String> = (0..size)
            .map(|r| format!("127.0.0.1:{}", base_port + r as u16))
            .collect();
        Self::connect(rank, &hosts)
    }

    /// Join a mesh given every rank's `host:port` (index = rank).
    pub fn connect(rank: usize, hosts: &[String]) -> Result<TcpGroup> {
        let size = hosts.len();
        if rank >= size {
            return Err(Error::Comm(format!("rank {rank} of {size}")));
        }
        let listener = TcpListener::bind(&hosts[rank])
            .map_err(|e| Error::Comm(format!("bind {}: {e}", hosts[rank])))?;

        let mut writers: Vec<Option<BufWriter<TcpStream>>> =
            (0..size).map(|_| None).collect();
        let mut readers: Vec<Option<BufReader<TcpStream>>> =
            (0..size).map(|_| None).collect();

        // connect to all lower ranks (with retry while they boot)
        for peer in 0..rank {
            let stream = Self::connect_retry(&hosts[peer], Duration::from_secs(20))?;
            stream.set_nodelay(true).ok();
            let mut w = BufWriter::new(stream.try_clone().map_err(io_err)?);
            w.write_all(&(rank as u32).to_le_bytes()).map_err(io_err)?;
            w.flush().map_err(io_err)?;
            writers[peer] = Some(w);
            readers[peer] = Some(BufReader::new(stream));
        }
        // accept from all higher ranks
        for _ in rank + 1..size {
            let (stream, _) = listener.accept().map_err(io_err)?;
            stream.set_nodelay(true).ok();
            let mut r = BufReader::new(stream.try_clone().map_err(io_err)?);
            let mut b = [0u8; 4];
            r.read_exact(&mut b).map_err(io_err)?;
            let peer = u32::from_le_bytes(b) as usize;
            if peer <= rank || peer >= size {
                return Err(Error::Comm(format!("bad peer handshake {peer}")));
            }
            writers[peer] = Some(BufWriter::new(stream));
            readers[peer] = Some(r);
        }

        // Keepalive: reads tick at the probe interval from here on
        // (the handshake above ran on blocking sockets).  Frame reads
        // retry through mid-frame ticks; only an idle frame boundary
        // surfaces to the caller, which probes the peer (see
        // `read_msg_from`).
        for r in readers.iter().flatten() {
            r.get_ref().set_read_timeout(Some(KEEPALIVE_POLL)).ok();
        }

        Ok(TcpGroup {
            rank,
            size,
            writers,
            readers,
            parked: Vec::new(),
            flush_needed: false,
            spent: Vec::new(),
            spent_bytes: 0,
            progress: None,
            frames: Arc::new(FramePool::default()),
            recv_timeout: None,
            seq: 0,
            counters: Counters::new(),
        })
    }

    fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
        let start = Instant::now();
        loop {
            match TcpStream::connect(addr) {
                Ok(s) => return Ok(s),
                Err(e) => {
                    if start.elapsed() > timeout {
                        return Err(Error::Comm(format!("connect {addr}: {e}")));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
    }

    /// Start the progress engine: one reader thread per peer socket,
    /// draining arrivals into a shared inbox concurrently with the
    /// caller's compute.  Call right after connecting, before the
    /// first exchange (frames already buffered in this thread's
    /// readers would otherwise be stranded).  Idempotent.
    pub fn enable_progress(&mut self) {
        if self.progress.is_some() {
            return;
        }
        let shared = Arc::new(ProgressShared {
            inbox: Mutex::new(Inbox {
                msgs: Vec::new(),
                closed: vec![None; self.size],
                arrivals: 0,
            }),
            cv: Condvar::new(),
        });
        for (peer, slot) in self.readers.iter_mut().enumerate() {
            let Some(mut reader) = slot.take() else { continue };
            let sh = shared.clone();
            let frames = self.frames.clone();
            // detached on purpose: the thread exits when the peer's
            // socket closes; joining at drop could deadlock on a peer
            // that outlives us.
            std::thread::Builder::new()
                .name(format!("tcp-progress-{}-{peer}", self.rank))
                .spawn(move || loop {
                    match read_frame(&mut reader, &frames) {
                        Ok(msg) if msg.tag == KEEPALIVE_TAG => {} // discard
                        Ok(msg) => {
                            let mut inbox = sh.inbox.lock().unwrap();
                            inbox.msgs.push(msg);
                            inbox.arrivals += 1;
                            sh.cv.notify_all();
                        }
                        // the engine has a dedicated blocked reader per
                        // peer; idle ticks just spin it again (EOF is
                        // its death signal)
                        Err(e) if is_timeout(&e) => {}
                        Err(e) => {
                            // keep the real cause: an eof at a frame
                            // boundary is a normal shutdown, anything
                            // else (I/O error, corrupt frame) is not
                            let reason =
                                if e.kind() == std::io::ErrorKind::UnexpectedEof {
                                    "connection closed".to_string()
                                } else {
                                    e.to_string()
                                };
                            sh.inbox.lock().unwrap().closed[peer] = Some(reason);
                            sh.cv.notify_all();
                            return;
                        }
                    }
                })
                .expect("spawn tcp progress reader");
        }
        self.progress = Some(shared);
    }

    /// Whether the progress engine is running.
    pub fn progress_enabled(&self) -> bool {
        self.progress.is_some()
    }

    /// Messages the progress engine has drained into user space that
    /// no receive has claimed yet (the "drain during compute" signal).
    pub fn pending_arrivals(&self) -> usize {
        self.progress
            .as_ref()
            .map(|s| s.inbox.lock().unwrap().msgs.len())
            .unwrap_or(0)
    }

    /// Total messages ever drained by the progress engine.
    pub fn progress_arrivals(&self) -> u64 {
        self.progress
            .as_ref()
            .map(|s| s.inbox.lock().unwrap().arrivals)
            .unwrap_or(0)
    }

    /// Write one framed message into `dst`'s buffered writer (no flush).
    fn write_frame(&mut self, dst: usize, tag: u64, data: &[f32]) -> Result<()> {
        self.counters.add("bytes_sent", (data.len() * 4) as u64);
        let rank = self.rank;
        let w = self.writers[dst]
            .as_mut()
            .ok_or_else(|| Error::Comm(format!("no link to peer {dst}")))?;
        w.write_all(&(rank as u32).to_le_bytes()).map_err(io_err)?;
        w.write_all(&tag.to_le_bytes()).map_err(io_err)?;
        w.write_all(&(data.len() as u64).to_le_bytes()).map_err(io_err)?;
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        w.write_all(bytes).map_err(io_err)?;
        Ok(())
    }

    /// Push every buffered `isend` frame to the kernel.  Called before
    /// any blocking read so no peer waits on bytes still in userspace.
    fn flush_pending(&mut self) -> Result<()> {
        if !self.flush_needed {
            return Ok(());
        }
        self.flush_needed = false;
        for w in self.writers.iter_mut().flatten() {
            w.flush().map_err(io_err)?;
        }
        Ok(())
    }

    /// The frame was copied into the writer; keep the caller's buffer
    /// for [`Comm::reclaim_spent`] (dropped once either cap is hit).
    fn retire(&mut self, data: Vec<f32>) {
        let bytes = data.capacity() * 4;
        if self.spent.len() < SPENT_CAP && self.spent_bytes + bytes <= SPENT_CAP_BYTES {
            self.spent_bytes += bytes;
            self.spent.push(data);
        }
    }

    /// Arm or disarm the receive deadline.  With a deadline set, a
    /// blocking receive whose peer stays silent past it returns
    /// [`Error::Timeout`] instead of waiting forever — the hook the
    /// fault layer uses to turn a hung worker into a typed suspicion.
    pub fn set_recv_timeout(&mut self, timeout: Option<Duration>) {
        self.recv_timeout = timeout;
    }

    /// Blocking read of one framed message from a specific peer socket
    /// (deferred-flush mode only; progress mode reads via the engine).
    ///
    /// Liveness: an idle frame boundary (the keepalive tick) probes
    /// the peer with an empty [`KEEPALIVE_TAG`] frame — a dead
    /// connection fails the probe *write* without waiting for the OS
    /// to deliver EOF, surfacing as a typed error instead of a hang.
    /// With a receive deadline armed, an idle peer past it surfaces
    /// [`Error::Timeout`] for `want_tag` (checked at the keepalive
    /// tick, so resolution is [`KEEPALIVE_POLL`]).
    fn read_msg_from(&mut self, peer: usize, want_tag: u64) -> Result<Msg> {
        let frames = self.frames.clone();
        let deadline = self
            .recv_timeout
            .map(|d| (Instant::now() + d, d.as_millis() as u64));
        loop {
            let res = {
                let reader = self.readers[peer]
                    .as_mut()
                    .ok_or_else(|| Error::Comm(format!("no link to peer {peer}")))?;
                read_frame(reader, &frames)
            };
            match res {
                Ok(msg) => return Ok(msg),
                Err(e) if is_timeout(&e) => {
                    if let Some((at, ms)) = deadline {
                        if Instant::now() >= at {
                            return Err(Error::Timeout { peer, tag: want_tag, ms });
                        }
                    }
                    self.probe_peer(peer)?;
                }
                Err(e) => return Err(io_err(e)),
            }
        }
    }

    /// Write one keepalive probe frame to `peer` and push it to the
    /// kernel.  A failed write means the connection is dead even if
    /// its EOF has not been delivered yet.
    fn probe_peer(&mut self, peer: usize) -> Result<()> {
        self.counters.add("keepalive_probes", 1);
        let rank = self.rank;
        let w = self.writers[peer]
            .as_mut()
            .ok_or_else(|| Error::Comm(format!("no link to peer {peer}")))?;
        let probe = (|| -> std::io::Result<()> {
            w.write_all(&(rank as u32).to_le_bytes())?;
            w.write_all(&KEEPALIVE_TAG.to_le_bytes())?;
            w.write_all(&0u64.to_le_bytes())?;
            w.flush()
        })();
        probe.map_err(|e| {
            Error::Comm(format!("tcp: peer {peer} down (keepalive probe failed: {e})"))
        })
    }

    /// Receive-path allocations: frames whose payload buffer had to
    /// touch the allocator because the [`Comm::recycle`] freelist had
    /// nothing big enough.  Flat in steady state when callers recycle.
    pub fn recv_buffer_allocs(&self) -> u64 {
        self.frames.allocs.load(Ordering::Relaxed)
    }

    /// Frames served entirely from recycled receive buffers.
    pub fn recv_buffer_hits(&self) -> u64 {
        self.frames.hits.load(Ordering::Relaxed)
    }

    /// Progress-mode receive: wait on the shared inbox.  An armed
    /// receive deadline bounds the condvar wait exactly (no keepalive
    /// tick on this path — the engine's reader threads own the
    /// sockets).
    fn recv_progress(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        let shared = self.progress.as_ref().expect("progress mode").clone();
        let deadline = self
            .recv_timeout
            .map(|d| (Instant::now() + d, d.as_millis() as u64));
        let mut inbox = shared.inbox.lock().unwrap();
        loop {
            if let Some(i) = inbox
                .msgs
                .iter()
                .position(|m| m.src == src && m.tag == tag)
            {
                return Ok(inbox.msgs.swap_remove(i).data);
            }
            if let Some(reason) = &inbox.closed[src] {
                return Err(Error::Comm(format!(
                    "tcp: peer {src} down before tag {tag} arrived ({reason})"
                )));
            }
            inbox = match deadline {
                Some((at, ms)) => {
                    let now = Instant::now();
                    if now >= at {
                        return Err(Error::Timeout { peer: src, tag, ms });
                    }
                    shared.cv.wait_timeout(inbox, at - now).unwrap().0
                }
                None => shared.cv.wait(inbox).unwrap(),
            };
        }
    }
}

/// Parse one wire frame (see module docs for the format), staging the
/// payload in a buffer drawn from the recycle freelist.
///
/// Error taxonomy matters to the progress engine's diagnostics: EOF
/// *before any header byte* (a frame boundary) is the one clean
/// shutdown and surfaces as `UnexpectedEof`; EOF mid-header or
/// mid-payload is a truncated frame and surfaces as `InvalidData`, so
/// a peer crash mid-exchange is never reported as a normal disconnect.
fn read_frame(
    reader: &mut BufReader<TcpStream>,
    frames: &FramePool,
) -> std::io::Result<Msg> {
    let mut hdr = [0u8; 4 + 8 + 8];
    let mut filled = 0usize;
    let mut stalled = 0u32;
    while filled < hdr.len() {
        let n = match reader.read(&mut hdr[filled..]) {
            Ok(n) => n,
            // keepalive tick: an *idle boundary* surfaces (the caller
            // probes and retries); mid-frame the peer was mid-send
            // moments ago, so keep reading — up to the stall bound
            Err(e) if is_timeout(&e) && filled == 0 => return Err(e),
            Err(e) if is_timeout(&e) => {
                stalled += 1;
                if stalled > STALL_TICKS_MAX {
                    return Err(stall_err("header", filled, hdr.len()));
                }
                continue;
            }
            // read_exact semantics: EINTR is retried, never fatal
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        stalled = 0;
        if n == 0 {
            return Err(if filled == 0 {
                std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed at frame boundary",
                )
            } else {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("eof mid-header ({filled}/{} bytes)", hdr.len()),
                )
            });
        }
        filled += n;
    }
    let src = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    let tag = u64::from_le_bytes(hdr[4..12].try_into().unwrap());
    let len = u64::from_le_bytes(hdr[12..20].try_into().unwrap()) as usize;
    if len > (1 << 31) {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("implausible frame of {len} floats"),
        ));
    }
    let mut data = frames.take(len);
    // Safety: reading LE f32 payload into the vec's byte view.
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, len * 4)
    };
    // read_exact semantics, riding through keepalive ticks (the header
    // already arrived, so the peer was alive moments ago) up to the
    // stall bound, and retrying EINTR
    let mut got = 0usize;
    let mut stalled = 0u32;
    while got < bytes.len() {
        match reader.read(&mut bytes[got..]) {
            Ok(0) => {
                // rebalance the pool's hand-out/return accounting: this
                // buffer never reaches a caller who could recycle it
                let _ = frames.give(data);
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("eof mid-frame ({len}-float payload truncated)"),
                ));
            }
            Ok(n) => {
                got += n;
                stalled = 0;
            }
            Err(e) if is_timeout(&e) => {
                stalled += 1;
                if stalled > STALL_TICKS_MAX {
                    let _ = frames.give(data);
                    return Err(stall_err("payload", got, bytes.len()));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                let _ = frames.give(data);
                return Err(e);
            }
        }
    }
    Ok(Msg { src, tag, data })
}

fn io_err(e: std::io::Error) -> Error {
    Error::Comm(format!("tcp: {e}"))
}

/// Write one wire frame (module-docs format) to an arbitrary stream.
///
/// The serve front end's client protocol reuses the mesh framing on
/// plain `TcpStream`s outside any `TcpGroup`: `src` carries a
/// caller-chosen identifier (the mesh uses the sender rank; the serve
/// protocol uses the client's request id) and `tag` carries the
/// protocol code.  Flushes, so the frame genuinely departs.
pub(crate) fn write_stream_frame(
    w: &mut impl Write,
    src: u32,
    tag: u64,
    data: &[f32],
) -> std::io::Result<()> {
    w.write_all(&src.to_le_bytes())?;
    w.write_all(&tag.to_le_bytes())?;
    w.write_all(&(data.len() as u64).to_le_bytes())?;
    // Safety: LE byte view of the f32 payload, same as `write_frame`.
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    w.write_all(bytes)?;
    w.flush()
}

/// Read one wire frame from an arbitrary stream with plain blocking
/// `read_exact` semantics (no keepalive machinery, no frame pool) —
/// the client-protocol counterpart of [`write_stream_frame`].
pub(crate) fn read_stream_frame(r: &mut impl Read) -> std::io::Result<Msg> {
    let mut hdr = [0u8; 4 + 8 + 8];
    r.read_exact(&mut hdr)?;
    let src = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    let tag = u64::from_le_bytes(hdr[4..12].try_into().unwrap());
    let len = u64::from_le_bytes(hdr[12..20].try_into().unwrap()) as usize;
    if len > (1 << 31) {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("implausible frame of {len} floats"),
        ));
    }
    let mut data = vec![0f32; len];
    // Safety: reading LE f32 payload into the vec's byte view.
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, len * 4)
    };
    r.read_exact(bytes)?;
    Ok(Msg { src, tag, data })
}

impl Comm for TcpGroup {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn counters(&mut self) -> &mut Counters {
        &mut self.counters
    }

    fn send(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<()> {
        if dst == self.rank {
            self.parked.push(Msg { src: dst, tag, data });
            return Ok(());
        }
        self.write_frame(dst, tag, &data)?;
        // blocking send frees its payload here — only `isend`, whose
        // callers pool their staging, retires buffers for reclaim
        drop(data);
        let w = self.writers[dst].as_mut().expect("checked by write_frame");
        w.flush().map_err(io_err)?;
        Ok(())
    }

    /// Nonblocking send.  Deferred-flush mode: the frame lands in the
    /// per-peer user-space buffer and is flushed in one syscall batch
    /// by the next blocking operation.  Progress mode: flushed eagerly,
    /// so the frame departs while the caller computes and the peer's
    /// engine drains it concurrently.
    fn isend(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<CommRequest> {
        if dst == self.rank {
            self.parked.push(Msg { src: dst, tag, data });
            return Ok(CommRequest::send_done());
        }
        self.write_frame(dst, tag, &data)?;
        self.retire(data);
        if self.progress.is_some() {
            let w = self.writers[dst].as_mut().expect("checked by write_frame");
            w.flush().map_err(io_err)?;
        } else {
            self.flush_needed = true;
        }
        Ok(CommRequest::send_done())
    }

    fn recv(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        self.flush_pending()?;
        // self-loopback (and pre-engine stragglers) park locally
        if let Some(i) = self
            .parked
            .iter()
            .position(|m| m.src == src && m.tag == tag)
        {
            return Ok(self.parked.swap_remove(i).data);
        }
        if self.progress.is_some() {
            return self.recv_progress(src, tag);
        }
        loop {
            let msg = self.read_msg_from(src, tag)?;
            if msg.tag == KEEPALIVE_TAG {
                continue; // a peer probing us while it waits — discard
            }
            if msg.src == src && msg.tag == tag {
                return Ok(msg.data);
            }
            self.parked.push(msg);
        }
    }

    /// Deferred-flush mode: flush buffered isends once, then complete
    /// in posted order (each peer is its own ordered byte stream, so
    /// out-of-order arrivals only happen across peers and land in the
    /// parked queue).  Progress mode: complete in **true arrival
    /// order** — whichever pending message the engine drains first
    /// fills its slot first, regardless of posted order.
    fn wait_all(&mut self, reqs: Vec<CommRequest>) -> Result<Vec<Option<Vec<f32>>>> {
        self.flush_pending()?;
        if self.progress.is_none() {
            return reqs.into_iter().map(|r| self.wait(r)).collect();
        }
        let mut out: Vec<Option<Vec<f32>>> = Vec::with_capacity(reqs.len());
        let mut pending: Vec<(usize, usize, u64)> = Vec::new();
        for (slot, req) in reqs.into_iter().enumerate() {
            out.push(None);
            if let Some((src, tag)) = req.pending_recv() {
                pending.push((slot, src, tag));
            }
        }
        // self-loopback messages first
        pending.retain(|&(slot, src, tag)| {
            match self
                .parked
                .iter()
                .position(|m| m.src == src && m.tag == tag)
            {
                Some(i) => {
                    out[slot] = Some(self.parked.swap_remove(i).data);
                    false
                }
                None => true,
            }
        });
        if pending.is_empty() {
            return Ok(out);
        }
        let shared = self.progress.as_ref().expect("progress mode").clone();
        let deadline = self
            .recv_timeout
            .map(|d| (Instant::now() + d, d.as_millis() as u64));
        let mut inbox = shared.inbox.lock().unwrap();
        loop {
            let msgs = &mut inbox.msgs;
            let mut matched = false;
            pending.retain(|&(slot, src, tag)| {
                match msgs.iter().position(|m| m.src == src && m.tag == tag) {
                    Some(i) => {
                        out[slot] = Some(msgs.swap_remove(i).data);
                        matched = true;
                        false
                    }
                    None => true,
                }
            });
            if pending.is_empty() {
                return Ok(out);
            }
            if !matched {
                if let Some(&(_, src, _)) = pending
                    .iter()
                    .find(|&&(_, src, _)| inbox.closed[src].is_some())
                {
                    let reason = inbox.closed[src].as_deref().unwrap_or("closed");
                    return Err(Error::Comm(format!(
                        "tcp: peer {src} down with receives outstanding ({reason})"
                    )));
                }
                inbox = match deadline {
                    Some((at, ms)) => {
                        let now = Instant::now();
                        if now >= at {
                            let &(_, src, tag) =
                                pending.first().expect("pending nonempty");
                            return Err(Error::Timeout { peer: src, tag, ms });
                        }
                        shared.cv.wait_timeout(inbox, at - now).unwrap().0
                    }
                    None => shared.cv.wait(inbox).unwrap(),
                };
            }
        }
    }

    fn flush(&mut self) -> Result<()> {
        self.flush_pending()
    }

    fn reclaim_spent(&mut self) -> Vec<Vec<f32>> {
        self.spent_bytes = 0;
        std::mem::take(&mut self.spent)
    }

    /// Feed the receive freelist: frames the readers hand out come
    /// back here once the caller has consumed them, closing the
    /// allocation loop of the receive path.  Buffers the pool is too
    /// full to keep are returned to the caller.
    fn recycle(&mut self, bufs: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        let mut declined = Vec::new();
        for b in bufs {
            if let Some(b) = self.frames.give(b) {
                declined.push(b);
            }
        }
        declined
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Thread-per-rank over real sockets (the framing/mesh code path;
    /// process-per-rank is exercised by `fastmoe dist-moe --backend tcp`).
    fn run_tcp<T, F>(size: usize, base_port: u16, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(TcpGroup) -> Result<T> + Send + Sync + 'static,
    {
        let f = std::sync::Arc::new(f);
        let mut joins = Vec::new();
        for rank in 0..size {
            let f = f.clone();
            joins.push(std::thread::spawn(move || {
                let g = TcpGroup::connect_local(rank, size, base_port).unwrap();
                f(g).unwrap()
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn tcp_all_to_all_and_reduce() {
        let out = run_tcp(3, 47310, |mut g| {
            let r = g.rank() as f32;
            let send: Vec<Vec<f32>> = (0..3).map(|p| vec![r * 10.0 + p as f32]).collect();
            let recv = g.all_to_all_v(send)?;
            for (p, buf) in recv.iter().enumerate() {
                assert_eq!(buf, &vec![p as f32 * 10.0 + r]);
            }
            let mut buf = vec![g.rank() as f32 + 1.0; 7];
            g.all_reduce_sum(&mut buf)?;
            assert!(buf.iter().all(|&x| x == 6.0)); // 1+2+3
            Ok(g.counters.get("bytes_sent"))
        });
        assert!(out.iter().all(|&b| b > 0));
    }

    #[test]
    fn tcp_variable_sizes_and_barrier() {
        run_tcp(2, 47330, |mut g| {
            let r = g.rank();
            let send: Vec<Vec<f32>> = (0..2).map(|p| vec![2.5; r * 3 + p]).collect();
            let recv = g.all_to_all_v(send)?;
            for (p, buf) in recv.iter().enumerate() {
                assert_eq!(buf.len(), p * 3 + r);
            }
            g.barrier()?;
            let mut v = if r == 0 { vec![9.0, 8.0] } else { vec![] };
            g.broadcast(&mut v, 0)?;
            assert_eq!(v, vec![9.0, 8.0]);
            Ok(())
        });
    }

    #[test]
    fn tcp_isend_defers_flush_until_wait() {
        run_tcp(2, 47370, |mut g| {
            let other = 1 - g.rank();
            let tag = (g.next_seq() << 8) | 1;
            g.isend(other, tag, vec![g.rank() as f32; 8])?;
            assert!(g.flush_needed, "isend must not flush eagerly");
            let req = g.irecv(other, tag)?;
            let data = g.wait(req)?.unwrap();
            assert!(!g.flush_needed, "wait must flush buffered isends");
            assert_eq!(data, vec![other as f32; 8]);
            // explicit flush pushes frames without blocking on arrivals
            let tag2 = (g.next_seq() << 8) | 1;
            g.isend(other, tag2, vec![7.0])?;
            g.flush()?;
            assert!(!g.flush_needed, "flush must clear the dirty flag");
            assert_eq!(g.recv(other, tag2)?, vec![7.0]);
            // both isend payloads were framed and are reclaimable
            let spent = g.reclaim_spent();
            assert_eq!(spent.len(), 2);
            assert!(g.reclaim_spent().is_empty(), "reclaim drains");
            Ok(())
        });
    }

    #[test]
    fn tcp_barrier_is_dissemination() {
        run_tcp(3, 47390, |mut g| {
            g.barrier()?;
            g.barrier()?;
            // ⌈log₂ 3⌉ = 2 rounds per barrier, no all-to-all traffic
            assert_eq!(g.counters.get("barrier_rounds"), 4);
            assert_eq!(g.counters.get("a2a_calls"), 0);
            g.barrier_a2a()?;
            assert_eq!(g.counters.get("a2a_calls"), 1);
            Ok(())
        });
    }

    #[test]
    fn tcp_large_payload_roundtrip() {
        run_tcp(2, 47350, |mut g| {
            let big = vec![g.rank() as f32; 200_000]; // 800 KB frames
            let recv = g.all_to_all_v(vec![big.clone(), big.clone()])?;
            let other = 1 - g.rank();
            assert_eq!(recv[other].len(), 200_000);
            assert!(recv[other].iter().all(|&x| x == other as f32));
            Ok(())
        });
    }

    #[test]
    fn tcp_recv_deadline_surfaces_timeout() {
        // deferred-flush path: resolution is the keepalive tick, so
        // any sub-tick deadline fires on the first idle boundary
        run_tcp(2, 47450, |mut g| {
            let other = 1 - g.rank();
            g.set_recv_timeout(Some(Duration::from_millis(100)));
            let unsent = (1u64 << 40) | 5;
            match g.recv(other, unsent) {
                Err(Error::Timeout { peer, tag, ms }) => {
                    assert_eq!(peer, other);
                    assert_eq!(tag, unsent);
                    assert_eq!(ms, 100);
                }
                r => panic!("expected Timeout, got {r:?}"),
            }
            // link is still usable after a timeout, and disarming
            // restores the wait-forever default
            g.set_recv_timeout(None);
            let tag = (g.next_seq() << 8) | 1;
            g.isend(other, tag, vec![g.rank() as f32])?;
            assert_eq!(g.recv(other, tag)?, vec![other as f32]);
            Ok(())
        });
        // progress path: the condvar wait is bounded exactly
        run_tcp(2, 47470, |mut g| {
            g.enable_progress();
            let other = 1 - g.rank();
            g.set_recv_timeout(Some(Duration::from_millis(80)));
            let unsent = (1u64 << 40) | 6;
            match g.recv(other, unsent) {
                Err(Error::Timeout { peer, tag, ms }) => {
                    assert_eq!(peer, other);
                    assert_eq!(tag, unsent);
                    assert_eq!(ms, 80);
                }
                r => panic!("expected Timeout, got {r:?}"),
            }
            g.set_recv_timeout(None);
            let tag = (g.next_seq() << 8) | 1;
            g.isend(other, tag, vec![g.rank() as f32])?;
            assert_eq!(g.recv(other, tag)?, vec![other as f32]);
            Ok(())
        });
    }

    #[test]
    fn frame_pool_best_fit_and_balance() {
        let p = FramePool::default();
        // empty pool: two allocations, counted
        let big = p.take(16);
        let small = p.take(4);
        assert_eq!(big.len(), 16);
        assert_eq!(p.allocs.load(Ordering::Relaxed), 2);
        // both come back: accepted (the pool is owed two)
        assert!(p.give(big).is_none());
        assert!(p.give(small).is_none());
        // a surplus give (never handed out) is declined — that buffer
        // is the caller's own staging (e.g. a self-loopback send), and
        // keeping it would drain the caller's arena into ours
        assert!(p.give(vec![0.0; 8]).is_some());
        // best fit: a small request must not burn the big buffer
        let s = p.take(3);
        assert!(s.capacity() < 16, "best fit took the big buffer");
        assert_eq!(p.allocs.load(Ordering::Relaxed), 2);
        assert_eq!(p.hits.load(Ordering::Relaxed), 1);
        // zero-length frames never touch the pool or the allocator
        assert_eq!(p.take(0).capacity(), 0);
        assert_eq!(p.allocs.load(Ordering::Relaxed), 2);
        let _ = p.give(s);
    }

    #[test]
    fn keepalive_probes_are_transparent_and_counted() {
        // Rank 1 withholds its send well past the keepalive interval;
        // rank 0's blocking recv must probe (counter) and still return
        // exactly the real payload once it arrives — the probe frames
        // rank 0 wrote meanwhile are discarded by rank 1's reads.
        let out = run_tcp(2, 47430, |mut g| {
            let other = 1 - g.rank();
            let tag = (g.next_seq() << 8) | 1;
            if g.rank() == 1 {
                std::thread::sleep(Duration::from_millis(1200));
            }
            g.isend(other, tag, vec![g.rank() as f32; 5])?;
            g.flush()?;
            let data = g.recv(other, tag)?;
            assert_eq!(data, vec![other as f32; 5]);
            // round 2 proves the stream survived the probe traffic
            let tag2 = (g.next_seq() << 8) | 1;
            g.isend(other, tag2, vec![7.0])?;
            assert_eq!(g.recv(other, tag2)?, vec![7.0]);
            Ok((g.rank(), g.counters.get("keepalive_probes")))
        });
        let probes: u64 = out
            .iter()
            .filter(|(r, _)| *r == 0)
            .map(|(_, p)| *p)
            .sum();
        assert!(probes >= 1, "rank 0 never probed its slow peer");
    }

    #[test]
    fn tcp_progress_engine_basic_roundtrip() {
        run_tcp(3, 47410, |mut g| {
            g.enable_progress();
            assert!(g.progress_enabled());
            // the full collective stack must run unchanged on top of
            // the engine's inbox path
            let r = g.rank() as f32;
            let send: Vec<Vec<f32>> =
                (0..3).map(|p| vec![r * 10.0 + p as f32; p + 1]).collect();
            let recv = g.all_to_all_v(send)?;
            for (p, buf) in recv.iter().enumerate() {
                assert_eq!(buf, &vec![p as f32 * 10.0 + r; g.rank() + 1]);
            }
            let mut buf = vec![g.rank() as f32 + 1.0; 5];
            g.all_reduce_sum(&mut buf)?;
            assert!(buf.iter().all(|&x| x == 6.0));
            g.barrier()?;
            assert!(g.progress_arrivals() > 0, "engine saw no traffic");
            Ok(())
        });
    }
}
