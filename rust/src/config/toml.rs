//! TOML-subset parser (substrate; no `toml` crate offline).
//!
//! Supported grammar — everything the fastmoe configs use:
//!
//! ```toml
//! # comment
//! top_level_key = 1
//! [section]            # or [a.b] nested sections
//! string = "value"
//! int = 42
//! float = 3.5          # also 1e-4
//! boolean = true
//! array = [1, 2, 3]    # flat arrays of scalars
//! ```
//!
//! Recognised sections and keys (defaults in `config::*Config`):
//!
//! | section   | keys |
//! |-----------|------|
//! | `[model]` | `vocab`, `seq`, `n_layer`, `d_model`, `n_head`, `d_hidden`, `moe`, `n_expert`, `top_k` |
//! | `[train]` | `model`, `steps`, `batch`, `lr`, `seed`, `log_every`, `eval_every`, `checkpoint_every`, `out_dir` |
//! | `[dist]`  | `workers`, `ne_local`, `top_k`, `net`, `seed` |
//! | `[moe]`   | `gate` (`"topk"` \| `"switch"` \| `"noisy_topk"`), `capacity_factor` (switch: per-expert capacity multiplier), `noise_std` (noisy_topk: score-noise std dev), `balance_coef` (GShard balance-loss gradient weight, default `0.01`; `0` = off, the pre-balance seed gradients) |
//! | `[placement]` | `policy` (`"static"` \| `"shadow"` \| `"migrate"` — dynamic expert placement, default `"static"` = the seed layout with no decision traffic; `"shadow"` replicates the hottest expert onto the least-loaded rank and keeps the replica bit-identical via an owner-broadcast Adam mirror, `"migrate"` swaps the hottest expert with a cold rank's coldest one, optimiser state and all), `threshold` (max/mean per-rank row-load ratio above which the rebalancer acts, default `1.5`, must be ≥ 1; at or below it standing shadows are dropped), `window` (steps per decision — also the sliding load-history window the all-reduced decision counts come from, default `8`, must be ≥ 1) |
//! | `[serve]` | `port` (front-end listener for client sessions, default `47800`; the expert-parallel mesh keeps its own `base_port + rank` range), `max_batch` (token rows admitted into one forward step; `0` = the layer batch `nb`, larger values clamp to it), `queue_depth` (bound on tokens queued beyond the in-flight batch, default `1024`; a request that would exceed it is rejected immediately — admission control, not back-pressure), `idle_ms` (how long an undersized batch waits for more arrivals before stepping anyway, default `50` — continuous batching's latency/utilisation knob) |
//! | `[comm]`  | `overlap` (pipeline the MoE dispatch/compute/combine against the wire, default `false`), `chunks` (ring-offset peer groups per exchange; `1` = blocking, `0` = adaptive from the previous step's measured wire:compute ratio, clamped to the worker count), `chunk_policy` (`"mean"` \| `"max"` — how ranks agree the adaptive chunk count from their exchanged ratios: the default mean, or the straggler-aware max where the slowest rank decides), `pool` (step-persistent buffer pools on the hot path, default `true`; `false` reallocates every step — A/B knob, bit-identical outputs), `progress` (TCP progress engine: per-peer reader threads drain arrivals during expert compute and `isend` departs eagerly, default `false`; thread-channel workers ignore it), `grad_overlap` (bucketed nonblocking gradient all-reduce in the trainers: `MoeLayerTrainer` flies the gate-grad bucket during the expert backward, `DistTrainer` pipelines bucket completions against host Adam; default `false`, bit-identical results either way), `bucket_kb` (target gradient-bucket payload in KiB, default `512`, must be ≥ 1; tensors are never split across buckets — that is what keeps the overlapped bits identical to the blocking per-tensor rings), `grad_shard` (`"none"` \| `"zero"` — ZeRO-style sharded optimizer under the bucketed sync, default `"none"` = every rank runs full Adam on the all-reduced gradients; `"zero"` reduce-scatters each per-tensor ring so every rank owns a contiguous gradient shard, runs Adam on *only* that shard (~1/workers optimizer memory and host math) and all-gathers the updated parameters — same wire volume as the plain ring, bit-identical parameters, rail-aware across nodes under `topology = "hier"`; mutually exclusive with `grad_overlap`), `topology` (`"flat"` \| `"hier"` — collective routing policy, default `"flat"` = the seed ring, bit-for-bit; `"hier"` routes the all-to-all through node leaders, builds the two-level tree all-reduce under the bucketed sync, and orders the pipelined layer's exchange chunks most-local-first), `nodes` / `local_size` (the hier node split: contiguous rank blocks of `local_size`, lowest rank = leader; give either — they must agree if both — default two nodes; `world % local_size` must be 0) |
//! | `[fault]` | `recover` (`"abort"` \| `"degrade"` \| `"rejoin"` — what to do when a worker is declared dead, default `"abort"` = unwind with a typed error; `"degrade"` quarantines the dead rank at the next step boundary and keeps training on the survivors — shadow-covered experts fail over to their replicas, uncovered ones are score-masked; `"rejoin"` additionally restores a restarted rank from its latest checkpoint plus live shadow transfer and returns to full strength), `ckpt_interval` (periodic per-rank checkpoint cadence in steps, default `0` = off; atomic tmp+rename writes of params, Adam moments and counters), `ckpt_dir` (checkpoint directory, default `"ckpt"`), `recv_timeout_ms` (receive deadline in milliseconds on thread and tcp backends, default `0` = wait forever; an expiry surfaces as the typed, peer-attributed timeout error that feeds suspicion), `chaos` (deterministic fault schedule for testing, default empty; comma-separated `kill@N:rR`, `delay@N:rR:MS`, `rejoin@N:rR` events fired at step boundaries) |
//! | `[auto]`  | `enabled` (online autotuning: calibrate an α-β cost model from measured phase timers and search the `[comm]` knob lattice for the modelled-fastest config, default `false` = no calibration traffic at all), `calib_steps` (instrumented steps per calibration window, default `8`, must be ≥ 1), `retune_drift` (relative drift of the rank-agreed measured step time from the prediction above which a fresh calibration window opens, default `0.25`, must be > 0), `apply` (`"report"` \| `"live"` — what to do with the search result, default `"report"` = log the winning config as a pasteable `[comm]` snippet and change nothing, bit-identical to disabled; `"live"` applies the step-boundary-safe knobs — `chunks`, `chunk_policy`, `bucket_kb` — on every rank in lockstep, leaving restart-only knobs like `topology`/`grad_shard` as recommendations) |

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed TOML value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
    Table(BTreeMap<String, TomlValue>),
}

impl TomlValue {
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        match self {
            TomlValue::Table(t) => t.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.as_i64())
            .map(|i| i.max(0) as usize)
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

/// Parse a TOML-subset document into a table tree.
pub fn parse(text: &str) -> Result<TomlValue> {
    let mut root = BTreeMap::new();
    let mut path: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            path = name.split('.').map(|s| s.trim().to_string()).collect();
            ensure_table(&mut root, &path, lineno)?;
        } else {
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| err(lineno, "expected `key = value`"))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(value.trim(), lineno)?;
            insert(&mut root, &path, key, value, lineno)?;
        }
    }
    Ok(TomlValue::Table(root))
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("toml line {}: {msg}", lineno + 1))
}

fn strip_comment(line: &str) -> &str {
    // a `#` outside of a string starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table(
    root: &mut BTreeMap<String, TomlValue>,
    path: &[String],
    lineno: usize,
) -> Result<()> {
    let mut cur = root;
    for p in path {
        let entry = cur
            .entry(p.clone())
            .or_insert_with(|| TomlValue::Table(BTreeMap::new()));
        match entry {
            TomlValue::Table(t) => cur = t,
            _ => return Err(err(lineno, "section name collides with a key")),
        }
    }
    Ok(())
}

fn insert(
    root: &mut BTreeMap<String, TomlValue>,
    path: &[String],
    key: &str,
    value: TomlValue,
    lineno: usize,
) -> Result<()> {
    let mut cur = root;
    for p in path {
        match cur.get_mut(p) {
            Some(TomlValue::Table(t)) => cur = t,
            _ => return Err(err(lineno, "internal section error")),
        }
    }
    if cur.insert(key.to_string(), value).is_some() {
        return Err(err(lineno, &format!("duplicate key `{key}`")));
    }
    Ok(())
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue> {
    if s.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(lineno, "embedded quote in string"));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?
            .trim();
        let mut items = Vec::new();
        if !inner.is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part.trim(), lineno)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(lineno, &format!("cannot parse value `{s}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_sections() {
        let t = parse("a = 1\n[s]\nb = \"x\"\nc = 2.5\nd = true\n").unwrap();
        assert_eq!(t.get("a").unwrap().as_i64(), Some(1));
        let s = t.get("s").unwrap();
        assert_eq!(s.get("b").unwrap().as_str(), Some("x"));
        assert_eq!(s.get("c").unwrap().as_f64(), Some(2.5));
        assert_eq!(s.get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn comments_and_blank_lines() {
        let t = parse("# top\n\na = 1 # trailing\ns = \"has # inside\"\n").unwrap();
        assert_eq!(t.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(t.get("s").unwrap().as_str(), Some("has # inside"));
    }

    #[test]
    fn nested_sections() {
        let t = parse("[a.b]\nx = 1\n[a.c]\ny = 2\n").unwrap();
        let a = t.get("a").unwrap();
        assert_eq!(a.get("b").unwrap().get("x").unwrap().as_i64(), Some(1));
        assert_eq!(a.get("c").unwrap().get("y").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn arrays() {
        let t = parse("xs = [1, 2, 3]\nys = []\nzs = [1.5, 2]\n").unwrap();
        let xs = match t.get("xs").unwrap() {
            TomlValue::Array(a) => a,
            _ => panic!(),
        };
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_i64(), Some(3));
    }

    #[test]
    fn errors() {
        assert!(parse("a\n").is_err());
        assert!(parse("a = \n").is_err());
        assert!(parse("a = 1\na = 2\n").is_err());
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("v = \"oops\n").is_err());
        assert!(parse("v = what\n").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let t = parse("a = -5\nb = 1e-4\nc = -2.5e3\n").unwrap();
        assert_eq!(t.get("a").unwrap().as_i64(), Some(-5));
        assert!((t.get("b").unwrap().as_f64().unwrap() - 1e-4).abs() < 1e-12);
        assert!((t.get("c").unwrap().as_f64().unwrap() + 2500.0).abs() < 1e-9);
    }
}
