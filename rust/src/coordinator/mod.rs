//! The Layer-3 coordinator — FastMoE's system contribution.
//!
//! * [`DistMoeLayer`] (`dist_moe`) — the expert-parallel MoE layer: the
//!   Figure-2 two-phase exchange and the full manual backward chain,
//!   as thin orchestration over the pluggable
//!   [`Gate`](crate::moe::Gate) /
//!   [`ExpertShard`](crate::moe::ExpertShard) hierarchy.
//! * [`MoeLayerBuilder`] — assembles a layer from the `[moe]` config
//!   section (gate kind, capacity factor, noise std) and the artifact
//!   manifest's geometry.
//! * [`Trainer`] / [`DistTrainer`] / [`MoeLayerTrainer`] (`trainer`) —
//!   the fused single-graph training loop (Figure 7), its
//!   data-parallel multi-worker variant with tag-aware gradient
//!   synchronisation, and the expert-parallel layer trainer with
//!   per-step balance-loss metrics.
//! * [`GradSync`] — the heterogeneity-aware synchronisation module of
//!   §3.2: parameters tagged `world` / `data_parallel` are averaged over
//!   their groups, `none` (expert shards) are left alone in sharded
//!   mode.  With `[comm] grad_overlap` the sync runs *bucketed and
//!   nonblocking* ([`Comm::all_reduce_start`]): tag-homogeneous runs of
//!   whole tensors form buckets of `[comm] bucket_kb`, every bucket's
//!   first ring round is on the wire before anything blocks, and
//!   [`GradSync::start_bucket`] / [`GradSync::finish_bucket`] let the
//!   trainers overlap completion with backward compute and host Adam.
//!   Tensors are never split across buckets, so overlapped results are
//!   bit-identical to the blocking per-tensor rings.
//! * [`ServeLoop`] (`serve_loop`) — the inference-side sibling of the
//!   trainers: keeps the expert-parallel workers resident between
//!   requests, steps them in lockstep on a control tag when the front
//!   end has a batch, and drives only the forward path
//!   ([`DistMoeLayer::forward_infer`] — no gradients, no cotangent
//!   pool roles).

mod dist_moe;
mod serve_loop;
mod trainer;

pub use dist_moe::{DistMoeLayer, LayerGrads, MoeLayerBuilder, MoeLayerState};
pub use serve_loop::{ServeLoop, CTL_STEP, CTL_STOP, CTL_TAG};
pub use trainer::{DistTrainer, MoeLayerTrainer, MoeStepStats, StepStats, Trainer};

use crate::comm::{Comm, PendingAllReduce, Topology};
use crate::config::CommConfig;
use crate::error::{Error, Result};
use crate::model::Adam;
use crate::runtime::SyncTag;
use crate::tensor::TensorF32;

/// How `SyncTag::None` parameters are handled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpertMode {
    /// Expert params physically sharded per worker (stage mode): never
    /// synchronised — each shard already saw every token routed to it.
    Sharded,
    /// Expert params replicated on every worker (the DP-emulated fig-7
    /// path): averaged like `world`, which is mathematically identical
    /// to one global expert updated with all routed tokens.
    Replicated,
}

/// How one gradient bucket is synchronised.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BucketScope {
    /// Ring all-reduce over all ranks (bucketed + nonblocking in
    /// overlapped mode).
    World,
    /// Subgroup all-reduce over `dp_group` — completed at launch time
    /// (the gather-based subgroup reduction has no decomposed form).
    Group,
    /// No synchronisation (sharded expert grads are already final).
    Local,
}

/// One bucket of the overlapped sync plan: a run of whole,
/// consecutively-indexed tensors sharing a [`BucketScope`].
#[derive(Clone, Debug)]
pub struct GradBucket {
    pub indices: Vec<usize>,
    pub scope: BucketScope,
}

/// Tag-aware gradient synchroniser (the paper's customised DDP).
pub struct GradSync {
    /// Ranks of this worker's data-parallel group (must include self).
    pub dp_group: Vec<usize>,
    pub mode: ExpertMode,
    /// Bucketed nonblocking sync (`[comm] grad_overlap`); the blocking
    /// per-tensor rings otherwise.  Results are bit-identical.
    pub overlap: bool,
    /// Target bucket payload in bytes (`[comm] bucket_kb`); tensors
    /// are never split, so a bucket is a run of whole tensors.
    pub bucket_bytes: usize,
    /// ZeRO-sharded optimiser mode (`[comm] grad_shard = "zero"`):
    /// `World`-scope tensors reduce-scatter so each rank owns one
    /// contiguous shard, Adam runs on the owned slice only, and the
    /// updated params all-gather back ([`GradSync::sync_zero`]).  Takes
    /// precedence over `overlap` — the zero schedule is already
    /// bucketed and nonblocking.
    pub shard: bool,
}

impl GradSync {
    /// Everyone in one DP group (pure data/expert parallelism),
    /// blocking sync — the seed schedule.
    pub fn world(size: usize, mode: ExpertMode) -> GradSync {
        GradSync {
            dp_group: (0..size).collect(),
            mode,
            overlap: false,
            bucket_bytes: CommConfig::default().bucket_kb * 1024,
            shard: false,
        }
    }

    /// Adopt the `[comm]` section's grad-sync knobs.
    pub fn comm_config(mut self, cfg: &CommConfig) -> GradSync {
        self.overlap = cfg.grad_overlap;
        self.bucket_bytes = cfg.bucket_kb.max(1) * 1024;
        self.shard = cfg.grad_shard == "zero";
        self
    }

    fn scope_of(&self, tag: SyncTag, world: usize) -> BucketScope {
        match tag {
            SyncTag::World => BucketScope::World,
            SyncTag::DataParallel => {
                if self.dp_group.len() == world {
                    BucketScope::World
                } else if self.dp_group.len() > 1 {
                    BucketScope::Group
                } else {
                    BucketScope::Local
                }
            }
            SyncTag::None => match self.mode {
                ExpertMode::Sharded => BucketScope::Local,
                ExpertMode::Replicated => BucketScope::World,
            },
        }
    }

    /// Partition the gradient list into buckets: consecutive same-scope
    /// tensors group together, `World` runs splitting at
    /// [`GradSync::bucket_bytes`].  The plan covers every index exactly
    /// once, in order — the overlapped trainer steps the optimiser
    /// bucket by bucket against it.
    pub fn plan(
        &self,
        grads: &[TensorF32],
        tags: &[SyncTag],
        world: usize,
    ) -> Vec<GradBucket> {
        assert_eq!(grads.len(), tags.len());
        let mut out: Vec<GradBucket> = Vec::new();
        let mut bytes = 0usize;
        for (i, &tag) in tags.iter().enumerate() {
            let scope = self.scope_of(tag, world);
            let sz = grads[i].data.len() * 4;
            let split = match out.last() {
                Some(b) if b.scope == scope => {
                    scope == BucketScope::World && bytes + sz > self.bucket_bytes
                }
                _ => true,
            };
            if split {
                out.push(GradBucket { indices: Vec::new(), scope });
                bytes = 0;
            }
            out.last_mut().expect("bucket pushed").indices.push(i);
            bytes += sz;
        }
        out
    }

    /// Launch one bucket: `World` buckets take the tensors' buffers and
    /// start their nonblocking rings (round-0 frames depart before this
    /// returns); `Group` buckets run the blocking subgroup reduction on
    /// the spot (and scale); `Local` buckets do nothing.
    pub fn start_bucket(
        &self,
        comm: &mut impl Comm,
        grads: &mut [TensorF32],
        bucket: &GradBucket,
    ) -> Result<Option<PendingAllReduce>> {
        match bucket.scope {
            BucketScope::Local => Ok(None),
            BucketScope::Group => {
                let scale = 1.0 / self.dp_group.len() as f32;
                for &i in &bucket.indices {
                    comm.all_reduce_sum_group(&mut grads[i].data, &self.dp_group)?;
                    for x in grads[i].data.iter_mut() {
                        *x *= scale;
                    }
                }
                Ok(None)
            }
            BucketScope::World => {
                let bufs: Vec<Vec<f32>> = bucket
                    .indices
                    .iter()
                    .map(|&i| std::mem::take(&mut grads[i].data))
                    .collect();
                Ok(Some(comm.all_reduce_start(bufs)?))
            }
        }
    }

    /// Complete a launched bucket: drive its rings to completion, scale
    /// by the world size and hand the buffers back to the tensors.
    pub fn finish_bucket(
        &self,
        comm: &mut impl Comm,
        grads: &mut [TensorF32],
        bucket: &GradBucket,
        pending: Option<PendingAllReduce>,
    ) -> Result<()> {
        let Some(pending) = pending else { return Ok(()) };
        let bufs = pending.finish(comm)?;
        let world = comm.size();
        let scale = 1.0 / world as f32;
        for (&i, buf) in bucket.indices.iter().zip(bufs) {
            grads[i].data = buf;
            if world > 1 {
                for x in grads[i].data.iter_mut() {
                    *x *= scale;
                }
            }
        }
        Ok(())
    }

    /// The one copy of the overlapped launch/complete protocol: plan,
    /// launch **every** bucket (so all round-0 frames share the wire),
    /// then complete buckets in plan order — the order every rank must
    /// share (see [`crate::comm::PendingAllReduce::wait_bucket`]) —
    /// invoking `synced` after each bucket's grads land.  The hook is
    /// where `DistTrainer` runs host Adam on the synced slice while
    /// later buckets' current rounds are still in flight; plain
    /// [`GradSync::sync`] passes a no-op.
    pub fn sync_overlapped(
        &self,
        comm: &mut impl Comm,
        grads: &mut [TensorF32],
        tags: &[SyncTag],
        mut synced: impl FnMut(&GradBucket, &[TensorF32]) -> Result<()>,
    ) -> Result<()> {
        let buckets = self.plan(grads, tags, comm.size());
        // Every World ring launches before anything blocks — a Group
        // bucket's subgroup reduction is a blocking gather, and running
        // it first would keep later rings off the wire.  Two passes in
        // the same order on every rank keep the protocol in lockstep;
        // reordering is value-safe because tensors are independent.
        let mut pend = Vec::with_capacity(buckets.len());
        for b in &buckets {
            pend.push(match b.scope {
                BucketScope::World => self.start_bucket(comm, grads, b)?,
                _ => None,
            });
        }
        for b in &buckets {
            if b.scope != BucketScope::World {
                self.start_bucket(comm, grads, b)?;
            }
        }
        for (b, p) in buckets.iter().zip(pend) {
            self.finish_bucket(comm, grads, b, p)?;
            synced(b, grads)?;
        }
        Ok(())
    }

    /// The owned shard range per slot under the zero schedule: `Some`
    /// for `World`-scope slots (whose Adam state shrinks to the owned
    /// range — pass the result to [`Adam::new_sharded`]), `None` for
    /// `Group`/`Local` slots (full-tensor state).  Deterministic in
    /// (shapes, tags, rank, topology), so the layout is fixed before
    /// any collective runs — checkpoints persist exactly the owned
    /// slices.
    pub fn shard_plan(
        &self,
        params: &[TensorF32],
        tags: &[SyncTag],
        topo: &Topology,
        rank: usize,
    ) -> Vec<Option<std::ops::Range<usize>>> {
        assert_eq!(params.len(), tags.len());
        params
            .iter()
            .zip(tags)
            .map(|(p, &t)| {
                if self.scope_of(t, topo.world()) == BucketScope::World {
                    Some(crate::comm::zero_shard_range(topo, rank, p.data.len()))
                } else {
                    Option::None
                }
            })
            .collect()
    }

    /// The fused ZeRO sync + optimiser step (`grad_shard = "zero"`).
    ///
    /// `World` buckets all launch their zero schedules first (every
    /// tensor is its own ring, so shard ranges are per-slot), then
    /// complete in plan order: reduce-scatter pauses with this rank's
    /// owned shard fully reduced, the shard is scaled by `1/world` and
    /// fed to [`Adam::update_shard`] against the matching param slice,
    /// the *updated params* are written back into the wire buffer, and
    /// the all-gather half broadcasts them — so every rank ends the
    /// step with identical full params while holding only `1/world` of
    /// the optimizer state.  Later buckets' scatter rounds stay in
    /// flight while earlier buckets run host Adam, preserving the
    /// overlapped pipeline.  `Group` buckets run the blocking subgroup
    /// reduction + full-tensor Adam; `Local` slots run full-tensor Adam
    /// on their raw grads.
    ///
    /// On return, `World` slots' `grads` buffers are recycled scratch
    /// (contents undefined); the optimiser must have been built with
    /// [`GradSync::shard_plan`] over the *same* topology the comm
    /// shards with, which is re-checked per bucket against
    /// [`Comm::zero_shard`].
    pub fn sync_zero(
        &self,
        comm: &mut impl Comm,
        grads: &mut [TensorF32],
        tags: &[SyncTag],
        params: &mut [TensorF32],
        opt: &mut Adam,
    ) -> Result<()> {
        assert_eq!(grads.len(), tags.len());
        assert_eq!(params.len(), grads.len());
        let world = comm.size();
        let buckets = self.plan(grads, tags, world);
        opt.begin_step();
        // Same two-pass launch order as sync_overlapped: every zero
        // schedule's round-0 frames hit the wire before a Group
        // bucket's blocking gather can stall them.
        let mut pend = Vec::with_capacity(buckets.len());
        for b in &buckets {
            pend.push(match b.scope {
                BucketScope::World => {
                    let bufs: Vec<Vec<f32>> = b
                        .indices
                        .iter()
                        .map(|&i| std::mem::take(&mut grads[i].data))
                        .collect();
                    Some(comm.all_reduce_zero(bufs)?)
                }
                _ => Option::None,
            });
        }
        for b in &buckets {
            if b.scope != BucketScope::World {
                self.start_bucket(comm, grads, b)?;
            }
        }
        let scale = 1.0 / world as f32;
        for (b, p) in buckets.iter().zip(pend) {
            match b.scope {
                BucketScope::World => {
                    let mut pending = p.expect("world bucket launched");
                    for (j, &i) in b.indices.iter().enumerate() {
                        let (range, buf) = pending.wait_bucket_shard(comm, j)?;
                        if opt.shard.get(i) != Some(&Some(range.clone())) {
                            return Err(Error::msg(format!(
                                "sync_zero: slot {i} optimizer shard {:?} != comm \
                                 shard {range:?} (was the Adam built via shard_plan \
                                 over the comm's topology?)",
                                opt.shard.get(i)
                            )));
                        }
                        if world > 1 {
                            for x in buf[range.clone()].iter_mut() {
                                *x *= scale;
                            }
                        }
                        // Shard-local Adam updates the owned param slice
                        // in place; the wire buffer then carries the
                        // *updated params* into the all-gather half.
                        opt.update_shard(
                            i,
                            &mut params[i].data[range.clone()],
                            &buf[range.clone()],
                        )?;
                        buf[range.clone()].copy_from_slice(&params[i].data[range]);
                        let full = pending.gather_bucket(comm, j)?;
                        // The gathered buffer *is* the updated params;
                        // hand the stale param buffer to grads so the
                        // allocation pool stays warm.
                        grads[i].data = std::mem::replace(&mut params[i].data, full);
                    }
                }
                _ => {
                    for &i in &b.indices {
                        opt.update_slot(i, &mut params[i], &grads[i])?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Average gradients according to their tags.
    ///
    /// * `world` — all-reduce over **all** ranks.
    /// * `data_parallel` — all-reduce over `dp_group`.
    /// * `none` — skipped (Sharded) or treated as `world` (Replicated).
    ///
    /// In overlapped mode every bucket is launched before the first is
    /// completed, so all round-0 frames share the wire; the result is
    /// bit-identical to the blocking path (same per-tensor rings, same
    /// scale).
    pub fn sync(
        &self,
        comm: &mut impl Comm,
        grads: &mut [TensorF32],
        tags: &[SyncTag],
    ) -> Result<()> {
        assert_eq!(grads.len(), tags.len());
        if self.overlap && comm.size() > 1 {
            return self.sync_overlapped(comm, grads, tags, |_, _| Ok(()));
        }
        let world: Vec<usize> = (0..comm.size()).collect();
        for (g, &tag) in grads.iter_mut().zip(tags) {
            let group: Option<&[usize]> = match tag {
                SyncTag::World => Some(&world),
                SyncTag::DataParallel => Some(&self.dp_group),
                SyncTag::None => match self.mode {
                    ExpertMode::Sharded => None,
                    ExpertMode::Replicated => Some(&world),
                },
            };
            if let Some(group) = group {
                if group.len() > 1 {
                    if group.len() == comm.size() {
                        comm.all_reduce_sum(&mut g.data)?;
                    } else {
                        comm.all_reduce_sum_group(&mut g.data, group)?;
                    }
                    let scale = 1.0 / group.len() as f32;
                    for x in g.data.iter_mut() {
                        *x *= scale;
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_workers;
    use crate::runtime::SyncTag::*;

    #[test]
    fn grad_sync_respects_tags() {
        let got = run_workers(4, |mut h| {
            let r = h.rank() as f32;
            let mut grads = vec![
                TensorF32::from_vec(&[2], vec![r, r]).unwrap(), // world
                TensorF32::from_vec(&[2], vec![r, r]).unwrap(), // dp
                TensorF32::from_vec(&[2], vec![r, r]).unwrap(), // none
            ];
            let tags = [World, DataParallel, None];
            // dp groups: {0,1} and {2,3}
            let dp = if h.rank() < 2 { vec![0, 1] } else { vec![2, 3] };
            let mut sync = GradSync::world(4, ExpertMode::Sharded);
            sync.dp_group = dp;
            sync.sync(&mut h, &mut grads, &tags)?;
            Ok((h.rank(), grads))
        })
        .unwrap();
        for (rank, grads) in got {
            // world: mean(0,1,2,3) = 1.5 everywhere
            assert_eq!(grads[0].data, vec![1.5, 1.5], "rank {rank}");
            // dp: mean within the pair
            let want_dp = if rank < 2 { 0.5 } else { 2.5 };
            assert_eq!(grads[1].data, vec![want_dp, want_dp]);
            // none: untouched
            assert_eq!(grads[2].data, vec![rank as f32, rank as f32]);
        }
    }

    #[test]
    fn replicated_mode_averages_experts() {
        let got = run_workers(2, |mut h| {
            let r = h.rank() as f32;
            let mut grads = vec![TensorF32::from_vec(&[1], vec![r]).unwrap()];
            let sync = GradSync::world(2, ExpertMode::Replicated);
            sync.sync(&mut h, &mut grads, &[None])?;
            Ok(grads[0].data[0])
        })
        .unwrap();
        assert_eq!(got, vec![0.5, 0.5]);
    }

    #[test]
    fn bucket_plan_groups_by_scope_and_bytes() {
        let mut sync = GradSync::world(4, ExpertMode::Sharded);
        sync.bucket_bytes = 56; // 14 floats: two 6-float tensors fit, not three
        sync.dp_group = vec![0, 1];
        let grads: Vec<TensorF32> = [6usize, 6, 6, 3, 2, 20, 1]
            .iter()
            .map(|&n| TensorF32::zeros(&[n]))
            .collect();
        let tags = [World, World, World, None, DataParallel, World, World];
        let buckets = sync.plan(&grads, &tags, 4);
        // world run 0..3 splits at the 56-byte budget: [0,1] then [2]
        assert_eq!(buckets[0].indices, vec![0, 1]);
        assert_eq!(buckets[0].scope, BucketScope::World);
        assert_eq!(buckets[1].indices, vec![2]);
        // sharded `none` is local, subgroup dp is its own scope
        assert_eq!(buckets[2].indices, vec![3]);
        assert_eq!(buckets[2].scope, BucketScope::Local);
        assert_eq!(buckets[3].indices, vec![4]);
        assert_eq!(buckets[3].scope, BucketScope::Group);
        // an over-budget tensor gets its own bucket; the tail follows
        assert_eq!(buckets[4].indices, vec![5]);
        assert_eq!(buckets[5].indices, vec![6]);
        // the plan covers every index exactly once, in order
        let all: Vec<usize> = buckets.iter().flat_map(|b| b.indices.clone()).collect();
        assert_eq!(all, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn zero_sync_matches_replicated_adam_bitwise() {
        // Two bucket budgets: one forcing several World buckets, one
        // putting the whole World run in a single bucket.
        for bucket_bytes in [64usize, 1 << 20] {
            let got = run_workers(4, move |mut h| {
                let r = h.rank();
                // grads vary per rank; params start identical everywhere
                let mkg = |n: usize, s: u64| {
                    TensorF32::from_vec(
                        &[n],
                        (0..n)
                            .map(|i| {
                                ((r as u64 * 31 + s * 7 + i as u64) % 97) as f32
                                    * 0.013
                                    - 0.4
                            })
                            .collect(),
                    )
                    .unwrap()
                };
                let mkp = |n: usize, s: u64| {
                    TensorF32::from_vec(
                        &[n],
                        (0..n)
                            .map(|i| {
                                ((s * 13 + i as u64) % 89) as f32 * 0.017 - 0.7
                            })
                            .collect(),
                    )
                    .unwrap()
                };
                let shapes = [130usize, 7, 64, 3, 200];
                let tags = [World, None, DataParallel, World, World];
                let dp = if r < 2 { vec![0, 1] } else { vec![2, 3] };
                let grads0: Vec<TensorF32> = shapes
                    .iter()
                    .zip(1u64..)
                    .map(|(&n, s)| mkg(n, s))
                    .collect();
                let params0: Vec<TensorF32> = shapes
                    .iter()
                    .zip(1u64..)
                    .map(|(&n, s)| mkp(n, s))
                    .collect();

                let mut refsync = GradSync::world(4, ExpertMode::Sharded);
                refsync.dp_group = dp.clone();
                let mut zsync = GradSync::world(4, ExpertMode::Sharded);
                zsync.dp_group = dp;
                zsync.shard = true;
                zsync.bucket_bytes = bucket_bytes;

                // replicated reference: blocking sync + full-state Adam
                let mut pa = params0.clone();
                let mut oa = Adam::new(&pa, 0.01);
                // zero path: shard-sized state from the deterministic plan
                let topo = Topology::flat(4);
                let shard = zsync.shard_plan(&params0, &tags, &topo, r);
                let mut pb = params0.clone();
                let mut ob = Adam::new_sharded(&pb, 0.01, &shard)?;

                for _ in 0..3 {
                    let mut ga = grads0.clone();
                    refsync.sync(&mut h, &mut ga, &tags)?;
                    oa.update(&mut pa, &ga)?;
                    let mut gb = grads0.clone();
                    zsync.sync_zero(&mut h, &mut gb, &tags, &mut pb, &mut ob)?;
                }
                for (i, (a, b)) in pa.iter().zip(&pb).enumerate() {
                    assert_eq!(
                        a.data, b.data,
                        "bucket_bytes {bucket_bytes} slot {i}: zero path changed bits"
                    );
                }
                // World slots hold only the owned slice of moment state.
                for (i, s) in shard.iter().enumerate() {
                    if let Some(rg) = s {
                        assert_eq!(ob.m[i].data.len(), rg.len());
                        assert!(rg.len() < shapes[i].max(4));
                    } else {
                        assert_eq!(ob.m[i].data.len(), shapes[i]);
                    }
                }
                Ok(())
            });
            got.unwrap();
        }
    }

    #[test]
    fn overlapped_sync_matches_blocking_bitwise() {
        for mode in [ExpertMode::Sharded, ExpertMode::Replicated] {
            let got = run_workers(4, move |mut h| {
                let r = h.rank();
                // irrational-ish values so addition order shows in bits
                let mk = |n: usize, s: u64| {
                    TensorF32::from_vec(
                        &[n],
                        (0..n)
                            .map(|i| {
                                ((r as u64 * 31 + s * 7 + i as u64) % 97) as f32 * 0.013
                                    - 0.4
                            })
                            .collect(),
                    )
                    .unwrap()
                };
                let grads: Vec<TensorF32> =
                    vec![mk(130, 1), mk(7, 2), mk(64, 3), mk(3, 4), mk(200, 5)];
                let tags = [World, None, DataParallel, World, World];
                let dp = if r < 2 { vec![0, 1] } else { vec![2, 3] };
                let mut blocking = GradSync::world(4, mode);
                blocking.dp_group = dp.clone();
                let mut overlapped = GradSync::world(4, mode);
                overlapped.dp_group = dp;
                overlapped.overlap = true;
                overlapped.bucket_bytes = 256; // force several world buckets
                let mut a = grads.clone();
                blocking.sync(&mut h, &mut a, &tags)?;
                let mut b = grads;
                overlapped.sync(&mut h, &mut b, &tags)?;
                for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                    assert_eq!(
                        x.data, y.data,
                        "mode {mode:?} tensor {i}: overlapped sync changed bits"
                    );
                }
                Ok(())
            });
            got.unwrap();
        }
    }
}
