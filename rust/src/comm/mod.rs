//! Collective communication substrate (the NCCL analog).
//!
//! Two backends share one [`Comm`] trait whose collectives are built
//! from point-to-point sends, exactly as the paper describes for its
//! global exchanges:
//!
//! * [`CommHandle`] — thread-backed channels (one process, used by the
//!   benches so timing isn't polluted by the kernel's socket stack);
//! * [`tcp::TcpGroup`] — real sockets over a full mesh, usable across
//!   processes and hosts (the paper's "multiple GPUs on multiple
//!   nodes" topology; `fastmoe dist-moe --backend tcp` spawns worker
//!   *processes*).
//!
//! Provided collectives:
//!
//! * [`Comm::all_to_all_v`] — the Figure-2 protocol: phase 1 exchanges
//!   per-peer *counts*, receivers size their buffers, phase 2 exchanges
//!   the data.
//! * [`Comm::all_reduce_sum`] — ring all-reduce (reduce-scatter +
//!   all-gather), the gradient-sync primitive.
//! * `all_gather`, `broadcast`, `barrier`, subgroup all-reduce.
//!
//! Every handle records bytes sent per collective, which
//! [`crate::sim::NetModel`] converts into simulated wire time for the
//! Figure-6 scalability study.

pub mod tcp;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

use crate::error::{Error, Result};
use crate::metrics::Counters;

/// A tagged point-to-point message.
pub(crate) struct Msg {
    pub src: usize,
    pub tag: u64,
    pub data: Vec<f32>,
}

/// The process-group interface: p2p primitives required, collectives
/// provided (identical across backends).
pub trait Comm {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;
    fn counters(&mut self) -> &mut Counters;

    /// Send `data` to `dst` under `tag` (non-blocking or buffered).
    fn send(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<()>;

    /// Blocking receive of the message with (src, tag); out-of-order
    /// arrivals must be parked, not dropped.
    fn recv(&mut self, src: usize, tag: u64) -> Result<Vec<f32>>;

    /// Monotonic per-handle collective sequence number (tag namespace).
    fn next_seq(&mut self) -> u64;

    /// Synchronisation barrier. Default: an empty all-to-all (every
    /// pair exchanges a count) — O(n²) messages but always correct.
    fn barrier(&mut self) -> Result<()> {
        let empties: Vec<Vec<f32>> = (0..self.size()).map(|_| Vec::new()).collect();
        let _ = self.all_to_all_v(empties)?;
        Ok(())
    }

    /// Variable all-to-all (Figure 2): `send[p]` goes to peer `p`; the
    /// return value's `recv[p]` came from peer `p`.
    ///
    /// Phase 1 exchanges the lengths (the paper's "exchange the size of
    /// expert inputs"), phase 2 the payloads. Counters record both.
    fn all_to_all_v(&mut self, send: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let size = self.size();
        let rank = self.rank();
        if send.len() != size {
            return Err(Error::Comm(format!(
                "all_to_all_v: {} buffers for {} peers",
                send.len(),
                size
            )));
        }
        let seq = self.next_seq();
        let tag_count = seq << 8;
        let tag_data = (seq << 8) | 1;
        self.counters().add("a2a_calls", 1);

        // Phase 1: counts.
        for p in 0..size {
            if p != rank {
                self.send(p, tag_count, vec![send[p].len() as f32])?;
            }
        }
        let mut incoming = vec![0usize; size];
        incoming[rank] = send[rank].len();
        for p in 0..size {
            if p != rank {
                let c = self.recv(p, tag_count)?;
                incoming[p] = c[0] as usize;
            }
        }
        self.counters()
            .add("a2a_count_bytes", (4 * (size - 1)) as u64);

        // Phase 2: payloads ("the workers start exchanging data directly").
        let mut out: Vec<Vec<f32>> = (0..size).map(|_| Vec::new()).collect();
        let mut send = send;
        out[rank] = std::mem::take(&mut send[rank]);
        let mut data_bytes = 0u64;
        for p in 0..size {
            if p != rank {
                let buf = std::mem::take(&mut send[p]);
                data_bytes += (buf.len() * 4) as u64;
                self.send(p, tag_data, buf)?;
            }
        }
        self.counters().add("a2a_data_bytes", data_bytes);
        for p in 0..size {
            if p != rank {
                let data = self.recv(p, tag_data)?;
                if data.len() != incoming[p] {
                    return Err(Error::Comm(format!(
                        "a2a: peer {p} announced {} floats, sent {}",
                        incoming[p],
                        data.len()
                    )));
                }
                out[p] = data;
            }
        }
        Ok(out)
    }

    /// Ring all-reduce (sum): reduce-scatter then all-gather, the
    /// standard 2(n-1)/n-bandwidth algorithm NCCL uses.
    fn all_reduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        let n = self.size();
        let rank = self.rank();
        if n == 1 {
            return Ok(());
        }
        let seq = self.next_seq();
        self.counters().add("allreduce_calls", 1);
        self.counters()
            .add("allreduce_bytes", (buf.len() * 4 * 2 * (n - 1) / n) as u64);
        let len = buf.len();
        let chunk = |i: usize| -> std::ops::Range<usize> {
            let per = len / n;
            let s = i * per;
            let e = if i + 1 == n { len } else { s + per };
            s..e
        };
        let next = (rank + 1) % n;
        let prev = (rank + n - 1) % n;

        // Reduce-scatter.
        for step in 0..n - 1 {
            let send_idx = (rank + n - step) % n;
            let recv_idx = (rank + n - step - 1) % n;
            let tag = (seq << 8) | (2 + step as u64);
            self.send(next, tag, buf[chunk(send_idx)].to_vec())?;
            let data = self.recv(prev, tag)?;
            for (x, y) in buf[chunk(recv_idx)].iter_mut().zip(&data) {
                *x += y;
            }
        }
        // All-gather.
        for step in 0..n - 1 {
            let send_idx = (rank + 1 + n - step) % n;
            let recv_idx = (rank + n - step) % n;
            let tag = (seq << 8) | (64 + step as u64);
            self.send(next, tag, buf[chunk(send_idx)].to_vec())?;
            let data = self.recv(prev, tag)?;
            buf[chunk(recv_idx)].copy_from_slice(&data);
        }
        Ok(())
    }

    /// All-reduce over a subgroup (data-parallel groups). `group` must
    /// contain this rank and be identical on all members.
    fn all_reduce_sum_group(&mut self, buf: &mut [f32], group: &[usize]) -> Result<()> {
        if group.len() <= 1 {
            return Ok(());
        }
        let rank = self.rank();
        let me = group
            .iter()
            .position(|&r| r == rank)
            .ok_or_else(|| Error::Comm("rank not in group".into()))?;
        let seq = self.next_seq();
        self.counters().add(
            "allreduce_bytes",
            (buf.len() * 4 * 2 * (group.len() - 1) / group.len()) as u64,
        );
        // gather onto group[0], sum, broadcast back
        let tag = (seq << 8) | 7;
        if me == 0 {
            let mut acc = buf.to_vec();
            for &p in &group[1..] {
                let data = self.recv(p, tag)?;
                for (x, y) in acc.iter_mut().zip(&data) {
                    *x += y;
                }
            }
            for &p in &group[1..] {
                self.send(p, tag + 1, acc.clone())?;
            }
            buf.copy_from_slice(&acc);
        } else {
            self.send(group[0], tag, buf.to_vec())?;
            let data = self.recv(group[0], tag + 1)?;
            buf.copy_from_slice(&data);
        }
        Ok(())
    }

    /// Gather equal-size buffers from all ranks (concatenated by rank).
    fn all_gather(&mut self, mine: &[f32]) -> Result<Vec<f32>> {
        let send: Vec<Vec<f32>> = (0..self.size()).map(|_| mine.to_vec()).collect();
        let parts = self.all_to_all_v(send)?;
        let mut out = Vec::with_capacity(mine.len() * self.size());
        for p in parts {
            if p.len() != mine.len() {
                return Err(Error::Comm("all_gather: ragged input".into()));
            }
            out.extend_from_slice(&p);
        }
        Ok(out)
    }

    /// Broadcast from `root` (everyone returns root's buffer).
    fn broadcast(&mut self, buf: &mut Vec<f32>, root: usize) -> Result<()> {
        let seq = self.next_seq();
        let tag = (seq << 8) | 9;
        if self.rank() == root {
            for p in 0..self.size() {
                if p != root {
                    self.send(p, tag, buf.clone())?;
                }
            }
        } else {
            *buf = self.recv(root, tag)?;
        }
        Ok(())
    }
}

/// One worker's endpoint into a thread-backed (single-process) group.
pub struct CommHandle {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Msg>>,
    receiver: Receiver<Msg>,
    /// Messages that arrived out of order (wrong tag/src), parked.
    parked: Vec<Msg>,
    barrier: Arc<Barrier>,
    seq: u64,
    pub counters: Counters,
}

/// Create a local (thread-backed) group of `size` workers.
pub fn local_group(size: usize) -> Vec<CommHandle> {
    assert!(size > 0);
    let mut senders = Vec::with_capacity(size);
    let mut receivers = Vec::with_capacity(size);
    for _ in 0..size {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    let barrier = Arc::new(Barrier::new(size));
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, receiver)| CommHandle {
            rank,
            size,
            senders: senders.clone(),
            receiver,
            parked: Vec::new(),
            barrier: barrier.clone(),
            seq: 0,
            counters: Counters::new(),
        })
        .collect()
}

impl Comm for CommHandle {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn counters(&mut self) -> &mut Counters {
        &mut self.counters
    }

    fn send(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<()> {
        self.counters.add("bytes_sent", (data.len() * 4) as u64);
        self.senders[dst]
            .send(Msg { src: self.rank, tag, data })
            .map_err(|_| Error::Comm(format!("peer {dst} hung up")))
    }

    fn recv(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        if let Some(i) = self
            .parked
            .iter()
            .position(|m| m.src == src && m.tag == tag)
        {
            return Ok(self.parked.swap_remove(i).data);
        }
        loop {
            let msg = self
                .receiver
                .recv()
                .map_err(|_| Error::Comm("channel closed".into()))?;
            if msg.src == src && msg.tag == tag {
                return Ok(msg.data);
            }
            self.parked.push(msg);
        }
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Threads share an OS barrier — cheaper than the message fallback.
    fn barrier(&mut self) -> Result<()> {
        self.barrier.wait();
        Ok(())
    }
}

/// Spawn `size` workers, run `f(handle)` on each, join, propagate errors.
pub fn run_workers<T, F>(size: usize, f: F) -> Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(CommHandle) -> Result<T> + Send + Sync + 'static,
{
    let handles = local_group(size);
    let f = Arc::new(f);
    let mut joins = Vec::new();
    for h in handles {
        let f = f.clone();
        let rank = h.rank;
        joins.push((
            rank,
            std::thread::Builder::new()
                .name(format!("worker-{rank}"))
                .spawn(move || f(h))
                .expect("spawn"),
        ));
    }
    let mut out = Vec::with_capacity(size);
    for (rank, j) in joins {
        match j.join() {
            Ok(Ok(v)) => out.push(v),
            Ok(Err(e)) => {
                return Err(Error::Worker { rank, msg: e.to_string() })
            }
            Err(_) => {
                return Err(Error::Worker { rank, msg: "panicked".into() })
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert, prop_assert_eq, PropResult};

    #[test]
    fn all_to_all_v_routes_correctly() {
        let out = run_workers(4, |mut h| {
            let r = h.rank() as f32;
            // send [r, p] to each peer p
            let send: Vec<Vec<f32>> =
                (0..4).map(|p| vec![r, p as f32]).collect();
            let recv = h.all_to_all_v(send)?;
            for (p, buf) in recv.iter().enumerate() {
                assert_eq!(buf, &vec![p as f32, r]);
            }
            Ok(())
        });
        out.unwrap();
    }

    #[test]
    fn all_to_all_v_variable_sizes() {
        run_workers(3, |mut h| {
            let r = h.rank();
            // rank r sends r+p floats to peer p
            let send: Vec<Vec<f32>> =
                (0..3).map(|p| vec![1.0; r + p]).collect();
            let recv = h.all_to_all_v(send)?;
            for (p, buf) in recv.iter().enumerate() {
                assert_eq!(buf.len(), p + r);
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn ring_all_reduce_sums() {
        for n in [1, 2, 3, 4, 8] {
            run_workers(n, move |mut h| {
                let mut buf: Vec<f32> =
                    (0..37).map(|i| (h.rank() * 100 + i) as f32).collect();
                let want: Vec<f32> = (0..37)
                    .map(|i| {
                        (0..n).map(|r| (r * 100 + i) as f32).sum::<f32>()
                    })
                    .collect();
                h.all_reduce_sum(&mut buf)?;
                assert_eq!(buf, want, "n={n}");
                Ok(())
            })
            .unwrap();
        }
    }

    #[test]
    fn subgroup_all_reduce() {
        run_workers(4, |mut h| {
            let group: Vec<usize> = if h.rank() % 2 == 0 {
                vec![0, 2]
            } else {
                vec![1, 3]
            };
            let mut buf = vec![h.rank() as f32 + 1.0; 5];
            h.all_reduce_sum_group(&mut buf, &group)?;
            let want = if h.rank() % 2 == 0 { 4.0 } else { 6.0 }; // 1+3 / 2+4
            assert!(buf.iter().all(|&x| x == want));
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn all_gather_concatenates() {
        run_workers(3, |mut h| {
            let mine = vec![h.rank() as f32; 2];
            let all = h.all_gather(&mine)?;
            assert_eq!(all, vec![0., 0., 1., 1., 2., 2.]);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn broadcast_from_each_root() {
        run_workers(3, |mut h| {
            for root in 0..3 {
                let mut buf = if h.rank() == root {
                    vec![root as f32 * 10.0; 4]
                } else {
                    vec![]
                };
                h.broadcast(&mut buf, root)?;
                assert_eq!(buf, vec![root as f32 * 10.0; 4]);
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn worker_error_propagates_with_rank() {
        let res = run_workers(3, |h| {
            if h.rank() == 1 {
                Err(Error::msg("boom"))
            } else {
                Ok(())
            }
        });
        match res {
            Err(Error::Worker { rank: 1, msg }) => assert!(msg.contains("boom")),
            other => panic!("expected worker error, got {other:?}"),
        }
    }

    #[test]
    fn prop_all_reduce_equals_sequential_sum() {
        check("ring all-reduce = sum", 20, |g| {
            let n = *g.choose(&[1usize, 2, 3, 4, 5, 8]);
            let len = g.usize_in(1, 200);
            let data: Vec<Vec<f32>> = (0..n)
                .map(|_| g.vec_f32(len, -8.0, 8.0))
                .collect();
            let want: Vec<f32> = (0..len)
                .map(|i| data.iter().map(|d| d[i]).sum())
                .collect();
            let data2 = data.clone();
            let got = run_workers(n, move |mut h| {
                let mut buf = data2[h.rank()].clone();
                h.all_reduce_sum(&mut buf)?;
                Ok(buf)
            })
            .map_err(|e| e.to_string())?;
            for r in 0..n {
                for i in 0..len {
                    prop_assert(
                        (got[r][i] - want[i]).abs() < 1e-3,
                        format!("rank {r} idx {i}: {} vs {}", got[r][i], want[i]),
                    )?;
                }
            }
            Ok(()) as PropResult
        });
    }

    #[test]
    fn prop_all_to_all_conserves_floats() {
        check("a2a conserves data", 20, |g| {
            let n = *g.choose(&[2usize, 3, 4]);
            let sizes: Vec<Vec<usize>> = (0..n)
                .map(|_| g.vec_usize(n, 0, 50))
                .collect();
            let sizes2 = sizes.clone();
            let got = run_workers(n, move |mut h| {
                let r = h.rank();
                let send: Vec<Vec<f32>> = (0..n)
                    .map(|p| vec![(r * n + p) as f32; sizes2[r][p]])
                    .collect();
                let total_sent: usize = send.iter().map(|b| b.len()).sum();
                let recv = h.all_to_all_v(send)?;
                // payload correctness: from peer p we see value p*n+r
                for (p, buf) in recv.iter().enumerate() {
                    for &v in buf {
                        if v != (p * n + r) as f32 {
                            return Err(Error::Comm("wrong payload".into()));
                        }
                    }
                }
                let total_recv: usize = recv.iter().map(|b| b.len()).sum();
                Ok((total_sent, total_recv))
            })
            .map_err(|e| e.to_string())?;
            let sent: usize = got.iter().map(|(s, _)| s).sum();
            let recv: usize = got.iter().map(|(_, r)| r).sum();
            prop_assert_eq(sent, recv)
        });
    }
}
