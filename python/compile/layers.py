"""Layer-2 building blocks: attention, layernorm, MoE-FFN, dense FFN.

This module is the JAX analog of FastMoE's ``FMoETransformerMLP`` plus
the surrounding Megatron-style transformer block.  The MoE FFN composes
the Layer-1 Pallas kernels (gate GEMM -> scatter -> grouped expert FFN ->
weighted combine) around a GShard-style capacity-bounded top-k dispatch.

Everything here is build-time python: ``aot.py`` lowers jitted closures
of these functions to HLO text once, and the Rust runtime replays them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import combine_rows, expert_ffn, gate_scores, scatter_rows
from .kernels.ref import topk_gate_ref


# ---------------------------------------------------------------------------
# Plain transformer pieces (jnp — XLA fuses these well; the paper's
# hot-spot, and our Pallas budget, is the MoE FFN).
# ---------------------------------------------------------------------------

def layernorm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def causal_attention(x, wqkv, bqkv, wo, bo, n_head: int):
    """Multi-head causal self-attention over ``x: [seq, d_m]``."""
    seq, d_m = x.shape
    d_head = d_m // n_head
    qkv = x @ wqkv + bqkv                      # [seq, 3*d_m]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(seq, n_head, d_head).transpose(1, 0, 2)

    q, k, v = heads(q), heads(k), heads(v)     # [h, seq, d_head]
    att = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(d_head))
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    att = jnp.where(mask[None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", att, v)   # [h, seq, d_head]
    out = out.transpose(1, 0, 2).reshape(seq, d_m)
    return out @ wo + bo


# ---------------------------------------------------------------------------
# MoE dispatch (GShard-style capacity-bounded top-k) — pure jnp index math;
# the data movement it parameterises is done by the Pallas kernels.
# ---------------------------------------------------------------------------

def moe_dispatch(idx, n_e: int, capacity: int):
    """Build scatter/combine index maps from top-k expert assignments.

    Args:
      idx: ``[n_b, k]`` int32 expert ids per token (top-k order).
      n_e: number of experts; capacity: max rows per expert.

    Returns:
      ``src``   ``[n_e * capacity]`` int32: source token per slot, -1 pad.
      ``slots`` ``[n_b, k]`` int32: slot per assignment, OOB when dropped.

    Within one expert, slots are granted in token order (token 0 first),
    matching the Rust ``DispatchPlan`` and the paper's drop policy.
    """
    n_b, k = idx.shape
    n_slots = n_e * capacity
    flat_e = idx.reshape(-1)                                   # [n_b*k]
    onehot = (flat_e[:, None] == jnp.arange(n_e)[None, :]).astype(jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - 1                      # [n_b*k, n_e]
    pos_in_e = jnp.take_along_axis(rank, flat_e[:, None], axis=1)[:, 0]
    kept = pos_in_e < capacity
    slot = flat_e * capacity + pos_in_e                        # valid iff kept
    slots = jnp.where(kept, slot, n_slots).astype(jnp.int32).reshape(n_b, k)

    token_of_flat = (jnp.arange(n_b * k) // k).astype(jnp.int32)
    src = jnp.full((n_slots + 1,), -1, jnp.int32)
    src = src.at[jnp.where(kept, slot, n_slots)].set(
        jnp.where(kept, token_of_flat, -1), mode="drop"
    )[:n_slots]
    return src, slots


_BIG = 1 << 30  # block size covering any dim: single-grid-step kernels


def moe_ffn(x, wg, bg, w1, b1, w2, b2, *, k: int, capacity: int,
            interpret: bool = True, fast: bool = True):
    """The FastMoE MoE-FFN over a flat token batch ``x: [n_b, d_m]``.

    gate GEMM (L1) -> softmax/top-k -> dispatch -> scatter (L1) ->
    grouped expert FFN (L1) -> weighted combine (L1).

    ``fast=True`` lowers the kernels with whole-array blocks (one grid
    step): the right configuration for the CPU PJRT backend, where
    interpret-mode pallas pays ~10 ms of callback machinery per grid
    step (EXPERIMENTS.md §Perf).  ``fast=False`` keeps the tiled TPU
    BlockSpecs (DESIGN.md §7).
    """
    n_b, d_m = x.shape
    n_e = wg.shape[1]
    k = min(k, n_e)  # e.g. the fig-5 n_e=1 point degenerates to top-1
    br = _BIG if fast else 128
    scores = gate_scores(x, wg, bg, block_rows=br, interpret=interpret)
    w, idx = topk_gate_ref(scores, k)
    src, slots = moe_dispatch(idx, n_e, capacity)
    xs = scatter_rows(x, src, n_slots=n_e * capacity, block_rows=br,
                      interpret=interpret)
    ys = expert_ffn(xs.reshape(n_e, capacity, d_m), w1, b1, w2, b2,
                    interpret=interpret, whole=fast)
    return combine_rows(ys.reshape(n_e * capacity, d_m), slots, w,
                        block_rows=br, interpret=interpret)


def naive_moe_ffn(x, wg, bg, w1, b1, w2, b2, *, k: int):
    """The Rau-(2019)-style baseline: no batched dispatch, no kernels.

    Every expert runs over the *whole* batch and the result is masked by
    the gate weights — the straightforward "pure framework ops" MoE that
    the paper benchmarks against in Figure 5.  Cost grows linearly with
    the number of experts regardless of how few tokens each receives.
    """
    n_e = wg.shape[1]
    k = min(k, n_e)
    scores = x.astype(jnp.float32) @ wg.astype(jnp.float32) + bg
    w, idx = topk_gate_ref(scores, k)
    # dense [n_b, n_e] gate weight matrix (0 where an expert is unselected)
    full_w = jnp.zeros((x.shape[0], n_e), jnp.float32).at[
        jnp.arange(x.shape[0])[:, None], idx
    ].set(w)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for e in range(n_e):  # deliberate python loop == sequential experts
        h = jax.nn.gelu(x.astype(jnp.float32) @ w1[e] + b1[e])
        ye = h @ w2[e] + b2[e]
        out = out + full_w[:, e : e + 1] * ye
    return out.astype(x.dtype)


def dense_ffn(x, w1, b1, w2, b2):
    """Plain transformer FFN (the non-MoE baseline of §5.4)."""
    h = jax.nn.gelu(x.astype(jnp.float32) @ w1.astype(jnp.float32) + b1)
    return (h @ w2.astype(jnp.float32) + b2).astype(x.dtype)


def moe_ffn_with_aux(x, wg, bg, w1, b1, w2, b2, *, k: int, capacity: int,
                     interpret: bool = True, fast: bool = True):
    """MoE FFN that also returns the GShard auxiliary balance loss.

    The paper lists load-balance loss support as future work (§6); this
    implements it: ``aux = n_e · Σ_e f_e · p_e`` where ``f_e`` is the
    fraction of assignments routed to expert e and ``p_e`` the mean
    softmax gate probability of e.  Minimised (=1) at a uniform load.
    """
    n_b, _ = x.shape
    n_e = wg.shape[1]
    k = min(k, n_e)
    br = _BIG if fast else 128
    scores = gate_scores(x, wg, bg, block_rows=br, interpret=interpret)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    w, idx = topk_gate_ref(scores, k)
    src, slots = moe_dispatch(idx, n_e, capacity)

    # f_e from the (non-differentiable) routing counts; p_e carries grads
    counts = jnp.sum(
        (idx.reshape(-1)[:, None] == jnp.arange(n_e)[None, :]).astype(jnp.float32),
        axis=0,
    )
    f = counts / jnp.maximum(1.0, jnp.sum(counts))
    p = jnp.mean(probs, axis=0)
    aux = n_e * jnp.sum(jax.lax.stop_gradient(f) * p)

    xs = scatter_rows(x, src, n_slots=n_e * capacity, block_rows=br,
                      interpret=interpret)
    ys = expert_ffn(xs.reshape(n_e, capacity, x.shape[1]), w1, b1, w2, b2,
                    interpret=interpret, whole=fast)
    y = combine_rows(ys.reshape(n_e * capacity, x.shape[1]), slots, w,
                     interpret=interpret)
    return y, aux


def capacity_for(n_b: int, k: int, n_e: int, factor: float = 1.25) -> int:
    """GShard capacity rule, rounded up to a multiple of 8 (sublanes)."""
    cap = int((n_b * k / n_e) * factor + 0.999)
    return max(8, (cap + 7) // 8 * 8)
