//! # fastmoe — a fast Mixture-of-Expert training system (reproduction)
//!
//! A from-scratch reproduction of *FastMoE: A Fast Mixture-of-Expert
//! Training System* (He et al., 2021) on a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time)** — Pallas kernels and JAX model graphs in
//!   `python/compile`, lowered once to `artifacts/*.hlo.txt`.
//! * **Layer 3 (this crate)** — the training system itself: the PJRT
//!   runtime that executes the AOT artifacts, the collective
//!   communication substrate, the expert-parallel dispatch machinery
//!   (Figure 2 of the paper), the heterogeneity-aware gradient
//!   synchronizer, the data pipeline, and the training loop.
//!
//! Python is never on the iteration path: once artifacts are built, the
//! `fastmoe` binary (and the examples) are self-contained.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`runtime`] | PJRT client + artifact registry + executable cache; [`runtime::Executable::run_refs`] executes from *borrowed* host tensors (no owned-argument staging clone) |
//! | [`comm`] | process groups: nonblocking `isend`/`irecv` + [`comm::CommRequest`] handles, decomposed all-to-all-v (consume arrivals as they land), bucketed nonblocking all-reduce ([`comm::Comm::all_reduce_start`] → [`comm::PendingAllReduce`], per-bucket rings completed in arrival order, bit-identical to the blocking ring; since PR 9 also the ZeRO schedule [`comm::Comm::all_reduce_zero`] — the same rings paused at their reduce-scatter midpoint ([`comm::PendingAllReduce::wait_bucket_shard`]) so a trainer can run shard-local Adam before the all-gather half carries the *updated parameters*, rail-aware across nodes under a hierarchical topology), spent-send reclaim + receive-buffer recycle ([`comm::Comm::recycle`]) for buffer pools, dissemination barrier, death-aware thread-channel receives (a crashed worker errors its peers instead of deadlocking them); the TCP backend's *progress engine* drains socket arrivals during expert compute, completes `wait_all` in true arrival order, and reads frames into recycled buffers (allocation-free receive path), while its deferred-flush mode keeps liveness with keepalive probe frames; the **topology layer** ([`comm::Topology`] + [`comm::Comm::split`] → [`comm::ProcessGroup`] sub-groups with their own rank/size/tag namespaces, on which every collective runs unchanged) and the policy wrapper [`comm::TopoComm`] (`[comm] topology = "hier"`: leader-aggregated all-to-all, two-level tree all-reduce as an alternate schedule under `PendingAllReduce`) |
//! | [`moe`] | the §3.1 hierarchy: [`moe::Gate`] policies (top-k / switch / noisy top-k, with the wired balance-loss gradient), [`moe::ExpertShard`] shards (FFN), over the fixed dispatch substrate (plans, ring-offset exchange chunks — locality-ordered under a hierarchical topology ([`moe::chunk_peer_groups_topo`]), slice-view chunk staging ([`moe::ChunkSlice`]), capacity buckets, adaptive chunk picking with the mean/max agreement policies ([`moe::agree_chunks`]), load monitor, balance loss) |
//! | [`coordinator`] | workers, the distributed MoE layer + [`coordinator::MoeLayerBuilder`] (assembles gate/expert from `[moe]`, exchange schedule from `[comm]` — blocking, or zero-copy chunked dispatch/compute/combine overlap with the count round folded into chunk 0 and a step-persistent buffer pool), tag-aware [`coordinator::GradSync`] (blocking, or `[comm] grad_overlap`: bucketed nonblocking sync — gate-grad buckets fly during the expert backward, `DistTrainer` pipelines bucket completions against host Adam; bit-identical either way; or `[comm] grad_shard = "zero"`: the ZeRO-sharded optimizer — reduce-scatter, shard-local Adam on ~1/workers of the state, all-gather of updated params, bit-identical to replicated Adam), train loops |
//! | [`serve`] | the `fastmoe serve` inference daemon: a rank-0 front end (TCP listener speaking the mesh frame format to lightweight client sessions) feeding a continuous-batching [`serve::Batcher`] (per-step `max_batch` admission, bounded `queue_depth`, explicit rejections), resident [`coordinator::ServeLoop`] workers on the forward-only zero-copy path, per-request latency [`metrics::Histogram`]s, and a thin [`serve::ClientConn`] for load generation |
//! | [`placement`] | dynamic expert placement (§6 "future work", closed-loop): [`placement::PlacementPlan`] (expert → owner + shadow replicas, plan-aware routing for [`moe::DispatchPlan::build_routed`]), the pure rank-symmetric [`placement::decide`] policy (`[placement] policy = "shadow" \| "migrate"`), and the [`placement::Rebalancer`] driving it from windowed load counts over an all-reduce — executed between steps by [`coordinator::DistMoeLayer::apply_delta`] (shadow replication with owner-broadcast Adam mirroring, or checkpoint-format expert migration with its optimiser state) |
//! | [`autotune`] | online autotuning (closes the paper's co-design loop): [`autotune::Calibrator`] fits the α-β [`sim::NetModel`] from a few instrumented steps (scoped phase timers + byte counters over a [`metrics::Counters::delta_since`] window, α pinned to the preset for identifiability, fit rank-agreed by an all-reduce mean), the pure deterministic [`autotune::search`] ranks the discrete `[comm]` knob lattice (chunks × chunk_policy × bucket_kb × flat/hier × overlap/grad_overlap/grad_shard) with the fitted model, and the [`autotune::Autotuner`] state machine drives `[auto]` at step boundaries — `apply = "report"` prints the winner as a pasteable `[comm]` snippet, `apply = "live"` applies the step-boundary-safe knobs in lockstep and re-calibrates when measured step time drifts past `retune_drift` |
//! | [`fault`] | elastic fault recovery: dissemination-gossip membership agreement over the reserved [`fault::FAULT_TAG`] band, the `[fault] recover = "abort" \| "degrade" \| "rejoin"` policy (quarantine-zombie degraded mode with shadow-replica failover + score-masked zero-weight drops, checkpoint/peer-transfer rejoin), and the deterministic [`fault::ChaosSchedule`] harness (`kill@N:rR`, `delay@N:rR:MS`, `rejoin@N:rR`) fired at step boundaries by [`fault::Recovery::poll`] on both backends |
//! | [`model`] | parameter store, Adam, checkpoints (+ the expert-slot pack/unpack wire format migrations and replicas ride on, and the atomic tmp+rename named-tensor files the periodic `[fault] ckpt_interval` checkpoints use) |
//! | [`data`] | synthetic corpus, tokenizer, batching |
//! | [`tensor`] | host tensors, the step-persistent [`tensor::BufferPool`] arena, and the math used outside XLA |
//! | [`sim`] | analytic network timing model (IB EDR / PCIe presets; scores overlapped steps as max(wire, compute) per chunk, a host bytes-copied + allocation cost term for the zero-copy study, the bucketed grad-sync pipeline vs the serial blocking trainer tail, and a second intra-node link (`alpha_local`/`beta_local`) with `*_hier` step variants + the [`sim::NetModel::hier_favourable`] regime predicate for the flat-vs-hier study) |
//! | [`config`], [`cli`], [`metrics`], [`bench`], [`testing`], [`rng`], [`util`] | substrates (no external deps available offline) |

pub mod autotune;
pub mod bench;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod fault;
pub mod metrics;
pub mod model;
pub mod moe;
pub mod placement;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tensor;
pub mod testing;
pub mod util;

pub use error::{Error, Result};
