//! Analytic α-β network timing model.
//!
//! The paper's testbed is 8 nodes × 1 V100 over Infiniband EDR.  Our
//! in-process channels move data at memcpy speed, so for the Figure-6
//! scalability study we account *simulated wire time* for each
//! collective with the classic latency/bandwidth (α-β) model:
//!
//!   t(message of b bytes) = α + b / β
//!
//! All-to-all across `n` workers sends `n-1` messages per worker in
//! parallel network directions; with a non-blocking switch (the paper's
//! EDR switch + 8 HCAs) each worker's egress is the bottleneck:
//!
//!   t_a2a = α·(n-1) + (bytes_sent_by_worker) / β
//!
//! Ring all-reduce of `s` bytes: 2(n-1) steps of s/n bytes each.
//!
//! Overlapped MoE steps (the `[comm] overlap` pipeline) are scored as
//! `max(wire, compute)` per chunk with fill/drain ends — see
//! [`NetModel::moe_step_overlapped`] vs the blocking
//! [`NetModel::moe_step_blocking`] — so Figure 6 reflects the win of
//! hiding the global exchange behind expert computation.  The
//! trainers' gradient sync is scored the same way:
//! [`NetModel::grad_step_overlapped`] pipelines bucketed ring
//! all-reduces against backward compute and the host optimiser,
//! degenerating to the serial [`NetModel::grad_step_blocking`] at one
//! bucket.
//!
//! Topology: the model carries a second, *intra-node* link
//! (`alpha_local` / `beta_local` — NVLink class against the NIC), and
//! every step has a `*_hier` variant scoring the node-aware policies
//! (`[comm] topology = "hier"`): leader-aggregated all-to-all
//! ([`NetModel::all_to_all_hier`]) and the two-level tree all-reduce
//! ([`NetModel::all_reduce_hier`]).  [`NetModel::hier_favourable`]
//! names the regime — inter-node bandwidth the bottleneck — in which
//! hier ≤ flat holds at every byte count (unit-tested, and asserted by
//! the fig-6 bench at every scale point in that regime).

/// Preset link parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetPreset {
    /// Infiniband EDR: 100 Gb/s ≈ 12.5 GB/s, ~1.5 µs MPI-level latency.
    IbEdr,
    /// PCIe 3.0 x16 host link: ~12 GB/s but higher software latency.
    Pcie3,
    /// Infinite network (disable simulated wire time).
    None,
}

impl NetPreset {
    pub fn parse(s: &str) -> Option<NetPreset> {
        match s {
            "ib-edr" | "ib_edr" | "ib" => Some(NetPreset::IbEdr),
            "pcie3" | "pcie" => Some(NetPreset::Pcie3),
            "none" | "infinite" => Some(NetPreset::None),
            _ => None,
        }
    }
}

/// The α-β model with per-collective helpers, plus a *host* cost term
/// for the zero-copy study: staging copies move at `host_beta`
/// (memcpy) and fresh padded allocations at `alloc_beta` (allocate +
/// zero, slower than memcpy), so a schedule that copies or allocates
/// more per step scores measurably worse even when its wire time is
/// identical — the difference the PR-3 zero-copy hot path eliminates.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// Per-message latency of the *inter-node* link, seconds.
    pub alpha: f64,
    /// Bandwidth of the *inter-node* link, bytes/second.
    pub beta: f64,
    /// Per-message latency of the intra-node link (NVLink/shared
    /// memory class — the hierarchical policies' fast lane), seconds.
    pub alpha_local: f64,
    /// Bandwidth of the intra-node link, bytes/second.
    pub beta_local: f64,
    /// Host memcpy bandwidth for staging copies, bytes/second.
    pub host_beta: f64,
    /// Effective allocate-and-zero bandwidth for fresh padded buffers,
    /// bytes/second.
    pub alloc_beta: f64,
    pub enabled: bool,
}

impl NetModel {
    pub fn preset(p: NetPreset) -> NetModel {
        match p {
            NetPreset::IbEdr => NetModel {
                alpha: 1.5e-6,
                beta: 12.5e9,
                // NVLink-class intra-node lane: ~300 GB/s, sub-µs
                alpha_local: 0.4e-6,
                beta_local: 300.0e9,
                host_beta: 16.0e9,
                alloc_beta: 6.0e9,
                enabled: true,
            },
            NetPreset::Pcie3 => NetModel {
                alpha: 5.0e-6,
                beta: 12.0e9,
                // intra-host PCIe switch: faster than the NIC, but not
                // by the margin the hier policies need at scale
                alpha_local: 2.0e-6,
                beta_local: 64.0e9,
                host_beta: 16.0e9,
                alloc_beta: 6.0e9,
                enabled: true,
            },
            NetPreset::None => NetModel {
                alpha: 0.0,
                beta: f64::INFINITY,
                alpha_local: 0.0,
                beta_local: f64::INFINITY,
                host_beta: f64::INFINITY,
                alloc_beta: f64::INFINITY,
                enabled: false,
            },
        }
    }

    /// Wire time of one point-to-point message.
    pub fn p2p(&self, bytes: usize) -> f64 {
        if !self.enabled {
            return 0.0;
        }
        self.alpha + bytes as f64 / self.beta
    }

    /// All-to-all among `n` workers where this worker sends
    /// `bytes_out` in total (egress-bound, non-blocking switch).
    pub fn all_to_all(&self, n: usize, bytes_out: usize) -> f64 {
        if !self.enabled || n <= 1 {
            return 0.0;
        }
        self.alpha * (n - 1) as f64 + bytes_out as f64 / self.beta
    }

    /// Ring all-reduce of a `bytes`-sized buffer among `n` workers.
    pub fn all_reduce(&self, n: usize, bytes: usize) -> f64 {
        if !self.enabled || n <= 1 {
            return 0.0;
        }
        let steps = 2 * (n - 1);
        let per_step = bytes as f64 / n as f64;
        steps as f64 * (self.alpha + per_step / self.beta)
    }

    /// One blocking MoE exchange+compute phase: the full all-to-all
    /// (`bytes_out` egress) strictly before `compute` seconds of
    /// expert work — the `chunks = 1` baseline the paper improves on.
    pub fn moe_step_blocking(&self, n: usize, bytes_out: usize, compute: f64) -> f64 {
        self.all_to_all(n, bytes_out) + compute
    }

    /// The same phase pipelined over `chunks` ring-offset peer groups:
    /// chunk `i+1`'s wire time hides behind chunk `i`'s compute (and
    /// vice versa), so steady state costs `max(wire, compute)` per
    /// chunk, plus one wire fill and one compute drain at the ends:
    ///
    /// ```text
    /// t = w + (C−1)·max(w, k) + k,   w = wire/C,  k = compute/C
    /// ```
    ///
    /// `chunks = 1` degenerates to [`NetModel::moe_step_blocking`]
    /// exactly; with both wire and compute nonzero and `chunks > 1`
    /// the pipelined time is strictly lower.
    pub fn moe_step_overlapped(
        &self,
        n: usize,
        bytes_out: usize,
        compute: f64,
        chunks: usize,
    ) -> f64 {
        if !self.enabled || n <= 1 {
            return compute;
        }
        let c = chunks.clamp(1, n) as f64;
        let wire_chunk =
            self.alpha * ((n - 1) as f64 / c) + bytes_out as f64 / self.beta / c;
        let comp_chunk = compute / c;
        wire_chunk + (c - 1.0) * wire_chunk.max(comp_chunk) + comp_chunk
    }

    /// One data-parallel trainer step with the *blocking* tail — the
    /// seed `DistTrainer` schedule: the whole backward, then the
    /// full-gradient ring all-reduce, then the host optimiser, all
    /// serial.
    pub fn grad_step_blocking(
        &self,
        n: usize,
        grad_bytes: usize,
        compute: f64,
        opt: f64,
    ) -> f64 {
        compute + self.all_reduce(n, grad_bytes) + opt
    }

    /// The same step with *bucketed, overlapped* gradient sync: the
    /// grads split into `B` buckets; bucket `i`'s ring launches as its
    /// grads materialise during backward and its host-optimiser update
    /// runs while later buckets are still on the wire — a three-stage
    /// pipeline with stage times `g = compute/B`, `w = ring(bytes/B)`,
    /// `a = opt/B`:
    ///
    /// ```text
    /// t(B) = g + w + a + (B−1)·max(g, w, a)
    /// ```
    ///
    /// Every extra bucket pays the ring's `2(n−1)·α` latency again, so
    /// the useful count is workload-dependent; like the runtime (whose
    /// `bucket_kb` knob merges small tensors into fewer, larger
    /// launches when latency dominates) the score takes the best
    /// `B ≤ buckets`.  `B = 1` is [`NetModel::grad_step_blocking`]
    /// exactly, so the overlapped score never exceeds the blocking one.
    ///
    /// This is the *idealized* pipeline bound for the schedule family:
    /// the implemented sync realises the round-0 launch overlap and
    /// per-bucket optimiser pipelining, but later ring rounds advance
    /// only inside waits (one outstanding round per bucket), so
    /// measured wins sit between this bound and blocking.
    pub fn grad_step_overlapped(
        &self,
        n: usize,
        grad_bytes: usize,
        compute: f64,
        opt: f64,
        buckets: usize,
    ) -> f64 {
        if !self.enabled || n <= 1 {
            return compute + opt;
        }
        let steps = 2 * (n - 1);
        let mut best = f64::INFINITY;
        for b in 1..=buckets.max(1) {
            let g = compute / b as f64;
            let a = opt / b as f64;
            let per_round = grad_bytes as f64 / b as f64 / n as f64;
            let w = steps as f64 * (self.alpha + per_round / self.beta);
            let t = g + w + a + (b as f64 - 1.0) * g.max(w).max(a);
            best = best.min(t);
        }
        best
    }

    /// Hierarchical all-to-all among `w` ranks in nodes of `l`
    /// ([`crate::comm::TopoComm`]'s hier policy): with uniform
    /// destinations, the intra share `(l−1)/(w−1)` of this rank's
    /// egress moves peer-to-peer on the local link, and the inter
    /// share is staged through the node leader (one local gather, one
    /// local scatter) to ride ONE leader exchange of `nodes−1`
    /// messages — the per-rank `α·(w−1)` and the intra bytes leave the
    /// inter link entirely, at the price of two local staging passes
    /// over the inter share.  `l = 1` degenerates to
    /// [`NetModel::all_to_all`] exactly.
    pub fn all_to_all_hier(&self, w: usize, l: usize, bytes_out: usize) -> f64 {
        if !self.enabled || w <= 1 {
            return 0.0;
        }
        if l <= 1 || w % l != 0 {
            return self.all_to_all(w, bytes_out);
        }
        if l >= w {
            // single node: all traffic on the local link
            return self.alpha_local * (w - 1) as f64
                + bytes_out as f64 / self.beta_local;
        }
        let nodes = w / l;
        let intra = bytes_out as f64 * (l - 1) as f64 / (w - 1) as f64;
        let inter = bytes_out as f64 - intra;
        let local = self.alpha_local * (l - 1) as f64 + intra / self.beta_local;
        let staging = 2.0 * (self.alpha_local + inter / self.beta_local);
        let leader = self.alpha * (nodes - 1) as f64 + inter / self.beta;
        local + staging + leader
    }

    /// Two-level tree all-reduce (the hier schedule under
    /// `PendingAllReduce`): members reduce onto the leader and receive
    /// the broadcast on the local link (`2(l−1)` full-buffer local
    /// hops), and only the leaders run the ring — over `nodes` instead
    /// of `w` ranks.  `l = 1` degenerates to [`NetModel::all_reduce`]
    /// exactly.
    pub fn all_reduce_hier(&self, w: usize, l: usize, bytes: usize) -> f64 {
        self.ar_hier_t(w, l, bytes as f64)
    }

    fn ar_hier_t(&self, w: usize, l: usize, bytes: f64) -> f64 {
        if !self.enabled || w <= 1 {
            return 0.0;
        }
        if l <= 1 || w % l != 0 {
            let steps = 2 * (w - 1);
            return steps as f64 * (self.alpha + bytes / w as f64 / self.beta);
        }
        let nodes = w / l;
        let local =
            2.0 * (l - 1) as f64 * (self.alpha_local + bytes / self.beta_local);
        let ring = if nodes > 1 {
            2.0 * (nodes - 1) as f64
                * (self.alpha + bytes / nodes as f64 / self.beta)
        } else {
            0.0
        };
        local + ring
    }

    /// Whether this model's inter-node link is the bottleneck for a
    /// `(w, l)` shape — the regime where the hierarchical policies pay
    /// off at *every* byte count.  Sufficient conditions, both proven
    /// in the step models' terms: (a2a) the local link absorbs the
    /// intra share plus both leader staging passes cheaper than the
    /// inter link moved the intra share, and the saved per-peer α
    /// covers the aggregation α; (all-reduce) the two full-buffer
    /// local hops per member cost less than the `w → nodes` ring
    /// shrinkage, i.e. `beta_local ≥ w · beta`.  When this returns
    /// true, every `*_hier` score is ≤ its flat counterpart (the fig-6
    /// acceptance assertion); when false the aggregation overhead may
    /// dominate and hier is not asserted cheaper.
    pub fn hier_favourable(&self, w: usize, l: usize) -> bool {
        if !self.enabled || l < 2 || w <= l || w % l != 0 {
            return false;
        }
        let nodes = w / l;
        let intra = (l - 1) as f64 / (w - 1) as f64;
        let inter = 1.0 - intra;
        let a2a_alpha =
            self.alpha_local * (l as f64 + 1.0) <= self.alpha * (w - nodes) as f64;
        let a2a_beta =
            (intra + 2.0 * inter) / self.beta_local <= intra / self.beta;
        let ar_alpha =
            self.alpha_local * (l as f64 - 1.0) <= self.alpha * (w - nodes) as f64;
        let ar_beta = self.beta_local >= self.beta * w as f64;
        a2a_alpha && a2a_beta && ar_alpha && ar_beta
    }

    /// [`NetModel::moe_step_blocking`] with the hierarchical exchange.
    pub fn moe_step_blocking_hier(
        &self,
        w: usize,
        l: usize,
        bytes_out: usize,
        compute: f64,
    ) -> f64 {
        self.all_to_all_hier(w, l, bytes_out) + compute
    }

    /// [`NetModel::moe_step_blocking_hier`] plus the serial host term.
    pub fn moe_step_blocking_hier_host(
        &self,
        w: usize,
        l: usize,
        bytes_out: usize,
        compute: f64,
        copied_bytes: usize,
        alloc_bytes: usize,
    ) -> f64 {
        self.moe_step_blocking_hier(w, l, bytes_out, compute)
            + self.host_overhead(copied_bytes, alloc_bytes)
    }

    /// [`NetModel::moe_step_overlapped`] with the hierarchical
    /// exchange as the wire stage: the same fill/steady/drain pipeline
    /// over `chunks`, each chunk's wire time `1/chunks` of the hier
    /// exchange (the locality-ordered chunk schedule).  Monotone in
    /// the wire term, so hier ≤ flat transfers from the exchange to
    /// the whole pipelined step whenever [`NetModel::hier_favourable`].
    pub fn moe_step_overlapped_hier(
        &self,
        w: usize,
        l: usize,
        bytes_out: usize,
        compute: f64,
        chunks: usize,
    ) -> f64 {
        if !self.enabled || w <= 1 {
            return compute;
        }
        let c = chunks.clamp(1, w) as f64;
        let wire_chunk = self.all_to_all_hier(w, l, bytes_out) / c;
        let comp_chunk = compute / c;
        wire_chunk + (c - 1.0) * wire_chunk.max(comp_chunk) + comp_chunk
    }

    /// [`NetModel::moe_step_overlapped_hier`] with the host term folded
    /// into the compute stage (as in the flat host variant).
    #[allow(clippy::too_many_arguments)]
    pub fn moe_step_overlapped_hier_host(
        &self,
        w: usize,
        l: usize,
        bytes_out: usize,
        compute: f64,
        chunks: usize,
        copied_bytes: usize,
        alloc_bytes: usize,
    ) -> f64 {
        let host = self.host_overhead(copied_bytes, alloc_bytes);
        if !self.enabled || w <= 1 {
            return compute + host;
        }
        self.moe_step_overlapped_hier(w, l, bytes_out, compute + host, chunks)
    }

    /// [`NetModel::grad_step_blocking`] with the tree all-reduce.
    pub fn grad_step_blocking_hier(
        &self,
        w: usize,
        l: usize,
        grad_bytes: usize,
        compute: f64,
        opt: f64,
    ) -> f64 {
        compute + self.all_reduce_hier(w, l, grad_bytes) + opt
    }

    /// [`NetModel::grad_step_overlapped`] with the tree all-reduce as
    /// each bucket's wire stage — the bound for `GradSync`'s bucketed
    /// overlap composed with the hier schedule.  `B = 1` equals
    /// [`NetModel::grad_step_blocking_hier`] exactly.
    pub fn grad_step_overlapped_hier(
        &self,
        w: usize,
        l: usize,
        grad_bytes: usize,
        compute: f64,
        opt: f64,
        buckets: usize,
    ) -> f64 {
        if !self.enabled || w <= 1 {
            return compute + opt;
        }
        let mut best = f64::INFINITY;
        for b in 1..=buckets.max(1) {
            let g = compute / b as f64;
            let a = opt / b as f64;
            let wire = self.ar_hier_t(w, l, grad_bytes as f64 / b as f64);
            let t = g + wire + a + (b as f64 - 1.0) * g.max(wire).max(a);
            best = best.min(t);
        }
        best
    }

    /// One data-parallel step under the *ZeRO-sharded* schedule
    /// (`[comm] grad_shard = "zero"`): the ring reduce-scatters the
    /// grads so each rank owns a contiguous `1/n` shard, runs the
    /// optimiser over only that shard, and all-gathers the updated
    /// params.  The wire volume is the same `2(n−1)` rounds of
    /// `bytes/n` as the plain ring (the scatter half carries grads,
    /// the gather half carries updated params), so the win is the
    /// optimiser term shrinking to `opt/n` — and, off-model, the
    /// `~1/n` optimizer-state memory.
    pub fn grad_step_zero(
        &self,
        n: usize,
        grad_bytes: usize,
        compute: f64,
        opt: f64,
    ) -> f64 {
        if !self.enabled || n <= 1 {
            return compute + opt;
        }
        compute + self.all_reduce(n, grad_bytes) + opt / n as f64
    }

    /// [`NetModel::grad_step_zero`] under the *rail-aware* hier
    /// schedule: each local rank first gathers its rail's sub-slice
    /// pieces from its `l−1` node neighbours (egress `bytes·(l−1)/l`
    /// on the local link), then rings its `bytes/l` sub-slice across
    /// nodes with its peer rank — `l` concurrent rails, each moving
    /// `2(nodes−1)` rounds of `bytes/(l·nodes)` on the inter link —
    /// and finally exchanges updated params back intra-node.  Every
    /// rank owns `1/w` of the params, so the optimiser term is
    /// `opt/w`.  `l = 1` (or a non-dividing shape) degenerates to the
    /// flat [`NetModel::grad_step_zero`] exactly.
    pub fn grad_step_zero_hier(
        &self,
        w: usize,
        l: usize,
        grad_bytes: usize,
        compute: f64,
        opt: f64,
    ) -> f64 {
        if !self.enabled || w <= 1 {
            return compute + opt;
        }
        if l <= 1 || w % l != 0 {
            return self.grad_step_zero(w, grad_bytes, compute, opt);
        }
        let nodes = w / l;
        let bytes = grad_bytes as f64;
        // phases A and D: intra gather-to-owner / updated-param exchange
        let local = 2.0
            * ((l - 1) as f64 * self.alpha_local
                + bytes * (l - 1) as f64 / l as f64 / self.beta_local);
        // phases B and C: l concurrent rail rings over the nodes
        let rails = if nodes > 1 {
            2.0 * (nodes - 1) as f64
                * (self.alpha + bytes / (l * nodes) as f64 / self.beta)
        } else {
            0.0
        };
        compute + local + rails + opt / w as f64
    }

    /// Host-side overhead of one step: staging copies + fresh padded
    /// allocations.  Zero when the model is disabled (`--net none`
    /// ablates *all* simulated cost, host included).
    pub fn host_overhead(&self, copied_bytes: usize, alloc_bytes: usize) -> f64 {
        if !self.enabled {
            return 0.0;
        }
        copied_bytes as f64 / self.host_beta + alloc_bytes as f64 / self.alloc_beta
    }

    /// [`NetModel::moe_step_blocking`] with the host cost term: copies
    /// and allocations are serial host work on top of the exchange.
    pub fn moe_step_blocking_host(
        &self,
        n: usize,
        bytes_out: usize,
        compute: f64,
        copied_bytes: usize,
        alloc_bytes: usize,
    ) -> f64 {
        self.moe_step_blocking(n, bytes_out, compute)
            + self.host_overhead(copied_bytes, alloc_bytes)
    }

    /// [`NetModel::moe_step_overlapped`] with the host cost term folded
    /// into the compute side of the pipeline (copies and allocations
    /// happen on the same core that drives the expert shard, chunk by
    /// chunk — they lengthen the compute stage, not the wire).
    ///
    /// Strictly monotone in both byte terms (at n > 1 with the model
    /// enabled), which is the acceptance property: the zero-copy
    /// schedule, having strictly fewer copied and allocated bytes than
    /// the copy-heavy one, scores strictly lower at every
    /// (workers, chunks) point.
    pub fn moe_step_overlapped_host(
        &self,
        n: usize,
        bytes_out: usize,
        compute: f64,
        chunks: usize,
        copied_bytes: usize,
        alloc_bytes: usize,
    ) -> f64 {
        let host = self.host_overhead(copied_bytes, alloc_bytes);
        if !self.enabled || n <= 1 {
            return compute + host;
        }
        self.moe_step_overlapped(n, bytes_out, compute + host, chunks)
    }

    /// One blocking expert-parallel step under *skewed* routing: rank
    /// `r` computes `rank_rows[r]` expert rows this step (shadow
    /// replicas split their expert's rows across its hosts — see
    /// `crate::placement::PlacementPlan::rank_rows`).  The step is
    /// synchronous, so every rank waits for the most-loaded one: full
    /// exchange latency, the hottest rank's ingress, and the hottest
    /// rank's compute:
    ///
    /// ```text
    /// t = α·(n−1) + max_r(rows_r)·bytes_per_row/β + max_r(rows_r)·secs_per_row
    /// ```
    ///
    /// Strictly increasing in the hottest rank's load — the fig-6 skew
    /// assertion: any re-sharding that lowers `max_r(rows_r)` scores
    /// strictly below the static layout.
    pub fn moe_step_skewed(
        &self,
        rank_rows: &[f64],
        bytes_per_row: usize,
        secs_per_row: f64,
    ) -> f64 {
        let n = rank_rows.len();
        let hottest = rank_rows.iter().cloned().fold(0.0, f64::max);
        if !self.enabled || n <= 1 {
            return hottest * secs_per_row;
        }
        self.alpha * (n - 1) as f64
            + hottest * bytes_per_row as f64 / self.beta
            + hottest * secs_per_row
    }

    /// One forward-only *serving* step: the Figure-2 dispatch exchange
    /// (`bytes_out` egress) plus `compute` seconds of expert forward —
    /// no backward exchange, no gradient ring, no optimiser, which is
    /// why a serve step is a fraction of the training step over the
    /// same layer (the training forward+backward runs ~3× the forward
    /// GEMMs and twice the exchange volume, plus the grad-sync tail).
    pub fn serve_step(&self, n: usize, bytes_out: usize, compute: f64) -> f64 {
        if !self.enabled || n <= 1 {
            return compute;
        }
        self.all_to_all(n, bytes_out) + compute
    }

    /// Modelled latency of a request of `rows` tokens arriving with
    /// `queued_rows` already ahead of it, under continuous batching
    /// that admits `max_batch` rows per step of `step_time` seconds:
    /// the request completes with the batch that drains its last row,
    /// i.e. after `ceil((queued_rows + rows) / max_batch)` steps.
    /// Quantised by construction — the unit the measured percentiles
    /// (`serve::ServeStats`) are compared against in the bench.
    pub fn serve_request_latency(
        &self,
        queued_rows: usize,
        rows: usize,
        max_batch: usize,
        step_time: f64,
    ) -> f64 {
        let total = queued_rows + rows;
        let steps = total.div_ceil(max_batch.max(1)).max(1);
        steps as f64 * step_time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse() {
        assert_eq!(NetPreset::parse("ib-edr"), Some(NetPreset::IbEdr));
        assert_eq!(NetPreset::parse("none"), Some(NetPreset::None));
        assert_eq!(NetPreset::parse("smoke-signal"), None);
    }

    #[test]
    fn p2p_scales_with_bytes() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let t1 = m.p2p(1 << 20);
        let t2 = m.p2p(2 << 20);
        assert!(t2 > t1);
        // 1 MiB at 12.5 GB/s ≈ 84 µs ≫ α
        assert!((t1 - (1.5e-6 + 1048576.0 / 12.5e9)).abs() < 1e-12);
    }

    #[test]
    fn disabled_is_free() {
        let m = NetModel::preset(NetPreset::None);
        assert_eq!(m.p2p(usize::MAX / 2), 0.0);
        assert_eq!(m.all_to_all(8, 1 << 30), 0.0);
        assert_eq!(m.all_reduce(8, 1 << 30), 0.0);
    }

    #[test]
    fn all_reduce_bandwidth_term_shrinks_with_n() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let big = 256 << 20;
        // 2(n-1)/n · s/β is increasing in n but bounded by 2s/β
        let t2 = m.all_reduce(2, big);
        let t8 = m.all_reduce(8, big);
        assert!(t8 > t2);
        assert!(t8 < 2.0 * big as f64 / m.beta + 16.0 * m.alpha);
    }

    #[test]
    fn single_worker_is_free() {
        let m = NetModel::preset(NetPreset::IbEdr);
        assert_eq!(m.all_to_all(1, 123), 0.0);
        assert_eq!(m.all_reduce(1, 123), 0.0);
    }

    #[test]
    fn overlap_one_chunk_equals_blocking() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let (n, bytes, compute) = (8usize, 4 << 20, 3e-3);
        let blocking = m.moe_step_blocking(n, bytes, compute);
        let degenerate = m.moe_step_overlapped(n, bytes, compute, 1);
        assert!((blocking - degenerate).abs() < 1e-15);
    }

    #[test]
    fn overlap_strictly_beats_blocking_with_work_on_both_sides() {
        // the acceptance property: at ≥ 4 workers, nonzero wire and
        // compute, chunked pipelining must score strictly lower
        let m = NetModel::preset(NetPreset::IbEdr);
        for n in [4usize, 8, 16] {
            for chunks in [2usize, 4] {
                for compute in [1e-4, 1e-2] {
                    let bytes = 8 << 20;
                    let blocking = m.moe_step_blocking(n, bytes, compute);
                    let overlapped = m.moe_step_overlapped(n, bytes, compute, chunks);
                    assert!(
                        overlapped < blocking,
                        "n={n} chunks={chunks} compute={compute}: \
                         {overlapped} !< {blocking}"
                    );
                    // and never better than the max(wire, compute) bound
                    assert!(
                        overlapped >= m.all_to_all(n, bytes).max(compute) - 1e-15,
                        "pipeline cannot beat its longest stage"
                    );
                }
            }
        }
    }

    #[test]
    fn overlap_disabled_net_is_pure_compute() {
        let m = NetModel::preset(NetPreset::None);
        assert_eq!(m.moe_step_overlapped(8, 1 << 30, 2.5, 4), 2.5);
        assert_eq!(m.moe_step_blocking(8, 1 << 30, 2.5), 2.5);
        // host term ablated with the network
        assert_eq!(m.host_overhead(1 << 30, 1 << 30), 0.0);
        assert_eq!(m.moe_step_overlapped_host(8, 1 << 30, 2.5, 4, 1 << 30, 1 << 30), 2.5);
    }

    #[test]
    fn grad_step_one_bucket_equals_blocking() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let (n, bytes, compute, opt) = (8usize, 16 << 20, 5e-3, 1e-3);
        let blocking = m.grad_step_blocking(n, bytes, compute, opt);
        let one = m.grad_step_overlapped(n, bytes, compute, opt, 1);
        assert!((blocking - one).abs() < 1e-15);
    }

    #[test]
    fn grad_step_overlapped_never_exceeds_blocking() {
        // the PR-4 acceptance property: bucketed overlapped grad sync
        // scores ≤ blocking at EVERY (workers, bytes, compute) point —
        // including α-dominated corners, where the best bucket count
        // degenerates to 1
        let m = NetModel::preset(NetPreset::IbEdr);
        for n in [2usize, 4, 8, 16] {
            for bytes in [64usize, 1 << 20, 64 << 20] {
                for compute in [0.0, 1e-4, 1e-2] {
                    for opt in [0.0, 1e-4, 1e-2] {
                        for buckets in [1usize, 2, 4, 16] {
                            let blocking = m.grad_step_blocking(n, bytes, compute, opt);
                            let over =
                                m.grad_step_overlapped(n, bytes, compute, opt, buckets);
                            assert!(
                                over <= blocking + 1e-15,
                                "n={n} bytes={bytes} compute={compute} opt={opt} \
                                 buckets={buckets}: {over} !<= {blocking}"
                            );
                        }
                    }
                }
            }
        }
        // and strictly better when there is real work on both sides
        let blocking = m.grad_step_blocking(8, 64 << 20, 1e-2, 2e-3);
        let over = m.grad_step_overlapped(8, 64 << 20, 1e-2, 2e-3, 8);
        assert!(over < blocking, "{over} !< {blocking}");
    }

    #[test]
    fn grad_step_disabled_net_is_compute_plus_opt() {
        let m = NetModel::preset(NetPreset::None);
        assert_eq!(m.grad_step_blocking(8, 1 << 30, 2.0, 0.5), 2.5);
        assert_eq!(m.grad_step_overlapped(8, 1 << 30, 2.0, 0.5, 16), 2.5);
        assert_eq!(m.grad_step_zero(8, 1 << 30, 2.0, 0.5), 2.5);
        assert_eq!(m.grad_step_zero_hier(8, 2, 1 << 30, 2.0, 0.5), 2.5);
    }

    #[test]
    fn grad_step_zero_never_exceeds_blocking() {
        // The PR-9 acceptance property: the ZeRO schedule moves the
        // same ring volume (scatter grads, gather updated params) but
        // pays only 1/n of the optimiser — so it scores ≤ blocking at
        // EVERY point, strictly below whenever opt > 0 and n > 1.
        let m = NetModel::preset(NetPreset::IbEdr);
        for n in [2usize, 4, 8, 16] {
            for bytes in [64usize, 1 << 20, 64 << 20] {
                for compute in [0.0, 1e-4, 1e-2] {
                    for opt in [0.0, 1e-4, 1e-2] {
                        let blocking = m.grad_step_blocking(n, bytes, compute, opt);
                        let zero = m.grad_step_zero(n, bytes, compute, opt);
                        assert!(
                            zero <= blocking + 1e-15,
                            "n={n} bytes={bytes} compute={compute} opt={opt}: \
                             {zero} !<= {blocking}"
                        );
                        if opt > 0.0 {
                            assert!(zero < blocking, "{zero} !< {blocking}");
                        }
                    }
                }
            }
        }
        // single worker: nothing to shard, nothing on the wire
        assert_eq!(m.grad_step_zero(1, 1 << 20, 2.0, 0.5), 2.5);
    }

    #[test]
    fn grad_step_zero_hier_rails_never_exceed_the_tree() {
        // The rail schedule wins the wire unconditionally when l | w:
        // the intra phases move (l−1)/l of the buffer instead of the
        // tree's (l−1) full-buffer hops, and each rail rings only its
        // 1/l sub-slice across nodes — plus the opt/w shard term.
        let m = NetModel::preset(NetPreset::IbEdr);
        for (w, l) in [(4usize, 2usize), (8, 2), (8, 4), (16, 4), (16, 8)] {
            for bytes in [64usize, 1 << 16, 8 << 20, 256 << 20] {
                for opt in [0.0, 1e-3] {
                    let tree = m.grad_step_blocking_hier(w, l, bytes, 1e-3, opt);
                    let zero = m.grad_step_zero_hier(w, l, bytes, 1e-3, opt);
                    assert!(
                        zero <= tree + 1e-15,
                        "w={w} l={l} bytes={bytes} opt={opt}: {zero} !<= {tree}"
                    );
                }
            }
        }
        // l = 1 (and non-dividing shapes) degenerate to the flat zero step
        assert_eq!(
            m.grad_step_zero_hier(8, 1, 4 << 20, 1e-3, 1e-3),
            m.grad_step_zero(8, 4 << 20, 1e-3, 1e-3)
        );
        assert_eq!(
            m.grad_step_zero_hier(8, 3, 4 << 20, 1e-3, 1e-3),
            m.grad_step_zero(8, 4 << 20, 1e-3, 1e-3)
        );
        // single node: no inter rails, just the intra phases + opt/w
        let one_node = m.grad_step_zero_hier(4, 4, 4 << 20, 1e-3, 1e-3);
        assert!(one_node < m.grad_step_zero(4, 4 << 20, 1e-3, 1e-3));
    }

    #[test]
    fn hier_degenerates_to_flat_at_one_rank_per_node() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let (w, bytes, compute, opt) = (8usize, 4 << 20, 3e-3, 1e-3);
        assert_eq!(m.all_to_all_hier(w, 1, bytes), m.all_to_all(w, bytes));
        assert_eq!(m.all_reduce_hier(w, 1, bytes), m.all_reduce(w, bytes));
        // (same value, different association of the /chunks division)
        let d = (m.moe_step_overlapped_hier(w, 1, bytes, compute, 4)
            - m.moe_step_overlapped(w, bytes, compute, 4))
        .abs();
        assert!(d < 1e-12, "overlapped degenerate diff {d}");
        assert_eq!(
            m.grad_step_overlapped_hier(w, 1, bytes, compute, opt, 8),
            m.grad_step_overlapped(w, bytes, compute, opt, 8)
        );
        // one-bucket hier grad step is the blocking hier step exactly
        let one = m.grad_step_overlapped_hier(8, 2, bytes, compute, opt, 1);
        let blk = m.grad_step_blocking_hier(8, 2, bytes, compute, opt);
        assert!((one - blk).abs() < 1e-15);
        // disabled net ablates the hier terms with everything else
        let none = NetModel::preset(NetPreset::None);
        assert_eq!(none.all_to_all_hier(8, 2, 1 << 30), 0.0);
        assert_eq!(none.all_reduce_hier(8, 2, 1 << 30), 0.0);
        assert!(!none.hier_favourable(8, 2));
    }

    #[test]
    fn hier_beats_flat_whenever_inter_bandwidth_is_the_bottleneck() {
        // The PR-5 acceptance property: in the hier_favourable regime
        // (fast local lane, inter link the bottleneck) every hier
        // score is ≤ its flat counterpart, at EVERY byte count, chunk
        // count and bucket count — including α-dominated tiny messages.
        let m = NetModel::preset(NetPreset::IbEdr);
        let mut asserted = 0usize;
        for w in [4usize, 6, 8, 16] {
            for l in [2usize, 3, 4, 8] {
                if !m.hier_favourable(w, l) {
                    continue;
                }
                asserted += 1;
                for bytes in [64usize, 1 << 16, 8 << 20, 256 << 20] {
                    let a2a_f = m.all_to_all(w, bytes);
                    let a2a_h = m.all_to_all_hier(w, l, bytes);
                    assert!(
                        a2a_h <= a2a_f + 1e-15,
                        "a2a w={w} l={l} bytes={bytes}: {a2a_h} !<= {a2a_f}"
                    );
                    let ar_f = m.all_reduce(w, bytes);
                    let ar_h = m.all_reduce_hier(w, l, bytes);
                    assert!(
                        ar_h <= ar_f + 1e-15,
                        "ar w={w} l={l} bytes={bytes}: {ar_h} !<= {ar_f}"
                    );
                    for compute in [0.0, 1e-4, 1e-2] {
                        for chunks in [1usize, 2, 4] {
                            let f = m.moe_step_overlapped_host(
                                w, bytes, compute, chunks, bytes, 0,
                            );
                            let h = m.moe_step_overlapped_hier_host(
                                w, l, bytes, compute, chunks, bytes, 0,
                            );
                            assert!(
                                h <= f + 1e-15,
                                "moe w={w} l={l} bytes={bytes} c={chunks}: {h} !<= {f}"
                            );
                        }
                        for buckets in [1usize, 4, 16] {
                            let f = m.grad_step_overlapped(
                                w, bytes, compute, 1e-3, buckets,
                            );
                            let h = m.grad_step_overlapped_hier(
                                w, l, bytes, compute, 1e-3, buckets,
                            );
                            assert!(
                                h <= f + 1e-15,
                                "grad w={w} l={l} bytes={bytes} b={buckets}: \
                                 {h} !<= {f}"
                            );
                        }
                    }
                }
            }
        }
        assert!(asserted >= 4, "regime too narrow: {asserted} shapes asserted");
        // and outside the regime the predicate really gates: a model
        // whose local link is no faster than the NIC is never favourable
        let flat_local = NetModel { alpha_local: m.alpha, beta_local: m.beta, ..m };
        assert!(!flat_local.hier_favourable(8, 2));
    }

    #[test]
    fn serve_step_is_a_fraction_of_the_training_step() {
        let m = NetModel::preset(NetPreset::IbEdr);
        for n in [2usize, 4, 8] {
            for bytes in [1usize << 16, 4 << 20] {
                for compute in [1e-4, 1e-2] {
                    let serve = m.serve_step(n, bytes, compute);
                    // a conservative training step over the same layer:
                    // forward+backward exchanges and ~3× the forward
                    // GEMMs, before any grad-sync tail
                    let train = m.moe_step_blocking(n, 2 * bytes, 3.0 * compute);
                    assert!(
                        serve < train,
                        "n={n} bytes={bytes} compute={compute}: {serve} !< {train}"
                    );
                }
            }
        }
        // disabled net: pure compute
        let none = NetModel::preset(NetPreset::None);
        assert_eq!(none.serve_step(8, 1 << 30, 2.5), 2.5);
    }

    #[test]
    fn serve_latency_quantises_by_steps_and_grows_with_queue() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let step = 2e-3;
        // an empty queue: one step, whatever the (admissible) size
        assert_eq!(m.serve_request_latency(0, 1, 8, step), step);
        assert_eq!(m.serve_request_latency(0, 8, 8, step), step);
        // queue ahead pushes the request into later batches
        assert_eq!(m.serve_request_latency(8, 1, 8, step), 2.0 * step);
        assert_eq!(m.serve_request_latency(15, 1, 8, step), 2.0 * step);
        assert_eq!(m.serve_request_latency(16, 1, 8, step), 3.0 * step);
        // monotone in queue depth
        let mut last = 0.0;
        for q in 0..64 {
            let t = m.serve_request_latency(q, 4, 8, step);
            assert!(t >= last, "q={q}: {t} < {last}");
            last = t;
        }
    }

    #[test]
    fn skewed_step_scores_the_hottest_rank() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let (bytes, spr) = (4096usize, 1e-6);
        let balanced = m.moe_step_skewed(&[100.0, 100.0, 100.0, 100.0], bytes, spr);
        let skewed = m.moe_step_skewed(&[250.0, 50.0, 50.0, 50.0], bytes, spr);
        assert!(skewed > balanced, "{skewed} !> {balanced}");
        // same totals: only the hottest rank matters
        let spread = m.moe_step_skewed(&[100.0, 100.0, 100.0, 100.0], bytes, spr);
        assert_eq!(spread, balanced);
        // halving the hottest rank (a shadow splitting its rows)
        // strictly lowers the score
        let shadowed = m.moe_step_skewed(&[125.0, 125.0, 50.0, 50.0], bytes, spr);
        assert!(shadowed < skewed, "{shadowed} !< {skewed}");
        // degenerate cases: single rank / disabled net are pure compute
        assert_eq!(m.moe_step_skewed(&[7.0], bytes, spr), 7.0 * spr);
        let none = NetModel::preset(NetPreset::None);
        assert_eq!(none.moe_step_skewed(&[9.0, 1.0], bytes, spr), 9.0 * spr);
    }

    #[test]
    fn host_overhead_prices_copies_and_allocs() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let mb = 1usize << 20;
        // allocation (allocate + zero) is dearer than a memcpy
        assert!(m.host_overhead(0, mb) > m.host_overhead(mb, 0));
        // additive and linear
        let c = m.host_overhead(mb, 0);
        assert!((m.host_overhead(2 * mb, 0) - 2.0 * c).abs() < 1e-12);
        assert!(
            (m.host_overhead(mb, mb) - m.host_overhead(mb, 0) - m.host_overhead(0, mb))
                .abs()
                < 1e-12
        );
    }

    #[test]
    fn zero_copy_schedule_strictly_beats_copy_heavy_one() {
        // The PR-3 acceptance property, at the model level: with the
        // same wire bytes and raw compute, the schedule that copies
        // each arrived row once and allocates nothing must score
        // strictly below the PR-2 schedule (extra chunk-batch copy +
        // fresh per-chunk buckets) on EVERY (workers, chunks) point.
        let m = NetModel::preset(NetPreset::IbEdr);
        for n in [2usize, 4, 8, 16] {
            for chunks in [1usize, 2, 4, 8] {
                for compute in [1e-4, 1e-2] {
                    let wire_bytes = 4 << 20;
                    let row_bytes = 2 << 20; // rows landed on this worker
                    let zero_copy =
                        m.moe_step_overlapped_host(n, wire_bytes, compute, chunks, 2 * row_bytes, 0);
                    let copy_heavy = m.moe_step_overlapped_host(
                        n,
                        wire_bytes,
                        compute,
                        chunks,
                        3 * row_bytes,   // extra wire→chunk-batch copy
                        2 * row_bytes,   // fresh padded chunk buckets
                    );
                    assert!(
                        zero_copy < copy_heavy,
                        "n={n} chunks={chunks} compute={compute}: \
                         {zero_copy} !< {copy_heavy}"
                    );
                    // the host term never makes overlap beat its bound
                    assert!(zero_copy >= m.moe_step_overlapped(n, wire_bytes, compute, chunks) - 1e-15);
                }
            }
        }
    }

    #[test]
    fn blocking_host_adds_serial_overhead() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let base = m.moe_step_blocking(4, 1 << 20, 1e-3);
        let with = m.moe_step_blocking_host(4, 1 << 20, 1e-3, 1 << 20, 1 << 20);
        assert!((with - base - m.host_overhead(1 << 20, 1 << 20)).abs() < 1e-15);
    }

    #[test]
    fn every_step_score_is_monotone_in_bytes_and_nonnegative() {
        // The autotune sanity matrix: `autotune::search` ranks candidate
        // configs by these scores, which is only meaningful if EVERY
        // `*_step_*` variant is (a) non-negative and (b) strictly
        // monotone increasing in its byte argument across the whole
        // search lattice (workers × local_size × chunks × buckets ×
        // compute/opt corners, both real presets).  A variant that
        // plateaued or dipped with bytes would let the argmin pick a
        // config on modelling noise instead of cost.
        type Score = (&'static str, Box<dyn Fn(usize) -> f64>);
        let ladder = [64usize, 1 << 12, 1 << 16, 1 << 20, 8 << 20, 64 << 20];
        for preset in [NetPreset::IbEdr, NetPreset::Pcie3] {
            let m = NetModel::preset(preset);
            for w in [2usize, 4, 8, 16] {
                for l in [1usize, 2, 4] {
                    if w % l != 0 {
                        continue;
                    }
                    for compute in [0.0, 1e-3] {
                        for opt in [0.0, 5e-4] {
                            for c in [1usize, 2, 4, 8] {
                                for b in [1usize, 4, 16] {
                                    // every variant the search scores, as
                                    // bytes → score closures over one
                                    // lattice point
                                    let scores: Vec<Score> = vec![
                                        ("moe_blocking", Box::new(move |x| m.moe_step_blocking(w, x, compute))),
                                        ("moe_overlapped", Box::new(move |x| m.moe_step_overlapped(w, x, compute, c))),
                                        ("moe_blocking_hier", Box::new(move |x| m.moe_step_blocking_hier(w, l, x, compute))),
                                        ("moe_overlapped_hier", Box::new(move |x| m.moe_step_overlapped_hier(w, l, x, compute, c))),
                                        ("moe_blocking_host", Box::new(move |x| m.moe_step_blocking_host(w, x, compute, x, x / 2))),
                                        ("moe_overlapped_host", Box::new(move |x| m.moe_step_overlapped_host(w, x, compute, c, x, x / 2))),
                                        ("moe_blocking_hier_host", Box::new(move |x| m.moe_step_blocking_hier_host(w, l, x, compute, x, x / 2))),
                                        ("moe_overlapped_hier_host", Box::new(move |x| {
                                            m.moe_step_overlapped_hier_host(w, l, x, compute, c, x, x / 2)
                                        })),
                                        ("grad_blocking", Box::new(move |x| m.grad_step_blocking(w, x, compute, opt))),
                                        ("grad_overlapped", Box::new(move |x| m.grad_step_overlapped(w, x, compute, opt, b))),
                                        ("grad_blocking_hier", Box::new(move |x| m.grad_step_blocking_hier(w, l, x, compute, opt))),
                                        ("grad_overlapped_hier", Box::new(move |x| {
                                            m.grad_step_overlapped_hier(w, l, x, compute, opt, b)
                                        })),
                                        ("grad_zero", Box::new(move |x| m.grad_step_zero(w, x, compute, opt))),
                                        ("grad_zero_hier", Box::new(move |x| m.grad_step_zero_hier(w, l, x, compute, opt))),
                                        ("serve_step", Box::new(move |x| m.serve_step(w, x, compute))),
                                        ("moe_skewed", Box::new(move |x| {
                                            m.moe_step_skewed(&vec![100.0; w], x, compute)
                                        })),
                                    ];
                                    for (name, f) in &scores {
                                        let mut last = -1.0f64;
                                        for &bytes in &ladder {
                                            let t = f(bytes);
                                            assert!(
                                                t.is_finite() && t >= 0.0,
                                                "{preset:?} {name} w={w} l={l} c={c} b={b} \
                                                 bytes={bytes}: score {t} not finite/≥0"
                                            );
                                            assert!(
                                                t > last,
                                                "{preset:?} {name} w={w} l={l} c={c} b={b} \
                                                 compute={compute} opt={opt}: score not \
                                                 strictly monotone at {bytes} bytes \
                                                 ({t} !> {last})"
                                            );
                                            last = t;
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        // disabled model: scores stay non-negative (and byte-flat, which
        // is why `search` requires a *fitted* — enabled — model)
        let none = NetModel::preset(NetPreset::None);
        for &bytes in &ladder {
            assert!(none.moe_step_blocking(8, bytes, 1e-3) >= 0.0);
            assert!(none.grad_step_overlapped(8, bytes, 1e-3, 1e-4, 4) >= 0.0);
        }
    }
}
