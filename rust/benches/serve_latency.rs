//! Serving: continuous-batching throughput and request latency of the
//! `fastmoe serve` daemon.
//!
//! Two sections share one JSON record:
//!
//! * **Modelled** (always runs): `sim::NetModel::serve_step` prices the
//!   forward-only inference step against the full training step at the
//!   same geometry (one exchange pair and one GEMM pass instead of two
//!   and three — the serve step should be a small fraction), and
//!   `sim::NetModel::serve_request_latency` quantises request latency
//!   by the step clock: a request behind `q` queued tokens waits
//!   `ceil((q + rows) / max_batch)` steps.  The modelled latency
//!   distribution over a uniform queue-occupancy sweep feeds a
//!   [`metrics::Histogram`], so `latency_p50/p95/p99` keys are present
//!   in the JSON even where the runtime is absent.
//! * **Measured** (runtime-gated): a real thread-backend daemon
//!   ([`serve::run_thread_daemon`]) on port 48170, driven by
//!   `--sessions` concurrent client sessions of `--requests` requests
//!   each.  Reports daemon-side stats (step percentiles, rows/s) and
//!   client-observed latency percentiles; the daemon-side numbers
//!   overwrite the modelled percentile keys.
//!
//! ```bash
//! cargo bench --bench serve_latency                      # both sections
//! cargo bench --bench serve_latency -- --sessions 4 --requests 64
//! cargo bench --bench serve_latency -- --max-batch 8     # tighter admission
//! cargo bench --bench serve_latency -- --json out.json   # machine-readable
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use fastmoe::bench::Table;
use fastmoe::cli::Args;
use fastmoe::config::{CommConfig, MoeConfig, ServeConfig};
use fastmoe::metrics::{Histogram, Stopwatch};
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::serve::{run_thread_daemon, ClientConn, Reply};
use fastmoe::sim::{NetModel, NetPreset};
use fastmoe::util::json::Json;

/// Front-end port of the measured section (47870/47970/48070 belong to
/// the failure tests, 48270.. to the integration tests).
const BENCH_PORT: usize = 48170;

fn main() -> fastmoe::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(argv, &[])?;
    let workers = args.usize_or("workers", 2)?.max(1);
    let sessions = args.usize_or("sessions", 3)?.max(1);
    let requests = args.usize_or("requests", 32)?.max(1);
    let rows = args.usize_or("rows", 4)?.max(1);
    let max_batch = args.usize_or("max-batch", 0)?;
    let queue_depth = args.usize_or("queue-depth", 1024)?.max(1);
    let idle_ms = args.u64_or("idle-ms", 5)?.max(1);
    let seed = args.u64_or("seed", 17)?;
    let net_name = args.str_or("net", "ib-edr");
    let json_path = args.get("json").map(|s| s.to_string());
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    root.insert("bench".into(), Json::Str("serve_latency".into()));
    root.insert("workers".into(), Json::Num(workers as f64));
    root.insert("sessions".into(), Json::Num(sessions as f64));
    root.insert("rows_per_request".into(), Json::Num(rows as f64));

    // ---- modelled section -------------------------------------------------
    // nominal geometry: the preset's serving point (per-rank token
    // bytes ≈ max_batch rows of a 1k-float model dim, 2 ms of expert
    // compute per step — the shape, not the absolute scale, is what
    // the section checks)
    let net = NetModel::preset(NetPreset::parse(&net_name).unwrap_or(NetPreset::IbEdr));
    let model_batch = if max_batch == 0 { 32 } else { max_batch };
    let bytes = model_batch * 1024 * 4;
    let compute = 2e-3;
    let serve_step = net.serve_step(workers, bytes, compute);
    let train_step = net.moe_step_blocking(workers, 2 * bytes, 3.0 * compute);
    println!(
        "serve latency — modelled ({net_name}, {workers} workers, \
         max_batch {model_batch}): serve step {:.2} ms vs train step {:.2} ms \
         ({:.0}% of training)\n",
        serve_step * 1e3,
        train_step * 1e3,
        100.0 * serve_step / train_step.max(1e-12),
    );
    let mut table = Table::new(&["queued_rows", "steps_waited", "latency_ms"]);
    let mut modelled = Histogram::latency();
    // uniform queue-occupancy sweep: a request arriving behind q queued
    // tokens — the modelled stand-in for the measured arrival process
    for q in 0..=(2 * model_batch) {
        let lat = net.serve_request_latency(q, rows, model_batch, serve_step);
        modelled.record(lat);
        if q % (model_batch / 4).max(1) == 0 {
            table.row(vec![
                q.to_string(),
                format!("{:.0}", (lat / serve_step.max(1e-12)).round()),
                format!("{:.2}", lat * 1e3),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "modelled request latency: p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms\n",
        modelled.p50() * 1e3,
        modelled.p95() * 1e3,
        modelled.p99() * 1e3,
    );
    root.insert("modelled_serve_step_s".into(), Json::Num(serve_step));
    root.insert("modelled_train_step_s".into(), Json::Num(train_step));
    root.insert("latency_p50".into(), Json::Num(modelled.p50()));
    root.insert("latency_p95".into(), Json::Num(modelled.p95()));
    root.insert("latency_p99".into(), Json::Num(modelled.p99()));
    root.insert("measured".into(), Json::Bool(false));

    // ---- measured section (runtime-gated) ---------------------------------
    if let Ok(rt) = Runtime::open_default() {
        let rt = Arc::new(rt);
        // probe the layer geometry from the gate artifact: the clients
        // need `dm` to size payloads before any layer exists
        let gate = rt
            .manifest
            .artifact(&format!("gate_fwd_w{workers}"))
            .ok_or_else(|| fastmoe::Error::msg("no gate artifact for this worker count"))?;
        let dm = gate.inputs[0].shape[1];
        let cfg = ServeConfig {
            port: BENCH_PORT,
            max_batch,
            queue_depth,
            idle_ms,
        };
        println!(
            "serve latency — measured: {workers} resident workers, \
             {sessions} sessions x {requests} requests of {rows}x{dm} tokens"
        );
        let moe = MoeConfig::default();
        let comm = CommConfig::default();
        let daemon = std::thread::spawn(move || {
            run_thread_daemon(rt, workers, seed, moe, comm, cfg)
        });
        let addr = format!("127.0.0.1:{BENCH_PORT}");
        let drivers: Vec<_> = (0..sessions)
            .map(|s| {
                let addr = addr.clone();
                std::thread::spawn(move || -> fastmoe::Result<(Histogram, u64)> {
                    let mut conn = ClientConn::connect(&addr)?;
                    let mut rng = Rng::new(seed ^ (s as u64) << 8);
                    let mut lat = Histogram::latency();
                    let mut rejected = 0u64;
                    for i in 0..requests {
                        let mut x = vec![0f32; rows * dm];
                        rng.fill_normal(&mut x, 1.0);
                        let t = Stopwatch::start();
                        conn.request(i as u32, rows, &x)?;
                        match conn.recv_reply()? {
                            Reply::Ok { .. } => lat.record(t.secs()),
                            Reply::Rejected { .. } => rejected += 1,
                        }
                    }
                    Ok((lat, rejected))
                })
            })
            .collect();
        let mut client_lat = Histogram::latency();
        let mut rejected = 0u64;
        for d in drivers {
            let (l, r) = d
                .join()
                .map_err(|_| fastmoe::Error::msg("bench session panicked"))??;
            client_lat.merge(&l);
            rejected += r;
        }
        let mut stop = ClientConn::connect(&addr)?;
        stop.shutdown()?;
        let stats = daemon
            .join()
            .map_err(|_| fastmoe::Error::msg("daemon thread panicked"))??;
        println!(
            "  daemon: {} steps, {} requests ({} rows) in {:.2} s — \
             {:.0} rows/s, {} rejected, step p50 {:.2} ms",
            stats.steps,
            stats.requests,
            stats.rows,
            stats.elapsed_sec,
            stats.rows as f64 / stats.elapsed_sec.max(1e-9),
            stats.rejected,
            stats.step_time.p50() * 1e3,
        );
        println!(
            "  client-observed latency: p50 {:.2} ms, p95 {:.2} ms, \
             p99 {:.2} ms ({} ok, {rejected} rejected)",
            client_lat.p50() * 1e3,
            client_lat.p95() * 1e3,
            client_lat.p99() * 1e3,
            client_lat.count(),
        );
        // the daemon-side record carries the percentile keys; keep the
        // client view alongside for the queueing-delay comparison
        if let Json::Object(stats_obj) = stats.to_json() {
            for (k, v) in stats_obj {
                root.insert(k, v);
            }
        }
        root.insert("measured".into(), Json::Bool(true));
        root.insert("client_latency_p50".into(), Json::Num(client_lat.p50()));
        root.insert("client_latency_p95".into(), Json::Num(client_lat.p95()));
        root.insert("client_latency_p99".into(), Json::Num(client_lat.p99()));
        root.insert("client_rejected".into(), Json::Num(rejected as f64));
    } else {
        println!("(runtime unavailable — measured section skipped)");
    }

    if let Some(path) = json_path {
        std::fs::write(&path, Json::Object(root).to_string())?;
        println!("{path} written");
    }
    Ok(())
}
