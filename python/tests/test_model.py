"""GPT model + train step: shapes, FLOPs parity, loss decrease, stages."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import gpt, stages, train

TINY = gpt.GptConfig(
    vocab=64, seq=16, n_layer=2, d_model=32, n_head=2, d_hidden=64,
    moe=True, n_expert=4, top_k=2,
)
TINY_DENSE = dataclasses.replace(TINY, moe=False)


@pytest.fixture(scope="module")
def params_moe():
    return gpt.init_params(TINY, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def params_dense():
    return gpt.init_params(TINY_DENSE, jax.random.PRNGKey(0))


def _batch(cfg, b=2, seed=0):
    r = np.random.default_rng(seed)
    tok = jnp.asarray(r.integers(0, cfg.vocab, (b, cfg.seq)), jnp.int32)
    tgt = jnp.asarray(r.integers(0, cfg.vocab, (b, cfg.seq)), jnp.int32)
    return tok, tgt


def test_registry_tags_partition():
    """Every parameter has exactly one sync tag; experts are `none`,
    the gate is `world` (FastMoE §3.2)."""
    specs = gpt.param_specs(TINY)
    for s in specs:
        assert s.tag in ("world", "data_parallel", "none")
        if "/moe/gate/" in s.name:
            assert s.tag == "world"
        if "/moe/expert/" in s.name:
            assert s.tag == "none"
        if "/attn/" in s.name or s.name.startswith("embed"):
            assert s.tag == "data_parallel"
    assert len({s.name for s in specs}) == len(specs)


def test_logits_shape(params_moe):
    tok, _ = _batch(TINY)
    logits = gpt.gpt_logits(params_moe, tok, TINY)
    assert logits.shape == (2, TINY.seq, TINY.vocab)


def test_initial_loss_near_uniform(params_moe):
    tok, tgt = _batch(TINY)
    loss = gpt.lm_loss(params_moe, tok, tgt, TINY)
    assert abs(float(loss) - np.log(TINY.vocab)) < 0.5


def test_flops_parity_moe_vs_dense():
    """§5.4: expert hidden size is divided by top_k so per-token FLOPs of
    MoE and dense models match (up to the negligible gate)."""
    f_moe = gpt.model_flops_per_token(TINY)
    f_dense = gpt.model_flops_per_token(TINY_DENSE)
    gate = TINY.n_layer * 2 * TINY.d_model * TINY.n_expert
    assert abs(f_moe - f_dense) <= gate


def test_train_step_decreases_loss(params_moe):
    cfg = TINY
    step_fn, specs = train.make_train_step(cfg, lr=1e-2)
    names = [s.name for s in specs]
    flat = [params_moe[n] for n in names]
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    tok, tgt = _batch(cfg)

    jit_step = jax.jit(step_fn)
    losses = []
    for i in range(5):
        out = jit_step(tok, tgt, jnp.float32(i + 1), *flat, *m, *v)
        losses.append(float(out[0]))
        n = len(names)
        flat = list(out[1 : 1 + n])
        m = list(out[1 + n : 1 + 2 * n])
        v = list(out[1 + 2 * n :])
    assert losses[-1] < losses[0], losses


def test_grad_step_matches_train_direction(params_moe):
    """grad_step's gradients applied via the python Adam mirror must equal
    the fused train_step output (same math, two ABIs)."""
    cfg = TINY
    step_fn, specs = train.make_train_step(cfg, lr=1e-3)
    grad_fn, _ = train.make_grad_step(cfg)
    names = [s.name for s in specs]
    flat = [params_moe[n] for n in names]
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    tok, tgt = _batch(cfg)

    fused = step_fn(tok, tgt, jnp.float32(1.0), *flat, *m, *v)
    gout = grad_fn(tok, tgt, *flat)
    np.testing.assert_allclose(float(fused[0]), float(gout[0]), rtol=1e-5)
    n = len(names)
    for i in range(n):
        p2, _, _ = train.adam_update(
            flat[i], gout[1 + i], m[i], v[i], jnp.float32(1.0), 1e-3
        )
        np.testing.assert_allclose(fused[1 + i], p2, rtol=1e-5, atol=1e-7,
                                   err_msg=names[i])


def test_eval_step_matches_loss(params_moe):
    cfg = TINY
    eval_fn, specs = train.make_eval_step(cfg)
    names = [s.name for s in specs]
    tok, tgt = _batch(cfg)
    (loss,) = eval_fn(tok, tgt, *[params_moe[n] for n in names])
    direct = gpt.lm_loss(params_moe, tok, tgt, cfg)
    np.testing.assert_allclose(float(loss), float(direct), rtol=1e-6)


# ---------------------------------------------------------------------------
# Stage graphs vs fused layer: the distributed path must be the same math
# ---------------------------------------------------------------------------

def test_staged_moe_layer_equals_fused(rng):
    """Emulate the Rust coordinator's stage chain in numpy and check it
    reproduces the fused MoE layer exactly (no capacity drops)."""
    from compile import layers

    nb, dm, dh, ne, k = 24, 8, 16, 4, 2
    x = jnp.asarray(rng.standard_normal((nb, dm)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((dm, ne)), jnp.float32)
    bg = jnp.asarray(rng.standard_normal(ne) * 0.1, jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((ne, dm, dh)) * 0.3, jnp.float32)
    b1 = jnp.asarray(rng.standard_normal((ne, dh)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((ne, dh, dm)) * 0.3, jnp.float32)
    b2 = jnp.asarray(rng.standard_normal((ne, dm)) * 0.1, jnp.float32)

    fused = layers.moe_ffn(x, wg, bg, w1, b1, w2, b2, k=k, capacity=nb * k)

    # --- stage chain (host logic in numpy, kernels via stages.*) ---
    (scores,) = stages.gate_fwd(x, wg, bg)
    w_gate, idx = stages.topk_softmax(scores, k)
    w_gate, idx = np.asarray(w_gate), np.asarray(idx)

    # host dispatch: slot per assignment ordered by expert (like Rust)
    flat_e = idx.reshape(-1)
    order = np.argsort(flat_e, kind="stable")
    slots = np.empty(nb * k, np.int32)
    slots[order] = np.arange(nb * k)
    counts = np.bincount(flat_e, minlength=ne)

    # pack rows in slot order (host scatter), bucket per expert = max count
    cap = max(1, int(counts.max()))
    xs = np.zeros((ne, cap, dm), np.float32)
    xnp = np.asarray(x)
    token_of_flat = np.arange(nb * k) // k
    offs = np.zeros(ne, np.int64)
    start = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for a in order:  # assignments grouped by expert
        e = flat_e[a]
        xs[e, offs[e]] = xnp[token_of_flat[a]]
        offs[e] += 1

    (ys,) = stages.expert_fwd(jnp.asarray(xs), w1, b1, w2, b2)
    ys = np.asarray(ys)

    # unpack back to slot-ordered flat rows
    y_slots = np.zeros((nb * k, dm), np.float32)
    offs[:] = 0
    for a in order:
        e = flat_e[a]
        y_slots[slots[a]] = ys[e, offs[e]]
        offs[e] += 1

    (out,) = stages.combine_fwd(
        jnp.asarray(y_slots),
        jnp.asarray(slots.reshape(nb, k)),
        jnp.asarray(w_gate),
    )
    np.testing.assert_allclose(out, fused, rtol=2e-4, atol=2e-5)


def test_topk_softmax_equals_renormalized_softmax(rng):
    """The two gating formulations used in fused vs staged paths are the
    same function — this equality is what licenses the split."""
    scores = jnp.asarray(rng.standard_normal((40, 8)), jnp.float32)
    from compile.kernels.ref import topk_gate_ref

    w1, i1 = stages.topk_softmax(scores, 2)
    w2, i2 = topk_gate_ref(scores, 2)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(w1, w2, rtol=1e-5)


def test_gate_bwd_matches_autodiff(rng):
    x = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((8, 6)), jnp.float32)
    bg = jnp.asarray(rng.standard_normal(6), jnp.float32)
    ds = jnp.asarray(rng.standard_normal((16, 6)), jnp.float32)

    def f(x, wg, bg):
        (s,) = stages.gate_fwd(x, wg, bg)
        return jnp.sum(s * ds)

    want = jax.grad(f, argnums=(0, 1, 2))(x, wg, bg)
    got = stages.gate_bwd(x, wg, ds)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_expert_bwd_matches_autodiff(rng):
    ne, b, dm, dh = 2, 8, 4, 8
    xs = jnp.asarray(rng.standard_normal((ne, b, dm)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((ne, dm, dh)) * 0.3, jnp.float32)
    b1 = jnp.asarray(rng.standard_normal((ne, dh)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((ne, dh, dm)) * 0.3, jnp.float32)
    b2 = jnp.asarray(rng.standard_normal((ne, dm)) * 0.1, jnp.float32)
    dys = jnp.asarray(rng.standard_normal((ne, b, dm)), jnp.float32)

    got = stages.expert_bwd(xs, w1, b1, w2, b2, dys)

    def f(xs, w1, b1, w2, b2):
        (y,) = stages.expert_fwd(xs, w1, b1, w2, b2)
        return jnp.sum(y * dys)

    want = jax.grad(f, argnums=tuple(range(5)))(xs, w1, b1, w2, b2)
    for a, b_, nm in zip(got, want, ["dxs", "dw1", "db1", "dw2", "db2"]):
        np.testing.assert_allclose(a, b_, rtol=5e-4, atol=1e-5, err_msg=nm)


def test_combine_bwd_matches_autodiff(rng):
    nb, k, dm = 12, 2, 6
    n_slots = nb * k
    ys = jnp.asarray(rng.standard_normal((n_slots, dm)), jnp.float32)
    slots = jnp.asarray(rng.permutation(n_slots).reshape(nb, k).astype(np.int32))
    w = jnp.asarray(rng.random((nb, k)), jnp.float32)
    dout = jnp.asarray(rng.standard_normal((nb, dm)), jnp.float32)

    got = stages.combine_bwd(ys, slots, w, dout)

    def f(ys, w):
        (o,) = stages.combine_fwd(ys, slots, w)
        return jnp.sum(o * dout)

    want = jax.grad(f, argnums=(0, 1))(ys, w)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_topk_softmax_bwd_matches_autodiff(rng):
    scores = jnp.asarray(rng.standard_normal((10, 6)), jnp.float32)
    dw = jnp.asarray(rng.standard_normal((10, 2)), jnp.float32)
    got = stages.topk_softmax_bwd(scores, 2, dw)

    def f(s):
        w, _ = stages.topk_softmax(s, 2)
        return jnp.sum(w * dw)

    want = jax.grad(f)(scores)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)
