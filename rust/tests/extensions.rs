//! Extension-feature integration tests: balance-loss training, config
//! file round-trip through the launcher path, checkpoint-resume.

use std::sync::Arc;

use fastmoe::config::ConfigFile;
use fastmoe::coordinator::Trainer;
use fastmoe::data::{BatchIter, Corpus};
use fastmoe::model::{load_checkpoint, save_checkpoint};
use fastmoe::runtime::Runtime;

fn rt() -> Option<Arc<Runtime>> {
    Runtime::open_default().ok().map(Arc::new)
}

#[test]
fn balance_loss_model_trains() {
    let Some(rt) = rt() else { return };
    if rt.manifest.models.get("gpt_moe_bal").is_none() {
        return;
    }
    let mut tr = Trainer::new(&rt, "gpt_moe_bal", 2).unwrap();
    let vocab = tr.entry.config_usize("vocab").unwrap();
    let seq = tr.entry.config_usize("seq").unwrap();
    let batch = tr.entry.config_usize("batch").unwrap();
    let corpus = Corpus::synthetic(vocab, 60_000, 13);
    let mut it = BatchIter::new(&corpus, batch, seq, 6);
    let first = tr.train_step(&it.next_batch()).unwrap().loss;
    let mut last = first;
    for _ in 0..6 {
        last = tr.train_step(&it.next_batch()).unwrap().loss;
    }
    // loss includes +0.01·aux (aux ≥ 1), still must decrease
    assert!(last < first, "first={first} last={last}");
    assert!(tr.params.all_finite());
}

#[test]
fn sample_config_file_parses_and_validates() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/default.toml");
    let cfg = ConfigFile::load(path).unwrap();
    let m = cfg.model().unwrap();
    assert!(m.moe && m.n_expert == 16);
    let t = cfg.train().unwrap();
    assert_eq!(t.model, "gpt_moe");
    assert!((t.lr - 3e-4).abs() < 1e-12);
    let d = cfg.dist().unwrap();
    assert_eq!(d.workers, 4);
}

#[test]
fn checkpoint_resume_reproduces_training() {
    let Some(rt) = rt() else { return };
    let corpus = Corpus::synthetic(64, 60_000, 3);

    // run A: 4 steps straight
    let mut a = Trainer::new(&rt, "gpt_moe", 5).unwrap();
    let seq = a.entry.config_usize("seq").unwrap();
    let batch = a.entry.config_usize("batch").unwrap();
    let vocab = a.entry.config_usize("vocab").unwrap();
    let corpus = if vocab == 64 { corpus } else { Corpus::synthetic(vocab, 60_000, 3) };
    let mut it = BatchIter::new(&corpus, batch, seq, 8);
    let batches: Vec<_> = (0..4).map(|_| it.next_batch()).collect();
    for b in &batches[..2] {
        a.train_step(b).unwrap();
    }
    // checkpoint the *parameters* mid-run
    let ck = std::env::temp_dir().join(format!("fastmoe_resume_{}", std::process::id()));
    save_checkpoint(&ck, &a.params).unwrap();

    // run B: fresh trainer, load params, replay remaining batches with a
    // fresh optimizer; loss trajectory must start from A's loss level
    let mut b_tr = Trainer::new(&rt, "gpt_moe", 999).unwrap();
    load_checkpoint(&ck, &mut b_tr.params).unwrap();
    let la = a.eval(&batches[2]).unwrap();
    let lb = b_tr.eval(&batches[2]).unwrap();
    assert!((la - lb).abs() < 1e-5, "restored params diverge: {la} vs {lb}");
    let _ = std::fs::remove_file(ck);
}
