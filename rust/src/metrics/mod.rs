//! Metrics: timers, counters, throughput accounting, CSV/JSONL sinks.
//!
//! Every experiment binary logs through this module so EXPERIMENTS.md
//! rows can be regenerated from the emitted files.

use std::collections::BTreeMap;
use std::io::Write;
use std::time::{Duration, Instant};

use crate::error::Result;

/// Wall-clock stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Streaming summary statistics (Welford).
///
/// `n`/mean/std/min/max are exact for the whole stream.  Percentiles
/// are served from a bounded, *deterministically seeded* reservoir
/// ([`Summary::RESERVOIR`] samples, algorithm R with an inline
/// xorshift64): exact while the stream fits the reservoir — every
/// existing few-hundred-sample bench is unchanged — and an unbiased
/// estimate beyond it, instead of the previous unbounded `samples`
/// vector (a slow leak in any long-lived process that kept a `Summary`
/// per metric).  The fixed seed keeps runs reproducible.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
    samples: Vec<f64>,
    /// xorshift64 state for reservoir eviction; lazily (re)seeded so a
    /// `Default`-constructed summary never sticks at the zero state.
    rng: u64,
}

impl Summary {
    /// Reservoir capacity: percentiles are exact below this, sampled
    /// above it.  4096 f64s ≈ 32 KiB per summary, a hard ceiling.
    pub const RESERVOIR: usize = 4096;

    pub fn new() -> Self {
        Self { min: f64::INFINITY, max: f64::NEG_INFINITY, ..Default::default() }
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = if self.rng == 0 { 0x9E37_79B9_7F4A_7C15 } else { self.rng };
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng = x;
        x
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.samples.len() < Self::RESERVOIR {
            self.samples.push(x);
        } else {
            // algorithm R: the n-th sample replaces a reservoir slot
            // with probability RESERVOIR/n
            let j = (self.next_u64() % self.n) as usize;
            if j < Self::RESERVOIR {
                self.samples[j] = x;
            }
        }
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }
}

/// Fixed-bucket histogram with percentile extraction — the serving
/// path's latency tracker.
///
/// Unlike [`Summary`] (which keeps every sample and sorts on demand —
/// fine for a bench's few hundred step times), a histogram holds O(1)
/// state per bucket no matter how many requests pass through, which is
/// what a long-lived daemon needs.  Buckets are half-open ranges
/// `(bounds[i-1], bounds[i]]` over ascending upper `bounds`, plus an
/// implicit overflow bucket above the last bound.  Percentiles
/// interpolate linearly inside the bucket the rank falls in (the
/// overflow bucket reports its recorded maximum), so p50/p95/p99 come
/// out smooth rather than snapped to bucket edges.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    n: u64,
    sum: f64,
    max: f64,
}

impl Histogram {
    /// Build over ascending upper bucket bounds (an overflow bucket is
    /// implicit).  Panics on an empty or unsorted bound list — the
    /// presets are compile-time constants, so this is a programmer
    /// error, not input validation.
    pub fn new(bounds: &[f64]) -> Self {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be ascending"
        );
        Self {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            n: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    /// Latency preset in seconds: exponential bounds from 100 µs to
    /// ~52 s (20 buckets, ×1.9 steps) — covers sub-millisecond thread
    /// backend steps through multi-second cold-start outliers.
    pub fn latency() -> Self {
        let mut bounds = Vec::with_capacity(20);
        let mut b = 1e-4;
        for _ in 0..20 {
            bounds.push(b);
            b *= 1.9;
        }
        Self::new(&bounds)
    }

    pub fn record(&mut self, x: f64) {
        let i = self.bounds.partition_point(|&b| b < x);
        self.counts[i] += 1;
        self.n += 1;
        self.sum += x;
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// The `p`-th percentile (0–100), linearly interpolated within the
    /// bucket the rank lands in; `0.0` when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * self.n as f64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (seen + c) as f64 >= rank {
                if i == self.bounds.len() {
                    // overflow bucket: no upper bound, report the max
                    return self.max;
                }
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                let frac = (rank - seen as f64) / c as f64;
                return lo + (hi - lo) * frac.clamp(0.0, 1.0);
            }
            seen += c;
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Merge another histogram recorded over the *same* bounds (e.g.
    /// per-session trackers into the daemon total).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds, "merging mismatched histograms");
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

/// Named counters (bytes sent, tokens dropped, …).
#[derive(Default, Debug, Clone)]
pub struct Counters {
    map: BTreeMap<String, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, v: u64) {
        *self.map.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.map.get(name).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &u64)> {
        self.map.iter()
    }

    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in &other.map {
            self.add(k, *v);
        }
    }

    /// A point-in-time copy, for later differencing with
    /// [`Counters::delta_since`].  Counters are process-lifetime
    /// monotonic by design (benches report totals), so any *windowed*
    /// consumer — the autotune `Calibrator`, Rebalancer-style loops —
    /// must work on deltas or it silently mixes in all prior history.
    pub fn snapshot(&self) -> Counters {
        self.clone()
    }

    /// Per-key difference `self - earlier` (saturating: a key that
    /// shrank — e.g. after an external reset — reads as 0 rather than
    /// wrapping).  Keys absent from `earlier` count in full; keys only
    /// in `earlier` are omitted (their delta is 0).
    pub fn delta_since(&self, earlier: &Counters) -> Counters {
        let mut out = Counters::new();
        for (k, v) in &self.map {
            let d = v.saturating_sub(earlier.get(k));
            if d > 0 {
                out.add(k, d);
            }
        }
        out
    }
}

/// Scoped phase timer: measures one instrumented region and records it
/// as a nanosecond counter (`<name>` holds summed ns, u64).  An explicit
/// `stop` call — not a Drop guard — so the region body keeps free use
/// of `&mut Counters`:
///
/// ```ignore
/// let t = Phase::start();
/// /* ... dispatch wire ... */
/// t.stop(counters, "phase_dispatch_ns");
/// ```
pub struct Phase {
    start: Instant,
}

impl Phase {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Record the elapsed nanoseconds under `name` and consume the
    /// timer.
    pub fn stop(self, counters: &mut Counters, name: &str) {
        counters.add(name, self.start.elapsed().as_nanos() as u64);
    }

    /// Elapsed seconds without recording (for callers that fold the
    /// measurement into an existing accumulator).
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// CSV writer with a fixed header.
pub struct CsvWriter {
    file: std::fs::File,
    cols: usize,
}

impl CsvWriter {
    pub fn create(path: &str, header: &[&str]) -> Result<Self> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(Self { file, cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        debug_assert_eq!(values.len(), self.cols, "csv row arity");
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, values: &[f64]) -> Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// Matmul FLOPs of an `[m,k]·[k,n]` product.
pub fn matmul_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

/// Forward matmul FLOPs of one MoE FFN layer application over `rows`
/// tokens-assignments (two GEMMs per expert row).
pub fn moe_ffn_flops(rows: usize, d_model: usize, d_hidden: usize) -> f64 {
    2.0 * matmul_flops(rows, d_model, d_hidden)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_edges() {
        let mut s = Summary::new();
        s.add(10.0);
        assert_eq!(s.p50(), 10.0);
        assert_eq!(s.p95(), 10.0);
        assert_eq!(Summary::new().p50(), 0.0);
    }

    #[test]
    fn summary_reservoir_is_bounded_and_deterministic() {
        // Pre-fix, `samples` grew one f64 per `add` forever.  The
        // reservoir must cap memory, keep the exact aggregates, stay
        // a sane percentile estimate, and reproduce bit-for-bit across
        // runs (fixed seed).
        let feed = |s: &mut Summary| {
            for i in 0..100_000u64 {
                // a shuffled-looking but deterministic 0..1000 stream
                s.add((i.wrapping_mul(7919) % 1000) as f64);
            }
        };
        let mut s = Summary::new();
        feed(&mut s);
        assert_eq!(s.samples.len(), Summary::RESERVOIR);
        assert_eq!(s.n, 100_000);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 999.0);
        // uniform 0..1000: the sampled p50 lands near 500
        let p50 = s.p50();
        assert!((p50 - 500.0).abs() < 60.0, "p50 = {p50}");
        assert!(s.percentile(95.0) > s.percentile(50.0));
        // identical stream → identical reservoir → identical bits
        let mut t = Summary::new();
        feed(&mut t);
        assert_eq!(s.p50().to_bits(), t.p50().to_bits());
        assert_eq!(s.p95().to_bits(), t.p95().to_bits());
        // below the cap the reservoir is the whole stream: exact
        let mut small = Summary::new();
        for i in 0..100 {
            small.add(i as f64);
        }
        assert_eq!(small.samples.len(), 100);
        assert_eq!(small.p50(), 50.0);
    }

    #[test]
    fn histogram_percentiles_interpolate() {
        // uniform 0..100 into 10 equal buckets: percentiles ≈ identity
        let bounds: Vec<f64> = (1..=10).map(|i| i as f64 * 10.0).collect();
        let mut h = Histogram::new(&bounds);
        for i in 0..1000 {
            h.record(i as f64 / 10.0 + 0.05);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.p50() - 50.0).abs() < 1.0, "p50 = {}", h.p50());
        assert!((h.p95() - 95.0).abs() < 1.0, "p95 = {}", h.p95());
        assert!((h.p99() - 99.0).abs() < 1.0, "p99 = {}", h.p99());
        assert!((h.mean() - 50.0).abs() < 0.5);
    }

    #[test]
    fn histogram_edges_and_overflow() {
        let mut h = Histogram::new(&[1.0, 2.0]);
        assert_eq!(h.p50(), 0.0); // empty
        h.record(0.5);
        h.record(1.5);
        h.record(10.0); // overflow bucket
        assert_eq!(h.count(), 3);
        // the overflow bucket reports its recorded max, not a bound
        assert_eq!(h.percentile(100.0), 10.0);
        assert!(h.p50() <= 2.0);
        // ordering: percentiles are monotone in p
        assert!(h.percentile(10.0) <= h.percentile(60.0));
        assert!(h.percentile(60.0) <= h.percentile(99.0));
    }

    #[test]
    fn histogram_merge_matches_combined_stream() {
        let mut a = Histogram::latency();
        let mut b = Histogram::latency();
        let mut all = Histogram::latency();
        for i in 1..=50 {
            let x = i as f64 * 1e-3;
            if i % 2 == 0 { a.record(x) } else { b.record(x) }
            all.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for p in [50.0, 95.0, 99.0] {
            assert!((a.percentile(p) - all.percentile(p)).abs() < 1e-12);
        }
    }

    #[test]
    fn counters_merge() {
        let mut a = Counters::new();
        a.add("bytes", 10);
        let mut b = Counters::new();
        b.add("bytes", 5);
        b.add("drops", 1);
        a.merge(&b);
        assert_eq!(a.get("bytes"), 15);
        assert_eq!(a.get("drops"), 1);
        assert_eq!(a.get("missing"), 0);
    }

    #[test]
    fn counter_windows_do_not_double_count() {
        // The lifetime-monotonic counter bug: a windowed consumer that
        // reads totals sees window 2 = window 1 + window 2.  Two
        // back-to-back windows over snapshots must each report exactly
        // their own traffic.
        let mut c = Counters::new();
        let w0 = c.snapshot();
        c.add("bytes", 100);
        c.add("steps", 1);
        let w1 = c.snapshot();
        let d1 = w1.delta_since(&w0);
        assert_eq!(d1.get("bytes"), 100);
        assert_eq!(d1.get("steps"), 1);
        c.add("bytes", 40);
        c.add("steps", 1);
        c.add("late", 7); // key born inside window 2 counts in full
        let d2 = c.delta_since(&w1);
        assert_eq!(d2.get("bytes"), 40, "window 2 must not include window 1");
        assert_eq!(d2.get("steps"), 1);
        assert_eq!(d2.get("late"), 7);
        // the lifetime total is untouched by snapshotting
        assert_eq!(c.get("bytes"), 140);
        // saturating: differencing against a *later* snapshot reads 0
        assert_eq!(w1.delta_since(&c).get("bytes"), 0);
    }

    #[test]
    fn phase_records_nanos() {
        let mut c = Counters::new();
        let t = Phase::start();
        std::thread::sleep(Duration::from_millis(2));
        t.stop(&mut c, "phase_test_ns");
        let ns = c.get("phase_test_ns");
        assert!(ns >= 1_000_000, "expected >= 1ms recorded, got {ns}ns");
        // additive across stops, like every other counter
        let t2 = Phase::start();
        t2.stop(&mut c, "phase_test_ns");
        assert!(c.get("phase_test_ns") >= ns);
    }

    #[test]
    fn csv_writes_rows() {
        let path = std::env::temp_dir().join("fastmoe_csv_test.csv");
        let path = path.to_str().unwrap();
        {
            let mut w = CsvWriter::create(path, &["a", "b"]).unwrap();
            w.rowf(&[1.0, 2.0]).unwrap();
            w.row(&["x".into(), "y".into()]).unwrap();
        }
        let text = std::fs::read_to_string(path).unwrap();
        assert_eq!(text, "a,b\n1,2\nx,y\n");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn flops_formulas() {
        assert_eq!(matmul_flops(2, 3, 4), 48.0);
        assert_eq!(moe_ffn_flops(10, 4, 8), 2.0 * 2.0 * 10.0 * 4.0 * 8.0);
    }
}
