#!/usr/bin/env bash
# Perf trajectory: run the sim-backed Figure-6 scaling bench with the
# exchange/compute overlap scored on AND off, and record the result as
# BENCH_pr2.json at the repo root.
#
#   scripts/bench_report.sh            # default: 4 chunks, 4 iters
#   CHUNKS=8 ITERS=8 scripts/bench_report.sh
#
# One bench invocation scores both modes (blocking `wire + compute` vs
# overlapped `max(wire, compute)` per chunk) from the same measured
# compute and exchange volume, so the comparison is apples-to-apples;
# a second invocation actually *exercises* the pipelined layer path
# (--overlap) as a correctness/perf sanity artifact under runs/.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
CHUNKS="${CHUNKS:-4}"
ITERS="${ITERS:-4}"

cd "$ROOT/rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install the rust toolchain" >&2
    echo "       (rustup.rs, or the image's baked-in rust_pallas toolchain)" >&2
    exit 1
fi

mkdir -p runs

# 1. measured on the blocking path, scored both ways → the PR record
cargo bench --bench fig6_scale -- \
    --iters "$ITERS" --chunks "$CHUNKS" --json "$ROOT/BENCH_pr2.json"

# 2. measured on the pipelined path (exercises chunked isend/irecv),
#    kept as a side artifact
cargo bench --bench fig6_scale -- \
    --iters "$ITERS" --chunks "$CHUNKS" --overlap \
    --json runs/fig6_overlap_measured.json

echo "bench_report.sh: wrote $ROOT/BENCH_pr2.json (and runs/fig6_overlap_measured.json)"
