//! Typed configuration + a TOML-subset parser + the `fmoefy` transform.
//!
//! The launcher reads a TOML-subset config file (sections, scalar keys,
//! flat arrays — everything our configs need), merges CLI overrides, and
//! produces the typed configs the rest of the system consumes.
//!
//! [`fmoefy`] reproduces the paper's Listing 1: take a *dense* model
//! config and return the MoE version of it — FFNs replaced by an expert
//! pool with the hidden size divided by `top_k` so per-token FLOPs stay
//! constant (§5.4).

mod toml;

pub use toml::TomlValue;

use crate::comm::Topology;
use crate::error::{Error, Result};

/// Model hyper-parameters (mirrors `python/compile/gpt.py::GptConfig`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub seq: usize,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_hidden: usize,
    pub moe: bool,
    pub n_expert: usize,
    pub top_k: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self {
            vocab: 256,
            seq: 128,
            n_layer: 4,
            d_model: 256,
            n_head: 8,
            d_hidden: 1024,
            moe: true,
            n_expert: 16,
            top_k: 2,
        }
    }
}

impl ModelConfig {
    /// Expert hidden size under FLOPs parity (§5.4).
    pub fn d_hidden_expert(&self) -> usize {
        (self.d_hidden / self.top_k).max(8)
    }

    /// Approximate parameter count (matches the python registry).
    pub fn n_params(&self) -> usize {
        let d = self.d_model;
        let mut n = self.vocab * d + self.seq * d; // embeddings
        for _ in 0..self.n_layer {
            n += 2 * d + d * 3 * d + 3 * d + d * d + d + 2 * d; // ln1+attn+ln2
            if self.moe {
                let de = self.d_hidden_expert();
                n += d * self.n_expert + self.n_expert; // gate
                n += self.n_expert * (d * de + de + de * d + d);
            } else {
                n += d * self.d_hidden + self.d_hidden + self.d_hidden * d + d;
            }
        }
        n += 2 * d + d * self.vocab; // final ln + head
        n
    }
}

/// Training-loop configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub model: String, // manifest model name, e.g. "gpt_moe"
    pub steps: usize,
    pub batch: usize,
    pub lr: f64,
    pub seed: u64,
    pub log_every: usize,
    pub eval_every: usize,
    pub checkpoint_every: usize,
    pub out_dir: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "gpt_moe".into(),
            steps: 200,
            batch: 4,
            lr: 3e-4,
            seed: 42,
            log_every: 10,
            eval_every: 50,
            checkpoint_every: 0,
            out_dir: "runs".into(),
        }
    }
}

/// MoE layer-assembly configuration — the `[moe]` config section,
/// consumed by `coordinator::MoeLayerBuilder`.
///
/// ```toml
/// [moe]
/// gate = "switch"        # "topk" (default) | "switch" | "noisy_topk"
/// capacity_factor = 1.25 # switch gate: per-expert capacity multiplier
/// noise_std = 1.0        # noisy_topk gate: score-noise std dev
/// balance_coef = 0.01    # GShard balance-loss gradient weight (0 = off)
/// ```
///
/// `balance_coef` defaults to `0.01`: FastMoE-style training wants the
/// gate nudged toward balanced routing out of the box.  Set it to `0`
/// (config or `--balance-coef 0`) to reproduce the pre-balance seed
/// gradients bit-for-bit.
#[derive(Clone, Debug, PartialEq)]
pub struct MoeConfig {
    /// Gate kind: "topk" | "switch" | "noisy_topk".
    pub gate: String,
    /// Switch gate: each expert accepts up to
    /// `ceil(capacity_factor * nb / n_e)` tokens per batch.
    pub capacity_factor: f64,
    /// Noisy top-k gate: std dev of the Gaussian score noise.
    pub noise_std: f64,
    /// Weight of the GShard auxiliary balance-loss gradient added to
    /// the gate scores on the backward pass (`Gate::balance_grad`).
    /// Defaults to `0.01`; `0` disables it and restores the pre-wiring
    /// gradients exactly.
    pub balance_coef: f64,
}

impl Default for MoeConfig {
    fn default() -> Self {
        Self {
            gate: "topk".into(),
            capacity_factor: 1.25,
            noise_std: 1.0,
            balance_coef: 0.01,
        }
    }
}

impl MoeConfig {
    /// The `[moe]` section of an optional `--config` file, with
    /// `--gate`, `--capacity-factor`, `--noise-std` and
    /// `--balance-coef` CLI overrides — the one merge rule shared by
    /// the launcher and the examples.
    pub fn from_args(args: &crate::cli::Args) -> Result<MoeConfig> {
        let mut cfg = if let Some(path) = args.get("config") {
            ConfigFile::load(path)?.moe()?
        } else {
            MoeConfig::default()
        };
        cfg.gate = args.choice_or("gate", GATE_KINDS, &cfg.gate)?;
        cfg.capacity_factor = args.f64_or("capacity-factor", cfg.capacity_factor)?;
        cfg.noise_std = args.f64_or("noise-std", cfg.noise_std)?;
        cfg.balance_coef = args.f64_or("balance-coef", cfg.balance_coef)?;
        Ok(cfg)
    }
}

/// Communication configuration — the `[comm]` config section,
/// consumed by `coordinator::MoeLayerBuilder` and the launcher.
///
/// ```toml
/// [comm]
/// overlap = true      # pipeline dispatch / expert compute / combine
/// chunks = 4          # ring-offset peer groups per exchange (1 = blocking,
///                     # 0 = adaptive from the previous step's wire:compute ratio)
/// chunk_policy = "mean" # how ranks agree the adaptive chunk count from
///                     # their measured ratios: "mean" | "max" (straggler-aware)
/// pool = true         # step-persistent buffer pools on the MoE hot path
/// progress = false    # TCP progress engine (reader threads drain arrivals
///                     # during expert compute; tcp backend only)
/// grad_overlap = true # bucketed nonblocking gradient all-reduce in the
///                     # trainers, overlapped with backward / host Adam
/// bucket_kb = 512     # target gradient-bucket payload (KiB; tensors are
///                     # never split across buckets)
/// grad_shard = "none" # ZeRO optimizer-state sharding: "none" | "zero"
///                     # (reduce-scatter grads, shard-local Adam on the
///                     # owned slice, all-gather the updated params)
/// topology = "hier"   # collective routing policy: "flat" (default, the
///                     # seed ring) | "hier" (node-aware: leader-aggregated
///                     # all-to-all, two-level tree all-reduce)
/// nodes = 2           # hier: number of nodes (0 = derive / default 2)
/// local_size = 4      # hier: ranks per node (0 = derive from `nodes`;
///                     # contiguous rank blocks, lowest rank = node leader)
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct CommConfig {
    /// Pipeline the MoE exchanges against expert compute (§4 overlap).
    /// Off by default: the blocking path is the seed behaviour and the
    /// `chunks = 1` degenerate case of the pipelined one.
    pub overlap: bool,
    /// Ring-offset peer groups per exchange; clamped to the worker
    /// count at layer-build time.  `0` picks the count adaptively each
    /// step from the previously measured wire:compute ratio
    /// (`moe::adaptive_chunks`).  Ignored unless `overlap` is on.
    pub chunks: usize,
    /// Recycle padded batches, cotangent containers and per-peer
    /// send/recv staging across steps through the layer's
    /// `BufferPool`.  On by default; `false` is the A/B knob (outputs
    /// are bit-identical either way).
    pub pool: bool,
    /// Run the TCP backend's progress engine (`TcpGroup::
    /// enable_progress`): per-peer reader threads drain socket
    /// arrivals while the expert shard computes, `isend` departs
    /// eagerly, and `wait_all` completes in true arrival order.
    /// Thread-channel workers ignore it.
    pub progress: bool,
    /// Overlapped gradient synchronisation in the trainers: the
    /// data-parallel grads go through the bucketed nonblocking
    /// all-reduce (`Comm::all_reduce_start`) instead of the serial
    /// blocking ring — `MoeLayerTrainer` flies the gate-grad bucket
    /// during the expert backward, `DistTrainer` pipelines bucket
    /// completions against host Adam.  Off by default (the seed
    /// schedule); results are bit-identical either way.
    pub grad_overlap: bool,
    /// Target gradient-bucket payload in KiB for `grad_overlap`.
    /// Tensors are never split across buckets (that is what keeps the
    /// bits identical to the per-tensor blocking rings), so a bucket
    /// is a run of whole same-tag tensors up to this size.  Must be
    /// ≥ 1.
    pub bucket_kb: usize,
    /// ZeRO-style optimizer-state sharding over the replicated
    /// (`world`-scope) parameters: `"none"` (the default — full Adam
    /// state on every rank) or `"zero"` — each tensor reduce-scatters
    /// so every rank owns one contiguous shard, Adam steps only the
    /// owned slice (state cut ~`1/world`), and the *updated params*
    /// all-gather back.  Bit-identical to the replicated path; under
    /// `topology = "hier"` the schedule is rail-aware (every local
    /// rank rings across nodes with its peer, no leader bottleneck).
    /// Incompatible with `grad_overlap` (the zero schedule is already
    /// bucketed and nonblocking).
    pub grad_shard: String,
    /// How the ranks agree the *adaptive* chunk count (`chunks = 0`)
    /// from their exchanged wire:compute ratios: `"mean"` (the
    /// default — average balance) or `"max"` (straggler-aware: the
    /// slowest rank's ratio decides, so a skewed-routing straggler
    /// pulls everyone to finer chunks).
    pub chunk_policy: String,
    /// Collective routing policy: `"flat"` (the default — every peer
    /// one ring, bit-for-bit the seed behaviour) or `"hier"` —
    /// node-aware collectives over the [`crate::comm::Topology`] from
    /// `nodes`/`local_size`: HetuMoE-style leader-aggregated
    /// all-to-all, two-level tree all-reduce, and a locality-ordered
    /// chunk schedule for the pipelined layer path.
    pub topology: String,
    /// Hier: node count.  `0` = derive from `local_size`, or default
    /// to 2 nodes when neither is given.
    pub nodes: usize,
    /// Hier: ranks per node (contiguous blocks; the lowest rank of a
    /// block is its leader).  `0` = derive from `nodes`.
    pub local_size: usize,
}

impl Default for CommConfig {
    fn default() -> Self {
        Self {
            overlap: false,
            chunks: 4,
            pool: true,
            progress: false,
            grad_overlap: false,
            bucket_kb: 512,
            grad_shard: "none".into(),
            chunk_policy: "mean".into(),
            topology: "flat".into(),
            nodes: 0,
            local_size: 0,
        }
    }
}

impl CommConfig {
    /// The `[comm]` section of an optional `--config` file, with the
    /// `--overlap` / `--no-overlap` / `--no-pool` / `--progress` /
    /// `--no-progress` / `--grad-overlap` / `--no-grad-overlap` flags
    /// and `--chunks N` (`0` = adaptive) / `--chunk-policy mean|max` /
    /// `--bucket-kb N` / `--grad-shard none|zero` /
    /// `--topology flat|hier` / `--nodes N` / `--local-size N`
    /// overrides.
    pub fn from_args(args: &crate::cli::Args) -> Result<CommConfig> {
        let mut cfg = if let Some(path) = args.get("config") {
            ConfigFile::load(path)?.comm()?
        } else {
            CommConfig::default()
        };
        if args.has_flag("overlap") {
            cfg.overlap = true;
        }
        if args.has_flag("no-overlap") {
            cfg.overlap = false;
        }
        if args.has_flag("no-pool") {
            cfg.pool = false;
        }
        if args.has_flag("progress") {
            cfg.progress = true;
        }
        if args.has_flag("no-progress") {
            cfg.progress = false;
        }
        if args.has_flag("grad-overlap") {
            cfg.grad_overlap = true;
        }
        if args.has_flag("no-grad-overlap") {
            cfg.grad_overlap = false;
        }
        cfg.chunks = args.usize_or("chunks", cfg.chunks)?;
        cfg.bucket_kb = args.usize_or("bucket-kb", cfg.bucket_kb)?;
        cfg.grad_shard =
            args.choice_or("grad-shard", GRAD_SHARD_KINDS, &cfg.grad_shard)?;
        cfg.chunk_policy =
            args.choice_or("chunk-policy", CHUNK_POLICIES, &cfg.chunk_policy)?;
        cfg.topology = args.choice_or("topology", TOPOLOGY_KINDS, &cfg.topology)?;
        cfg.nodes = args.usize_or("nodes", cfg.nodes)?;
        cfg.local_size = args.usize_or("local-size", cfg.local_size)?;
        cfg.validate()
    }

    fn validate(self) -> Result<CommConfig> {
        if self.bucket_kb == 0 {
            return Err(Error::Config(
                "comm.bucket_kb must be ≥ 1 (tensors are never split; \
                 use grad_overlap = false to disable bucketing)"
                    .into(),
            ));
        }
        if !GRAD_SHARD_KINDS.contains(&self.grad_shard.as_str()) {
            return Err(Error::Config(format!(
                "comm.grad_shard must be one of {GRAD_SHARD_KINDS:?}, got `{}`",
                self.grad_shard
            )));
        }
        if self.grad_shard == "zero" && self.grad_overlap {
            return Err(Error::Config(
                "comm.grad_shard = \"zero\" is already a bucketed \
                 nonblocking schedule — turn grad_overlap off"
                    .into(),
            ));
        }
        if !CHUNK_POLICIES.contains(&self.chunk_policy.as_str()) {
            return Err(Error::Config(format!(
                "comm.chunk_policy must be one of {CHUNK_POLICIES:?}, got `{}`",
                self.chunk_policy
            )));
        }
        if !TOPOLOGY_KINDS.contains(&self.topology.as_str()) {
            return Err(Error::Config(format!(
                "comm.topology must be one of {TOPOLOGY_KINDS:?}, got `{}`",
                self.topology
            )));
        }
        Ok(self)
    }

    /// Resolve the configured [`Topology`] for a concrete world size:
    /// `"flat"` ignores `nodes`/`local_size`; `"hier"` derives the
    /// node size from whichever of the two is given (both must agree
    /// if both are), defaulting to 2 nodes, and validates that the
    /// world divides evenly into contiguous node blocks.
    pub fn topology_for(&self, world: usize) -> Result<Topology> {
        if world == 0 {
            return Err(Error::Config("topology over an empty world".into()));
        }
        if self.topology == "flat" {
            return Ok(Topology::flat(world));
        }
        let local = if self.local_size > 0 {
            if self.nodes > 0 && self.nodes * self.local_size != world {
                return Err(Error::Config(format!(
                    "comm: nodes = {} × local_size = {} ≠ {} workers",
                    self.nodes, self.local_size, world
                )));
            }
            self.local_size
        } else if self.nodes > 0 {
            if world % self.nodes != 0 {
                return Err(Error::Config(format!(
                    "comm: {world} workers not divisible into {} nodes",
                    self.nodes
                )));
            }
            world / self.nodes
        } else if world % 2 == 0 {
            world / 2 // the default hier shape: two nodes
        } else {
            return Err(Error::Config(format!(
                "comm: topology = \"hier\" with {world} workers needs an \
                 explicit nodes / local_size split"
            )));
        };
        Topology::new(world, local)
    }

    /// Resolve the [`Topology`] for a concrete multi-host `hosts` list
    /// (one `addr[:port]` entry per rank, as taken by
    /// `TcpGroup::connect`).  When `topology = "hier"` and neither
    /// `nodes` nor `local_size` is pinned explicitly, the node split
    /// is *discovered* from the addresses — ranks on the same address
    /// share a node ([`Topology::from_hosts`]) — so cross-machine
    /// `--backend tcp` self-configures.  Explicit knobs (or `"flat"`)
    /// keep their [`CommConfig::topology_for`] meaning.
    pub fn topology_for_hosts(&self, hosts: &[String]) -> Result<Topology> {
        if self.topology == "hier" && self.nodes == 0 && self.local_size == 0 {
            let t = Topology::from_hosts(hosts)?;
            if t.hierarchical() {
                return Ok(t);
            }
            // a single host (or an undiscoverable layout) falls back to
            // the explicit-knob path, which defaults to two nodes
        }
        self.topology_for(hosts.len())
    }
}

/// Valid `[comm] topology` values.
pub const TOPOLOGY_KINDS: &[&str] = &["flat", "hier"];

/// Valid `[comm] grad_shard` values.
pub const GRAD_SHARD_KINDS: &[&str] = &["none", "zero"];

/// Valid `[comm] chunk_policy` values — aliased from
/// [`crate::moe::ChunkPolicy::KINDS`], the single source of truth.
pub const CHUNK_POLICIES: &[&str] = crate::moe::ChunkPolicy::KINDS;

pub const GATE_KINDS: &[&str] = &["topk", "switch", "noisy_topk"];

/// Serving configuration — the `[serve]` config section, consumed by
/// the `fastmoe serve` daemon (`crate::serve`).
///
/// ```toml
/// [serve]
/// port = 47800        # front-end listener port for client sessions
/// max_batch = 0       # token rows admitted per step (0 = the layer batch)
/// queue_depth = 1024  # queued-token bound; past it requests are rejected
/// idle_ms = 50        # batcher wait for arrivals before an undersized step
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Front-end listener port for client sessions (the expert-parallel
    /// mesh keeps its own `base_port + rank` range).
    pub port: usize,
    /// Token rows admitted into one forward step.  `0` (the default)
    /// means the full layer batch `nb`; larger values are clamped to
    /// `nb` at daemon start.
    pub max_batch: usize,
    /// Bound on tokens queued *beyond* the in-flight batch: a request
    /// that would push the queue past this is rejected immediately
    /// (admission control) instead of stalling every client behind it.
    pub queue_depth: usize,
    /// How long the batcher waits for more arrivals before running an
    /// undersized step — continuous batching's latency/utilisation
    /// knob, in milliseconds.
    pub idle_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { port: 47800, max_batch: 0, queue_depth: 1024, idle_ms: 50 }
    }
}

impl ServeConfig {
    /// The `[serve]` section of an optional `--config` file, with
    /// `--serve-port`, `--max-batch`, `--queue-depth` and `--idle-ms`
    /// CLI overrides.  (`--port` stays the mesh base port, as in
    /// `dist-moe`.)
    pub fn from_args(args: &crate::cli::Args) -> Result<ServeConfig> {
        let mut cfg = if let Some(path) = args.get("config") {
            ConfigFile::load(path)?.serve()?
        } else {
            ServeConfig::default()
        };
        cfg.port = args.usize_or("serve-port", cfg.port)?;
        cfg.max_batch = args.usize_or("max-batch", cfg.max_batch)?;
        cfg.queue_depth = args.usize_or("queue-depth", cfg.queue_depth)?;
        cfg.idle_ms = args.u64_or("idle-ms", cfg.idle_ms)?;
        cfg.validate()
    }

    fn validate(self) -> Result<ServeConfig> {
        if self.port == 0 || self.port > 65535 {
            return Err(Error::Config(format!(
                "serve.port must be in 1..=65535, got {}",
                self.port
            )));
        }
        if self.queue_depth == 0 {
            return Err(Error::Config(
                "serve.queue_depth must be ≥ 1 (a zero queue would reject \
                 every request the batch cannot take immediately)"
                    .into(),
            ));
        }
        if self.idle_ms == 0 {
            return Err(Error::Config(
                "serve.idle_ms must be ≥ 1 (the batcher needs a wait bound)"
                    .into(),
            ));
        }
        Ok(self)
    }
}

/// Dynamic expert placement — the `[placement]` config section,
/// consumed by `coordinator::MoeLayerTrainer::with_placement` via
/// [`crate::placement::Rebalancer::from_config`].
///
/// ```toml
/// [placement]
/// policy = "shadow"  # "static" (default) | "shadow" | "migrate"
/// threshold = 1.5    # act when max/mean window row load exceeds this
/// window = 8         # steps per decision window (and its load history)
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct PlacementConfig {
    /// Re-sharding policy: `"static"` (never move anything — the seed
    /// layout, no decision traffic), `"shadow"` (replicate the hottest
    /// expert onto the least-loaded rank) or `"migrate"` (swap the
    /// hottest expert with a cold rank's coldest one, Adam state and
    /// all).
    pub policy: String,
    /// Max/mean per-rank row-load ratio above which the rebalancer
    /// acts; at or below it, standing shadows are dropped.
    pub threshold: f64,
    /// Decision cadence in steps — also the sliding-window length of
    /// the load history the decision is computed from.
    pub window: usize,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        Self { policy: "static".into(), threshold: 1.5, window: 8 }
    }
}

impl PlacementConfig {
    /// The `[placement]` section of an optional `--config` file, with
    /// `--placement`, `--placement-threshold` and `--placement-window`
    /// CLI overrides.
    pub fn from_args(args: &crate::cli::Args) -> Result<PlacementConfig> {
        let mut cfg = if let Some(path) = args.get("config") {
            ConfigFile::load(path)?.placement()?
        } else {
            PlacementConfig::default()
        };
        cfg.policy = args.choice_or(
            "placement",
            crate::placement::PlacementPolicy::KINDS,
            &cfg.policy,
        )?;
        cfg.threshold = args.f64_or("placement-threshold", cfg.threshold)?;
        cfg.window = args.usize_or("placement-window", cfg.window)?;
        cfg.validate()
    }

    fn validate(self) -> Result<PlacementConfig> {
        if !crate::placement::PlacementPolicy::KINDS.contains(&self.policy.as_str()) {
            return Err(Error::Config(format!(
                "placement.policy must be one of {:?}, got `{}`",
                crate::placement::PlacementPolicy::KINDS,
                self.policy
            )));
        }
        if !self.threshold.is_finite() || self.threshold < 1.0 {
            return Err(Error::Config(format!(
                "placement.threshold must be ≥ 1 (a max/mean ratio), got {}",
                self.threshold
            )));
        }
        if self.window == 0 {
            return Err(Error::Config(
                "placement.window must be ≥ 1 (steps per decision)".into(),
            ));
        }
        Ok(self)
    }
}

/// Elastic fault recovery — the `[fault]` config section, consumed by
/// [`crate::fault::Recovery`] and the trainers' checkpoint hooks.
///
/// ```toml
/// [fault]
/// recover = "degrade"   # "abort" (default) | "degrade" | "rejoin"
/// ckpt_interval = 50    # checkpoint every N steps (0 = off)
/// ckpt_dir = "runs/ckpt" # per-rank rank<r>.fmoe files land here
/// recv_timeout_ms = 0   # blocking-recv deadline (0 = wait forever)
/// chaos = ""            # deterministic schedule, e.g. "kill@5:r1, rejoin@9:r1"
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// What to do when a rank is declared dead: `"abort"` (the seed
    /// behaviour — fail the run), `"degrade"` (survivors continue with
    /// shadow-replica failover + score-masked drops) or `"rejoin"`
    /// (degrade, then restore the rank from checkpoint/peer transfer
    /// when its `rejoin@…` event fires).
    pub recover: String,
    /// Periodic-checkpoint cadence in steps; `0` disables.
    pub ckpt_interval: usize,
    /// Directory for the per-rank `rank<r>.fmoe` checkpoint files.
    pub ckpt_dir: String,
    /// Deadline for blocking receives in milliseconds; a peer silent
    /// past it surfaces as [`crate::error::Error::Timeout`] instead of
    /// hanging the rank.  `0` (the default) waits forever.
    pub recv_timeout_ms: u64,
    /// Deterministic chaos schedule ([`crate::fault::ChaosSchedule`]):
    /// comma-separated `kill@N:rR`, `delay@N:rR:MS`, `rejoin@N:rR`
    /// events fired at step boundaries.  Empty = no injection.
    pub chaos: String,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            recover: "abort".into(),
            ckpt_interval: 0,
            ckpt_dir: "runs/ckpt".into(),
            recv_timeout_ms: 0,
            chaos: String::new(),
        }
    }
}

impl FaultConfig {
    /// The `[fault]` section of an optional `--config` file, with
    /// `--recover`, `--ckpt-interval`, `--ckpt-dir`,
    /// `--recv-timeout-ms` and `--chaos` CLI overrides.
    pub fn from_args(args: &crate::cli::Args) -> Result<FaultConfig> {
        let mut cfg = if let Some(path) = args.get("config") {
            ConfigFile::load(path)?.fault()?
        } else {
            FaultConfig::default()
        };
        cfg.recover =
            args.choice_or("recover", crate::fault::RecoverMode::KINDS, &cfg.recover)?;
        cfg.ckpt_interval = args.usize_or("ckpt-interval", cfg.ckpt_interval)?;
        cfg.ckpt_dir = args.str_or("ckpt-dir", &cfg.ckpt_dir);
        cfg.recv_timeout_ms = args.u64_or("recv-timeout-ms", cfg.recv_timeout_ms)?;
        cfg.chaos = args.str_or("chaos", &cfg.chaos);
        cfg.validate()
    }

    fn validate(self) -> Result<FaultConfig> {
        crate::fault::RecoverMode::parse(&self.recover)?;
        crate::fault::ChaosSchedule::parse(&self.chaos)?;
        Ok(self)
    }
}

/// Online autotuning — the `[auto]` config section, consumed by
/// [`crate::autotune::Autotuner`] through the trainers.
///
/// ```toml
/// [auto]
/// enabled = true       # calibrate + search at all (default off)
/// calib_steps = 8      # instrumented steps per calibration window
/// retune_drift = 0.25  # re-calibrate when the measured step time drifts
///                      # more than this fraction from the prediction
/// apply = "report"     # "report" (log the recommendation, change nothing)
///                      # | "live" (apply safe-at-step-boundary knobs:
///                      # chunks, chunk_policy, bucket_kb — in lockstep)
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct AutoConfig {
    /// Master switch; off by default (the seed behaviour — no
    /// calibration traffic, no recommendation logging).
    pub enabled: bool,
    /// Steps per calibration window: the tuner accumulates phase
    /// timings and byte counters over this many steps before fitting
    /// the model and (re)searching.  Must be ≥ 1.
    pub calib_steps: usize,
    /// Relative drift (|measured − predicted| / predicted) of the
    /// rank-agreed mean step time above which a new calibration window
    /// opens.  Must be > 0 and finite; larger = more tolerant.
    pub retune_drift: f64,
    /// What to do with the search result: `"report"` logs the chosen
    /// config as a `[comm]` snippet and changes nothing (bit-identical
    /// to `enabled = false`); `"live"` applies the step-boundary-safe
    /// knobs (chunks, chunk_policy, bucket_kb) on every rank in
    /// lockstep.
    pub apply: String,
}

impl Default for AutoConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            calib_steps: 8,
            retune_drift: 0.25,
            apply: "report".into(),
        }
    }
}

impl AutoConfig {
    /// The `[auto]` section of an optional `--config` file, with the
    /// `--auto` / `--no-auto` flags and `--calib-steps N` /
    /// `--retune-drift X` / `--auto-apply report|live` CLI overrides.
    pub fn from_args(args: &crate::cli::Args) -> Result<AutoConfig> {
        let mut cfg = if let Some(path) = args.get("config") {
            ConfigFile::load(path)?.auto()?
        } else {
            AutoConfig::default()
        };
        if args.has_flag("auto") {
            cfg.enabled = true;
        }
        if args.has_flag("no-auto") {
            cfg.enabled = false;
        }
        cfg.calib_steps = args.usize_or("calib-steps", cfg.calib_steps)?;
        cfg.retune_drift = args.f64_or("retune-drift", cfg.retune_drift)?;
        cfg.apply = args.choice_or("auto-apply", AUTO_APPLY_KINDS, &cfg.apply)?;
        cfg.validate()
    }

    fn validate(self) -> Result<AutoConfig> {
        if self.calib_steps == 0 {
            return Err(Error::Config(
                "auto.calib_steps must be ≥ 1 (the fit needs at least one \
                 measured step)"
                    .into(),
            ));
        }
        if !self.retune_drift.is_finite() || self.retune_drift <= 0.0 {
            return Err(Error::Config(format!(
                "auto.retune_drift must be a positive fraction, got {}",
                self.retune_drift
            )));
        }
        if !AUTO_APPLY_KINDS.contains(&self.apply.as_str()) {
            return Err(Error::Config(format!(
                "auto.apply must be one of {AUTO_APPLY_KINDS:?}, got `{}`",
                self.apply
            )));
        }
        Ok(self)
    }
}

/// Valid `[auto] apply` values.
pub const AUTO_APPLY_KINDS: &[&str] = &["report", "live"];

/// Distributed-runtime configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct DistConfig {
    pub workers: usize,
    pub ne_local: usize,
    pub top_k: usize,
    /// Network preset for simulated wire time: "ib-edr", "pcie3", "none".
    pub net: String,
    pub seed: u64,
}

impl Default for DistConfig {
    fn default() -> Self {
        Self { workers: 4, ne_local: 4, top_k: 2, net: "ib-edr".into(), seed: 7 }
    }
}

/// `fmoefy(model, num_experts)` — Listing 1 of the paper as a config
/// transform: dense FFN -> expert pool at constant per-token FLOPs.
pub fn fmoefy(dense: &ModelConfig, n_expert: usize, top_k: usize) -> Result<ModelConfig> {
    if dense.moe {
        return Err(Error::Config("fmoefy: model is already MoE".into()));
    }
    if n_expert == 0 || top_k == 0 || top_k > n_expert {
        return Err(Error::Config(format!(
            "fmoefy: bad expert config n_expert={n_expert} top_k={top_k}"
        )));
    }
    let mut m = dense.clone();
    m.moe = true;
    m.n_expert = n_expert;
    m.top_k = top_k;
    Ok(m)
}

/// Load a config file section into the typed structs.
pub struct ConfigFile {
    root: TomlValue,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<Self> {
        Ok(Self { root: toml::parse(text)? })
    }

    pub fn load(path: &str) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    fn section(&self, name: &str) -> Option<&TomlValue> {
        self.root.get(name)
    }

    pub fn model(&self) -> Result<ModelConfig> {
        let mut m = ModelConfig::default();
        if let Some(s) = self.section("model") {
            m.vocab = s.usize_or("vocab", m.vocab);
            m.seq = s.usize_or("seq", m.seq);
            m.n_layer = s.usize_or("n_layer", m.n_layer);
            m.d_model = s.usize_or("d_model", m.d_model);
            m.n_head = s.usize_or("n_head", m.n_head);
            m.d_hidden = s.usize_or("d_hidden", m.d_hidden);
            m.moe = s.bool_or("moe", m.moe);
            m.n_expert = s.usize_or("n_expert", m.n_expert);
            m.top_k = s.usize_or("top_k", m.top_k);
        }
        if m.d_model % m.n_head != 0 {
            return Err(Error::Config(format!(
                "d_model {} not divisible by n_head {}",
                m.d_model, m.n_head
            )));
        }
        Ok(m)
    }

    pub fn train(&self) -> Result<TrainConfig> {
        let mut t = TrainConfig::default();
        if let Some(s) = self.section("train") {
            t.model = s.str_or("model", &t.model);
            t.steps = s.usize_or("steps", t.steps);
            t.batch = s.usize_or("batch", t.batch);
            t.lr = s.f64_or("lr", t.lr);
            t.seed = s.usize_or("seed", t.seed as usize) as u64;
            t.log_every = s.usize_or("log_every", t.log_every);
            t.eval_every = s.usize_or("eval_every", t.eval_every);
            t.checkpoint_every = s.usize_or("checkpoint_every", t.checkpoint_every);
            t.out_dir = s.str_or("out_dir", &t.out_dir);
        }
        if t.steps == 0 {
            return Err(Error::Config("train.steps must be > 0".into()));
        }
        Ok(t)
    }

    pub fn moe(&self) -> Result<MoeConfig> {
        let mut m = MoeConfig::default();
        if let Some(s) = self.section("moe") {
            m.gate = s.str_or("gate", &m.gate);
            m.capacity_factor = s.f64_or("capacity_factor", m.capacity_factor);
            m.noise_std = s.f64_or("noise_std", m.noise_std);
            m.balance_coef = s.f64_or("balance_coef", m.balance_coef);
        }
        if !GATE_KINDS.contains(&m.gate.as_str()) {
            return Err(Error::Config(format!(
                "moe.gate must be one of {GATE_KINDS:?}, got `{}`",
                m.gate
            )));
        }
        if !m.capacity_factor.is_finite() || m.capacity_factor <= 0.0 {
            return Err(Error::Config(format!(
                "moe.capacity_factor must be > 0, got {}",
                m.capacity_factor
            )));
        }
        if m.noise_std < 0.0 {
            return Err(Error::Config(format!(
                "moe.noise_std must be >= 0, got {}",
                m.noise_std
            )));
        }
        if !m.balance_coef.is_finite() || m.balance_coef < 0.0 {
            return Err(Error::Config(format!(
                "moe.balance_coef must be >= 0, got {}",
                m.balance_coef
            )));
        }
        Ok(m)
    }

    pub fn comm(&self) -> Result<CommConfig> {
        let mut c = CommConfig::default();
        if let Some(s) = self.section("comm") {
            c.overlap = s.bool_or("overlap", c.overlap);
            // 0 is meaningful: adaptive chunk count (moe::adaptive_chunks)
            c.chunks = s.usize_or("chunks", c.chunks);
            c.pool = s.bool_or("pool", c.pool);
            c.progress = s.bool_or("progress", c.progress);
            c.grad_overlap = s.bool_or("grad_overlap", c.grad_overlap);
            c.bucket_kb = s.usize_or("bucket_kb", c.bucket_kb);
            c.grad_shard = s.str_or("grad_shard", &c.grad_shard);
            c.chunk_policy = s.str_or("chunk_policy", &c.chunk_policy);
            c.topology = s.str_or("topology", &c.topology);
            c.nodes = s.usize_or("nodes", c.nodes);
            c.local_size = s.usize_or("local_size", c.local_size);
        }
        c.validate()
    }

    pub fn serve(&self) -> Result<ServeConfig> {
        let mut v = ServeConfig::default();
        if let Some(s) = self.section("serve") {
            v.port = s.usize_or("port", v.port);
            v.max_batch = s.usize_or("max_batch", v.max_batch);
            v.queue_depth = s.usize_or("queue_depth", v.queue_depth);
            v.idle_ms = s.usize_or("idle_ms", v.idle_ms as usize) as u64;
        }
        v.validate()
    }

    pub fn placement(&self) -> Result<PlacementConfig> {
        let mut p = PlacementConfig::default();
        if let Some(s) = self.section("placement") {
            p.policy = s.str_or("policy", &p.policy);
            p.threshold = s.f64_or("threshold", p.threshold);
            p.window = s.usize_or("window", p.window);
        }
        p.validate()
    }

    pub fn fault(&self) -> Result<FaultConfig> {
        let mut f = FaultConfig::default();
        if let Some(s) = self.section("fault") {
            f.recover = s.str_or("recover", &f.recover);
            f.ckpt_interval = s.usize_or("ckpt_interval", f.ckpt_interval);
            f.ckpt_dir = s.str_or("ckpt_dir", &f.ckpt_dir);
            f.recv_timeout_ms =
                s.usize_or("recv_timeout_ms", f.recv_timeout_ms as usize) as u64;
            f.chaos = s.str_or("chaos", &f.chaos);
        }
        f.validate()
    }

    pub fn auto(&self) -> Result<AutoConfig> {
        let mut a = AutoConfig::default();
        if let Some(s) = self.section("auto") {
            a.enabled = s.bool_or("enabled", a.enabled);
            a.calib_steps = s.usize_or("calib_steps", a.calib_steps);
            a.retune_drift = s.f64_or("retune_drift", a.retune_drift);
            a.apply = s.str_or("apply", &a.apply);
        }
        a.validate()
    }

    pub fn dist(&self) -> Result<DistConfig> {
        let mut d = DistConfig::default();
        if let Some(s) = self.section("dist") {
            d.workers = s.usize_or("workers", d.workers);
            d.ne_local = s.usize_or("ne_local", d.ne_local);
            d.top_k = s.usize_or("top_k", d.top_k);
            d.net = s.str_or("net", &d.net);
            d.seed = s.usize_or("seed", d.seed as usize) as u64;
        }
        if d.workers == 0 || !d.workers.is_power_of_two() {
            return Err(Error::Config(format!(
                "dist.workers must be a positive power of two, got {}",
                d.workers
            )));
        }
        Ok(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# training config
[model]
d_model = 128
n_layer = 2
moe = false

[train]
steps = 50
lr = 0.001
model = "gpt_dense"

[dist]
workers = 8
net = "ib-edr"

[moe]
gate = "switch"
capacity_factor = 1.5
balance_coef = 0.01

[comm]
overlap = true
chunks = 2

[placement]
policy = "shadow"
threshold = 2.0
window = 4
"#;

    #[test]
    fn parse_sections() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        let m = c.model().unwrap();
        assert_eq!(m.d_model, 128);
        assert_eq!(m.n_layer, 2);
        assert!(!m.moe);
        assert_eq!(m.vocab, 256); // default preserved
        let t = c.train().unwrap();
        assert_eq!(t.steps, 50);
        assert!((t.lr - 0.001).abs() < 1e-12);
        assert_eq!(t.model, "gpt_dense");
        let d = c.dist().unwrap();
        assert_eq!(d.workers, 8);
        let moe = c.moe().unwrap();
        assert_eq!(moe.gate, "switch");
        assert!((moe.capacity_factor - 1.5).abs() < 1e-12);
        assert!((moe.noise_std - 1.0).abs() < 1e-12); // default preserved
        assert!((moe.balance_coef - 0.01).abs() < 1e-12);
        let comm = c.comm().unwrap();
        assert!(comm.overlap);
        assert_eq!(comm.chunks, 2);
        let p = c.placement().unwrap();
        assert_eq!(p.policy, "shadow");
        assert!((p.threshold - 2.0).abs() < 1e-12);
        assert_eq!(p.window, 4);
    }

    #[test]
    fn placement_section_defaults_and_validation() {
        // no [placement] section at all → static defaults
        let c = ConfigFile::parse("[train]\nsteps = 1\n").unwrap();
        assert_eq!(c.placement().unwrap(), PlacementConfig::default());
        assert_eq!(c.placement().unwrap().policy, "static");
        // bad policy name, sub-unity threshold, zero window
        let c = ConfigFile::parse("[placement]\npolicy = \"teleport\"\n").unwrap();
        assert!(c.placement().is_err());
        let c = ConfigFile::parse("[placement]\nthreshold = 0.5\n").unwrap();
        assert!(c.placement().is_err());
        let c = ConfigFile::parse("[placement]\nwindow = 0\n").unwrap();
        assert!(c.placement().is_err());
        // CLI merge mirrors the other sections
        let argv = |s: &str| {
            crate::cli::Args::parse(s.split_whitespace().map(|x| x.to_string()), &[])
                .unwrap()
        };
        let cfg = PlacementConfig::from_args(&argv(
            "x --placement migrate --placement-threshold 1.25 --placement-window 2",
        ))
        .unwrap();
        assert_eq!(cfg.policy, "migrate");
        assert!((cfg.threshold - 1.25).abs() < 1e-12);
        assert_eq!(cfg.window, 2);
        assert_eq!(
            PlacementConfig::from_args(&argv("x")).unwrap(),
            PlacementConfig::default()
        );
        assert!(PlacementConfig::from_args(&argv("x --placement nowhere")).is_err());
    }

    #[test]
    fn comm_section_defaults_and_validation() {
        // no [comm] section at all → defaults (overlap off, pool on)
        let c = ConfigFile::parse("[train]\nsteps = 1\n").unwrap();
        assert_eq!(c.comm().unwrap(), CommConfig::default());
        assert!(!c.comm().unwrap().overlap);
        assert!(c.comm().unwrap().pool);
        assert!(!c.comm().unwrap().progress);
        // zero chunks = adaptive (picked from the measured ratio)
        let c = ConfigFile::parse("[comm]\nchunks = 0\n").unwrap();
        assert_eq!(c.comm().unwrap().chunks, 0);
        // pool / progress knobs parse
        let c = ConfigFile::parse("[comm]\npool = false\nprogress = true\n").unwrap();
        assert!(!c.comm().unwrap().pool);
        assert!(c.comm().unwrap().progress);
        // grad-sync knobs parse, and bucket_kb = 0 is rejected
        let c = ConfigFile::parse("[comm]\ngrad_overlap = true\nbucket_kb = 64\n")
            .unwrap();
        assert!(c.comm().unwrap().grad_overlap);
        assert_eq!(c.comm().unwrap().bucket_kb, 64);
        let c = ConfigFile::parse("[comm]\nbucket_kb = 0\n").unwrap();
        assert!(c.comm().is_err());
        // CLI merge: flags flip overlap, --chunks overrides
        let argv = |s: &str| {
            crate::cli::Args::parse(
                s.split_whitespace().map(|x| x.to_string()),
                &[
                    "overlap",
                    "no-overlap",
                    "no-pool",
                    "progress",
                    "no-progress",
                    "grad-overlap",
                    "no-grad-overlap",
                ],
            )
            .unwrap()
        };
        let cfg = CommConfig::from_args(&argv("x --overlap --chunks 8")).unwrap();
        assert!(cfg.overlap);
        assert_eq!(cfg.chunks, 8);
        let cfg = CommConfig::from_args(&argv("x")).unwrap();
        assert_eq!(cfg, CommConfig::default());
        assert!(!cfg.grad_overlap, "grad overlap must default off (seed schedule)");
        assert_eq!(cfg.bucket_kb, 512);
        // 0 = adaptive through the CLI as well
        let cfg = CommConfig::from_args(&argv("x --chunks 0")).unwrap();
        assert_eq!(cfg.chunks, 0);
        let cfg = CommConfig::from_args(&argv("x --no-pool --progress")).unwrap();
        assert!(!cfg.pool);
        assert!(cfg.progress);
        let cfg = CommConfig::from_args(&argv("x --grad-overlap --bucket-kb 32")).unwrap();
        assert!(cfg.grad_overlap);
        assert_eq!(cfg.bucket_kb, 32);
        assert!(CommConfig::from_args(&argv("x --bucket-kb 0")).is_err());
        // ZeRO sharding: off by default, togglable, validated
        assert_eq!(cfg.grad_shard, "none");
        let cfg = CommConfig::from_args(&argv("x --grad-shard zero")).unwrap();
        assert_eq!(cfg.grad_shard, "zero");
        assert!(CommConfig::from_args(&argv("x --grad-shard half")).is_err());
        // the zero schedule is already bucketed+nonblocking: grad_overlap
        // on top is rejected rather than silently ignored
        assert!(
            CommConfig::from_args(&argv("x --grad-shard zero --grad-overlap"))
                .is_err()
        );
        let c = ConfigFile::parse("[comm]\ngrad_shard = \"zero\"\n").unwrap();
        assert_eq!(c.comm().unwrap().grad_shard, "zero");
        let c = ConfigFile::parse("[comm]\ngrad_shard = \"ddp\"\n").unwrap();
        assert!(c.comm().is_err());
    }

    #[test]
    fn topology_knobs_parse_and_validate() {
        // defaults: flat, auto split, mean policy — the seed behaviour
        let c = ConfigFile::parse("[train]\nsteps = 1\n").unwrap();
        let cfg = c.comm().unwrap();
        assert_eq!(cfg.topology, "flat");
        assert_eq!(cfg.chunk_policy, "mean");
        assert_eq!((cfg.nodes, cfg.local_size), (0, 0));
        assert!(!cfg.topology_for(4).unwrap().hierarchical());
        // hier section parses; default split is two nodes
        let c = ConfigFile::parse("[comm]\ntopology = \"hier\"\n").unwrap();
        let cfg = c.comm().unwrap();
        let t = cfg.topology_for(4).unwrap();
        assert_eq!((t.nodes(), t.local_size()), (2, 2));
        assert!(t.hierarchical());
        // explicit local_size / nodes, and their consistency
        let c = ConfigFile::parse("[comm]\ntopology = \"hier\"\nlocal_size = 4\n")
            .unwrap();
        assert_eq!(c.comm().unwrap().topology_for(8).unwrap().nodes(), 2);
        let c = ConfigFile::parse("[comm]\ntopology = \"hier\"\nnodes = 4\n").unwrap();
        assert_eq!(c.comm().unwrap().topology_for(8).unwrap().local_size(), 2);
        let c = ConfigFile::parse(
            "[comm]\ntopology = \"hier\"\nnodes = 2\nlocal_size = 3\n",
        )
        .unwrap();
        assert!(c.comm().unwrap().topology_for(8).is_err()); // 2×3 ≠ 8
        let c = ConfigFile::parse("[comm]\ntopology = \"hier\"\nnodes = 3\n").unwrap();
        assert!(c.comm().unwrap().topology_for(8).is_err()); // 8 % 3
        // odd world without an explicit split cannot default to 2 nodes
        let c = ConfigFile::parse("[comm]\ntopology = \"hier\"\n").unwrap();
        assert!(c.comm().unwrap().topology_for(3).is_err());
        // bad enum values are rejected
        let c = ConfigFile::parse("[comm]\ntopology = \"star\"\n").unwrap();
        assert!(c.comm().is_err());
        let c = ConfigFile::parse("[comm]\nchunk_policy = \"median\"\n").unwrap();
        assert!(c.comm().is_err());
        // CLI overrides
        let argv = |s: &str| {
            crate::cli::Args::parse(s.split_whitespace().map(|x| x.to_string()), &[])
                .unwrap()
        };
        let cfg = CommConfig::from_args(&argv(
            "x --topology hier --nodes 2 --local-size 2 --chunk-policy max",
        ))
        .unwrap();
        assert_eq!(cfg.topology, "hier");
        assert_eq!(cfg.chunk_policy, "max");
        assert_eq!(cfg.topology_for(4).unwrap().local_size(), 2);
        assert!(CommConfig::from_args(&argv("x --topology ring")).is_err());
        assert!(CommConfig::from_args(&argv("x --chunk-policy min")).is_err());
    }

    #[test]
    fn serve_section_defaults_and_validation() {
        // no [serve] section at all → defaults
        let c = ConfigFile::parse("[train]\nsteps = 1\n").unwrap();
        assert_eq!(c.serve().unwrap(), ServeConfig::default());
        assert_eq!(c.serve().unwrap().port, 47800);
        assert_eq!(c.serve().unwrap().max_batch, 0);
        // section keys parse
        let c = ConfigFile::parse(
            "[serve]\nport = 48000\nmax_batch = 8\nqueue_depth = 32\nidle_ms = 5\n",
        )
        .unwrap();
        let cfg = c.serve().unwrap();
        assert_eq!(cfg.port, 48000);
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.queue_depth, 32);
        assert_eq!(cfg.idle_ms, 5);
        // admission control needs a nonzero queue, and a real port
        let c = ConfigFile::parse("[serve]\nqueue_depth = 0\n").unwrap();
        assert!(c.serve().is_err());
        let c = ConfigFile::parse("[serve]\nport = 0\n").unwrap();
        assert!(c.serve().is_err());
        let c = ConfigFile::parse("[serve]\nidle_ms = 0\n").unwrap();
        assert!(c.serve().is_err());
        // CLI merge: --serve-port (not --port, which stays the mesh base)
        let argv = |s: &str| {
            crate::cli::Args::parse(s.split_whitespace().map(|x| x.to_string()), &[])
                .unwrap()
        };
        let cfg = ServeConfig::from_args(&argv(
            "x --serve-port 48100 --max-batch 4 --queue-depth 16 --idle-ms 10",
        ))
        .unwrap();
        assert_eq!(cfg.port, 48100);
        assert_eq!(cfg.max_batch, 4);
        assert_eq!(cfg.queue_depth, 16);
        assert_eq!(cfg.idle_ms, 10);
        assert_eq!(ServeConfig::from_args(&argv("x")).unwrap(), ServeConfig::default());
        assert!(ServeConfig::from_args(&argv("x --queue-depth 0")).is_err());
    }

    #[test]
    fn fault_section_defaults_and_validation() {
        // no [fault] section at all → abort, no checkpoints, no chaos
        let c = ConfigFile::parse("[train]\nsteps = 1\n").unwrap();
        assert_eq!(c.fault().unwrap(), FaultConfig::default());
        assert_eq!(c.fault().unwrap().recover, "abort");
        assert_eq!(c.fault().unwrap().ckpt_interval, 0);
        assert_eq!(c.fault().unwrap().recv_timeout_ms, 0);
        // section keys parse
        let c = ConfigFile::parse(
            "[fault]\nrecover = \"rejoin\"\nckpt_interval = 5\n\
             ckpt_dir = \"tmp/ck\"\nrecv_timeout_ms = 250\n\
             chaos = \"kill@3:r1, rejoin@6:r1\"\n",
        )
        .unwrap();
        let cfg = c.fault().unwrap();
        assert_eq!(cfg.recover, "rejoin");
        assert_eq!(cfg.ckpt_interval, 5);
        assert_eq!(cfg.ckpt_dir, "tmp/ck");
        assert_eq!(cfg.recv_timeout_ms, 250);
        assert!(!cfg.chaos.is_empty());
        // bad recover mode / malformed chaos schedule are rejected
        let c = ConfigFile::parse("[fault]\nrecover = \"panic\"\n").unwrap();
        assert!(c.fault().is_err());
        let c = ConfigFile::parse("[fault]\nchaos = \"explode@3:r1\"\n").unwrap();
        assert!(c.fault().is_err());
        // CLI merge mirrors the other sections
        let argv = |s: &str| {
            crate::cli::Args::parse(s.split_whitespace().map(|x| x.to_string()), &[])
                .unwrap()
        };
        let cfg = FaultConfig::from_args(&argv(
            "x --recover degrade --ckpt-interval 2 --ckpt-dir d \
             --recv-timeout-ms 100 --chaos kill@5:r0",
        ))
        .unwrap();
        assert_eq!(cfg.recover, "degrade");
        assert_eq!(cfg.ckpt_interval, 2);
        assert_eq!(cfg.ckpt_dir, "d");
        assert_eq!(cfg.recv_timeout_ms, 100);
        assert_eq!(cfg.chaos, "kill@5:r0");
        assert_eq!(FaultConfig::from_args(&argv("x")).unwrap(), FaultConfig::default());
        assert!(FaultConfig::from_args(&argv("x --recover never")).is_err());
    }

    #[test]
    fn auto_section_defaults_and_validation() {
        // no [auto] section at all → disabled, report mode
        let c = ConfigFile::parse("[train]\nsteps = 1\n").unwrap();
        assert_eq!(c.auto().unwrap(), AutoConfig::default());
        assert!(!c.auto().unwrap().enabled);
        assert_eq!(c.auto().unwrap().apply, "report");
        // section keys parse
        let c = ConfigFile::parse(
            "[auto]\nenabled = true\ncalib_steps = 4\nretune_drift = 0.5\n\
             apply = \"live\"\n",
        )
        .unwrap();
        let cfg = c.auto().unwrap();
        assert!(cfg.enabled);
        assert_eq!(cfg.calib_steps, 4);
        assert!((cfg.retune_drift - 0.5).abs() < 1e-12);
        assert_eq!(cfg.apply, "live");
        // zero calibration window, non-positive drift, bad apply mode
        let c = ConfigFile::parse("[auto]\ncalib_steps = 0\n").unwrap();
        assert!(c.auto().is_err());
        let c = ConfigFile::parse("[auto]\nretune_drift = 0\n").unwrap();
        assert!(c.auto().is_err());
        let c = ConfigFile::parse("[auto]\napply = \"yolo\"\n").unwrap();
        assert!(c.auto().is_err());
        // CLI merge mirrors the other sections
        let argv = |s: &str| {
            crate::cli::Args::parse(
                s.split_whitespace().map(|x| x.to_string()),
                &["auto", "no-auto"],
            )
            .unwrap()
        };
        let cfg = AutoConfig::from_args(&argv(
            "x --auto --calib-steps 3 --retune-drift 0.1 --auto-apply live",
        ))
        .unwrap();
        assert!(cfg.enabled);
        assert_eq!(cfg.calib_steps, 3);
        assert!((cfg.retune_drift - 0.1).abs() < 1e-12);
        assert_eq!(cfg.apply, "live");
        assert_eq!(AutoConfig::from_args(&argv("x")).unwrap(), AutoConfig::default());
        assert!(AutoConfig::from_args(&argv("x --auto-apply dryrun")).is_err());
        assert!(AutoConfig::from_args(&argv("x --calib-steps 0")).is_err());
    }

    #[test]
    fn topology_discovered_from_hosts() {
        let hosts = |list: &[&str]| -> Vec<String> {
            list.iter().map(|s| s.to_string()).collect()
        };
        let hier = CommConfig { topology: "hier".into(), ..Default::default() };
        // two addresses × two ranks each → discovered 2-node split
        let t = hier
            .topology_for_hosts(&hosts(&["10.0.0.1:5000", "10.0.0.1:5001", "10.0.0.2:5000", "10.0.0.2:5001"]))
            .unwrap();
        assert!(t.hierarchical());
        assert_eq!((t.nodes(), t.local_size()), (2, 2));
        // all ranks on one host → nothing to discover; falls back to the
        // explicit-knob default (two nodes)
        let t = hier
            .topology_for_hosts(&hosts(&["127.0.0.1:1", "127.0.0.1:2", "127.0.0.1:3", "127.0.0.1:4"]))
            .unwrap();
        assert_eq!(t.nodes(), 2);
        // explicit knobs win over discovery
        let pinned = CommConfig {
            topology: "hier".into(),
            nodes: 4,
            ..Default::default()
        };
        let t = pinned
            .topology_for_hosts(&hosts(&["a:1", "a:2", "b:1", "b:2"]))
            .unwrap();
        assert_eq!(t.nodes(), 4);
        // flat ignores the host layout entirely
        let flat = CommConfig::default();
        let t = flat.topology_for_hosts(&hosts(&["a:1", "a:2", "b:1", "b:2"])).unwrap();
        assert!(!t.hierarchical());
    }

    #[test]
    fn balance_coef_validation() {
        let c = ConfigFile::parse("[moe]\nbalance_coef = -0.5\n").unwrap();
        assert!(c.moe().is_err());
        let c = ConfigFile::parse("[moe]\nbalance_coef = 0.25\n").unwrap();
        assert!((c.moe().unwrap().balance_coef - 0.25).abs() < 1e-12);
    }

    #[test]
    fn moe_section_defaults_and_validation() {
        // no [moe] section at all → defaults
        let c = ConfigFile::parse("[train]\nsteps = 1\n").unwrap();
        assert_eq!(c.moe().unwrap(), MoeConfig::default());
        // bad gate name
        let c = ConfigFile::parse("[moe]\ngate = \"random\"\n").unwrap();
        assert!(c.moe().is_err());
        // bad capacity factor
        let c = ConfigFile::parse("[moe]\ncapacity_factor = 0\n").unwrap();
        assert!(c.moe().is_err());
        // bad noise std
        let c = ConfigFile::parse("[moe]\nnoise_std = -1.0\n").unwrap();
        assert!(c.moe().is_err());
    }

    #[test]
    fn validation_errors() {
        let c = ConfigFile::parse("[model]\nd_model = 100\nn_head = 7\n").unwrap();
        assert!(c.model().is_err());
        let c = ConfigFile::parse("[dist]\nworkers = 3\n").unwrap();
        assert!(c.dist().is_err());
        let c = ConfigFile::parse("[train]\nsteps = 0\n").unwrap();
        assert!(c.train().is_err());
    }

    #[test]
    fn fmoefy_listing1() {
        let dense = ModelConfig { moe: false, ..Default::default() };
        let moe = fmoefy(&dense, 96, 2).unwrap();
        assert!(moe.moe);
        assert_eq!(moe.n_expert, 96);
        // FLOPs parity: expert hidden halved for top-2
        assert_eq!(moe.d_hidden_expert(), dense.d_hidden / 2);
        // idempotence guard
        assert!(fmoefy(&moe, 8, 2).is_err());
        assert!(fmoefy(&dense, 4, 8).is_err());
    }

    #[test]
    fn n_params_moe_exceeds_dense() {
        let dense = ModelConfig { moe: false, ..Default::default() };
        let moe = fmoefy(&dense, 16, 2).unwrap();
        // the whole point of MoE: more parameters at equal FLOPs
        assert!(moe.n_params() > 3 * dense.n_params());
    }
}
