//! Serving integration: the `fastmoe serve` daemon end to end.
//!
//! The load-bearing property is **batching transparency**: a request's
//! output rows must be *bitwise* identical whether the request ran
//! alone or was coalesced into a continuous batch with strangers.
//! Under the top-k gate every per-row stage is row-local — the gate
//! GEMM row, the per-row top-k, the expert FFN rows and the weighted
//! combine all depend only on that row's values — and zero padding
//! rows cannot perturb real rows' bits.  (The switch gate's capacity
//! clipping *does* couple rows, which is why serving equivalence is
//! pinned on `topk`.)
//!
//! Coverage:
//! * batched-vs-sequential bitwise equivalence on the thread backend
//!   and on real sockets, with and without the progress engine;
//! * admission control over the wire without any runtime (oversized
//!   and malformed requests are rejected as typed frames);
//! * a full daemon run — three concurrent client sessions, replies
//!   checked bitwise against an identically-seeded reference layer,
//!   latency percentiles present in the stats JSON;
//! * queue overflow under a saturating client: rejections, not stalls;
//! * per-session fairness: a chatty session pipelining a burst cannot
//!   starve a quiet session's request out of the next batch (the PR-7
//!   round-robin packing).
//!
//! Ports: 48270 (daemon), 48470/48570 (tcp equivalence ± progress),
//! 48670 (runtime-free admission), 48770 (overflow), 48870
//! (starvation).  The failure tests own 47870/47970/48070; the serve
//! bench owns 48170.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fastmoe::comm::tcp::TcpGroup;
use fastmoe::comm::{run_workers, Comm};
use fastmoe::config::{CommConfig, MoeConfig, ServeConfig};
use fastmoe::coordinator::{DistMoeLayer, MoeLayerBuilder};
use fastmoe::metrics::Counters;
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::serve::{
    run_thread_daemon, Batcher, ClientConn, Reply, Request, ServeDaemon,
};
use fastmoe::tensor::TensorF32;
use fastmoe::util::json::Json;

const WORKERS: usize = 2;

fn request_data(seed: u64, n: usize) -> Vec<f32> {
    let mut data = vec![0f32; n];
    Rng::new(seed).fill_normal(&mut data, 1.0);
    data
}

/// Drive one batched step (requests packed by a real [`Batcher`]) and
/// then each request alone at rows `0..r` of a zero batch; assert the
/// request's output rows are bitwise identical either way.  Every rank
/// calls this (the forwards are collective); only rank 0 carries data.
fn assert_batched_matches_sequential(
    comm: &mut impl Comm,
    layer: &DistMoeLayer,
) -> fastmoe::Result<()> {
    let (nb, dm) = (layer.nb, layer.dm);
    let rank0 = comm.rank() == 0;
    let r = (nb / 6).max(1);
    let rows = [r, r, r];
    let mut counters = Counters::new();
    let mut reqs: Vec<Vec<f32>> = Vec::new();
    let mut batcher = Batcher::new(nb, 16 * nb);
    if rank0 {
        for (i, &ri) in rows.iter().enumerate() {
            let data = request_data(1000 + i as u64, ri * dm);
            reqs.push(data.clone());
            batcher
                .admit(Request {
                    id: i as u32,
                    session: 0,
                    rows: ri,
                    data,
                    arrived: Instant::now(),
                })
                .map_err(|_| fastmoe::Error::msg("admit failed"))?;
        }
    }
    let (x, pending) = if rank0 {
        batcher.take_batch(nb, dm).expect("non-empty queue")
    } else {
        (TensorF32::zeros(&[nb, dm]), Vec::new())
    };
    if rank0 {
        assert_eq!(pending.len(), rows.len(), "all requests must co-batch");
    }
    let y_batch = layer.forward_infer(comm, x, &mut counters)?;
    for (i, &ri) in rows.iter().enumerate() {
        let mut x = TensorF32::zeros(&[nb, dm]);
        if rank0 {
            x.data[..ri * dm].copy_from_slice(&reqs[i]);
        }
        let y = layer.forward_infer(comm, x, &mut counters)?;
        if rank0 {
            let off = pending[i].row;
            for (j, (a, b)) in y.data[..ri * dm]
                .iter()
                .zip(&y_batch.data[off * dm..(off + ri) * dm])
                .enumerate()
            {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "request {i} elem {j}: sequential {a} != batched {b}"
                );
            }
        }
    }
    Ok(())
}

#[test]
fn batched_forward_is_bitwise_sequential_thread() {
    let Ok(rt) = Runtime::open_default() else { return };
    let rt = Arc::new(rt);
    run_workers(WORKERS, move |mut h| {
        let layer = MoeLayerBuilder::new()
            .gate("topk")
            .seed(31)
            .build(rt.clone(), WORKERS, h.rank())?;
        layer.warm()?;
        assert_batched_matches_sequential(&mut h, &layer)
    })
    .unwrap();
}

fn tcp_equivalence(base_port: u16, progress: bool) {
    let Ok(rt) = Runtime::open_default() else { return };
    let rt = Arc::new(rt);
    let joins: Vec<_> = (0..WORKERS)
        .map(|rank| {
            let rt = rt.clone();
            std::thread::spawn(move || -> fastmoe::Result<()> {
                let mut g = TcpGroup::connect_local(rank, WORKERS, base_port)?;
                if progress {
                    g.enable_progress();
                }
                let layer = MoeLayerBuilder::new()
                    .gate("topk")
                    .seed(31)
                    .build(rt, WORKERS, rank)?;
                layer.warm()?;
                assert_batched_matches_sequential(&mut g, &layer)?;
                g.barrier()
            })
        })
        .collect();
    for (rank, j) in joins.into_iter().enumerate() {
        j.join().unwrap_or_else(|_| panic!("tcp rank {rank} panicked")).unwrap();
    }
}

#[test]
fn batched_forward_is_bitwise_sequential_tcp() {
    tcp_equivalence(48470, false);
}

#[test]
fn batched_forward_is_bitwise_sequential_tcp_progress() {
    tcp_equivalence(48570, true);
}

#[test]
fn admission_control_rejects_over_the_wire_without_runtime() {
    // the front end alone — no workers, no artifacts: oversized and
    // malformed requests must come back as typed REJECT frames before
    // any batch forms
    let cfg = ServeConfig { port: 48670, max_batch: 2, queue_depth: 8, idle_ms: 5 };
    let (nb, dm) = (4usize, 3usize);
    let mut daemon = ServeDaemon::bind(&cfg, nb, dm).unwrap();
    let mut c = ClientConn::connect("127.0.0.1:48670").unwrap();
    // rows > max_batch: can never be scheduled
    c.request(7, 3, &[0.0; 9]).unwrap();
    assert_eq!(c.recv_reply().unwrap(), Reply::Rejected { id: 7 });
    // payload length disagrees with the row count
    c.request(8, 2, &[0.0; 5]).unwrap();
    assert_eq!(c.recv_reply().unwrap(), Reply::Rejected { id: 8 });
    // zero rows
    c.request(9, 0, &[]).unwrap();
    assert_eq!(c.recv_reply().unwrap(), Reply::Rejected { id: 9 });
    daemon.close();
}

#[test]
fn round_robin_prevents_session_starvation_over_the_wire() {
    // the front end alone, no runtime: a chatty session pipelines a
    // six-request burst before a quiet session sends its single
    // request.  Per-session round-robin packing must put the quiet
    // session into the *first* two-row batch — under the old FIFO
    // packing it would queue behind the entire burst.
    let cfg = ServeConfig { port: 48870, max_batch: 2, queue_depth: 64, idle_ms: 5 };
    let (nb, dm) = (4usize, 2usize);
    let mut daemon = ServeDaemon::bind(&cfg, nb, dm).unwrap();
    let mut chatty = ClientConn::connect("127.0.0.1:48870").unwrap();
    for id in 0..6u32 {
        chatty.request(id, 1, &[id as f32; 2]).unwrap();
    }
    let mut quiet = ClientConn::connect("127.0.0.1:48870").unwrap();
    quiet.request(100, 1, &[7.0; 2]).unwrap();
    // the session readers are free-running threads; give the whole
    // burst ample time to be admitted before packing begins
    std::thread::sleep(Duration::from_millis(500));

    let (_, first) = daemon.next_batch(nb, dm).expect("queued work");
    let first_ids: Vec<u32> = first.iter().map(|p| p.req.id).collect();
    assert!(
        first_ids.contains(&100),
        "quiet session must ride in the first batch, got {first_ids:?}"
    );
    // the burst still drains completely, FIFO within its session
    let mut burst_ids: Vec<u32> =
        first_ids.iter().copied().filter(|&id| id < 100).collect();
    while burst_ids.len() < 6 {
        let (_, pending) = daemon.next_batch(nb, dm).expect("burst not drained");
        burst_ids.extend(
            pending.iter().map(|p| p.req.id).filter(|&id| id < 100),
        );
    }
    assert_eq!(burst_ids, (0..6).collect::<Vec<u32>>());
    daemon.close();
}

#[test]
fn daemon_serves_three_concurrent_sessions_bitwise() {
    let Ok(rt) = Runtime::open_default() else { return };
    let rt = Arc::new(rt);
    let Some(gate) = rt.manifest.artifact(&format!("gate_fwd_w{WORKERS}")) else {
        return;
    };
    let nb = gate.inputs[0].shape[0];
    let dm = gate.inputs[0].shape[1];
    let r = (nb / 4).max(1);
    const SESSIONS: usize = 3;
    const PER_SESSION: usize = 2;
    let seed = 21u64;
    let cfg = ServeConfig { port: 48270, max_batch: 0, queue_depth: 1024, idle_ms: 30 };
    let daemon = {
        let rt = rt.clone();
        std::thread::spawn(move || {
            run_thread_daemon(
                rt,
                WORKERS,
                seed,
                MoeConfig::default(),
                CommConfig::default(),
                cfg,
            )
        })
    };

    // three concurrent sessions, each with its own deterministic data
    let sessions: Vec<_> = (0..SESSIONS)
        .map(|s| {
            std::thread::spawn(move || -> fastmoe::Result<Vec<(u32, Vec<f32>)>> {
                let mut conn = ClientConn::connect("127.0.0.1:48270")?;
                let mut got = Vec::new();
                for i in 0..PER_SESSION {
                    let id = (s * PER_SESSION + i) as u32;
                    let data = request_data(500 + id as u64, r * dm);
                    conn.request(id, r, &data)?;
                    match conn.recv_reply()? {
                        Reply::Ok { id, data } => got.push((id, data)),
                        Reply::Rejected { id } => {
                            panic!("request {id} rejected under an empty queue")
                        }
                    }
                }
                Ok(got)
            })
        })
        .collect();
    let mut replies: Vec<(u32, Vec<f32>)> = Vec::new();
    for (s, j) in sessions.into_iter().enumerate() {
        let got = j.join().unwrap_or_else(|_| panic!("session {s} panicked")).unwrap();
        assert_eq!(got.len(), PER_SESSION);
        replies.extend(got);
    }
    let mut stop = ClientConn::connect("127.0.0.1:48270").unwrap();
    stop.shutdown().unwrap();
    let stats = daemon.join().unwrap().unwrap();

    // accounting: every request answered, nobody dropped
    let total = (SESSIONS * PER_SESSION) as u64;
    assert_eq!(stats.requests, total, "{stats:?}");
    assert_eq!(stats.rows, total * r as u64);
    assert_eq!(stats.disconnects, 0);
    assert!(stats.steps >= 1 && stats.steps <= total, "{}", stats.steps);

    // acceptance (d): the percentile keys ride in the stats JSON
    let Json::Object(obj) = stats.to_json() else { panic!("stats not an object") };
    for key in ["latency_p50", "latency_p95", "latency_p99", "rows_per_sec"] {
        match obj.get(key) {
            Some(Json::Num(v)) => assert!(*v >= 0.0, "{key} = {v}"),
            other => panic!("missing numeric {key}: {other:?}"),
        }
    }
    assert!(stats.latency.p99() >= stats.latency.p50());

    // acceptance (a): every daemon reply is bitwise the sequential
    // single-request forward of an identically-seeded layer
    let expected: Vec<Vec<f32>> = {
        let rt = rt.clone();
        run_workers(WORKERS, move |mut h| {
            let layer = MoeLayerBuilder::from_config(&MoeConfig::default())
                .comm_config(&CommConfig::default())
                .seed(seed)
                .build(rt.clone(), WORKERS, h.rank())?;
            layer.warm()?;
            let mut counters = Counters::new();
            let mut outs = Vec::new();
            for id in 0..SESSIONS * PER_SESSION {
                let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
                if h.rank() == 0 {
                    x.data[..r * dm]
                        .copy_from_slice(&request_data(500 + id as u64, r * dm));
                }
                let y = layer.forward_infer(&mut h, x, &mut counters)?;
                outs.push(y.data[..r * dm].to_vec());
            }
            Ok(outs)
        })
        .unwrap()
        .swap_remove(0)
    };
    assert_eq!(replies.len(), SESSIONS * PER_SESSION);
    for (id, data) in &replies {
        let want = &expected[*id as usize];
        assert_eq!(data.len(), want.len(), "request {id}");
        for (j, (a, b)) in data.iter().zip(want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "request {id} elem {j}: daemon {a} != reference {b}"
            );
        }
    }
}

#[test]
fn queue_overflow_rejects_instead_of_stalling() {
    let Ok(rt) = Runtime::open_default() else { return };
    let rt = Arc::new(rt);
    // a one-row step with a one-row queue: a pipelined burst must
    // overflow admission control while the collective forward runs
    let cfg = ServeConfig { port: 48770, max_batch: 1, queue_depth: 1, idle_ms: 1 };
    let Some(gate) = rt.manifest.artifact(&format!("gate_fwd_w{WORKERS}")) else {
        return;
    };
    let dm = gate.inputs[0].shape[1];
    let daemon = {
        let rt = rt.clone();
        std::thread::spawn(move || {
            run_thread_daemon(
                rt,
                WORKERS,
                3,
                MoeConfig::default(),
                CommConfig::default(),
                cfg,
            )
        })
    };
    const BURST: usize = 6;
    let mut conn = ClientConn::connect("127.0.0.1:48770").unwrap();
    let data = request_data(9, dm);
    // pipeline the whole burst before reading anything: the queue holds
    // one row, so most of these arrive against a full queue
    for id in 0..BURST as u32 {
        conn.request(id, 1, &data).unwrap();
    }
    let (mut ok, mut rejected) = (0u64, 0u64);
    for _ in 0..BURST {
        // every request gets *some* reply — this loop completing is the
        // "no stall" half of the property
        match conn.recv_reply().unwrap() {
            Reply::Ok { .. } => ok += 1,
            Reply::Rejected { .. } => rejected += 1,
        }
    }
    let mut stop = ClientConn::connect("127.0.0.1:48770").unwrap();
    stop.shutdown().unwrap();
    let stats = daemon.join().unwrap().unwrap();
    assert_eq!(ok + rejected, BURST as u64);
    assert!(ok >= 1, "the head request must be served");
    assert!(
        rejected >= 1,
        "a {BURST}-deep burst into a 1-row queue must overflow"
    );
    assert_eq!(stats.requests, ok);
    assert_eq!(stats.rejected, rejected, "{stats:?}");
}
