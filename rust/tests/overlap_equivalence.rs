//! The overlap redesign's contract: the chunked, pipelined exchange
//! must be a pure *schedule* change — same bytes, same values, same
//! bits — with the blocking path as its `chunks = 1` degenerate case.
//!
//! Two layers of evidence:
//!
//! * a pure-comm protocol test (no artifacts needed) that drives the
//!   layer's own scheduling primitives (`moe::post_chunk` /
//!   `moe::wait_chunk` over ring-offset peer groups) with per-chunk
//!   tags and an echo "compute", and checks the schedule reproduces a
//!   blocking `all_to_all_v` exactly, round trip included;
//! * a runtime-gated test that runs the real `DistMoeLayer` forward +
//!   backward with overlap off and on and asserts bitwise-identical
//!   outputs and gradients (skipped when no artifacts are installed).

use std::sync::Arc;

use fastmoe::comm::{run_workers, Comm, TopoComm};
use fastmoe::config::CommConfig;
use fastmoe::coordinator::MoeLayerBuilder;
use fastmoe::metrics::Counters;
use fastmoe::comm::Topology;
use fastmoe::moe::{
    chunk_peer_groups, chunk_peer_groups_topo, post_chunk, wait_chunk, PendingChunk,
};
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::tensor::TensorF32;

#[test]
fn chunked_schedule_reproduces_blocking_all_to_all() {
    for (workers, chunks) in [(4usize, 2usize), (4, 4), (3, 2), (8, 4)] {
        run_workers(workers, move |mut h| {
            let r = h.rank();
            let send: Vec<Vec<f32>> = (0..workers)
                .map(|p| vec![(r * workers + p) as f32; (r + p) % 3 + 1])
                .collect();
            // reference dispatch through the blocking collective
            let recv_ref = h.all_to_all_v(send.clone())?;

            // the layer's pipelined schedule, driven through the same
            // moe::post_chunk / moe::wait_chunk the layer itself uses:
            // per-chunk tags reserved up front, chunk c+1 posted before
            // chunk c is drained, hosted rows echoed back per chunk
            // ("identity expert") along the reversed edges
            let groups = chunk_peer_groups(r, workers, chunks);
            let nc = groups.len();
            let disp_tags: Vec<u64> =
                (0..nc).map(|_| (h.next_seq() << 8) | 1).collect();
            let ret_tags: Vec<u64> =
                (0..nc).map(|_| (h.next_seq() << 8) | 1).collect();
            let mut outbox = send.clone();
            let mut recv_parts: Vec<Option<Vec<f32>>> =
                (0..workers).map(|_| None).collect();
            let mut back_parts: Vec<Option<Vec<f32>>> =
                (0..workers).map(|_| None).collect();
            let mut disp_pend: Vec<PendingChunk> =
                (0..nc).map(|_| Vec::new()).collect();
            let mut ret_pend: Vec<PendingChunk> =
                (0..nc).map(|_| Vec::new()).collect();

            post_chunk(
                &mut h, r, &groups[0], disp_tags[0], &mut outbox,
                &mut recv_parts, &mut disp_pend[0],
            )?;
            for c in 0..nc {
                if c + 1 < nc {
                    post_chunk(
                        &mut h, r, &groups[c + 1], disp_tags[c + 1], &mut outbox,
                        &mut recv_parts, &mut disp_pend[c + 1],
                    )?;
                }
                wait_chunk(&mut h, std::mem::take(&mut disp_pend[c]), &mut recv_parts)?;
                // "compute" chunk c: echo each hosted buffer back
                let mut echo: Vec<Vec<f32>> =
                    (0..workers).map(|_| Vec::new()).collect();
                for &p in &groups[c].in_peers {
                    echo[p] = recv_parts[p].clone().unwrap_or_default();
                }
                post_chunk(
                    &mut h, r, &groups[c].reversed(), ret_tags[c], &mut echo,
                    &mut back_parts, &mut ret_pend[c],
                )?;
            }
            for pend in ret_pend {
                wait_chunk(&mut h, pend, &mut back_parts)?;
            }

            // chunked dispatch == blocking dispatch, peer for peer
            for (p, want) in recv_ref.iter().enumerate() {
                assert_eq!(
                    recv_parts[p].as_ref(),
                    Some(want),
                    "w={workers} c={chunks}: dispatch mismatch at peer {p}"
                );
            }
            // identity round trip: everything returns to its owner
            for (p, want) in send.iter().enumerate() {
                assert_eq!(
                    back_parts[p].as_ref(),
                    Some(want),
                    "w={workers} c={chunks}: return mismatch at peer {p}"
                );
            }
            Ok(())
        })
        .unwrap();
    }
}

#[test]
fn topo_chunked_schedule_reproduces_blocking_all_to_all() {
    // The locality-ordered (hier) chunk schedule is a pure reordering
    // of the same per-chunk tag protocol: driven through the layer's
    // own post_chunk / wait_chunk, it must reproduce a blocking
    // all_to_all_v exactly — the mirror property across ranks is what
    // keeps the tags in lockstep despite the reordering.
    for (workers, local, chunks) in [(4usize, 2usize, 2usize), (8, 2, 4), (8, 4, 3), (6, 3, 2)]
    {
        run_workers(workers, move |mut h| {
            let topo = Topology::new(workers, local).unwrap();
            let r = h.rank();
            let send: Vec<Vec<f32>> = (0..workers)
                .map(|p| vec![(r * workers + p) as f32; (r + 2 * p) % 4 + 1])
                .collect();
            let recv_ref = h.all_to_all_v(send.clone())?;
            let groups = chunk_peer_groups_topo(r, &topo, chunks);
            let nc = groups.len();
            let tags: Vec<u64> = (0..nc).map(|_| (h.next_seq() << 8) | 1).collect();
            let mut outbox = send;
            let mut parts: Vec<Option<Vec<f32>>> =
                (0..workers).map(|_| None).collect();
            let mut pend: Vec<PendingChunk> = (0..nc).map(|_| Vec::new()).collect();
            post_chunk(&mut h, r, &groups[0], tags[0], &mut outbox, &mut parts, &mut pend[0])?;
            for c in 0..nc {
                if c + 1 < nc {
                    post_chunk(
                        &mut h, r, &groups[c + 1], tags[c + 1], &mut outbox,
                        &mut parts, &mut pend[c + 1],
                    )?;
                }
                wait_chunk(&mut h, std::mem::take(&mut pend[c]), &mut parts)?;
            }
            for (p, part) in parts.iter().enumerate() {
                assert_eq!(
                    part.as_ref().unwrap_or(&Vec::new()),
                    &recv_ref[p],
                    "w={workers} l={local} c={chunks}: peer {p} mismatch"
                );
            }
            Ok(())
        })
        .unwrap();
    }
}

#[test]
fn overlapped_layer_is_bit_identical_to_blocking() {
    let Some(rt) = Runtime::open_default().ok().map(Arc::new) else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 4usize;
    if rt
        .manifest
        .artifact(&format!("gate_fwd_w{workers}"))
        .is_none()
    {
        return;
    }
    let run = |overlap: bool, chunks: usize, pool: bool| {
        let rt = rt.clone();
        run_workers(workers, move |mut h| {
            let layer = MoeLayerBuilder::new()
                .seed(7)
                .overlap(overlap)
                .chunks(chunks)
                .pool(pool)
                .build(rt.clone(), workers, h.rank())?;
            let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
            Rng::new(2000 + h.rank() as u64).fill_normal(&mut x.data, 1.0);
            let mut counters = Counters::new();
            let (y, state) = layer.forward(&mut h, x, &mut counters)?;
            let mut dy = y.clone();
            let n = dy.data.len() as f32;
            for v in dy.data.iter_mut() {
                *v /= n;
            }
            let grads = layer.backward(&mut h, &state, &dy, &mut counters)?;
            Ok((y, grads, counters.get("moe_a2a_bytes")))
        })
        .unwrap()
    };
    let blocking = run(false, 1, true);
    // 0 = adaptive chunk count; false = pool disabled — the zero-copy
    // machinery must be a pure schedule/staging change in every mode
    for (chunks, pool) in [(2usize, true), (4, true), (4, false), (0, true)] {
        let overlapped = run(true, chunks, pool);
        for (rank, (b, o)) in blocking.iter().zip(&overlapped).enumerate() {
            assert_eq!(b.0.data, o.0.data, "rank {rank}: forward bits");
            assert_eq!(b.1.dx.data, o.1.dx.data, "rank {rank}: dx bits");
            assert_eq!(b.1.dwg.data, o.1.dwg.data, "rank {rank}: dwg bits");
            assert_eq!(b.1.dbg.data, o.1.dbg.data, "rank {rank}: dbg bits");
            for ((n1, g1), (n2, g2)) in b.1.expert.iter().zip(&o.1.expert) {
                assert_eq!(n1, n2);
                assert_eq!(g1.data, g2.data, "rank {rank}: expert grad {n1} bits");
            }
            // same exchange volume: overlap is a schedule, not a diet
            assert_eq!(b.2, o.2, "rank {rank}: a2a byte accounting drifted");
        }
    }
}

#[test]
fn hier_topology_layer_is_bit_identical_to_flat() {
    // One hierarchical configuration end to end (PR 5): the layer over
    // a 2-node `TopoComm`.  The blocking path routes its collectives
    // through the node leaders, the pipelined path through the
    // locality-ordered chunk schedule — both are pure *routing*
    // changes (no cross-rank reduction happens inside the layer when
    // grad_overlap is off), so outputs and every gradient must be
    // bitwise identical to each other AND to the flat blocking layer.
    let Some(rt) = Runtime::open_default().ok().map(Arc::new) else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 4usize;
    if rt
        .manifest
        .artifact(&format!("gate_fwd_w{workers}"))
        .is_none()
    {
        return;
    }
    let run_hier = |overlap: bool, chunks: usize| {
        let rt = rt.clone();
        run_workers(workers, move |h| {
            let comm_cfg = CommConfig {
                topology: "hier".into(),
                nodes: 2,
                overlap,
                chunks,
                ..CommConfig::default()
            };
            let mut h = TopoComm::new(h, comm_cfg.topology_for(workers)?)?;
            let layer = MoeLayerBuilder::new()
                .seed(7)
                .comm_config(&comm_cfg)
                .build(rt.clone(), workers, h.rank())?;
            let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
            Rng::new(2000 + h.rank() as u64).fill_normal(&mut x.data, 1.0);
            let mut counters = Counters::new();
            let (y, state) = layer.forward(&mut h, x, &mut counters)?;
            let mut dy = y.clone();
            let n = dy.data.len() as f32;
            for v in dy.data.iter_mut() {
                *v /= n;
            }
            let grads = layer.backward(&mut h, &state, &dy, &mut counters)?;
            Ok((y, grads))
        })
        .unwrap()
    };
    // flat blocking reference, same seeds/inputs as the hier runs
    let flat = run_workers(workers, {
        let rt = rt.clone();
        move |mut h| {
            let layer = MoeLayerBuilder::new()
                .seed(7)
                .build(rt.clone(), workers, h.rank())?;
            let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
            Rng::new(2000 + h.rank() as u64).fill_normal(&mut x.data, 1.0);
            let mut counters = Counters::new();
            let (y, state) = layer.forward(&mut h, x, &mut counters)?;
            let mut dy = y.clone();
            let n = dy.data.len() as f32;
            for v in dy.data.iter_mut() {
                *v /= n;
            }
            let grads = layer.backward(&mut h, &state, &dy, &mut counters)?;
            Ok((y, grads))
        }
    })
    .unwrap();
    for (which, chunks) in [("blocking", 1usize), ("chunks=2", 2), ("chunks=4", 4)] {
        let hier = run_hier(which != "blocking", chunks);
        for (rank, (f, o)) in flat.iter().zip(&hier).enumerate() {
            assert_eq!(f.0.data, o.0.data, "{which} rank {rank}: forward bits");
            assert_eq!(f.1.dx.data, o.1.dx.data, "{which} rank {rank}: dx bits");
            assert_eq!(f.1.dwg.data, o.1.dwg.data, "{which} rank {rank}: dwg bits");
            assert_eq!(f.1.dbg.data, o.1.dbg.data, "{which} rank {rank}: dbg bits");
            for ((n1, g1), (n2, g2)) in f.1.expert.iter().zip(&o.1.expert) {
                assert_eq!(n1, n2);
                assert_eq!(
                    g1.data, g2.data,
                    "{which} rank {rank}: expert grad {n1} bits"
                );
            }
        }
    }
}
