//! Dynamic expert placement: shadow experts + load-driven re-sharding.
//!
//! FastMoE's linear expert scaling (paper §3.2) assumes routing stays
//! balanced; at scale a few hot experts saturate one rank while others
//! idle.  This module closes the loop from measured load to expert
//! layout:
//!
//! * [`PlacementPlan`] — where every global expert lives: an owning
//!   `(rank, slot)` plus optional *shadow* replicas hosted on other
//!   ranks.  Starts as the static seed layout (`expert e` on rank
//!   `e / ne_local`, slot `e % ne_local`), which is bit-compatible
//!   with the plain `DispatchPlan::build` path.
//! * [`PlanDelta`] — the three rebalancing moves: replicate a hot
//!   expert onto an underloaded rank (`AddShadow`), drop all replicas
//!   (`DropShadows`), or swap two experts' owners (`Swap`, executed by
//!   moving checkpoint-format param + Adam slots between ranks).
//! * [`decide`] — a *pure, deterministic* policy function from
//!   (plan, global load counts, threshold) to an optional delta.  All
//!   ranks call it on identical all-reduced counts and reach the same
//!   decision — there is no coordinator.
//! * [`Rebalancer`] — the step-boundary driver: feeds a windowed
//!   [`LoadMonitor`], and every `window` steps all-reduces the window
//!   totals and runs [`decide`].
//!
//! The execution half (routing tokens to the nearest replica, shadow
//! gradient all-reduce over an on-the-fly [`ProcessGroup`], slot
//! migration) lives in `coordinator::dist_moe`; this module is pure
//! bookkeeping and therefore usable from the simulator and benches
//! without a runtime or comm backend.
//!
//! [`ProcessGroup`]: crate::comm::topology::ProcessGroup
//! [`LoadMonitor`]: crate::moe::LoadMonitor

use crate::comm::Comm;
use crate::moe::LoadMonitor;
use crate::{Error, Result};

/// Tag-namespace salt for per-expert shadow gradient sub-groups.
///
/// Disjoint from the topology salts (`SALT_INTRA = 1 << 62`,
/// `SALT_INTER = 1 << 61`) and from all untagged world traffic; the
/// expert id sits above the `(seq << 8) | code` bits every collective
/// uses, so two shadowed experts never alias.
pub fn shadow_salt(expert: usize) -> u64 {
    (1u64 << 60) | ((expert as u64) << 32)
}

/// Rebalancing policy (`[placement] policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Never change the seed layout (the bit-compat default).
    Static,
    /// Replicate hot experts onto underloaded ranks.
    Shadow,
    /// Swap expert ownership between hot and cold ranks.
    Migrate,
}

impl PlacementPolicy {
    pub const KINDS: &'static [&'static str] = &["static", "shadow", "migrate"];

    pub fn parse(s: &str) -> Result<PlacementPolicy> {
        match s {
            "static" => Ok(PlacementPolicy::Static),
            "shadow" => Ok(PlacementPolicy::Shadow),
            "migrate" => Ok(PlacementPolicy::Migrate),
            other => Err(Error::Config(format!(
                "unknown placement policy '{other}' (expected one of {:?})",
                Self::KINDS
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::Static => "static",
            PlacementPolicy::Shadow => "shadow",
            PlacementPolicy::Migrate => "migrate",
        }
    }
}

/// One agreed-on change to the layout, applied at a step boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanDelta {
    /// Replicate `expert`'s param slot onto `host`.
    AddShadow { expert: usize, host: usize },
    /// Remove every shadow replica (load went back to balanced).
    DropShadows,
    /// Exchange the owning `(rank, slot)` of experts `a` and `b`.
    Swap { a: usize, b: usize },
}

/// Expert → rank layout: owner slots plus shadow replicas.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlacementPlan {
    pub workers: usize,
    pub ne_local: usize,
    /// Owning `(rank, local slot)` per global expert, `[ne_global]`.
    owner: Vec<(usize, usize)>,
    /// Per rank, the global experts it hosts shadow replicas for, in
    /// hosting order (replica `i` computes in extended slot
    /// `ne_local + i`).
    hosted: Vec<Vec<usize>>,
    /// A quarantined (dead) rank, if any: [`PlacementPlan::route`]
    /// steers around it to the nearest *live* replica, and
    /// [`PlacementPlan::rank_rows`] models its uncovered experts'
    /// tokens as dropped.  Ownership is untouched — the degraded
    /// layout is reversible by [`PlacementPlan::set_down`]`(None)`.
    down: Option<usize>,
}

impl PlacementPlan {
    /// The static layout the whole repo was built on: expert `e` owned
    /// by rank `e / ne_local` in slot `e % ne_local`, no shadows.
    pub fn seed(workers: usize, ne_local: usize) -> PlacementPlan {
        let owner = (0..workers * ne_local)
            .map(|e| (e / ne_local, e % ne_local))
            .collect();
        PlacementPlan {
            workers,
            ne_local,
            owner,
            hosted: vec![Vec::new(); workers],
            down: None,
        }
    }

    /// Quarantine (or restore) a rank: while `Some(r)`, routing avoids
    /// `r` and load modelling treats its uncovered experts as dropped.
    pub fn set_down(&mut self, down: Option<usize>) -> Result<()> {
        if let Some(r) = down {
            if r >= self.workers {
                return Err(Error::Config(format!(
                    "set_down({r}) out of range for {} workers",
                    self.workers
                )));
            }
        }
        self.down = down;
        Ok(())
    }

    /// The quarantined rank, if any.
    pub fn down(&self) -> Option<usize> {
        self.down
    }

    pub fn ne_global(&self) -> usize {
        self.workers * self.ne_local
    }

    /// Owning `(rank, local slot)` of global expert `e`.
    pub fn owner(&self, e: usize) -> (usize, usize) {
        self.owner[e]
    }

    /// Whether this is still exactly the seed layout (no migrations,
    /// no shadows) — the layer uses this to keep the bit-compatible
    /// `DispatchPlan::build` fast path.
    pub fn is_seed(&self) -> bool {
        self.down.is_none()
            && !self.has_shadows()
            && self
                .owner
                .iter()
                .enumerate()
                .all(|(e, &(r, s))| r == e / self.ne_local && s == e % self.ne_local)
    }

    pub fn has_shadows(&self) -> bool {
        self.hosted.iter().any(|h| !h.is_empty())
    }

    /// Extra compute slots needed beyond `ne_local`: the max number of
    /// replicas any single rank hosts.  The plan-aware `DispatchPlan`
    /// is built over `ne_local + shadow_width()` slots per rank.
    pub fn shadow_width(&self) -> usize {
        self.hosted.iter().map(|h| h.len()).max().unwrap_or(0)
    }

    /// Global experts rank `r` hosts shadow replicas for.
    pub fn hosted(&self, r: usize) -> &[usize] {
        &self.hosted[r]
    }

    /// Ranks holding a shadow replica of expert `e`, ascending.
    pub fn shadow_hosts(&self, e: usize) -> Vec<usize> {
        (0..self.workers).filter(|&r| self.hosted[r].contains(&e)).collect()
    }

    /// Route rank `from`'s tokens for expert `e` to the nearest *live*
    /// replica (owner or shadow host, skipping a quarantined rank) by
    /// forward ring distance, ties to the lowest rank.  Returns
    /// `(rank, extended slot)` where replicas occupy slots
    /// `ne_local + hosting_index` on their host.  If every copy sits on
    /// the down rank the dead owner is returned unchanged: the layer
    /// score-masks such experts, so no token actually lands there.
    pub fn route(&self, e: usize, from: usize) -> (usize, usize) {
        let (orank, oslot) = self.owner[e];
        let dist = |r: usize| (r + self.workers - from) % self.workers;
        let live = |r: usize| self.down != Some(r);
        // (rank, slot, dist); None until a live candidate is seen
        let mut best = live(orank).then(|| (orank, oslot, dist(orank)));
        for (r, hosted) in self.hosted.iter().enumerate() {
            if !live(r) {
                continue;
            }
            if let Some(i) = hosted.iter().position(|&h| h == e) {
                let d = dist(r);
                match best {
                    Some((br, _, bd)) if bd < d || (bd == d && br < r) => {}
                    _ => best = Some((r, self.ne_local + i, d)),
                }
            }
        }
        best.map_or((orank, oslot), |(r, s, _)| (r, s))
    }

    /// Expected rows per rank for the given per-expert token counts,
    /// under the model that each expert's load splits evenly across
    /// its *live* replicas (every source rank routes to its nearest
    /// copy; for uniformly spread sources that is an even split).
    /// Under a quarantined rank, its covered experts' load shifts to
    /// the surviving copies and its uncovered experts' tokens are
    /// dropped (the degraded layer masks them out of the gate).
    pub fn rank_rows(&self, counts: &[u32]) -> Vec<f64> {
        let mut rows = vec![0.0f64; self.workers];
        let live = |r: usize| self.down != Some(r);
        for (e, &c) in counts.iter().enumerate() {
            let mut copies = self.shadow_hosts(e);
            copies.push(self.owner[e].0);
            copies.retain(|&r| live(r));
            if copies.is_empty() {
                continue;
            }
            let share = c as f64 / copies.len() as f64;
            for r in copies {
                rows[r] += share;
            }
        }
        rows
    }

    /// Per shadowed expert (ascending id), the world ranks over which
    /// its gradient must be all-reduced: owner + hosts, ascending.
    pub fn shadow_groups(&self) -> Vec<(usize, Vec<usize>)> {
        let mut out = Vec::new();
        for e in 0..self.ne_global() {
            let hosts = self.shadow_hosts(e);
            if hosts.is_empty() {
                continue;
            }
            let mut members = hosts;
            members.push(self.owner[e].0);
            members.sort_unstable();
            out.push((e, members));
        }
        out
    }

    /// Apply an agreed delta.  Pure plan surgery — parameter movement
    /// is the layer's job.
    pub fn apply(&mut self, delta: &PlanDelta) -> Result<()> {
        match *delta {
            PlanDelta::AddShadow { expert, host } => self.add_shadow(expert, host),
            PlanDelta::DropShadows => {
                self.clear_shadows();
                Ok(())
            }
            PlanDelta::Swap { a, b } => self.swap_owners(a, b),
        }
    }

    pub fn add_shadow(&mut self, e: usize, host: usize) -> Result<()> {
        if e >= self.ne_global() || host >= self.workers {
            return Err(Error::Config(format!(
                "add_shadow({e}, {host}) out of range"
            )));
        }
        if self.owner[e].0 == host {
            return Err(Error::Config(format!(
                "add_shadow: rank {host} already owns expert {e}"
            )));
        }
        if self.hosted[host].contains(&e) {
            return Err(Error::Config(format!(
                "add_shadow: rank {host} already hosts expert {e}"
            )));
        }
        // A host's replicas compute on a second ne_local-wide shard,
        // so it can host at most ne_local of them.
        if self.hosted[host].len() >= self.ne_local {
            return Err(Error::Config(format!(
                "add_shadow: rank {host} is full ({} replicas)",
                self.hosted[host].len()
            )));
        }
        self.hosted[host].push(e);
        Ok(())
    }

    pub fn clear_shadows(&mut self) {
        for h in &mut self.hosted {
            h.clear();
        }
    }

    pub fn swap_owners(&mut self, a: usize, b: usize) -> Result<()> {
        if a >= self.ne_global() || b >= self.ne_global() {
            return Err(Error::Config(format!("swap_owners({a}, {b}) out of range")));
        }
        if self.hosted.iter().any(|h| h.contains(&a) || h.contains(&b)) {
            return Err(Error::Config(
                "swap_owners: drop shadows before migrating".into(),
            ));
        }
        self.owner.swap(a, b);
        Ok(())
    }
}

/// The pure rebalancing decision: identical inputs on every rank yield
/// the identical `Option<PlanDelta>`.
///
/// `counts` are the *global* (all-reduced) per-expert token counts over
/// the observation window.  Imbalance is max/mean of the plan-modelled
/// per-rank rows; at or below `threshold` the layout is considered
/// healthy (existing shadows are dropped), above it the policy picks
/// one move:
///
/// * `Shadow` — replicate the hottest expert owned by the most loaded
///   rank (ties: lowest id) onto the least-loaded eligible rank
///   (ties: lowest rank).
/// * `Migrate` — swap the hottest expert on the most loaded rank with
///   the coldest expert on the least loaded rank, if that actually
///   moves load.
pub fn decide(
    policy: PlacementPolicy,
    plan: &PlacementPlan,
    counts: &[u32],
    threshold: f64,
) -> Option<PlanDelta> {
    if policy == PlacementPolicy::Static || counts.len() != plan.ne_global() {
        return None;
    }
    let rows = plan.rank_rows(counts);
    let total: f64 = rows.iter().sum();
    if total <= 0.0 {
        return None;
    }
    let mean = total / plan.workers as f64;
    let max = rows.iter().cloned().fold(0.0, f64::max);
    if max / mean <= threshold {
        return if plan.has_shadows() { Some(PlanDelta::DropShadows) } else { None };
    }
    let hot_rank = argmax(&rows)?;
    // Hottest expert *owned by* the bottleneck rank (ties: lowest id).
    let e_hot = (0..plan.ne_global())
        .filter(|&e| plan.owner(e).0 == hot_rank)
        .max_by_key(|&e| (counts[e], std::cmp::Reverse(e)))?;
    if counts[e_hot] == 0 {
        return None;
    }
    match policy {
        PlacementPolicy::Shadow => {
            let host = (0..plan.workers)
                .filter(|&r| {
                    r != plan.owner(e_hot).0
                        && !plan.hosted(r).contains(&e_hot)
                        && plan.hosted(r).len() < plan.ne_local
                })
                .min_by(|&a, &b| {
                    rows[a].partial_cmp(&rows[b]).unwrap().then(a.cmp(&b))
                })?;
            Some(PlanDelta::AddShadow { expert: e_hot, host })
        }
        PlacementPolicy::Migrate => {
            if plan.has_shadows() {
                return Some(PlanDelta::DropShadows);
            }
            let cold_rank = (0..plan.workers)
                .min_by(|&a, &b| rows[a].partial_cmp(&rows[b]).unwrap().then(a.cmp(&b)))?;
            if cold_rank == hot_rank {
                return None;
            }
            let e_cold = (0..plan.ne_global())
                .filter(|&e| plan.owner(e).0 == cold_rank)
                .min_by_key(|&e| (counts[e], e))?;
            if counts[e_hot] > counts[e_cold] {
                Some(PlanDelta::Swap { a: e_hot, b: e_cold })
            } else {
                None
            }
        }
        PlacementPolicy::Static => None,
    }
}

fn argmax(xs: &[f64]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, &x) in xs.iter().enumerate() {
        match best {
            Some(b) if xs[b] >= x => {}
            _ => best = Some(i),
        }
    }
    best
}

/// Step-boundary rebalancing driver.
///
/// Feed [`Rebalancer::observe`] this rank's kept per-expert counts each
/// step; every `window` observations [`Rebalancer::maybe_rebalance`]
/// all-reduces the window totals (exact in f32 for realistic windows)
/// and runs [`decide`] on the agreed global counts.  Because every rank
/// observes on the same step schedule, the collective stays in world
/// sequence-number lockstep.
#[derive(Debug)]
pub struct Rebalancer {
    pub policy: PlacementPolicy,
    pub threshold: f64,
    window: LoadMonitor,
    every: usize,
    steps: usize,
    /// While frozen (a degraded run), window boundaries pass without
    /// any decision *or collective* — every rank freezes at the same
    /// step boundary, so tag lockstep is preserved by omission.
    frozen: bool,
    /// World ranks the boundary all-reduce runs over (`None` = world).
    group: Option<Vec<usize>>,
}

impl Rebalancer {
    pub fn new(
        policy: PlacementPolicy,
        n_expert: usize,
        threshold: f64,
        window: usize,
    ) -> Rebalancer {
        let every = window.max(1);
        Rebalancer {
            policy,
            threshold,
            window: LoadMonitor::windowed(n_expert, every),
            every,
            steps: 0,
            frozen: false,
            group: None,
        }
    }

    /// Freeze (or thaw) rebalancing — the degraded-mode guard: a
    /// quarantined layout must not be mutated under the survivors'
    /// feet, and a frozen boundary runs no collective at all.
    pub fn freeze(&mut self, frozen: bool) {
        self.frozen = frozen;
    }

    pub fn is_frozen(&self) -> bool {
        self.frozen
    }

    /// Re-bind the boundary all-reduce to a survivor sub-group
    /// (`None` restores the full world).  Every participating rank
    /// must bind the same group at the same boundary.
    pub fn bind_group(&mut self, group: Option<Vec<usize>>) {
        self.group = group;
    }

    pub fn from_config(cfg: &crate::config::PlacementConfig, n_expert: usize) -> Result<Rebalancer> {
        Ok(Rebalancer::new(
            PlacementPolicy::parse(&cfg.policy)?,
            n_expert,
            cfg.threshold,
            cfg.window,
        ))
    }

    /// Record one step's kept per-expert counts (capacity-dropped
    /// tokens are already excluded by `GateAssign::kept_counts`).
    pub fn observe(&mut self, counts: &[u32]) {
        self.window.record(counts);
        self.steps += 1;
    }

    /// At a window boundary, agree on global counts and decide.  Must
    /// be called on every rank at the same step — the all-reduce is a
    /// collective.
    pub fn maybe_rebalance<C: Comm + ?Sized>(
        &mut self,
        comm: &mut C,
        plan: &PlacementPlan,
    ) -> Result<Option<PlanDelta>> {
        if self.frozen {
            return Ok(None);
        }
        if self.steps == 0 || self.steps % self.every != 0 {
            return Ok(None);
        }
        if self.policy == PlacementPolicy::Static {
            return Ok(None);
        }
        let totals = self.window.window_totals();
        let mut buf: Vec<f32> = totals.iter().map(|&c| c as f32).collect();
        match &self.group {
            Some(g) => comm.all_reduce_sum_group(&mut buf, g)?,
            None if comm.size() > 1 => comm.all_reduce_sum(&mut buf)?,
            None => {}
        }
        let counts: Vec<u32> = buf.iter().map(|&x| x as u32).collect();
        Ok(decide(self.policy, plan, &counts, self.threshold))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_plan_is_seed() {
        let p = PlacementPlan::seed(4, 2);
        assert!(p.is_seed());
        assert!(!p.has_shadows());
        assert_eq!(p.shadow_width(), 0);
        assert_eq!(p.owner(5), (2, 1));
        assert_eq!(p.route(5, 0), (2, 1));
        assert_eq!(p.route(5, 3), (2, 1));
    }

    #[test]
    fn shadow_routing_picks_nearest_replica() {
        let mut p = PlacementPlan::seed(4, 2);
        // expert 0 (owner rank 0) gets a replica on rank 2, slot 2+0
        p.add_shadow(0, 2).unwrap();
        assert!(p.has_shadows() && !p.is_seed());
        assert_eq!(p.shadow_width(), 1);
        // sources route to the nearest copy by forward ring distance
        assert_eq!(p.route(0, 0), (0, 0)); // local owner
        assert_eq!(p.route(0, 2), (2, 2)); // local replica, ext slot
        assert_eq!(p.route(0, 1), (2, 2)); // dist 1 to host vs 3 to owner
        assert_eq!(p.route(0, 3), (0, 0)); // dist 1 to owner vs 3 to host
        // other experts untouched
        assert_eq!(p.route(1, 1), (0, 1));
        assert_eq!(p.shadow_groups(), vec![(0, vec![0, 2])]);
        p.clear_shadows();
        assert!(p.is_seed());
    }

    #[test]
    fn shadow_capacity_and_ownership_guards() {
        let mut p = PlacementPlan::seed(2, 1);
        assert!(p.add_shadow(0, 0).is_err()); // owner can't host itself
        p.add_shadow(0, 1).unwrap();
        assert!(p.add_shadow(0, 1).is_err()); // duplicate replica
        assert!(p.add_shadow(1, 0).is_ok());
        assert!(p.add_shadow(0, 1).is_err()); // ne_local=1 → host full
        assert!(p.swap_owners(0, 1).is_err()); // must drop shadows first
    }

    #[test]
    fn swap_moves_owner_slots() {
        let mut p = PlacementPlan::seed(2, 2);
        p.swap_owners(0, 3).unwrap();
        assert!(!p.is_seed());
        assert_eq!(p.owner(0), (1, 1));
        assert_eq!(p.owner(3), (0, 0));
        assert_eq!(p.route(0, 0), (1, 1));
        p.swap_owners(0, 3).unwrap();
        assert!(p.is_seed());
    }

    #[test]
    fn rank_rows_splits_across_replicas() {
        let mut p = PlacementPlan::seed(2, 1);
        assert_eq!(p.rank_rows(&[90, 10]), vec![90.0, 10.0]);
        p.add_shadow(0, 1).unwrap();
        assert_eq!(p.rank_rows(&[90, 10]), vec![45.0, 55.0]);
    }

    #[test]
    fn decide_is_deterministic_and_balanced_is_noop() {
        let p = PlacementPlan::seed(2, 2);
        let balanced = [5u32, 5, 5, 5];
        assert_eq!(decide(PlacementPolicy::Shadow, &p, &balanced, 1.5), None);
        assert_eq!(decide(PlacementPolicy::Static, &p, &[100, 0, 0, 0], 1.5), None);
        // skew → replicate the hot expert onto the cold rank, twice the
        // same answer from the same inputs
        let skew = [100u32, 5, 5, 5];
        let d1 = decide(PlacementPolicy::Shadow, &p, &skew, 1.5);
        let d2 = decide(PlacementPolicy::Shadow, &p, &skew, 1.5);
        assert_eq!(d1, d2);
        assert_eq!(d1, Some(PlanDelta::AddShadow { expert: 0, host: 1 }));
    }

    #[test]
    fn decide_drops_shadows_when_balance_returns() {
        let mut p = PlacementPlan::seed(2, 2);
        p.add_shadow(0, 1).unwrap();
        let balanced = [5u32, 5, 5, 5];
        assert_eq!(
            decide(PlacementPolicy::Shadow, &p, &balanced, 1.5),
            Some(PlanDelta::DropShadows)
        );
    }

    #[test]
    fn decide_migrate_swaps_hot_and_cold() {
        let p = PlacementPlan::seed(2, 2);
        let skew = [100u32, 5, 1, 2];
        assert_eq!(
            decide(PlacementPolicy::Migrate, &p, &skew, 1.5),
            Some(PlanDelta::Swap { a: 0, b: 2 })
        );
        // applying the swap rebalances the modelled rows
        let mut q = p.clone();
        q.swap_owners(0, 2).unwrap();
        let before = p.rank_rows(&skew);
        let after = q.rank_rows(&skew);
        let imb = |r: &[f64]| {
            let m = r.iter().sum::<f64>() / r.len() as f64;
            r.iter().cloned().fold(0.0, f64::max) / m
        };
        assert!(imb(&after) < imb(&before));
    }

    #[test]
    fn down_rank_routing_steers_to_live_replicas() {
        let mut p = PlacementPlan::seed(4, 2);
        p.add_shadow(6, 1).unwrap(); // expert 6 owned by rank 3, replica on 1
        p.set_down(Some(3)).unwrap();
        assert!(!p.is_seed(), "a quarantined seed layout is not seed-routable");
        assert_eq!(p.down(), Some(3));
        // covered expert: every source routes to the surviving replica
        for from in 0..4 {
            assert_eq!(p.route(6, from), (1, 2), "from {from}");
        }
        // uncovered expert on the dead rank: falls back to the dead
        // owner (the layer masks it, so nothing actually routes there)
        assert_eq!(p.route(7, 0), (3, 1));
        // experts elsewhere are untouched
        assert_eq!(p.route(0, 2), (0, 0));
        // restore
        p.set_down(None).unwrap();
        assert_eq!(p.route(6, 3), (3, 0));
        assert!(p.set_down(Some(9)).is_err());
    }

    #[test]
    fn rank_rows_drops_uncovered_dead_load() {
        let mut p = PlacementPlan::seed(2, 1);
        p.add_shadow(1, 0).unwrap(); // expert 1 (rank 1) covered on rank 0
        assert_eq!(p.rank_rows(&[10, 40]), vec![30.0, 20.0]);
        p.set_down(Some(1)).unwrap();
        // the covered expert's full load lands on its surviving copy;
        // nothing lands on the dead rank
        assert_eq!(p.rank_rows(&[10, 40]), vec![50.0, 0.0]);
        // uncovered dead-owned load is dropped, not redistributed
        let mut q = PlacementPlan::seed(2, 1);
        q.set_down(Some(1)).unwrap();
        assert_eq!(q.rank_rows(&[10, 40]), vec![10.0, 0.0]);
    }

    #[test]
    fn frozen_rebalancer_runs_no_collective() {
        // one frozen rank alone would deadlock the boundary all-reduce
        // if freezing still issued it — freeze on both, observe a full
        // window, and assert no decision and no hang
        crate::comm::run_workers(2, |mut h| {
            let plan = PlacementPlan::seed(2, 1);
            let mut rb = Rebalancer::new(PlacementPolicy::Shadow, 2, 1.5, 2);
            rb.freeze(true);
            assert!(rb.is_frozen());
            for _ in 0..4 {
                rb.observe(&[20, 0]);
                assert_eq!(rb.maybe_rebalance(&mut h, &plan)?, None);
            }
            // thawed + bound to a "survivor" group of one: decisions
            // come back, now from local counts only
            rb.freeze(false);
            rb.bind_group(Some(vec![h.rank()]));
            rb.observe(&[20, 0]);
            rb.observe(&[20, 0]);
            let d = rb.maybe_rebalance(&mut h, &plan)?;
            assert_eq!(d, Some(PlanDelta::AddShadow { expert: 0, host: 1 }));
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn shadow_salts_are_disjoint() {
        let a = shadow_salt(0);
        let b = shadow_salt(1);
        assert_ne!(a, b);
        // clear of the topology salts and of the (seq << 8) | code bits
        for s in [a, b] {
            assert_eq!(s & (1 << 62), 0);
            assert_eq!(s & (1 << 61), 0);
            assert_eq!(s & 0xffff_ffff, 0);
        }
    }

    #[test]
    fn rebalancer_windows_and_fires_on_boundary() {
        // two ranks observe complementary local skew; the all-reduced
        // window totals agree, so both decide the same delta on the
        // window boundary and nothing in between
        crate::comm::run_workers(2, |mut h| {
            let plan = PlacementPlan::seed(2, 1);
            let mut rb = Rebalancer::new(PlacementPolicy::Shadow, 2, 1.5, 4);
            for step in 0..8 {
                let counts = if h.rank() == 0 { [12u32, 0] } else { [8, 0] };
                rb.observe(&counts);
                let d = rb.maybe_rebalance(&mut h, &plan)?;
                if (step + 1) % 4 == 0 {
                    assert_eq!(d, Some(PlanDelta::AddShadow { expert: 0, host: 1 }));
                } else {
                    assert_eq!(d, None);
                }
            }
            Ok(())
        })
        .unwrap();
    }
}
