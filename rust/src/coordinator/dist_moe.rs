//! The distributed (expert-parallel) MoE layer — the heart of FastMoE.
//!
//! Each worker owns `ne_local` experts and runs, per iteration, the
//! stage chain of DESIGN.md §4 with the Figure-2 exchange in the
//! middle.  All heavy math is AOT-compiled HLO; this file is exactly
//! the coordination the paper contributes: planning, packing,
//! exchanging, bucketing, and the mirrored backward chain.
//!
//! Following §3.1's hierarchical interface, the layer itself is thin
//! orchestration over two swappable policies:
//!
//! * the [`Gate`] (which experts, at what weight) — see
//!   [`crate::moe::gate`];
//! * the [`ExpertShard`] (what an expert computes) — see
//!   [`crate::moe::expert`].
//!
//! Layers are assembled by [`MoeLayerBuilder`], normally from the
//! `[moe]` and `[comm]` config sections:
//!
//! ```ignore
//! let layer = MoeLayerBuilder::from_config(&cfg.moe()?)
//!     .comm_config(&cfg.comm()?)
//!     .seed(seed)
//!     .build(rt, workers, rank)?;
//! ```
//!
//! With `[comm] overlap = true` the Figure-2 exchanges run *pipelined*
//! (the §4 performance story): the dispatch decomposes into ring-offset
//! peer chunks over the nonblocking `isend`/`irecv` transport, chunk
//! `c+1`'s tokens flying while chunk `c` runs through the expert shard
//! and the return exchange streaming per chunk; the backward mirrors
//! this and additionally hides the gate GEMM backward behind the
//! cotangent flight.  `chunks = 1` (or `overlap = false`, the default)
//! is the blocking path with bit-identical outputs.
//!
//! [`DistMoeLayer::init`] remains as the seed-compatible shorthand for
//! the default top-k softmax gate + FFN shard (bit-identical routing
//! and weights to the pre-trait layer).

use std::sync::Arc;

use crate::comm::Comm;
use crate::config::{CommConfig, MoeConfig};
use crate::error::{Error, Result};
use crate::metrics::Counters;
use crate::model::Adam;
use crate::moe::{
    balance_loss, chunk_peer_groups, gate, post_chunk, wait_chunk, DispatchPlan,
    ExpertBatch, ExpertShard, FfnExpertShard, Gate, GateAssign, PendingChunk,
};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor::{ops, HostTensor, TensorF32};

/// Manifest-derived geometry shared by every layer built on a runtime.
#[derive(Clone, Debug)]
struct LayerGeom {
    nb: usize,
    dm: usize,
    dh: usize,
    ne_local: usize,
    k: usize,
    buckets: Vec<usize>,
}

/// Probe the artifact manifest for the layer geometry of a topology.
fn probe_geometry(rt: &Runtime, workers: usize) -> Result<LayerGeom> {
    let m = &rt.manifest;
    let gate = m
        .artifact(&format!("gate_fwd_w{workers}"))
        .ok_or_else(|| {
            Error::ArtifactNotFound(format!(
                "gate_fwd_w{workers} (worker count not in preset)"
            ))
        })?;
    let nb = gate.inputs[0].shape[0];
    let dm = gate.inputs[0].shape[1];
    let ne_global = gate.inputs[1].shape[1];
    let ne_local = ne_global / workers;
    let combine = m
        .artifact("combine_fwd")
        .ok_or_else(|| Error::ArtifactNotFound("combine_fwd".into()))?;
    let k = combine.inputs[1].shape[1];
    let buckets = m.buckets();
    if buckets.is_empty() {
        return Err(Error::Manifest("no expert buckets in manifest".into()));
    }
    // dh from any expert artifact
    let eart = m
        .artifact(&format!("expert_fwd_b{}", buckets[0]))
        .ok_or_else(|| Error::ArtifactNotFound("expert_fwd".into()))?;
    let dh = eart.inputs[1].shape[2];
    if eart.inputs[0].shape[0] != ne_local {
        return Err(Error::Manifest(format!(
            "expert artifact has {} local experts, topology wants {}",
            eart.inputs[0].shape[0], ne_local
        )));
    }
    Ok(LayerGeom { nb, dm, dh, ne_local, k, buckets })
}

/// Assembles a [`DistMoeLayer`] from a gate policy + expert shard.
///
/// The builder owns everything that *selects* modules (the `[moe]`
/// config section, the init seed); geometry comes from the artifact
/// manifest at [`MoeLayerBuilder::build`] time.
#[derive(Clone, Debug)]
pub struct MoeLayerBuilder {
    cfg: MoeConfig,
    comm: CommConfig,
    seed: u64,
}

impl Default for MoeLayerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl MoeLayerBuilder {
    /// Default modules: top-k softmax gate + FFN expert shard,
    /// blocking (non-overlapped) exchanges.
    pub fn new() -> MoeLayerBuilder {
        MoeLayerBuilder {
            cfg: MoeConfig::default(),
            comm: CommConfig::default(),
            seed: 0,
        }
    }

    /// Select modules from a `[moe]` config section.
    pub fn from_config(cfg: &MoeConfig) -> MoeLayerBuilder {
        MoeLayerBuilder {
            cfg: cfg.clone(),
            comm: CommConfig::default(),
            seed: 0,
        }
    }

    /// Select the exchange schedule from a `[comm]` config section
    /// (overlap on/off, chunk count).
    pub fn comm_config(mut self, comm: &CommConfig) -> MoeLayerBuilder {
        self.comm = comm.clone();
        self
    }

    /// Override exchange/compute overlap directly.
    pub fn overlap(mut self, on: bool) -> MoeLayerBuilder {
        self.comm.overlap = on;
        self
    }

    /// Override the exchange chunk count directly.
    pub fn chunks(mut self, chunks: usize) -> MoeLayerBuilder {
        self.comm.chunks = chunks;
        self
    }

    /// Seed for parameter init (and the noisy gate's noise stream).
    pub fn seed(mut self, seed: u64) -> MoeLayerBuilder {
        self.seed = seed;
        self
    }

    /// Override the gate kind ("topk" | "switch" | "noisy_topk").
    pub fn gate(mut self, name: &str) -> MoeLayerBuilder {
        self.cfg.gate = name.to_string();
        self
    }

    /// Override the switch-gate capacity factor.
    pub fn capacity_factor(mut self, cf: f64) -> MoeLayerBuilder {
        self.cfg.capacity_factor = cf;
        self
    }

    /// Override the noisy-gate noise std.
    pub fn noise_std(mut self, std: f64) -> MoeLayerBuilder {
        self.cfg.noise_std = std;
        self
    }

    /// Override the balance-loss gradient weight.
    pub fn balance_coef(mut self, coef: f64) -> MoeLayerBuilder {
        self.cfg.balance_coef = coef;
        self
    }

    /// Build one worker's layer for a `(workers, rank)` comm topology.
    ///
    /// Gate weights are derived from `seed` only (identical on every
    /// worker — they are `world`-tagged); expert weights from
    /// `(seed, rank)`.  Both derivations are bit-identical to the seed
    /// system's `DistMoeLayer::init`.
    pub fn build(
        &self,
        rt: Arc<Runtime>,
        workers: usize,
        rank: usize,
    ) -> Result<DistMoeLayer> {
        let g = probe_geometry(&rt, workers)?;
        let ne_global = workers * g.ne_local;

        let mut gate_rng = Rng::new(self.seed ^ 0x6a7e);
        let mut wg = TensorF32::zeros(&[g.dm, ne_global]);
        gate_rng.fill_normal(&mut wg.data, 0.02);
        let bg = TensorF32::zeros(&[ne_global]);

        let expert: Box<dyn ExpertShard> = Box::new(FfnExpertShard::init(
            rt.clone(),
            g.ne_local,
            g.dm,
            g.dh,
            g.buckets.clone(),
            self.seed,
            rank,
        ));
        let gate = gate::from_config(&self.cfg, self.seed)?;

        Ok(DistMoeLayer {
            rt,
            workers,
            rank,
            ne_local: g.ne_local,
            k: g.k,
            nb: g.nb,
            dm: g.dm,
            dh: g.dh,
            buckets: g.buckets,
            wg,
            bg,
            gate,
            expert,
            overlap: self.comm.overlap,
            chunks: self.comm.chunks.clamp(1, workers),
            balance_coef: self.cfg.balance_coef as f32,
        })
    }

    /// Convenience: build for an existing comm handle's topology.
    pub fn build_for(
        &self,
        rt: Arc<Runtime>,
        comm: &impl Comm,
    ) -> Result<DistMoeLayer> {
        self.build(rt, comm.size(), comm.rank())
    }
}

/// Per-worker gate parameters + pluggable gate/expert modules for one
/// MoE layer.
pub struct DistMoeLayer {
    rt: Arc<Runtime>,
    pub workers: usize,
    pub rank: usize,
    pub ne_local: usize,
    pub k: usize,
    pub nb: usize,
    pub dm: usize,
    /// Expert hidden width from the manifest (FFN shard geometry; kept
    /// on the layer because the fused comparison artifacts share it).
    pub dh: usize,
    buckets: Vec<usize>,
    // replicated gate GEMM parameters (tag: world)
    pub wg: TensorF32,
    pub bg: TensorF32,
    gate: Box<dyn Gate>,
    expert: Box<dyn ExpertShard>,
    /// Pipeline the exchanges against expert compute (`[comm] overlap`).
    pub overlap: bool,
    /// Ring-offset peer chunks per exchange (clamped to `workers`).
    pub chunks: usize,
    /// GShard balance-loss gradient weight (`[moe] balance_coef`).
    balance_coef: f32,
}

/// Forward residuals needed by the backward chain.
pub struct MoeLayerState {
    pub assign: GateAssign,
    pub plan: DispatchPlan,
    pub eb: ExpertBatch,
    /// Expert outputs in packed slot order (combine input), saved for
    /// combine_bwd.
    pub y_slots: TensorF32,
    /// This worker's token features (gate_bwd + scatter transpose).
    pub x: TensorF32,
    /// Per-global-expert counts this worker routed (load monitor food;
    /// shared with `plan.counts_global`).  Counts every assignment
    /// slot, including zero-weight drops/fillers, because every slot
    /// transits the exchange.
    pub counts_global: Vec<u32>,
    /// Per-global-expert counts of *kept* (weight > 0) assignments —
    /// the histogram load metrics should use.  Identical to
    /// `counts_global` for gates that never zero-weight.
    pub counts_kept: Vec<u32>,
    /// GShard auxiliary balance loss of this iteration's routing
    /// (over the kept counts).
    pub balance: f64,
}

/// Gradients produced by the backward pass.
pub struct LayerGrads {
    pub dx: TensorF32,
    pub dwg: TensorF32,
    pub dbg: TensorF32,
    /// Expert-shard gradients as named slots, in
    /// [`ExpertShard::params`] order.
    pub expert: Vec<(&'static str, TensorF32)>,
}

impl LayerGrads {
    /// Look an expert gradient up by slot name.
    pub fn expert_grad(&self, name: &str) -> Option<&TensorF32> {
        self.expert.iter().find(|(n, _)| *n == name).map(|(_, t)| t)
    }
}

impl DistMoeLayer {
    /// Seed-compatible shorthand: default top-k softmax gate + FFN
    /// shard, weights derived exactly as the pre-trait layer did.
    pub fn init(
        rt: Arc<Runtime>,
        workers: usize,
        rank: usize,
        seed: u64,
    ) -> Result<DistMoeLayer> {
        MoeLayerBuilder::new().seed(seed).build(rt, workers, rank)
    }

    /// The routing policy this layer was built with.
    pub fn gate(&self) -> &dyn Gate {
        self.gate.as_ref()
    }

    /// The expert shard this layer was built with.
    pub fn expert(&self) -> &dyn ExpertShard {
        self.expert.as_ref()
    }

    /// All trainable parameters as named slots: gate GEMM first
    /// (`wg`, `bg`), then the expert shard's slots.
    pub fn params(&self) -> Vec<(&'static str, &TensorF32)> {
        let mut v = vec![("wg", &self.wg), ("bg", &self.bg)];
        v.extend(self.expert.params());
        v
    }

    /// Apply one optimiser step over all layer parameters from a
    /// backward pass's gradients (same slot order as [`Self::params`]).
    pub fn apply_grads(&mut self, opt: &mut Adam, grads: &LayerGrads) -> Result<()> {
        {
            let pnames: Vec<&str> = self.expert.params().iter().map(|(n, _)| *n).collect();
            let gnames: Vec<&str> = grads.expert.iter().map(|(n, _)| *n).collect();
            if pnames != gnames {
                return Err(Error::Shape(format!(
                    "expert grad slots {gnames:?} do not match params {pnames:?}"
                )));
            }
        }
        let mut gs: Vec<&TensorF32> = vec![&grads.dwg, &grads.dbg];
        gs.extend(grads.expert.iter().map(|(_, g)| g));
        let mut ps: Vec<&mut TensorF32> = vec![&mut self.wg, &mut self.bg];
        ps.extend(self.expert.params_mut().into_iter().map(|(_, t)| t));
        opt.update_refs(&mut ps, &gs)
    }

    /// Pre-compile every stage executable this layer can touch.
    pub fn warm(&self) -> Result<()> {
        self.rt.executable(&format!("gate_fwd_w{}", self.workers))?;
        self.rt.executable(&format!("gate_bwd_w{}", self.workers))?;
        self.rt.executable("combine_fwd")?;
        self.rt.executable("combine_bwd")?;
        self.expert.warm()
    }

    /// Matmul FLOPs this worker performed for `state` (fig-6 metric):
    /// gate GEMM + the expert shard over real (unpadded) rows.
    pub fn flops(&self, state: &MoeLayerState) -> f64 {
        let gate = 2.0 * self.nb as f64 * self.dm as f64
            * (self.workers * self.ne_local) as f64;
        let rows: usize = state.eb.rows_per_expert.iter().sum();
        gate + self.expert.flops(rows)
    }

    /// Whether forward/backward take the chunked overlap path.
    fn pipelined(&self) -> bool {
        self.overlap && self.chunks > 1 && self.workers > 1
    }

    /// Forward pass over this worker's `x: [nb, dm]`.
    ///
    /// `counters` records exchange volumes for the net model.  With
    /// `[comm] overlap` the phase-2 exchange and the expert shard run
    /// pipelined ([`Self::dispatch_compute_overlapped`]); outputs are
    /// bit-identical either way.
    pub fn forward(
        &self,
        comm: &mut impl Comm,
        x: TensorF32,
        counters: &mut Counters,
    ) -> Result<(TensorF32, MoeLayerState)> {
        // ---- gate scores (L1 kernel via HLO) ----
        let gate = self.rt.executable(&format!("gate_fwd_w{}", self.workers))?;
        let out = gate.run(&[
            x.clone().into(),
            self.wg.clone().into(),
            self.bg.clone().into(),
        ])?;
        let scores = out.into_iter().next().unwrap().into_f32()?;

        // ---- host gating + plan (the paper's "local shuffle") ----
        let assign = self.gate.route(&scores, self.k)?;
        let plan = DispatchPlan::build(&assign, self.workers, self.ne_local)?;

        // ---- Figure 2 phase 1: exchange per-expert counts ----
        let count_bufs: Vec<Vec<f32>> = plan
            .send_counts
            .iter()
            .map(|c| c.iter().map(|&x| x as f32).collect())
            .collect();
        let recv_count_bufs = comm.all_to_all_v(count_bufs)?;
        let recv_counts: Vec<Vec<u32>> = recv_count_bufs
            .iter()
            .map(|b| b.iter().map(|&x| x as u32).collect())
            .collect();

        // ---- Figure 2 phase 2 + expert shard ----
        let send = plan.pack(&x)?;
        let sent_bytes: usize = send.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", sent_bytes as u64);
        let (eb, y_slots) = if self.pipelined() {
            self.dispatch_compute_overlapped(comm, &plan, send, recv_counts, counters)?
        } else {
            // blocking path — the `chunks = 1` degenerate case
            let recv = comm.all_to_all_v(send)?;
            let eb = ExpertBatch::build(
                recv_counts,
                &recv,
                self.ne_local,
                self.dm,
                &self.buckets,
            )?;
            counters.add("moe_bucket_rows", (eb.bucket * eb.ne_local) as u64);
            counters.add(
                "moe_real_rows",
                eb.rows_per_expert.iter().sum::<usize>() as u64,
            );
            let ys = self.expert.forward(&eb)?;
            let ret = eb.split_outputs(&ys)?;
            counters.add(
                "moe_a2a_bytes",
                ret.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
            );
            let back = comm.all_to_all_v(ret)?;
            let y_slots = plan.unpack_returned(&back, self.dm)?;
            (eb, y_slots)
        };

        let combine = self.rt.executable("combine_fwd")?;
        let w_t = TensorF32::from_vec(&[self.nb, self.k], assign.w.clone())?;
        let out = combine.run(&[
            y_slots.clone().into(),
            HostTensor::I32(plan.slots_i32()),
            w_t.into(),
        ])?;
        let y = out.into_iter().next().unwrap().into_f32()?;

        // ---- per-step routing metrics (monitor food) ----
        // Load metrics count only kept (weight > 0) assignments so
        // capacity gates' zero-weight drop/filler slots don't read as
        // phantom load; the dispatch histogram keeps counting them
        // because they really transit the exchange.
        let counts_kept = assign.kept_counts(self.workers * self.ne_local);
        let balance = match &assign.probs {
            Some(p) => balance_loss(&counts_kept, p),
            None => {
                let mut p = scores.clone();
                ops::softmax_rows(&mut p)?;
                balance_loss(&counts_kept, &p)
            }
        };
        let counts_global = plan.counts_global.clone();

        Ok((
            y,
            MoeLayerState { assign, plan, eb, y_slots, x, counts_global, counts_kept, balance },
        ))
    }

    /// Figure-2 phase 2 + expert execution, pipelined (the §4 overlap):
    /// the exchange decomposes into ring-offset peer chunks; while
    /// chunk `c`'s rows run through the expert shard, chunk `c+1`'s
    /// tokens are already on the wire, and each chunk's outputs stream
    /// back the moment they exist.  The combine input `y_slots` and the
    /// saved full batch are assembled exactly as the blocking path
    /// assembles them — expert math is row-independent — so outputs
    /// stay bit-identical.
    ///
    /// Host-work trade-off, accepted for wire time: rows are copied
    /// twice (into the backward residual and into the chunk's compute
    /// batch), and each chunk pads to its own bucket, so
    /// `moe_bucket_rows` (and total padded compute) can exceed the
    /// blocking path's single bucket.  The win is hiding the exchange;
    /// on a free network (`--net none`, or the thread backend's memcpy
    /// wire) prefer `overlap = false`.
    fn dispatch_compute_overlapped(
        &self,
        comm: &mut impl Comm,
        plan: &DispatchPlan,
        mut send: Vec<Vec<f32>>,
        recv_counts: Vec<Vec<u32>>,
        counters: &mut Counters,
    ) -> Result<(ExpertBatch, TensorF32)> {
        let w = self.workers;
        let rank = self.rank;
        let chunks = self.chunks.clamp(1, w);
        let groups = chunk_peer_groups(rank, w, chunks);
        counters.add("moe_overlap_chunks", chunks as u64);

        // Tag reservation order is part of the wire protocol: every
        // rank takes 2·chunks seqs in the same sequence.
        let disp_tags: Vec<u64> =
            (0..chunks).map(|_| (comm.next_seq() << 8) | 1).collect();
        let ret_tags: Vec<u64> =
            (0..chunks).map(|_| (comm.next_seq() << 8) | 1).collect();

        // full-batch residual for the backward pass, filled in place as
        // chunks land (same bucket selection and row layout as the
        // blocking path, so `state.eb` stays bit-identical)
        let mut eb = ExpertBatch::shell(
            recv_counts.clone(),
            self.ne_local,
            self.dm,
            &self.buckets,
        )?;

        let mut recv_parts: Vec<Option<Vec<f32>>> = (0..w).map(|_| None).collect();
        let mut back_parts: Vec<Option<Vec<f32>>> = (0..w).map(|_| None).collect();
        let mut disp_pend: Vec<PendingChunk> =
            (0..chunks).map(|_| Vec::new()).collect();
        let mut ret_pend: Vec<PendingChunk> =
            (0..chunks).map(|_| Vec::new()).collect();

        post_chunk(
            comm, rank, &groups[0], disp_tags[0], &mut send, &mut recv_parts,
            &mut disp_pend[0],
        )?;
        for c in 0..chunks {
            // keep the next chunk's tokens in flight through this
            // chunk's expert execution
            if c + 1 < chunks {
                post_chunk(
                    comm, rank, &groups[c + 1], disp_tags[c + 1], &mut send,
                    &mut recv_parts, &mut disp_pend[c + 1],
                )?;
            }
            wait_chunk(comm, std::mem::take(&mut disp_pend[c]), &mut recv_parts)?;

            // file this chunk's rows into the full-batch residual…
            for &p in &groups[c].in_peers {
                eb.fill_peer(p, recv_parts[p].as_deref().unwrap_or(&[]))?;
            }
            // …and regroup them as this chunk's compute batch
            let counts_c: Vec<Vec<u32>> = groups[c]
                .in_peers
                .iter()
                .map(|&p| recv_counts[p].clone())
                .collect();
            let parts_c: Vec<&[f32]> = groups[c]
                .in_peers
                .iter()
                .map(|&p| recv_parts[p].as_deref().unwrap_or(&[]))
                .collect();
            let eb_c = ExpertBatch::build_from(
                counts_c, &parts_c, self.ne_local, self.dm, &self.buckets,
            )?;
            counters.add("moe_bucket_rows", (eb_c.bucket * eb_c.ne_local) as u64);
            counters.add(
                "moe_real_rows",
                eb_c.rows_per_expert.iter().sum::<usize>() as u64,
            );
            let ys_c = self.expert.forward(&eb_c)?;

            // stream this chunk's outputs straight back
            let ret_c = eb_c.split_outputs(&ys_c)?;
            counters.add(
                "moe_a2a_bytes",
                ret_c.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
            );
            let mut ret_abs: Vec<Vec<f32>> = (0..w).map(|_| Vec::new()).collect();
            for (buf, &p) in ret_c.into_iter().zip(&groups[c].in_peers) {
                ret_abs[p] = buf;
            }
            post_chunk(
                comm, rank, &groups[c].reversed(), ret_tags[c], &mut ret_abs,
                &mut back_parts, &mut ret_pend[c],
            )?;
            // wire buffers are copied out; free them inside the window
            for &p in &groups[c].in_peers {
                recv_parts[p] = None;
            }
        }
        for pend in ret_pend {
            wait_chunk(comm, pend, &mut back_parts)?;
        }

        let back: Vec<Vec<f32>> = back_parts
            .into_iter()
            .map(|b| b.unwrap_or_default())
            .collect();
        let y_slots = plan.unpack_returned(&back, self.dm)?;
        Ok((eb, y_slots))
    }

    /// Gate backward: routing Jacobian + balance-loss gradient + gate
    /// GEMM transpose.  Returns `(dx_from_gate, dwg, dbg)`.
    fn gate_backward(
        &self,
        state: &MoeLayerState,
        dw: &TensorF32,
    ) -> Result<(TensorF32, TensorF32, TensorF32)> {
        let ne_global = self.workers * self.ne_local;
        let mut dscores = self.gate.route_bwd(&state.assign, &dw.data, ne_global)?;
        // auxiliary balance-loss gradient over the *kept* counts (the
        // histogram the forward loss uses), scaled by moe.balance_coef
        self.gate.balance_grad(
            &state.assign,
            &state.counts_kept,
            self.balance_coef,
            &mut dscores,
        );
        let gbwd = self.rt.executable(&format!("gate_bwd_w{}", self.workers))?;
        let out = gbwd.run(&[
            state.x.clone().into(),
            self.wg.clone().into(),
            dscores.into(),
        ])?;
        let mut it = out.into_iter();
        let dx = it.next().unwrap().into_f32()?;
        let dwg = it.next().unwrap().into_f32()?;
        let dbg = it.next().unwrap().into_f32()?;
        Ok((dx, dwg, dbg))
    }

    /// Scatter-transpose `dx[token] += dx_packed[slot(assignment)]` —
    /// one fixed assignment order on both paths, so the k-way f32
    /// additions stay bit-identical regardless of arrival order.
    fn scatter_transpose(
        &self,
        plan: &DispatchPlan,
        dx_packed: &TensorF32,
        dx: &mut TensorF32,
    ) {
        for a in 0..plan.nb * plan.k {
            let token = a / plan.k;
            let s = plan.slots[a] as usize;
            let src = &dx_packed.data[s * self.dm..(s + 1) * self.dm];
            let dst = &mut dx.data[token * self.dm..(token + 1) * self.dm];
            for (d, v) in dst.iter_mut().zip(src) {
                *d += v;
            }
        }
    }

    /// Backward pass: `dy: [nb, dm]` → input + parameter gradients.
    /// With `[comm] overlap` the cotangent exchanges run chunked, the
    /// gate GEMM backward overlapping the dispatch flight
    /// ([`Self::backward_overlapped`]); gradients are bit-identical
    /// either way.
    pub fn backward(
        &self,
        comm: &mut impl Comm,
        state: &MoeLayerState,
        dy: &TensorF32,
        counters: &mut Counters,
    ) -> Result<LayerGrads> {
        let plan = &state.plan;

        // ---- combine backward (L1 transpose) ----
        let cbwd = self.rt.executable("combine_bwd")?;
        let w_t = TensorF32::from_vec(&[self.nb, self.k], state.assign.w.clone())?;
        let out = cbwd.run(&[
            state.y_slots.clone().into(),
            HostTensor::I32(plan.slots_i32()),
            w_t.into(),
            dy.clone().into(),
        ])?;
        let mut it = out.into_iter();
        let dys = it.next().unwrap().into_f32()?; // [nb*k, dm] packed order
        let dw = it.next().unwrap().into_f32()?; // [nb, k]

        if self.pipelined() {
            return self.backward_overlapped(comm, state, dys, &dw, counters);
        }

        // ---- gate backward: routing Jacobian + gate GEMM ----
        let (mut dx, dwg, dbg) = self.gate_backward(state, &dw)?;

        // ---- reverse exchange of output cotangents ----
        // dys is already in packed order; split by destination rows.
        let mut send: Vec<Vec<f32>> = Vec::with_capacity(self.workers);
        let mut pos = 0usize;
        for w in 0..self.workers {
            let rows = plan.send_rows[w];
            send.push(dys.data[pos * self.dm..(pos + rows) * self.dm].to_vec());
            pos += rows;
        }
        counters.add(
            "moe_a2a_bytes",
            send.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
        );
        let recv = comm.all_to_all_v(send)?;
        let dys_in = state.eb.rebatch(&recv)?;

        // ---- expert shard backward (recompute-style artifact) ----
        let (dxs, expert_grads) = self.expert.backward(&state.eb, dys_in)?;

        // ---- route input cotangents back to token owners ----
        let ret = state.eb.split_outputs(&dxs)?;
        counters.add(
            "moe_a2a_bytes",
            ret.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
        );
        let back = comm.all_to_all_v(ret)?;
        let dx_packed = plan.unpack_returned(&back, self.dm)?;

        self.scatter_transpose(plan, &dx_packed, &mut dx);

        Ok(LayerGrads { dx, dwg, dbg, expert: expert_grads })
    }

    /// Backward with comm/compute overlap: every chunk of output
    /// cotangents is queued *before* the gate GEMM backward runs, so
    /// the gate compute hides the dispatch flight; the expert backward
    /// then runs once over the full forward batch (keeping the
    /// parameter-gradient reduction order — and therefore the bits —
    /// identical to blocking), and the input-cotangent returns stream
    /// back per chunk.
    fn backward_overlapped(
        &self,
        comm: &mut impl Comm,
        state: &MoeLayerState,
        dys: TensorF32,
        dw: &TensorF32,
        counters: &mut Counters,
    ) -> Result<LayerGrads> {
        let plan = &state.plan;
        let w = self.workers;
        let rank = self.rank;
        let chunks = self.chunks.clamp(1, w);
        let groups = chunk_peer_groups(rank, w, chunks);
        let offsets = plan.send_offsets();
        counters.add("moe_overlap_chunks", chunks as u64);
        let disp_tags: Vec<u64> =
            (0..chunks).map(|_| (comm.next_seq() << 8) | 1).collect();
        let ret_tags: Vec<u64> =
            (0..chunks).map(|_| (comm.next_seq() << 8) | 1).collect();

        // queue every chunk of packed cotangent rows
        counters.add("moe_a2a_bytes", (plan.nb * plan.k * self.dm * 4) as u64);
        let mut send: Vec<Vec<f32>> = (0..w)
            .map(|p| dys.data[offsets[p] * self.dm..offsets[p + 1] * self.dm].to_vec())
            .collect();
        let mut recv_parts: Vec<Option<Vec<f32>>> = (0..w).map(|_| None).collect();
        let mut disp_pend: Vec<PendingChunk> =
            (0..chunks).map(|_| Vec::new()).collect();
        for (c, group) in groups.iter().enumerate() {
            post_chunk(
                comm, rank, group, disp_tags[c], &mut send, &mut recv_parts,
                &mut disp_pend[c],
            )?;
        }
        // push queued frames to the kernel NOW — without this, a
        // deferred-flush backend (TCP) would hold every cotangent in
        // userspace through the gate GEMM and the overlap below would
        // be fictional
        comm.flush()?;

        // gate backward overlaps the cotangent flight
        let (mut dx, dwg, dbg) = self.gate_backward(state, dw)?;

        for pend in disp_pend {
            wait_chunk(comm, pend, &mut recv_parts)?;
        }
        let recv: Vec<Vec<f32>> = recv_parts
            .into_iter()
            .map(|p| p.unwrap_or_default())
            .collect();
        let dys_in = state.eb.rebatch(&recv)?;

        // full-batch expert backward: same reduction order as blocking
        let (dxs, expert_grads) = self.expert.backward(&state.eb, dys_in)?;

        // streamed return of input cotangents
        let mut ret = state.eb.split_outputs(&dxs)?;
        counters.add(
            "moe_a2a_bytes",
            ret.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
        );
        let mut back_parts: Vec<Option<Vec<f32>>> = (0..w).map(|_| None).collect();
        let mut ret_pend: Vec<PendingChunk> =
            (0..chunks).map(|_| Vec::new()).collect();
        for (c, group) in groups.iter().enumerate() {
            post_chunk(
                comm, rank, &group.reversed(), ret_tags[c], &mut ret,
                &mut back_parts, &mut ret_pend[c],
            )?;
        }
        for pend in ret_pend {
            wait_chunk(comm, pend, &mut back_parts)?;
        }
        let back: Vec<Vec<f32>> = back_parts
            .into_iter()
            .map(|b| b.unwrap_or_default())
            .collect();
        let dx_packed = plan.unpack_returned(&back, self.dm)?;
        self.scatter_transpose(plan, &dx_packed, &mut dx);
        Ok(LayerGrads { dx, dwg, dbg, expert: expert_grads })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_carries_config_overrides() {
        let b = MoeLayerBuilder::new()
            .gate("switch")
            .capacity_factor(1.5)
            .noise_std(0.25)
            .balance_coef(0.02)
            .overlap(true)
            .chunks(8)
            .seed(9);
        assert_eq!(b.cfg.gate, "switch");
        assert!((b.cfg.capacity_factor - 1.5).abs() < 1e-12);
        assert!((b.cfg.noise_std - 0.25).abs() < 1e-12);
        assert!((b.cfg.balance_coef - 0.02).abs() < 1e-12);
        assert!(b.comm.overlap);
        assert_eq!(b.comm.chunks, 8);
        assert_eq!(b.seed, 9);
        // gate selection itself is validated without a runtime
        assert!(gate::from_config(&b.cfg, b.seed).is_ok());
        let bad = MoeLayerBuilder::new().gate("mystery");
        assert!(gate::from_config(&bad.cfg, 0).is_err());
    }

    #[test]
    fn builder_adopts_comm_section() {
        let comm = CommConfig { overlap: true, chunks: 2 };
        let b = MoeLayerBuilder::new().comm_config(&comm);
        assert_eq!(b.comm, comm);
        // defaults keep the seed-identical blocking schedule
        let d = MoeLayerBuilder::new();
        assert!(!d.comm.overlap);
    }
}
