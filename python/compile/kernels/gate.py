"""Gate score kernel: ``scores = x @ Wg + bg``.

The gate of an MoE layer scores every token against every expert.  It is
a skinny GEMM (``n_e`` is small compared to ``d_m``), so the kernel tiles
only the token dimension: each grid step loads one row block of ``x``
plus the whole (small) gate weight into VMEM and issues a single MXU
matmul.  Accumulation is always f32 regardless of the input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block: multiple of 8 sublanes; 128 keeps the MXU systolic array busy
# and bounds the VMEM footprint at bm*(d_m + n_e)*4 bytes per step.
DEFAULT_BLOCK_ROWS = 128


def _gate_kernel(x_ref, wg_ref, bg_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    wg = wg_ref[...].astype(jnp.float32)
    bg = bg_ref[...].astype(jnp.float32)
    o_ref[...] = (jnp.dot(x, wg, preferred_element_type=jnp.float32) + bg[None, :]).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _gate_scores_call(x, wg, bg, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """Compute gate scores for every (token, expert) pair.

    Args:
      x:  ``[n_b, d_m]`` token features.
      wg: ``[d_m, n_e]`` gate weight.
      bg: ``[n_e]`` gate bias.
      block_rows: token-dimension tile size (padded up if ``n_b`` smaller).
      interpret: run the Pallas kernel in interpret mode (required for the
        CPU PJRT path; see DESIGN.md §7).

    Returns:
      ``[n_b, n_e]`` f32 scores (pre-softmax logits).
    """
    n_b, d_m = x.shape
    d_m2, n_e = wg.shape
    assert d_m == d_m2, f"gate dim mismatch: {d_m} vs {d_m2}"
    assert bg.shape == (n_e,)

    bm = min(block_rows, n_b)
    pad = (-n_b) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = ((n_b + pad) // bm,)

    out = pl.pallas_call(
        _gate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d_m), lambda i: (i, 0)),
            pl.BlockSpec((d_m, n_e), lambda i: (0, 0)),
            pl.BlockSpec((n_e,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n_e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_b + pad, n_e), jnp.float32),
        interpret=interpret,
    )(x, wg, bg)
    return out[:n_b]


def gate_scores(x, wg, bg, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = True):
    """Differentiable wrapper around the Pallas gate kernel.

    Pallas calls have no automatic transpose rule, so the backward pass
    is supplied explicitly: the three gate GEMM cotangents as plain f32
    XLA matmuls (on TPU these hit the MXU exactly like a kernel would;
    the paper's contribution is the *forward* dispatch machinery).
    """

    def impl(x_, wg_, bg_):
        return _gate_scores_call(x_, wg_, bg_, block_rows=block_rows,
                                 interpret=interpret)

    f = jax.custom_vjp(impl)

    def fwd(x_, wg_, bg_):
        return impl(x_, wg_, bg_), (x_, wg_)

    def bwd(res, ds):
        x_, wg_ = res
        ds32 = ds.astype(jnp.float32)
        dx = (ds32 @ wg_.astype(jnp.float32).T).astype(x_.dtype)
        dwg = (x_.astype(jnp.float32).T @ ds32).astype(wg_.dtype)
        dbg = jnp.sum(ds32, axis=0).astype(bg.dtype)
        return dx, dwg, dbg

    f.defvjp(fwd, bwd)
    return f(x, wg, bg)
