//! Listing 1 of the paper, as an API demo:
//!
//! ```python
//! from fmoe.megatron import fmoefy
//! model = fmoefy(model, num_experts=<n>)
//! ```
//!
//! becomes, in this reproduction,
//!
//! ```rust
//! let moe_cfg = fastmoe::config::fmoefy(&dense_cfg, n_experts, top_k)?;
//! ```
//!
//! — a config transform that swaps the Megatron-style dense FFN for an
//! expert pool at constant per-token FLOPs, plus the matching AOT
//! artifacts.  The demo prints the transform and runs one real training
//! step of each variant to show interface-level compatibility.

use fastmoe::cli::Args;
use fastmoe::config::{fmoefy, ModelConfig};
use fastmoe::coordinator::Trainer;
use fastmoe::data::{BatchIter, Corpus};
use fastmoe::runtime::Runtime;

fn main() -> fastmoe::Result<()> {
    let args = Args::from_env(&[])?;
    let n_experts = args.usize_or("experts", 16)?;
    let top_k = args.usize_or("top-k", 2)?;

    // ---- the two-line transform ----
    let dense = ModelConfig { moe: false, ..Default::default() };
    let moe = fmoefy(&dense, n_experts, top_k)?;

    println!("fmoefy(dense, num_experts={n_experts}, top_k={top_k}):");
    println!("  ffn:  d_hidden {}  ->  {} experts × d_hidden {}", dense.d_hidden, moe.n_expert, moe.d_hidden_expert());
    println!("  params: {}  ->  {}  ({:.1}x capacity at equal FLOPs)",
        dense.n_params(), moe.n_params(),
        moe.n_params() as f64 / dense.n_params() as f64);
    println!("  sync tags: gate=world  attention/ln/embed=data_parallel  experts=none");

    // ---- both variants run through the same Trainer interface ----
    let rt = Runtime::open_default()?;
    let corpus = Corpus::synthetic(256, 100_000, 3);
    for model in ["gpt_dense", "gpt_moe"] {
        let mut tr = Trainer::new(&rt, model, 9)?;
        let seq = tr.entry.config_usize("seq").unwrap_or(128);
        let batch = tr.entry.config_usize("batch").unwrap_or(4);
        let mut it = BatchIter::new(&corpus, batch, seq, 5);
        let s = tr.train_step(&it.next_batch())?;
        println!(
            "  one step of {model:<10} loss {:.4}  ({:.0} ms)",
            s.loss,
            s.secs * 1e3
        );
    }
    println!("fmoefy demo OK — same training interface, MoE swapped in.");
    Ok(())
}
