//! The distributed (expert-parallel) MoE layer — the heart of FastMoE.
//!
//! Each worker owns `ne_local` experts and runs, per iteration, the
//! stage chain of DESIGN.md §4 with the Figure-2 exchange in the
//! middle.  All heavy math is AOT-compiled HLO; this file is exactly
//! the coordination the paper contributes: counting, planning, packing,
//! exchanging, bucketing, and the mirrored backward chain.

use std::sync::Arc;

use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::metrics::Counters;
use crate::moe::{
    topk_softmax, topk_softmax_bwd, DispatchPlan, ExpertBatch, GateAssign,
};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor::{HostTensor, TensorF32};

/// Per-worker parameters + compiled stage executables for one MoE layer.
pub struct DistMoeLayer {
    rt: Arc<Runtime>,
    pub workers: usize,
    pub rank: usize,
    pub ne_local: usize,
    pub k: usize,
    pub nb: usize,
    pub dm: usize,
    pub dh: usize,
    buckets: Vec<usize>,
    // replicated gate (tag: world)
    pub wg: TensorF32,
    pub bg: TensorF32,
    // local expert shard (tag: none)
    pub w1: TensorF32,
    pub b1: TensorF32,
    pub w2: TensorF32,
    pub b2: TensorF32,
}

/// Forward residuals needed by the backward chain.
pub struct MoeLayerState {
    pub assign: GateAssign,
    pub plan: DispatchPlan,
    pub eb: ExpertBatch,
    /// Expert outputs in packed slot order (combine input), saved for
    /// combine_bwd.
    pub y_slots: TensorF32,
    /// This worker's token features (gate_bwd + scatter transpose).
    pub x: TensorF32,
    /// Per-global-expert counts this worker routed (load monitor food).
    pub counts_global: Vec<u32>,
}

/// Gradients produced by the backward pass.
pub struct LayerGrads {
    pub dx: TensorF32,
    pub dwg: TensorF32,
    pub dbg: TensorF32,
    pub dw1: TensorF32,
    pub db1: TensorF32,
    pub dw2: TensorF32,
    pub db2: TensorF32,
}

impl DistMoeLayer {
    /// Initialise a worker's shard. Gate weights are derived from
    /// `seed` only (identical on every worker — it is `world`-tagged);
    /// expert weights are derived from `(seed, rank)`.
    pub fn init(
        rt: Arc<Runtime>,
        workers: usize,
        rank: usize,
        seed: u64,
    ) -> Result<DistMoeLayer> {
        let m = &rt.manifest;
        let gate = m
            .artifact(&format!("gate_fwd_w{workers}"))
            .ok_or_else(|| {
                Error::ArtifactNotFound(format!(
                    "gate_fwd_w{workers} (worker count not in preset)"
                ))
            })?;
        let nb = gate.inputs[0].shape[0];
        let dm = gate.inputs[0].shape[1];
        let ne_global = gate.inputs[1].shape[1];
        let ne_local = ne_global / workers;
        let combine = m
            .artifact("combine_fwd")
            .ok_or_else(|| Error::ArtifactNotFound("combine_fwd".into()))?;
        let k = combine.inputs[1].shape[1];
        let buckets = m.buckets();
        if buckets.is_empty() {
            return Err(Error::Manifest("no expert buckets in manifest".into()));
        }
        // dh from any expert artifact
        let eart = m
            .artifact(&format!("expert_fwd_b{}", buckets[0]))
            .ok_or_else(|| Error::ArtifactNotFound("expert_fwd".into()))?;
        let dh = eart.inputs[1].shape[2];
        if eart.inputs[0].shape[0] != ne_local {
            return Err(Error::Manifest(format!(
                "expert artifact has {} local experts, topology wants {}",
                eart.inputs[0].shape[0], ne_local
            )));
        }

        let mut gate_rng = Rng::new(seed ^ 0x6a7e);
        let mut wg = TensorF32::zeros(&[dm, ne_global]);
        gate_rng.fill_normal(&mut wg.data, 0.02);
        let bg = TensorF32::zeros(&[ne_global]);

        let mut erng = Rng::new(seed ^ (0xe0 + rank as u64));
        let mut w1 = TensorF32::zeros(&[ne_local, dm, dh]);
        erng.fill_normal(&mut w1.data, 0.02);
        let b1 = TensorF32::zeros(&[ne_local, dh]);
        let mut w2 = TensorF32::zeros(&[ne_local, dh, dm]);
        erng.fill_normal(&mut w2.data, 0.02);
        let b2 = TensorF32::zeros(&[ne_local, dm]);

        Ok(DistMoeLayer {
            rt, workers, rank, ne_local, k, nb, dm, dh, buckets,
            wg, bg, w1, b1, w2, b2,
        })
    }

    /// Pre-compile every stage executable this layer can touch.
    pub fn warm(&self) -> Result<()> {
        self.rt.executable(&format!("gate_fwd_w{}", self.workers))?;
        self.rt.executable(&format!("gate_bwd_w{}", self.workers))?;
        self.rt.executable("combine_fwd")?;
        self.rt.executable("combine_bwd")?;
        for &b in &self.buckets {
            self.rt.executable(&format!("expert_fwd_b{b}"))?;
            self.rt.executable(&format!("expert_bwd_b{b}"))?;
        }
        Ok(())
    }

    /// Matmul FLOPs this worker performed for `state` (fig-6 metric):
    /// gate GEMM + both expert GEMMs over real (unpadded) rows.
    pub fn flops(&self, state: &MoeLayerState) -> f64 {
        let gate = 2.0 * self.nb as f64 * self.dm as f64
            * (self.workers * self.ne_local) as f64;
        let rows: usize = state.eb.rows_per_expert.iter().sum();
        let expert = 2.0 * 2.0 * rows as f64 * self.dm as f64 * self.dh as f64;
        gate + expert
    }

    /// Forward pass over this worker's `x: [nb, dm]`.
    ///
    /// `counters` records exchange volumes for the net model.
    pub fn forward(
        &self,
        comm: &mut impl Comm,
        x: TensorF32,
        counters: &mut Counters,
    ) -> Result<(TensorF32, MoeLayerState)> {
        let ne_global = self.workers * self.ne_local;

        // ---- gate scores (L1 kernel via HLO) ----
        let gate = self.rt.executable(&format!("gate_fwd_w{}", self.workers))?;
        let out = gate.run(&[
            x.clone().into(),
            self.wg.clone().into(),
            self.bg.clone().into(),
        ])?;
        let scores = out.into_iter().next().unwrap().into_f32()?;

        // ---- host gating + plan (the paper's "local shuffle") ----
        let assign = topk_softmax(&scores, self.k)?;
        let plan = DispatchPlan::build(&assign, self.workers, self.ne_local)?;
        let mut counts_global = vec![0u32; ne_global];
        for &e in &assign.idx {
            counts_global[e as usize] += 1;
        }

        // ---- Figure 2 phase 1: exchange per-expert counts ----
        let count_bufs: Vec<Vec<f32>> = plan
            .send_counts
            .iter()
            .map(|c| c.iter().map(|&x| x as f32).collect())
            .collect();
        let recv_count_bufs = comm.all_to_all_v(count_bufs)?;
        let recv_counts: Vec<Vec<u32>> = recv_count_bufs
            .iter()
            .map(|b| b.iter().map(|&x| x as u32).collect())
            .collect();

        // ---- Figure 2 phase 2: exchange token rows ----
        let send = plan.pack(&x)?;
        let sent_bytes: usize = send.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", sent_bytes as u64);
        let recv = comm.all_to_all_v(send)?;

        // ---- bucketed expert shard execution ----
        let eb = ExpertBatch::build(recv_counts, &recv, self.ne_local, self.dm, &self.buckets)?;
        counters.add("moe_bucket_rows", (eb.bucket * eb.ne_local) as u64);
        counters.add(
            "moe_real_rows",
            eb.rows_per_expert.iter().sum::<usize>() as u64,
        );
        let efwd = self.rt.executable(&format!("expert_fwd_b{}", eb.bucket))?;
        let out = efwd.run(&[
            eb.xs.clone().into(),
            self.w1.clone().into(),
            self.b1.clone().into(),
            self.w2.clone().into(),
            self.b2.clone().into(),
        ])?;
        let ys = out.into_iter().next().unwrap().into_f32()?;

        // ---- return exchange + combine ----
        let ret = eb.split_outputs(&ys)?;
        counters.add(
            "moe_a2a_bytes",
            ret.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
        );
        let back = comm.all_to_all_v(ret)?;
        let y_slots = plan.unpack_returned(&back, self.dm)?;

        let combine = self.rt.executable("combine_fwd")?;
        let w_t = TensorF32::from_vec(&[self.nb, self.k], assign.w.clone())?;
        let out = combine.run(&[
            y_slots.clone().into(),
            HostTensor::I32(plan.slots_i32()),
            w_t.into(),
        ])?;
        let y = out.into_iter().next().unwrap().into_f32()?;

        Ok((y, MoeLayerState { assign, plan, eb, y_slots, x, counts_global }))
    }

    /// Backward pass: `dy: [nb, dm]` → input + parameter gradients.
    pub fn backward(
        &self,
        comm: &mut impl Comm,
        state: &MoeLayerState,
        dy: &TensorF32,
        counters: &mut Counters,
    ) -> Result<LayerGrads> {
        let ne_global = self.workers * self.ne_local;
        let plan = &state.plan;

        // ---- combine backward (L1 transpose) ----
        let cbwd = self.rt.executable("combine_bwd")?;
        let w_t = TensorF32::from_vec(&[self.nb, self.k], state.assign.w.clone())?;
        let out = cbwd.run(&[
            state.y_slots.clone().into(),
            HostTensor::I32(plan.slots_i32()),
            w_t.into(),
            dy.clone().into(),
        ])?;
        let mut it = out.into_iter();
        let dys = it.next().unwrap().into_f32()?; // [nb*k, dm] packed order
        let dw = it.next().unwrap().into_f32()?; // [nb, k]

        // ---- gate backward: softmax-topk Jacobian + gate GEMM ----
        let dscores = topk_softmax_bwd(&state.assign, &dw.data, ne_global)?;
        let gbwd = self.rt.executable(&format!("gate_bwd_w{}", self.workers))?;
        let out = gbwd.run(&[
            state.x.clone().into(),
            self.wg.clone().into(),
            dscores.into(),
        ])?;
        let mut it = out.into_iter();
        let mut dx = it.next().unwrap().into_f32()?;
        let dwg = it.next().unwrap().into_f32()?;
        let dbg = it.next().unwrap().into_f32()?;

        // ---- reverse exchange of output cotangents ----
        // dys is already in packed order; split by destination rows.
        let mut send: Vec<Vec<f32>> = Vec::with_capacity(self.workers);
        let mut pos = 0usize;
        for w in 0..self.workers {
            let rows = plan.send_rows[w];
            send.push(dys.data[pos * self.dm..(pos + rows) * self.dm].to_vec());
            pos += rows;
        }
        counters.add(
            "moe_a2a_bytes",
            send.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
        );
        let recv = comm.all_to_all_v(send)?;
        let dys_in = state.eb.rebatch(&recv)?;

        // ---- expert shard backward (recompute-style artifact) ----
        let ebwd = self
            .rt
            .executable(&format!("expert_bwd_b{}", state.eb.bucket))?;
        let out = ebwd.run(&[
            state.eb.xs.clone().into(),
            self.w1.clone().into(),
            self.b1.clone().into(),
            self.w2.clone().into(),
            self.b2.clone().into(),
            dys_in.into(),
        ])?;
        let mut it = out.into_iter();
        let dxs = it.next().unwrap().into_f32()?;
        let dw1 = it.next().unwrap().into_f32()?;
        let db1 = it.next().unwrap().into_f32()?;
        let dw2 = it.next().unwrap().into_f32()?;
        let db2 = it.next().unwrap().into_f32()?;

        // ---- route input cotangents back to token owners ----
        let ret = state.eb.split_outputs(&dxs)?;
        counters.add(
            "moe_a2a_bytes",
            ret.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
        );
        let back = comm.all_to_all_v(ret)?;
        let dx_packed = plan.unpack_returned(&back, self.dm)?;

        // scatter-transpose: dx[token] += dx_packed[slot(assignment)]
        for a in 0..plan.nb * plan.k {
            let token = a / plan.k;
            let s = plan.slots[a] as usize;
            let src = &dx_packed.data[s * self.dm..(s + 1) * self.dm];
            let dst = &mut dx.data[token * self.dm..(token + 1) * self.dm];
            for (d, v) in dst.iter_mut().zip(src) {
                *d += v;
            }
        }

        Ok(LayerGrads { dx, dwg, dbg, dw1, db1, dw2, db2 })
    }
}
