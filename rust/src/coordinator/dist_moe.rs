//! The distributed (expert-parallel) MoE layer — the heart of FastMoE.
//!
//! Each worker owns `ne_local` experts and runs, per iteration, the
//! stage chain of DESIGN.md §4 with the Figure-2 exchange in the
//! middle.  All heavy math is AOT-compiled HLO; this file is exactly
//! the coordination the paper contributes: planning, packing,
//! exchanging, bucketing, and the mirrored backward chain.
//!
//! Following §3.1's hierarchical interface, the layer itself is thin
//! orchestration over two swappable policies:
//!
//! * the [`Gate`] (which experts, at what weight) — see
//!   [`crate::moe::gate`];
//! * the [`ExpertShard`] (what an expert computes) — see
//!   [`crate::moe::expert`].
//!
//! Layers are assembled by [`MoeLayerBuilder`], normally from the
//! `[moe]` config section:
//!
//! ```ignore
//! let layer = MoeLayerBuilder::from_config(&cfg.moe()?)
//!     .seed(seed)
//!     .build(rt, workers, rank)?;
//! ```
//!
//! [`DistMoeLayer::init`] remains as the seed-compatible shorthand for
//! the default top-k softmax gate + FFN shard (bit-identical routing
//! and weights to the pre-trait layer).

use std::sync::Arc;

use crate::comm::Comm;
use crate::config::MoeConfig;
use crate::error::{Error, Result};
use crate::metrics::Counters;
use crate::model::Adam;
use crate::moe::{
    balance_loss, gate, DispatchPlan, ExpertBatch, ExpertShard, FfnExpertShard,
    Gate, GateAssign,
};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor::{ops, HostTensor, TensorF32};

/// Manifest-derived geometry shared by every layer built on a runtime.
#[derive(Clone, Debug)]
struct LayerGeom {
    nb: usize,
    dm: usize,
    dh: usize,
    ne_local: usize,
    k: usize,
    buckets: Vec<usize>,
}

/// Probe the artifact manifest for the layer geometry of a topology.
fn probe_geometry(rt: &Runtime, workers: usize) -> Result<LayerGeom> {
    let m = &rt.manifest;
    let gate = m
        .artifact(&format!("gate_fwd_w{workers}"))
        .ok_or_else(|| {
            Error::ArtifactNotFound(format!(
                "gate_fwd_w{workers} (worker count not in preset)"
            ))
        })?;
    let nb = gate.inputs[0].shape[0];
    let dm = gate.inputs[0].shape[1];
    let ne_global = gate.inputs[1].shape[1];
    let ne_local = ne_global / workers;
    let combine = m
        .artifact("combine_fwd")
        .ok_or_else(|| Error::ArtifactNotFound("combine_fwd".into()))?;
    let k = combine.inputs[1].shape[1];
    let buckets = m.buckets();
    if buckets.is_empty() {
        return Err(Error::Manifest("no expert buckets in manifest".into()));
    }
    // dh from any expert artifact
    let eart = m
        .artifact(&format!("expert_fwd_b{}", buckets[0]))
        .ok_or_else(|| Error::ArtifactNotFound("expert_fwd".into()))?;
    let dh = eart.inputs[1].shape[2];
    if eart.inputs[0].shape[0] != ne_local {
        return Err(Error::Manifest(format!(
            "expert artifact has {} local experts, topology wants {}",
            eart.inputs[0].shape[0], ne_local
        )));
    }
    Ok(LayerGeom { nb, dm, dh, ne_local, k, buckets })
}

/// Assembles a [`DistMoeLayer`] from a gate policy + expert shard.
///
/// The builder owns everything that *selects* modules (the `[moe]`
/// config section, the init seed); geometry comes from the artifact
/// manifest at [`MoeLayerBuilder::build`] time.
#[derive(Clone, Debug)]
pub struct MoeLayerBuilder {
    cfg: MoeConfig,
    seed: u64,
}

impl Default for MoeLayerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl MoeLayerBuilder {
    /// Default modules: top-k softmax gate + FFN expert shard.
    pub fn new() -> MoeLayerBuilder {
        MoeLayerBuilder { cfg: MoeConfig::default(), seed: 0 }
    }

    /// Select modules from a `[moe]` config section.
    pub fn from_config(cfg: &MoeConfig) -> MoeLayerBuilder {
        MoeLayerBuilder { cfg: cfg.clone(), seed: 0 }
    }

    /// Seed for parameter init (and the noisy gate's noise stream).
    pub fn seed(mut self, seed: u64) -> MoeLayerBuilder {
        self.seed = seed;
        self
    }

    /// Override the gate kind ("topk" | "switch" | "noisy_topk").
    pub fn gate(mut self, name: &str) -> MoeLayerBuilder {
        self.cfg.gate = name.to_string();
        self
    }

    /// Override the switch-gate capacity factor.
    pub fn capacity_factor(mut self, cf: f64) -> MoeLayerBuilder {
        self.cfg.capacity_factor = cf;
        self
    }

    /// Override the noisy-gate noise std.
    pub fn noise_std(mut self, std: f64) -> MoeLayerBuilder {
        self.cfg.noise_std = std;
        self
    }

    /// Build one worker's layer for a `(workers, rank)` comm topology.
    ///
    /// Gate weights are derived from `seed` only (identical on every
    /// worker — they are `world`-tagged); expert weights from
    /// `(seed, rank)`.  Both derivations are bit-identical to the seed
    /// system's `DistMoeLayer::init`.
    pub fn build(
        &self,
        rt: Arc<Runtime>,
        workers: usize,
        rank: usize,
    ) -> Result<DistMoeLayer> {
        let g = probe_geometry(&rt, workers)?;
        let ne_global = workers * g.ne_local;

        let mut gate_rng = Rng::new(self.seed ^ 0x6a7e);
        let mut wg = TensorF32::zeros(&[g.dm, ne_global]);
        gate_rng.fill_normal(&mut wg.data, 0.02);
        let bg = TensorF32::zeros(&[ne_global]);

        let expert: Box<dyn ExpertShard> = Box::new(FfnExpertShard::init(
            rt.clone(),
            g.ne_local,
            g.dm,
            g.dh,
            g.buckets.clone(),
            self.seed,
            rank,
        ));
        let gate = gate::from_config(&self.cfg, self.seed)?;

        Ok(DistMoeLayer {
            rt,
            workers,
            rank,
            ne_local: g.ne_local,
            k: g.k,
            nb: g.nb,
            dm: g.dm,
            dh: g.dh,
            buckets: g.buckets,
            wg,
            bg,
            gate,
            expert,
        })
    }

    /// Convenience: build for an existing comm handle's topology.
    pub fn build_for(
        &self,
        rt: Arc<Runtime>,
        comm: &impl Comm,
    ) -> Result<DistMoeLayer> {
        self.build(rt, comm.size(), comm.rank())
    }
}

/// Per-worker gate parameters + pluggable gate/expert modules for one
/// MoE layer.
pub struct DistMoeLayer {
    rt: Arc<Runtime>,
    pub workers: usize,
    pub rank: usize,
    pub ne_local: usize,
    pub k: usize,
    pub nb: usize,
    pub dm: usize,
    /// Expert hidden width from the manifest (FFN shard geometry; kept
    /// on the layer because the fused comparison artifacts share it).
    pub dh: usize,
    buckets: Vec<usize>,
    // replicated gate GEMM parameters (tag: world)
    pub wg: TensorF32,
    pub bg: TensorF32,
    gate: Box<dyn Gate>,
    expert: Box<dyn ExpertShard>,
}

/// Forward residuals needed by the backward chain.
pub struct MoeLayerState {
    pub assign: GateAssign,
    pub plan: DispatchPlan,
    pub eb: ExpertBatch,
    /// Expert outputs in packed slot order (combine input), saved for
    /// combine_bwd.
    pub y_slots: TensorF32,
    /// This worker's token features (gate_bwd + scatter transpose).
    pub x: TensorF32,
    /// Per-global-expert counts this worker routed (load monitor food;
    /// shared with `plan.counts_global`).  Counts every assignment
    /// slot, including zero-weight drops/fillers, because every slot
    /// transits the exchange.
    pub counts_global: Vec<u32>,
    /// Per-global-expert counts of *kept* (weight > 0) assignments —
    /// the histogram load metrics should use.  Identical to
    /// `counts_global` for gates that never zero-weight.
    pub counts_kept: Vec<u32>,
    /// GShard auxiliary balance loss of this iteration's routing
    /// (over the kept counts).
    pub balance: f64,
}

/// Gradients produced by the backward pass.
pub struct LayerGrads {
    pub dx: TensorF32,
    pub dwg: TensorF32,
    pub dbg: TensorF32,
    /// Expert-shard gradients as named slots, in
    /// [`ExpertShard::params`] order.
    pub expert: Vec<(&'static str, TensorF32)>,
}

impl LayerGrads {
    /// Look an expert gradient up by slot name.
    pub fn expert_grad(&self, name: &str) -> Option<&TensorF32> {
        self.expert.iter().find(|(n, _)| *n == name).map(|(_, t)| t)
    }
}

impl DistMoeLayer {
    /// Seed-compatible shorthand: default top-k softmax gate + FFN
    /// shard, weights derived exactly as the pre-trait layer did.
    pub fn init(
        rt: Arc<Runtime>,
        workers: usize,
        rank: usize,
        seed: u64,
    ) -> Result<DistMoeLayer> {
        MoeLayerBuilder::new().seed(seed).build(rt, workers, rank)
    }

    /// The routing policy this layer was built with.
    pub fn gate(&self) -> &dyn Gate {
        self.gate.as_ref()
    }

    /// The expert shard this layer was built with.
    pub fn expert(&self) -> &dyn ExpertShard {
        self.expert.as_ref()
    }

    /// All trainable parameters as named slots: gate GEMM first
    /// (`wg`, `bg`), then the expert shard's slots.
    pub fn params(&self) -> Vec<(&'static str, &TensorF32)> {
        let mut v = vec![("wg", &self.wg), ("bg", &self.bg)];
        v.extend(self.expert.params());
        v
    }

    /// Apply one optimiser step over all layer parameters from a
    /// backward pass's gradients (same slot order as [`Self::params`]).
    pub fn apply_grads(&mut self, opt: &mut Adam, grads: &LayerGrads) -> Result<()> {
        {
            let pnames: Vec<&str> = self.expert.params().iter().map(|(n, _)| *n).collect();
            let gnames: Vec<&str> = grads.expert.iter().map(|(n, _)| *n).collect();
            if pnames != gnames {
                return Err(Error::Shape(format!(
                    "expert grad slots {gnames:?} do not match params {pnames:?}"
                )));
            }
        }
        let mut gs: Vec<&TensorF32> = vec![&grads.dwg, &grads.dbg];
        gs.extend(grads.expert.iter().map(|(_, g)| g));
        let mut ps: Vec<&mut TensorF32> = vec![&mut self.wg, &mut self.bg];
        ps.extend(self.expert.params_mut().into_iter().map(|(_, t)| t));
        opt.update_refs(&mut ps, &gs)
    }

    /// Pre-compile every stage executable this layer can touch.
    pub fn warm(&self) -> Result<()> {
        self.rt.executable(&format!("gate_fwd_w{}", self.workers))?;
        self.rt.executable(&format!("gate_bwd_w{}", self.workers))?;
        self.rt.executable("combine_fwd")?;
        self.rt.executable("combine_bwd")?;
        self.expert.warm()
    }

    /// Matmul FLOPs this worker performed for `state` (fig-6 metric):
    /// gate GEMM + the expert shard over real (unpadded) rows.
    pub fn flops(&self, state: &MoeLayerState) -> f64 {
        let gate = 2.0 * self.nb as f64 * self.dm as f64
            * (self.workers * self.ne_local) as f64;
        let rows: usize = state.eb.rows_per_expert.iter().sum();
        gate + self.expert.flops(rows)
    }

    /// Forward pass over this worker's `x: [nb, dm]`.
    ///
    /// `counters` records exchange volumes for the net model.
    pub fn forward(
        &self,
        comm: &mut impl Comm,
        x: TensorF32,
        counters: &mut Counters,
    ) -> Result<(TensorF32, MoeLayerState)> {
        // ---- gate scores (L1 kernel via HLO) ----
        let gate = self.rt.executable(&format!("gate_fwd_w{}", self.workers))?;
        let out = gate.run(&[
            x.clone().into(),
            self.wg.clone().into(),
            self.bg.clone().into(),
        ])?;
        let scores = out.into_iter().next().unwrap().into_f32()?;

        // ---- host gating + plan (the paper's "local shuffle") ----
        let assign = self.gate.route(&scores, self.k)?;
        let plan = DispatchPlan::build(&assign, self.workers, self.ne_local)?;

        // ---- Figure 2 phase 1: exchange per-expert counts ----
        let count_bufs: Vec<Vec<f32>> = plan
            .send_counts
            .iter()
            .map(|c| c.iter().map(|&x| x as f32).collect())
            .collect();
        let recv_count_bufs = comm.all_to_all_v(count_bufs)?;
        let recv_counts: Vec<Vec<u32>> = recv_count_bufs
            .iter()
            .map(|b| b.iter().map(|&x| x as u32).collect())
            .collect();

        // ---- Figure 2 phase 2: exchange token rows ----
        let send = plan.pack(&x)?;
        let sent_bytes: usize = send.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", sent_bytes as u64);
        let recv = comm.all_to_all_v(send)?;

        // ---- bucketed expert shard execution ----
        let eb = ExpertBatch::build(recv_counts, &recv, self.ne_local, self.dm, &self.buckets)?;
        counters.add("moe_bucket_rows", (eb.bucket * eb.ne_local) as u64);
        counters.add(
            "moe_real_rows",
            eb.rows_per_expert.iter().sum::<usize>() as u64,
        );
        let ys = self.expert.forward(&eb)?;

        // ---- return exchange + combine ----
        let ret = eb.split_outputs(&ys)?;
        counters.add(
            "moe_a2a_bytes",
            ret.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
        );
        let back = comm.all_to_all_v(ret)?;
        let y_slots = plan.unpack_returned(&back, self.dm)?;

        let combine = self.rt.executable("combine_fwd")?;
        let w_t = TensorF32::from_vec(&[self.nb, self.k], assign.w.clone())?;
        let out = combine.run(&[
            y_slots.clone().into(),
            HostTensor::I32(plan.slots_i32()),
            w_t.into(),
        ])?;
        let y = out.into_iter().next().unwrap().into_f32()?;

        // ---- per-step routing metrics (monitor food) ----
        // Load metrics count only kept (weight > 0) assignments so
        // capacity gates' zero-weight drop/filler slots don't read as
        // phantom load; the dispatch histogram keeps counting them
        // because they really transit the exchange.
        let counts_kept = assign.kept_counts(self.workers * self.ne_local);
        let balance = match &assign.probs {
            Some(p) => balance_loss(&counts_kept, p),
            None => {
                let mut p = scores.clone();
                ops::softmax_rows(&mut p)?;
                balance_loss(&counts_kept, &p)
            }
        };
        let counts_global = plan.counts_global.clone();

        Ok((
            y,
            MoeLayerState { assign, plan, eb, y_slots, x, counts_global, counts_kept, balance },
        ))
    }

    /// Backward pass: `dy: [nb, dm]` → input + parameter gradients.
    pub fn backward(
        &self,
        comm: &mut impl Comm,
        state: &MoeLayerState,
        dy: &TensorF32,
        counters: &mut Counters,
    ) -> Result<LayerGrads> {
        let ne_global = self.workers * self.ne_local;
        let plan = &state.plan;

        // ---- combine backward (L1 transpose) ----
        let cbwd = self.rt.executable("combine_bwd")?;
        let w_t = TensorF32::from_vec(&[self.nb, self.k], state.assign.w.clone())?;
        let out = cbwd.run(&[
            state.y_slots.clone().into(),
            HostTensor::I32(plan.slots_i32()),
            w_t.into(),
            dy.clone().into(),
        ])?;
        let mut it = out.into_iter();
        let dys = it.next().unwrap().into_f32()?; // [nb*k, dm] packed order
        let dw = it.next().unwrap().into_f32()?; // [nb, k]

        // ---- gate backward: routing Jacobian + gate GEMM ----
        let mut dscores = self.gate.route_bwd(&state.assign, &dw.data, ne_global)?;
        // balance-loss gradient hook (no-op until a later PR wires it)
        self.gate
            .balance_grad(&state.assign, &state.counts_global, &mut dscores);
        let gbwd = self.rt.executable(&format!("gate_bwd_w{}", self.workers))?;
        let out = gbwd.run(&[
            state.x.clone().into(),
            self.wg.clone().into(),
            dscores.into(),
        ])?;
        let mut it = out.into_iter();
        let mut dx = it.next().unwrap().into_f32()?;
        let dwg = it.next().unwrap().into_f32()?;
        let dbg = it.next().unwrap().into_f32()?;

        // ---- reverse exchange of output cotangents ----
        // dys is already in packed order; split by destination rows.
        let mut send: Vec<Vec<f32>> = Vec::with_capacity(self.workers);
        let mut pos = 0usize;
        for w in 0..self.workers {
            let rows = plan.send_rows[w];
            send.push(dys.data[pos * self.dm..(pos + rows) * self.dm].to_vec());
            pos += rows;
        }
        counters.add(
            "moe_a2a_bytes",
            send.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
        );
        let recv = comm.all_to_all_v(send)?;
        let dys_in = state.eb.rebatch(&recv)?;

        // ---- expert shard backward (recompute-style artifact) ----
        let (dxs, expert_grads) = self.expert.backward(&state.eb, dys_in)?;

        // ---- route input cotangents back to token owners ----
        let ret = state.eb.split_outputs(&dxs)?;
        counters.add(
            "moe_a2a_bytes",
            ret.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
        );
        let back = comm.all_to_all_v(ret)?;
        let dx_packed = plan.unpack_returned(&back, self.dm)?;

        // scatter-transpose: dx[token] += dx_packed[slot(assignment)]
        for a in 0..plan.nb * plan.k {
            let token = a / plan.k;
            let s = plan.slots[a] as usize;
            let src = &dx_packed.data[s * self.dm..(s + 1) * self.dm];
            let dst = &mut dx.data[token * self.dm..(token + 1) * self.dm];
            for (d, v) in dst.iter_mut().zip(src) {
                *d += v;
            }
        }

        Ok(LayerGrads { dx, dwg, dbg, expert: expert_grads })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_carries_config_overrides() {
        let b = MoeLayerBuilder::new()
            .gate("switch")
            .capacity_factor(1.5)
            .noise_std(0.25)
            .seed(9);
        assert_eq!(b.cfg.gate, "switch");
        assert!((b.cfg.capacity_factor - 1.5).abs() < 1e-12);
        assert!((b.cfg.noise_std - 0.25).abs() < 1e-12);
        assert_eq!(b.seed, 9);
        // gate selection itself is validated without a runtime
        assert!(gate::from_config(&b.cfg, b.seed).is_ok());
        let bad = MoeLayerBuilder::new().gate("mystery");
        assert!(gate::from_config(&bad.cfg, 0).is_err());
    }
}
